"""Hardware constraint constants for the ReStream memristor chip.

Single source of truth on the python (compile) side; mirrored in
``rust/src/config/hwspec.rs``. Every number traces to the paper:

* neuron output range [-0.5, 0.5]  — op-amp rails V_DD=0.5 V, V_SS=-0.5 V
  (section III.B).
* activation h(x) = x/4 clipped to the rails (Eq. 3 / Fig 6); it
  approximates f(x) = sigmoid(x) - 0.5.
* neuron outputs crossing the NoC are discretised by a 3-bit ADC
  (section IV.A).
* back-propagated errors are discretised to 8 bits: 1 sign + 7 magnitude
  (section III.F step 1).
* f'(DP) is looked up from a table (section III.F step 3) — we model a
  64-entry LUT over the clipped DP range.
* a neural core is a 400x200 crossbar = 400 inputs x 100 differential
  neurons (section IV.A); one input row is reserved for the bias.
* conductances are bounded: R_on ~ 10 kOhm, R_off/R_on ~ 1000 (section
  III.A), i.e. normalised g in [G_MIN, G_MAX] = [0.001, 1.0].
"""

# Op-amp output rails (volts, also the numeric range of all activations).
V_RAIL = 0.5

# h(x) linear-region slope and clip point: h(x) = x/4 for |x| < 2.
H_SLOPE = 0.25
H_CLIP_IN = 2.0

# ADC/DAC precisions.
OUT_BITS = 3          # neuron output ADC (section IV.A)
ERR_BITS = 8          # error discretisation: 1 sign + 7 magnitude bits
ERR_MAX = 1.0         # full-scale range of the error ADC (|t - y| <= 2*V_RAIL)
LUT_SIZE = 64         # f'(DP) lookup table entries

# Crossbar geometry: 400 rows x 200 columns = 400 inputs x 100 neurons
# (two columns per neuron: sigma+ and sigma-).
CORE_INPUTS = 400     # includes the bias row
CORE_NEURONS = 100

# Normalised conductance bounds (g = 1/R scaled so g_on = 1).
G_MIN = 0.001         # R_off = 1000 * R_on
G_MAX = 1.0

# Weight w = g+ - g-  =>  w in [-(G_MAX-G_MIN), +(G_MAX-G_MIN)].
W_MAX = G_MAX - G_MIN
