"""Layer-2 JAX model graphs for the ReStream chip, built on the L1 kernels.

These are the *functional* (numerics-only) models of what the chip
computes; the architectural behaviour (timing, energy, NoC traffic) is
simulated in the Rust layer. Everything here is build-time Python: the
graphs are lowered once by ``aot.py`` to HLO text and executed from Rust
over PJRT. No function in this module may appear on the request path.

Faithfulness notes (paper section III):

* weights are differential conductance pairs (g+, g-), bounded to the
  device range [G_MIN, G_MAX];
* every neuron output crossing a core boundary is 3-bit quantised
  (section IV.A) and the op-amp clips to +-0.5 V (Eq. 3);
* back-propagated errors are 8-bit sign-magnitude quantised (section
  III.F); f'(DP) comes from a lookup table;
* the bias is an extra crossbar row driven at the positive rail;
* training is stochastic (per-sample) BP, exactly section III.E. The
  f'(DP) factor is applied where the training unit forms the pulse
  (Eq. 6); for nets deeper than two layers the same discretised product
  drives the backward column DACs so the chain rule holds through depth.
"""

import jax
import jax.numpy as jnp

from . import hwspec as hw
from .kernels import (
    crossbar_bwd,
    crossbar_fwd,
    kmeans_distances,
    weight_update,
)
from .kernels.common import activation_deriv_lut, quantize_err


# --------------------------------------------------------------------------
# parameter helpers
# --------------------------------------------------------------------------

def init_params(layers, key, scale=1.0):
    """Initialise differential conductance pairs for a layer list.

    The paper initialises memristors to "high random resistances" (low
    conductance); we centre both g+ and g- near G_MIN plus headroom and
    encode a small random weight in the pair difference.
    """
    params = []
    base = hw.G_MIN + 0.12  # programming headroom above R_off
    for n_in, n_out in zip(layers[:-1], layers[1:]):
        key, sub = jax.random.split(key)
        w = (
            jax.random.uniform(sub, (n_in + 1, n_out), jnp.float32,
                               -scale, scale)
            / jnp.sqrt(jnp.float32(n_in))
        )
        gpos = jnp.clip(base + 0.5 * w, hw.G_MIN, hw.G_MAX)
        gneg = jnp.clip(base - 0.5 * w, hw.G_MIN, hw.G_MAX)
        params += [gpos, gneg]
    return params


def _with_bias(x):
    """Append the bias row: one input pinned at the positive rail."""
    b = x.shape[0]
    return jnp.concatenate(
        [x, jnp.full((b, 1), hw.V_RAIL, dtype=x.dtype)], axis=1
    )


# --------------------------------------------------------------------------
# forward / training graphs
# --------------------------------------------------------------------------

def mlp_forward(params, x, out_bits=hw.OUT_BITS):
    """Run x through every crossbar layer; returns (y, acts, dps).

    acts[l] is the (bias-augmented) input applied to layer l's rows —
    exactly what the chip re-applies during the weight-update step.
    """
    acts, dps = [], []
    h = jnp.clip(x, -hw.V_RAIL, hw.V_RAIL)
    for l in range(0, len(params), 2):
        a = _with_bias(h)
        acts.append(a)
        h, dp = crossbar_fwd(a, params[l], params[l + 1], out_bits=out_bits)
        dps.append(dp)
    return h, acts, dps


def mlp_infer(params, x):
    """Inference-only graph: returns the final-layer outputs."""
    y, _, _ = mlp_forward(params, x)
    return (y,)


def ae_fwd(params, x):
    """Autoencoder forward: returns (reconstruction, bottleneck code).

    For a stack deeper than two crossbars the code is the output of the
    middle layer (the encoder half).
    """
    acts, h = [], jnp.clip(x, -hw.V_RAIL, hw.V_RAIL)
    outs = []
    for l in range(0, len(params), 2):
        a = _with_bias(h)
        h, _ = crossbar_fwd(a, params[l], params[l + 1])
        outs.append(h)
    n_layers = len(params) // 2
    code = outs[n_layers // 2 - 1] if n_layers > 1 else outs[-1]
    return h, code


def encode(params, x):
    """Encoder-only stack (dimensionality-reduction path)."""
    y, _, _ = mlp_forward(params, x)
    return (y,)


def mlp_train_step(params, x, t, lr):
    """One stochastic-BP step (paper section III.E); returns params' + loss.

    Forward -> output error (Eq. 4) -> per-layer backward (Eq. 5, through
    the crossbar-transpose circuit of Fig 9) -> per-layer pulse update
    (Eq. 6). All errors pass the 8-bit error ADC; the f'(DP) LUT product is
    applied at each layer's training unit before propagating further.
    """
    y, acts, dps = mlp_forward(params, x)
    n_layers = len(params) // 2
    delta = quantize_err(t - y)                      # Eq. 4 + error ADC
    new_params = list(params)
    for l in range(n_layers - 1, -1, -1):
        gpos, gneg = params[2 * l], params[2 * l + 1]
        if l > 0:
            # The training unit's discretised delta*f'(DP) product drives
            # the backward column DACs (Fig 10 multiplexes this circuit).
            eff = quantize_err(delta * activation_deriv_lut(dps[l]))
            prev_delta = crossbar_bwd(eff, gpos, gneg)[:, :-1]  # drop bias
        gp, gn = weight_update(gpos, gneg, acts[l], delta, dps[l], lr)
        new_params[2 * l], new_params[2 * l + 1] = gp, gn
        if l > 0:
            delta = prev_delta
    loss = jnp.mean((t - y) ** 2)
    return tuple(new_params) + (loss,)


def ae_train_step(params, x, lr):
    """One layerwise-pretraining step: a 2-crossbar AE learns h(x) ~= x."""
    return mlp_train_step(params, x, jnp.clip(x, -hw.V_RAIL, hw.V_RAIL), lr)


def mlp_grad_batch(params, xs, ts):
    """Per-layer gradient sums of a mini-batch, training pulse withheld.

    The same forward/backward dataflow as :func:`mlp_train_step`, but
    instead of pulsing each crossbar the per-layer accumulators
    ``x^T @ quantize_err(delta * f'(dp))`` are returned (summed over the
    batch rows in order), so a data-parallel coordinator can add the
    accumulators of several shards and fire **one** update per
    mini-batch (:func:`apply_grads`). On one sample,
    ``apply_grads(params, *grads*, lr)`` reproduces
    :func:`mlp_train_step` exactly — mini-batch size 1 recovers the
    paper's per-sample stochastic BP.

    xs: (K, n_in); ts: (K, n_out); returns one (n_in+1, n_out) gradient
    array per layer plus the (K,) per-sample pre-update MSE losses.
    """
    y, acts, dps = mlp_forward(params, xs)
    losses = jnp.mean((ts - y) ** 2, axis=1)
    n_layers = len(params) // 2
    delta = quantize_err(ts - y)                     # Eq. 4 + error ADC
    grads = [None] * n_layers
    for l in range(n_layers - 1, -1, -1):
        gpos, gneg = params[2 * l], params[2 * l + 1]
        # the training unit's discretised delta * f'(DP) product — used
        # for this layer's accumulator and, through the transposed
        # crossbar, for the previous layer's error (Fig 10 multiplexes
        # this circuit), exactly as mlp_train_step's update/backward pair
        factor = quantize_err(delta * activation_deriv_lut(dps[l]))
        grads[l] = jax.lax.dot_general(
            acts[l], factor,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if l > 0:
            delta = crossbar_bwd(factor, gpos, gneg)[:, :-1]  # drop bias
    return tuple(grads) + (losses,)


def apply_grads(params, grads, lr):
    """Fire one training pulse from summed gradient accumulators.

    ``dw = lr * acc``; ``g+ += dw/2``, ``g- -= dw/2``, clipped to the
    device range — the update tail of the ``weight_update`` kernel with
    the accumulation factored out.
    """
    out = list(params)
    for l, g in enumerate(grads):
        dw = lr * g
        out[2 * l] = jnp.clip(params[2 * l] + 0.5 * dw,
                              hw.G_MIN, hw.G_MAX)
        out[2 * l + 1] = jnp.clip(params[2 * l + 1] - 0.5 * dw,
                                  hw.G_MIN, hw.G_MAX)
    return tuple(out)


# --------------------------------------------------------------------------
# clustering-core graphs
# --------------------------------------------------------------------------

def kmeans_step(x, centres):
    """One clustering-core pass over a batch (Fig 13 datapath).

    Returns (assignments, per-centre accumulator, per-centre count) so the
    Rust coordinator can fold batches into an epoch and divide at the end,
    exactly like the core's centre-accumulator registers and counters.
    """
    dists = kmeans_distances(x, centres)
    assign = jnp.argmin(dists, axis=1).astype(jnp.int32)
    k = centres.shape[0]
    acc = jax.ops.segment_sum(x, assign, num_segments=k)
    counts = jax.ops.segment_sum(
        jnp.ones((x.shape[0],), jnp.float32), assign, num_segments=k
    )
    # assignments cross the runtime boundary as f32 (uniform dtype keeps
    # the Rust side's tensor type single-typed); they are exact small ints
    return assign.astype(jnp.float32), acc, counts


def mlp_train_chunk(params, xs, ts, lr):
    """Scan stochastic BP over a chunk of samples inside one XLA program.

    Semantically identical to calling :func:`mlp_train_step` per sample
    in order (same per-sample updates); existence reason is performance:
    the Rust runtime's PJRT wrapper cannot untuple device buffers, so a
    per-sample artifact round-trips every conductance matrix through the
    host on each step. Scanning K samples inside the artifact amortises
    that boundary crossing K-fold (see EXPERIMENTS.md section Perf).

    xs: (K, n_in); ts: (K, n_out); returns params' + (K,) losses.
    """
    def body(ps, xt):
        x, t = xt
        out = mlp_train_step(list(ps), x[None, :], t[None, :], lr)
        return tuple(out[:-1]), out[-1]

    final, losses = jax.lax.scan(body, tuple(params), (xs, ts))
    return tuple(final) + (losses,)
