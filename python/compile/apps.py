"""Application registry — paper Table I, single source of truth.

Mirrored by ``rust/src/config/apps.rs``; the artifact names produced here
are the names the Rust runtime loads, so the two sides must agree. Keep
this file dependency-free (imported by both model code and tests).
"""

# name -> layer sizes (Table I)
NETWORKS = {
    "iris_class": [4, 10, 1],          # section VI.A supervised demo
    "iris_ae": [4, 2, 4],              # section VI.B unsupervised demo
    "kdd_ae": [41, 15, 41],            # anomaly detection
    "mnist_class": [784, 300, 200, 100, 10],
    "mnist_dr": [784, 300, 200, 100, 20],
    "isolet_class": [617, 2000, 1000, 500, 250, 26],
    "isolet_dr": [617, 2000, 1000, 500, 250, 20],
}

# autoencoder apps train layer-by-layer: each stage is an n->h->n AE
def dr_stages(name):
    layers = NETWORKS[name]
    return [(layers[i], layers[i + 1]) for i in range(len(layers) - 1)]

# clustering-core problems: (dims, clusters) after dimensionality reduction
KMEANS = {
    "mnist_kmeans": (20, 10),
    "isolet_kmeans": (20, 26),
}

TRAIN_BATCH = 1      # stochastic BP, per-sample, as on chip
FWD_BATCH = 64       # recognition batch the coordinator streams
BIG_TRAIN_BATCH = 16  # batched-training variant for the e2e example
TRAIN_CHUNK = 32      # samples scanned inside one chunked train artifact
GRAD_TILE = 8        # samples per data-parallel gradient shard (grad_tK)
