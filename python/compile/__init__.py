"""Build-time compile path for ReStream (never imported at runtime)."""
