"""Shared helpers for the Pallas kernels.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret-mode lowering (plain HLO ops) is the only
way the rust runtime can run them. The BlockSpec structure is still written
for the TPU deployment target (see DESIGN.md section 6): each grid step is
one MXU-shaped matmul whose operand blocks fit comfortably in VMEM.
"""

import jax.numpy as jnp

from .. import hwspec as hw

INTERPRET = True


def choose_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target``.

    Pallas blocks must tile the array exactly (edge masking is a TPU
    lowering detail we cannot rely on under interpret mode), so block sizes
    are chosen as divisors. Falls back to the full dimension when no
    divisor is close enough to be worth a grid (< 2 blocks).
    """
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            # A divisor so small it explodes the grid is worse than no grid.
            if dim // b > 64 and b < target // 4:
                return dim
            return b
    return dim


def quantize_unit(x, bits):
    """In-kernel clone of ref.quantize_unit (kept free of module deps)."""
    levels = float(2**bits - 1)
    x = jnp.clip(x, -hw.V_RAIL, hw.V_RAIL)
    return jnp.round((x + hw.V_RAIL) * levels) / levels - hw.V_RAIL


def quantize_err(x, bits=hw.ERR_BITS, full_scale=hw.ERR_MAX):
    """In-kernel clone of ref.quantize_err (sign-magnitude ADC)."""
    mag_levels = float(2 ** (bits - 1) - 1)
    mag = jnp.clip(jnp.abs(x), 0.0, full_scale)
    code = jnp.round(mag / full_scale * mag_levels)
    return jnp.sign(x) * code / mag_levels * full_scale


def activation(dp):
    """Op-amp activation h(x): slope 1/4, clipped to the +-0.5 V rails."""
    return jnp.clip(dp * hw.H_SLOPE, -hw.V_RAIL, hw.V_RAIL)


def activation_deriv_lut(dp):
    """LUT model of f'(DP); matches ref.activation_deriv_lut bit-exactly."""
    idx = jnp.clip(
        jnp.round((dp + hw.H_CLIP_IN) / (2 * hw.H_CLIP_IN) * (hw.LUT_SIZE - 1)),
        0,
        hw.LUT_SIZE - 1,
    )
    centre = idx / (hw.LUT_SIZE - 1) * (2 * hw.H_CLIP_IN) - hw.H_CLIP_IN
    s = 1.0 / (1.0 + jnp.exp(-centre))
    return s * (1.0 - s)
