"""Pallas kernel: training-pulse weight update.

Models the chip's weight-update step (paper Eq. 6, Fig 11): the training
unit forms eta * delta_j * f'(DP_j) (f' from a lookup table, the product
re-discretised by the 8-bit DAC that drives the pulse generator), the pulse
amplitude is modulated by the input x_i on the row wire, and the combined
voltage updates each differential pair by +dw/2 on sigma+ and -dw/2 on
sigma-. Conductances are clipped to the physical [G_MIN, G_MAX] range —
the device cannot be driven past R_on/R_off.

TPU mapping: the update is a rank-B outer product x^T @ factor computed as
one MXU matmul per conductance block; grid = (row blocks, column blocks).
Both conductance matrices are updated in the same kernel so the factor
matmul is computed once per block pair (the chip likewise shares the pulse
generator between the odd and even columns, section III.F step 3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import hwspec as hw
from .common import (
    INTERPRET,
    activation_deriv_lut,
    choose_block,
    quantize_err,
)


def _update_kernel(x_ref, delta_ref, dp_ref, lr_ref, gpos_ref, gneg_ref,
                   gp_out_ref, gn_out_ref):
    factor = quantize_err(delta_ref[...] * activation_deriv_lut(dp_ref[...]))
    dw = lr_ref[0, 0] * jax.lax.dot_general(
        x_ref[...],
        factor,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    gp_out_ref[...] = jnp.clip(gpos_ref[...] + 0.5 * dw, hw.G_MIN, hw.G_MAX)
    gn_out_ref[...] = jnp.clip(gneg_ref[...] - 0.5 * dw, hw.G_MIN, hw.G_MAX)


@jax.jit
def weight_update(gpos, gneg, x, delta, dp, lr):
    """Apply one training pulse; returns (gpos', gneg').

    gpos/gneg: (N_in, N_out); x: (B, N_in); delta/dp: (B, N_out);
    lr: (1, 1) learning-rate scalar (2*eta in the paper's Eq. 6 — the
    factor of 2 from the differential pair is folded into lr).
    """
    n_in, n_out = gpos.shape
    b = x.shape[0]
    bm = choose_block(n_in, 1024)
    bn = choose_block(n_out, 512)
    grid = (n_in // bm, n_out // bn)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bm), lambda i, j: (0, i)),
            pl.BlockSpec((b, bn), lambda i, j: (0, j)),
            pl.BlockSpec((b, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_in, n_out), jnp.float32),
            jax.ShapeDtypeStruct((n_in, n_out), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, delta, dp, lr, gpos, gneg)
