"""Pallas kernel: error back-propagation through the transposed crossbar.

Models the backward phase circuit (paper Fig 9, Eq. 7): the discretised
output errors +-delta_j are applied to the crossbar *columns* and the
row-wise currents give delta_i = sum_j (g+_ij - g-_ij) delta_j. The result
is discretised by the 8-bit (1 sign + 7 magnitude) error ADC before being
latched into the buffer (section III.F step 2).

TPU mapping: delta @ (g+ - g-)^T as a single MXU matmul per grid step;
grid = (batch blocks, input-row blocks), so the conductance operand block
is (bm x N_out) — the transpose is expressed through dot_general, no
materialised transpose of the crossbar.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, choose_block, quantize_err


def _bwd_kernel(delta_ref, gpos_ref, gneg_ref, out_ref):
    w = gpos_ref[...] - gneg_ref[...]
    back = jax.lax.dot_general(
        delta_ref[...],
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = quantize_err(back)


@jax.jit
def crossbar_bwd(delta, gpos, gneg):
    """(B, N_out) errors -> (B, N_in) previous-layer errors."""
    b, n_out = delta.shape
    n_in = gpos.shape[0]
    bb = choose_block(b, 64)
    bm = choose_block(n_in, 512)
    grid = (b // bb, n_in // bm)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n_out), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n_out), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, n_out), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_in), jnp.float32),
        interpret=INTERPRET,
    )(delta, gpos, gneg)
