"""Pallas kernels modelling the ReStream chip's compute hot-spots."""

from .crossbar_fwd import crossbar_fwd
from .crossbar_bwd import crossbar_bwd
from .weight_update import weight_update
from .kmeans import kmeans_distances

__all__ = [
    "crossbar_fwd",
    "crossbar_bwd",
    "weight_update",
    "kmeans_distances",
]
