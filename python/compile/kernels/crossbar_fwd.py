"""Pallas kernel: differential memristor crossbar forward pass.

Models one evaluation cycle of a neural core (paper Figs 5 & 8): the input
voltage vector is applied to the crossbar rows, every differential column
pair produces DP_j = sum_i x_i (g+_ij - g-_ij), the op-amp applies
h(DP_j), and a 3-bit ADC discretises the output for the routing network.

TPU mapping (DESIGN.md section 6 / "Hardware adaptation"): the analog
crossbar is one matmul on the MXU. The differential pair is folded into a
single matmul against (g+ - g-) inside the kernel — one pass over the
operands instead of two — and the ADC is VPU elementwise work fused in the
same kernel, exactly where the paper fuses the ADC at the column output.
Grid = (batch blocks, neuron blocks); each step's operand blocks
(bb x N_in, N_in x bn) are sized to sit in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import hwspec as hw
from .common import INTERPRET, activation, choose_block, quantize_unit


def _fwd_kernel(x_ref, gpos_ref, gneg_ref, y_ref, dp_ref, *, out_bits):
    w = gpos_ref[...] - gneg_ref[...]
    dp = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)
    dp_ref[...] = dp
    y_ref[...] = quantize_unit(activation(dp), out_bits)


@functools.partial(jax.jit, static_argnames=("out_bits",))
def crossbar_fwd(x, gpos, gneg, out_bits=hw.OUT_BITS):
    """(B, N_in) x (N_in, N_out) -> (y, dp), both (B, N_out)."""
    b, n_in = x.shape
    n_out = gpos.shape[1]
    bb = choose_block(b, 64)
    bn = choose_block(n_out, 512)
    grid = (b // bb, n_out // bn)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, out_bits=out_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n_in), lambda i, j: (i, 0)),
            pl.BlockSpec((n_in, bn), lambda i, j: (0, j)),
            pl.BlockSpec((n_in, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_out), jnp.float32),
            jax.ShapeDtypeStruct((b, n_out), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, gpos, gneg)
