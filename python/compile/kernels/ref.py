"""Pure-jnp oracles for the Pallas kernels.

These implement the memristor-chip math at the same abstraction the paper's
MATLAB model uses, with none of the Pallas tiling. Every Pallas kernel in
this package is pytest-checked against these functions (see
``python/tests``), and the L2 model graphs are themselves built from the
kernels, so the oracle chain is: paper equations -> ref.py -> kernels ->
model -> HLO artifacts -> rust runtime.
"""

import jax.numpy as jnp

from .. import hwspec as hw


def quantize_unit(x, bits):
    """Uniform mid-rise quantiser of [-0.5, 0.5] to 2**bits levels.

    Models the output ADC at a crossbar column (section IV.A). Values are
    clipped to the op-amp rails first, exactly as the analog circuit does.
    """
    levels = float(2**bits - 1)
    x = jnp.clip(x, -hw.V_RAIL, hw.V_RAIL)
    return jnp.round((x + hw.V_RAIL) * levels) / levels - hw.V_RAIL


def quantize_err(x, bits=hw.ERR_BITS, full_scale=hw.ERR_MAX):
    """Sign-magnitude error quantiser (1 sign + bits-1 magnitude bits).

    Models the error ADC of the back-propagation circuit (section III.F,
    step 1: "errors are discretized into 8 bit representations").
    """
    mag_levels = float(2 ** (bits - 1) - 1)
    mag = jnp.clip(jnp.abs(x), 0.0, full_scale)
    code = jnp.round(mag / full_scale * mag_levels)
    return jnp.sign(x) * code / mag_levels * full_scale


def activation(dp):
    """Op-amp activation h(x) (Eq. 3 / Fig 6): x/4 clipped to the rails."""
    return jnp.clip(dp * hw.H_SLOPE, -hw.V_RAIL, hw.V_RAIL)


def activation_deriv_lut(dp):
    """f'(DP) via the training unit's lookup table (section III.F step 3).

    The chip stores the derivative of the *target* activation
    f(x) = sigmoid(x) - 0.5 in a LUT_SIZE-entry table indexed by the
    discretised DP value over [-H_CLIP_IN, H_CLIP_IN].
    """
    idx = jnp.clip(
        jnp.round(
            (dp + hw.H_CLIP_IN) / (2 * hw.H_CLIP_IN) * (hw.LUT_SIZE - 1)
        ),
        0,
        hw.LUT_SIZE - 1,
    )
    # Reconstruct the LUT entry analytically: centre of the indexed bin.
    centre = idx / (hw.LUT_SIZE - 1) * (2 * hw.H_CLIP_IN) - hw.H_CLIP_IN
    s = 1.0 / (1.0 + jnp.exp(-centre))
    return s * (1.0 - s)


def crossbar_fwd(x, gpos, gneg, out_bits=hw.OUT_BITS):
    """Forward pass through one differential memristor crossbar.

    x:     (B, N_in)  input voltages (bias row included by the caller)
    gpos:  (N_in, N_out) sigma+ conductances
    gneg:  (N_in, N_out) sigma- conductances
    Returns (y, dp): quantised neuron outputs and the raw dot products
    (DP_j is re-measured on chip during the update step; we return it so
    the functional path matches the chip dataflow without a second pass).
    """
    dp = x @ (gpos - gneg)
    y = quantize_unit(activation(dp), out_bits)
    return y, dp


def crossbar_bwd(delta, gpos, gneg):
    """Back-propagate errors through the transposed crossbar (Fig 9, Eq 7).

    delta: (B, N_out) errors at this layer's neurons
    Returns (B, N_in) errors for the previous layer, discretised by the
    8-bit error ADC.
    """
    back = delta @ (gpos - gneg).T
    return quantize_err(back)


def weight_update(gpos, gneg, x, delta, dp, lr):
    """Training-pulse weight update (Eq. 6 / Fig 11).

    dw = 2*eta * delta * f'(DP) * x, applied as +dw/2 on sigma+ and -dw/2 on
    sigma-, each clipped to the physical conductance range.
    """
    factor = quantize_err(delta * activation_deriv_lut(dp))
    dw = lr * (x.T @ factor)
    gp = jnp.clip(gpos + 0.5 * dw, hw.G_MIN, hw.G_MAX)
    gn = jnp.clip(gneg - 0.5 * dw, hw.G_MIN, hw.G_MAX)
    return gp, gn


def kmeans_distances(x, centres):
    """Manhattan distances from each sample to each cluster centre.

    Models the digital clustering core's subtract/accumulate datapath
    (Fig 13): x (B, D), centres (K, D) -> (B, K).
    """
    return jnp.sum(jnp.abs(x[:, None, :] - centres[None, :, :]), axis=-1)
