"""Pallas kernel: digital clustering core distance datapath.

Models the k-means core (paper Fig 13, section IV.B): for each input
sample the Manhattan distances to all current cluster centres are
evaluated in parallel subtract/accumulate lanes. The core supports up to
32 centres of up to 32 dimensions; the kernel itself is shape-generic and
the L3 mapper enforces the core's limits.

TPU mapping: |x - c| reduction is VPU elementwise + reduce work; grid over
batch blocks with the (small) centre matrix resident per step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, choose_block


def _dist_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...]            # (bb, D)
    c = c_ref[...]            # (K, D)
    out_ref[...] = jnp.sum(
        jnp.abs(x[:, None, :] - c[None, :, :]), axis=-1
    )


@jax.jit
def kmeans_distances(x, centres):
    """(B, D), (K, D) -> (B, K) Manhattan distances."""
    b, d = x.shape
    k = centres.shape[0]
    bb = choose_block(b, 128)
    grid = (b // bb,)
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=INTERPRET,
    )(x, centres)
