"""AOT lowering: JAX model graphs -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. Lowering goes through stablehlo ->
XlaComputation with ``return_tuple=True``; the Rust side unwraps the tuple.

Each artifact gets a ``.meta`` sidecar listing the exact parameter and
result shapes so the Rust runtime can validate its buffers at load time.

Run via ``make artifacts`` (which is a no-op when inputs are unchanged);
``python -m compile.aot --out ../artifacts [--only REGEX]``.
"""

import argparse
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import apps, hwspec as hw, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def net_param_specs(layers):
    specs = []
    for n_in, n_out in zip(layers[:-1], layers[1:]):
        specs += [f32(n_in + 1, n_out), f32(n_in + 1, n_out)]
    return specs


def train_fn(n_layers):
    def fn(*args):
        params, (x, t, lr) = args[: 2 * n_layers], args[2 * n_layers:]
        return model.mlp_train_step(list(params), x, t, lr)
    return fn


def train_chunk_fn(n_layers):
    def fn(*args):
        params, (xs, ts, lr) = args[: 2 * n_layers], args[2 * n_layers:]
        return model.mlp_train_chunk(list(params), xs, ts, lr)
    return fn


def grad_fn(n_layers):
    def fn(*args):
        params, (xs, ts) = args[: 2 * n_layers], args[2 * n_layers:]
        return model.mlp_grad_batch(list(params), xs, ts)
    return fn


def infer_fn(n_layers):
    def fn(*args):
        params, (x,) = args[: 2 * n_layers], args[2 * n_layers:]
        return model.mlp_infer(list(params), x)
    return fn


def ae_fwd_fn(n_layers):
    def fn(*args):
        params, (x,) = args[: 2 * n_layers], args[2 * n_layers:]
        return model.ae_fwd(list(params), x)
    return fn


def registry():
    """Yield (artifact_name, fn, arg_specs) for every export."""
    entries = []

    def add(name, fn, specs):
        entries.append((name, fn, specs))

    for name, layers in apps.NETWORKS.items():
        nl = len(layers) - 1
        p = net_param_specs(layers)
        is_dr = name.endswith("_dr")
        is_ae = name.endswith("_ae")
        # training graphs: per-sample reference + scan-chunked hot path
        if not is_dr:
            add(
                f"{name}_train_b{apps.TRAIN_BATCH}",
                train_fn(nl),
                p + [f32(apps.TRAIN_BATCH, layers[0]),
                     f32(apps.TRAIN_BATCH, layers[-1]),
                     f32(1, 1)],
            )
            add(
                f"{name}_trainchunk_c{apps.TRAIN_CHUNK}",
                train_chunk_fn(nl),
                p + [f32(apps.TRAIN_CHUNK, layers[0]),
                     f32(apps.TRAIN_CHUNK, layers[-1]),
                     f32(1, 1)],
            )
            # data-parallel mini-batch gradient tile (update applied
            # host-side by the coordinator's shard reduction)
            add(
                f"{name}_grad_t{apps.GRAD_TILE}",
                grad_fn(nl),
                p + [f32(apps.GRAD_TILE, layers[0]),
                     f32(apps.GRAD_TILE, layers[-1])],
            )
        # forward graph
        fwd = ae_fwd_fn(nl) if is_ae else infer_fn(nl)
        add(f"{name}_fwd_b{apps.FWD_BATCH}", fwd,
            p + [f32(apps.FWD_BATCH, layers[0])])
        # dimensionality-reduction apps: layerwise AE stage training +
        # encoder-only forward
        if is_dr:
            for i, (n_in, n_hid) in enumerate(apps.dr_stages(name)):
                sp = net_param_specs([n_in, n_hid, n_in])
                add(
                    f"{name}_stage{i}_train_b{apps.TRAIN_BATCH}",
                    train_fn(2),
                    sp + [f32(apps.TRAIN_BATCH, n_in),
                          f32(apps.TRAIN_BATCH, n_in),
                          f32(1, 1)],
                )
                add(
                    f"{name}_stage{i}_trainchunk_c{apps.TRAIN_CHUNK}",
                    train_chunk_fn(2),
                    sp + [f32(apps.TRAIN_CHUNK, n_in),
                          f32(apps.TRAIN_CHUNK, n_in),
                          f32(1, 1)],
                )
                add(
                    f"{name}_stage{i}_grad_t{apps.GRAD_TILE}",
                    grad_fn(2),
                    sp + [f32(apps.GRAD_TILE, n_in),
                          f32(apps.GRAD_TILE, n_in)],
                )

    # batched-training variant for the end-to-end example
    layers = apps.NETWORKS["mnist_class"]
    add(
        f"mnist_class_train_b{apps.BIG_TRAIN_BATCH}",
        train_fn(len(layers) - 1),
        net_param_specs(layers)
        + [f32(apps.BIG_TRAIN_BATCH, layers[0]),
           f32(apps.BIG_TRAIN_BATCH, layers[-1]),
           f32(1, 1)],
    )

    # clustering-core step
    for name, (d, k) in apps.KMEANS.items():
        add(
            f"{name}_step_b{apps.FWD_BATCH}",
            model.kmeans_step,
            [f32(apps.FWD_BATCH, d), f32(k, d)],
        )
    return entries


def shape_str(s):
    dims = "x".join(str(d) for d in s.shape)
    return f"f32[{dims or 'scalar'}]"


def export_one(name, fn, specs, out_dir):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_tree = jax.eval_shape(fn, *specs)
    flat_out = jax.tree_util.tree_leaves(out_tree)
    meta_path = os.path.join(out_dir, f"{name}.meta")
    with open(meta_path, "w") as f:
        for i, s in enumerate(specs):
            f.write(f"input {i} {shape_str(s)}\n")
        for i, s in enumerate(flat_out):
            f.write(f"output {i} {shape_str(s)}\n")
    return len(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter over artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    pat = re.compile(args.only) if args.only else None
    n = 0
    for name, fn, specs in registry():
        if pat and not pat.search(name):
            continue
        size = export_one(name, fn, specs, args.out)
        n += 1
        print(f"[aot] {name}: {size} chars", flush=True)
    if n == 0:
        print("[aot] nothing matched --only filter", file=sys.stderr)
        sys.exit(1)
    print(f"[aot] wrote {n} artifacts to {args.out}")


if __name__ == "__main__":
    main()
