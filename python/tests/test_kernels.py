"""Pallas kernels vs the pure-jnp oracle (hypothesis shape/seed sweeps)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import hwspec as hw
from compile.kernels import (
    crossbar_bwd,
    crossbar_fwd,
    kmeans_distances,
    ref,
    weight_update,
)
from compile.kernels.common import choose_block

dims = st.integers(1, 70)
batches = st.sampled_from([1, 2, 3, 4, 8, 16, 64])
seeds = st.integers(0, 2**31 - 1)


def _rand(rng, shape, lo, hi):
    return jnp.asarray(rng.uniform(lo, hi, shape), jnp.float32)


@given(batches, dims, dims, seeds)
@settings(max_examples=25, deadline=None)
def test_crossbar_fwd_matches_ref(b, n_in, n_out, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, n_in), -0.5, 0.5)
    gp = _rand(rng, (n_in, n_out), hw.G_MIN, hw.G_MAX)
    gn = _rand(rng, (n_in, n_out), hw.G_MIN, hw.G_MAX)
    y, dp = crossbar_fwd(x, gp, gn)
    yr, dpr = ref.crossbar_fwd(x, gp, gn)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dpr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-6)


@given(batches, dims, dims, seeds)
@settings(max_examples=25, deadline=None)
def test_crossbar_bwd_matches_ref(b, n_in, n_out, seed):
    rng = np.random.default_rng(seed)
    d = _rand(rng, (b, n_out), -1.5, 1.5)
    gp = _rand(rng, (n_in, n_out), hw.G_MIN, hw.G_MAX)
    gn = _rand(rng, (n_in, n_out), hw.G_MIN, hw.G_MAX)
    np.testing.assert_allclose(
        np.asarray(crossbar_bwd(d, gp, gn)),
        np.asarray(ref.crossbar_bwd(d, gp, gn)),
        rtol=1e-5, atol=1e-5,
    )


@given(batches, dims, dims, seeds,
       st.floats(0.001953125, 0.5, allow_nan=False, width=32))
@settings(max_examples=25, deadline=None)
def test_weight_update_matches_ref(b, n_in, n_out, seed, lr):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, n_in), -0.5, 0.5)
    d = _rand(rng, (b, n_out), -1.0, 1.0)
    dp = _rand(rng, (b, n_out), -3.0, 3.0)
    gp = _rand(rng, (n_in, n_out), hw.G_MIN, hw.G_MAX)
    gn = _rand(rng, (n_in, n_out), hw.G_MIN, hw.G_MAX)
    lr_arr = jnp.full((1, 1), lr, jnp.float32)
    gp2, gn2 = weight_update(gp, gn, x, d, dp, lr_arr)
    gp2r, gn2r = ref.weight_update(gp, gn, x, d, dp, lr)
    np.testing.assert_allclose(np.asarray(gp2), np.asarray(gp2r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gn2), np.asarray(gn2r),
                               rtol=1e-5, atol=1e-5)


@given(batches, st.integers(1, 32), st.integers(1, 32), seeds)
@settings(max_examples=25, deadline=None)
def test_kmeans_distances_matches_ref(b, d, k, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, d), -0.5, 0.5)
    c = _rand(rng, (k, d), -0.5, 0.5)
    np.testing.assert_allclose(
        np.asarray(kmeans_distances(x, c)),
        np.asarray(ref.kmeans_distances(x, c)),
        rtol=1e-5, atol=1e-5,
    )


def test_weight_update_respects_conductance_bounds():
    """No pulse may drive a device past its physical resistance range."""
    rng = np.random.default_rng(7)
    gp = _rand(rng, (20, 10), hw.G_MIN, hw.G_MAX)
    gn = _rand(rng, (20, 10), hw.G_MIN, hw.G_MAX)
    x = _rand(rng, (4, 20), -0.5, 0.5)
    d = _rand(rng, (4, 10), -1, 1)
    dp = _rand(rng, (4, 10), -3, 3)
    lr = jnp.full((1, 1), 100.0, jnp.float32)   # absurdly large pulse
    gp2, gn2 = weight_update(gp, gn, x, d, dp, lr)
    assert float(jnp.min(gp2)) >= hw.G_MIN - 1e-6
    assert float(jnp.max(gp2)) <= hw.G_MAX + 1e-6
    assert float(jnp.min(gn2)) >= hw.G_MIN - 1e-6
    assert float(jnp.max(gn2)) <= hw.G_MAX + 1e-6


def test_fwd_output_is_3bit_grid():
    """Outputs land exactly on the 8-level ADC grid (section IV.A)."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (8, 50), -0.5, 0.5)
    gp = _rand(rng, (50, 30), hw.G_MIN, hw.G_MAX)
    gn = _rand(rng, (50, 30), hw.G_MIN, hw.G_MAX)
    y, _ = crossbar_fwd(x, gp, gn)
    levels = 2**hw.OUT_BITS - 1
    codes = (np.asarray(y) + hw.V_RAIL) * levels
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


@given(st.integers(1, 4096), st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_choose_block_divides(dim, target):
    b = choose_block(dim, target)
    assert 1 <= b <= dim
    assert dim % b == 0
