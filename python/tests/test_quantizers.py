"""Property tests for the ADC/DAC quantiser models (hypothesis)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import hwspec as hw
from compile.kernels import ref

floats = st.floats(-4.0, 4.0, allow_nan=False, width=32)


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_unit_bounded(xs):
    x = jnp.asarray(xs, jnp.float32)
    q = np.asarray(ref.quantize_unit(x, hw.OUT_BITS))
    assert np.all(q >= -hw.V_RAIL - 1e-6)
    assert np.all(q <= hw.V_RAIL + 1e-6)


@given(st.lists(floats, min_size=2, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_unit_monotone(xs):
    xs = sorted(xs)
    q = np.asarray(ref.quantize_unit(jnp.asarray(xs, jnp.float32), hw.OUT_BITS))
    assert np.all(np.diff(q) >= -1e-6)


@given(st.lists(st.floats(-0.5, 0.5, allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_unit_error_bound(xs):
    """In-range values are quantised within half an LSB."""
    x = jnp.asarray(xs, jnp.float32)
    q = np.asarray(ref.quantize_unit(x, hw.OUT_BITS))
    lsb = 1.0 / (2**hw.OUT_BITS - 1)
    assert np.all(np.abs(q - np.asarray(x)) <= lsb / 2 + 1e-6)


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_unit_idempotent(xs):
    x = jnp.asarray(xs, jnp.float32)
    q1 = ref.quantize_unit(x, hw.OUT_BITS)
    q2 = ref.quantize_unit(q1, hw.OUT_BITS)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_err_sign_and_bound(xs):
    x = np.asarray(xs, np.float32)
    q = np.asarray(ref.quantize_err(jnp.asarray(x)))
    assert np.all(np.abs(q) <= hw.ERR_MAX + 1e-6)
    nz = np.abs(q) > 1e-9
    assert np.all(np.sign(q[nz]) == np.sign(x[nz]))


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_err_odd_symmetry(xs):
    """Sign-magnitude ADC is an odd function: q(-x) == -q(x)."""
    x = jnp.asarray(xs, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.quantize_err(-x)),
        -np.asarray(ref.quantize_err(x)),
        atol=1e-6,
    )


@given(st.lists(st.floats(-hw.ERR_MAX, hw.ERR_MAX, allow_nan=False,
                          width=32), min_size=1, max_size=64))
@settings(max_examples=60, deadline=None)
def test_quantize_err_error_bound(xs):
    x = np.asarray(xs, np.float32)
    q = np.asarray(ref.quantize_err(jnp.asarray(x)))
    lsb = hw.ERR_MAX / (2 ** (hw.ERR_BITS - 1) - 1)
    assert np.all(np.abs(q - x) <= lsb / 2 + 1e-6)


def test_activation_matches_sigmoid_shape():
    """h(x) approximates f(x) = sigmoid(x) - 0.5 (paper Fig 6)."""
    x = jnp.linspace(-6, 6, 241)
    h = np.asarray(ref.activation(x))
    f = 1.0 / (1.0 + np.exp(-np.asarray(x))) - 0.5
    assert np.max(np.abs(h - f)) < 0.12   # Fig 6: close approximation
    assert abs(h[120]) < 1e-6              # h(0) = 0


def test_activation_deriv_lut_tracks_true_derivative():
    x = jnp.linspace(-hw.H_CLIP_IN, hw.H_CLIP_IN, 201)
    lut = np.asarray(ref.activation_deriv_lut(x))
    s = 1.0 / (1.0 + np.exp(-np.asarray(x)))
    true = s * (1 - s)
    assert np.max(np.abs(lut - true)) < 0.01  # 64-entry LUT resolution
