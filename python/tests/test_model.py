"""L2 model graph tests: shapes, convergence, clustering semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import apps, hwspec as hw, model


def _params(layers, seed=0):
    return model.init_params(layers, jax.random.PRNGKey(seed))


def test_forward_shapes_and_range():
    params = _params([7, 5, 3])
    x = jnp.zeros((4, 7), jnp.float32)
    y, acts, dps = model.mlp_forward(params, x)
    assert y.shape == (4, 3)
    assert [a.shape for a in acts] == [(4, 8), (4, 6)]  # bias-augmented
    assert [d.shape for d in dps] == [(4, 5), (4, 3)]
    assert float(jnp.max(jnp.abs(y))) <= hw.V_RAIL + 1e-6


def test_ae_fwd_code_is_bottleneck():
    params = _params([6, 2, 6])
    x = jnp.zeros((3, 6), jnp.float32)
    recon, code = model.ae_fwd(params, x)
    assert recon.shape == (3, 6)
    assert code.shape == (3, 2)


def test_train_step_learns_classifier():
    """Stochastic BP learns a decision boundary through the chip
    constraints (the paper's Fig 16 claim, miniaturised). Note: h(x) is
    near-linear until rail saturation, so — like the paper's own demos —
    the target is a separable boundary, not an XOR-style product."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-0.5, 0.5, (64, 4)), jnp.float32)
    t = (jnp.sign(x[:, :1] + x[:, 1:2] - 0.15) * 0.4).astype(jnp.float32)
    params = _params([4, 10, 1], seed=3)
    lr = jnp.full((1, 1), 1.0, jnp.float32)

    def stats(ps):
        y, _, _ = model.mlp_forward(ps, x)
        return (float(jnp.mean((t - y) ** 2)),
                float(jnp.mean(jnp.sign(y) == jnp.sign(t))))

    before, _ = stats(params)
    ps = list(params)
    for epoch in range(25):
        for i in range(x.shape[0]):
            out = model.mlp_train_step(ps, x[i:i + 1], t[i:i + 1], lr)
            ps = list(out[:-1])
    after, acc = stats(ps)
    assert after < before * 0.6, (before, after)
    assert acc > 0.9, acc


def test_ae_train_step_reconstructs():
    rng = np.random.default_rng(1)
    # rank-1 structured data: an AE with a 2-wide bottleneck can learn it
    basis = rng.uniform(-0.5, 0.5, (2, 6))
    coef = rng.uniform(-1, 1, (32, 2))
    x = jnp.asarray(np.clip(coef @ basis, -0.5, 0.5), jnp.float32)
    params = _params([6, 2, 6], seed=5)
    lr = jnp.full((1, 1), 0.5, jnp.float32)

    def recon_err(ps):
        recon, _ = model.ae_fwd(ps, x)
        return float(jnp.mean((jnp.clip(x, -0.5, 0.5) - recon) ** 2))

    before = recon_err(params)
    ps = list(params)
    for epoch in range(20):
        for i in range(x.shape[0]):
            out = model.ae_train_step(ps, x[i:i + 1], lr)
            ps = list(out[:-1])
    after = recon_err(ps)
    assert after < before * 0.8, (before, after)


def test_params_respect_conductance_bounds_after_training():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-0.5, 0.5, (8, 5)), jnp.float32)
    t = jnp.asarray(rng.uniform(-0.4, 0.4, (8, 2)), jnp.float32)
    ps = list(_params([5, 4, 2]))
    lr = jnp.full((1, 1), 2.0, jnp.float32)
    for i in range(8):
        out = model.mlp_train_step(ps, x[i:i + 1], t[i:i + 1], lr)
        ps = list(out[:-1])
    for g in ps:
        assert float(jnp.min(g)) >= hw.G_MIN - 1e-6
        assert float(jnp.max(g)) <= hw.G_MAX + 1e-6


def test_grad_batch_then_apply_recovers_train_step():
    """The batch-1 recovery contract: computing the gradient and firing
    the pulse separately reproduces the fused per-sample step (to the
    last ulp — XLA fusion inside the jitted update kernel reorders one
    multiply chain, so exact bit-equality is only guaranteed by the
    Rust native backend, whose scalar loops mirror both paths)."""
    rng = np.random.default_rng(4)
    for layers in ([4, 10, 1], [8, 6, 5, 3]):
        params = _params(layers, seed=7)
        x = jnp.asarray(rng.uniform(-0.5, 0.5, (1, layers[0])), jnp.float32)
        t = jnp.asarray(rng.uniform(-0.4, 0.4, (1, layers[-1])), jnp.float32)
        lr = jnp.full((1, 1), 0.8, jnp.float32)
        ref = model.mlp_train_step(list(params), x, t, lr)
        out = model.mlp_grad_batch(list(params), x, t)
        grads, losses = out[:-1], out[-1]
        assert losses.shape == (1,)
        np.testing.assert_allclose(float(losses[0]), float(ref[-1]),
                                   rtol=1e-6)
        applied = model.apply_grads(list(params), grads, lr)
        for l, (a, r) in enumerate(zip(applied, ref[:-1])):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(r), rtol=0, atol=1e-7,
                err_msg=f"layers {layers} param {l}")


def test_grad_batch_rows_sum_in_order():
    """A whole-batch accumulator equals the left-to-right sum of its
    tile accumulators (float-association tolerance) — the property the
    Rust coordinator's shard reduction is built on."""
    rng = np.random.default_rng(5)
    params = _params([4, 6, 2], seed=2)
    xs = jnp.asarray(rng.uniform(-0.5, 0.5, (16, 4)), jnp.float32)
    ts = jnp.asarray(rng.uniform(-0.4, 0.4, (16, 2)), jnp.float32)
    out = model.mlp_grad_batch(list(params), xs, ts)
    whole, losses = out[:-1], out[-1]
    assert losses.shape == (16,)
    total = None
    for lo in range(0, 16, 8):
        tile = model.mlp_grad_batch(list(params), xs[lo:lo + 8],
                                    ts[lo:lo + 8])[:-1]
        total = tile if total is None else [a + b
                                            for a, b in zip(total, tile)]
    for l, (w, s) in enumerate(zip(whole, total)):
        np.testing.assert_allclose(np.asarray(w), np.asarray(s),
                                   rtol=0, atol=1e-5,
                                   err_msg=f"layer {l}")


def test_apply_grads_respects_conductance_bounds():
    params = _params([5, 4, 2], seed=1)
    huge = [jnp.full_like(params[2 * l], 1e6)
            for l in range(len(params) // 2)]
    lr = jnp.full((1, 1), 1.0, jnp.float32)
    out = model.apply_grads(list(params), huge, lr)
    for g in out:
        assert float(jnp.min(g)) >= hw.G_MIN - 1e-6
        assert float(jnp.max(g)) <= hw.G_MAX + 1e-6


def test_kmeans_step_semantics():
    x = jnp.asarray(
        [[0.0, 0.0], [0.1, 0.0], [1.0, 1.0], [0.9, 1.0]], jnp.float32
    )
    centres = jnp.asarray([[0.0, 0.05], [1.0, 0.95]], jnp.float32)
    assign, acc, counts = model.kmeans_step(x, centres)
    np.testing.assert_array_equal(np.asarray(assign), [0, 0, 1, 1])
    np.testing.assert_allclose(np.asarray(counts), [2.0, 2.0])
    np.testing.assert_allclose(np.asarray(acc[0]), [0.1, 0.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(acc[1]), [1.9, 2.0], atol=1e-6)


def test_kmeans_empty_cluster_has_zero_count():
    x = jnp.zeros((4, 2), jnp.float32)
    centres = jnp.asarray([[0.0, 0.0], [5.0, 5.0]], jnp.float32)
    _, acc, counts = model.kmeans_step(x, centres)
    assert float(counts[1]) == 0.0
    np.testing.assert_allclose(np.asarray(acc[1]), [0.0, 0.0])


def test_registry_covers_every_table1_network():
    from compile.aot import registry
    names = {name for name, _, _ in registry()}
    for app in apps.NETWORKS:
        assert any(n.startswith(app) for n in names), app
    for app in apps.KMEANS:
        assert any(n.startswith(app) for n in names), app
