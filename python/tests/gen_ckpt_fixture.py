#!/usr/bin/env python3
"""Generate the golden checkpoint fixture under rust/tests/data/golden_ckpt/.

Independent (Python) implementation of the Rust checkpoint wire format —
`rust/src/checkpoint/{codec,manifest,mod}.rs` — so the cross-language
fixture pins the format: if the Rust encoder or the hwspec fingerprint
drifts, `rust/tests/checkpoint_determinism.rs::golden_fixture_*` fails.

Every float in the fixture is exactly representable in f32 (dyadic
rationals), so the bytes are identical on every platform.

Run from the repo root (idempotent, output is committed):

    python3 python/tests/gen_ckpt_fixture.py
"""

import os
import struct
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile import hwspec  # noqa: E402


# --- FNV-1a 64 (mirror of checkpoint::codec::fnv64) -------------------

FNV_OFFSET = 0xCBF2_9CE4_8422_2325
FNV_PRIME = 0x0000_0100_0000_01B3
MASK64 = (1 << 64) - 1


def fnv64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


assert fnv64(b"") == 0xCBF2_9CE4_8422_2325
assert fnv64(b"a") == 0xAF63_DC4C_8601_EC8C
assert fnv64(b"foobar") == 0x8594_4171_F739_67E8


# --- fixed-width LE codec (mirror of checkpoint::codec::Writer) -------


class Writer:
    def __init__(self):
        self.buf = bytearray()

    def magic(self, m: bytes):
        assert len(m) == 4
        self.buf += m

    def u8(self, v: int):
        self.buf += struct.pack("<B", v)

    def u32(self, v: int):
        self.buf += struct.pack("<I", v)

    def u64(self, v: int):
        self.buf += struct.pack("<Q", v)

    def f32(self, v: float):
        self.buf += struct.pack("<f", v)

    def bytes_field(self, v: bytes):
        self.u32(len(v))
        self.buf += v

    def index_vec(self, v):
        self.u64(len(v))
        for x in v:
            self.u64(x)

    def f32_vec(self, v):
        self.u64(len(v))
        for x in v:
            self.f32(x)

    def array(self, shape, data):
        n = 1
        for d in shape:
            n *= d
        assert n == len(data), (shape, len(data))
        self.u32(len(shape))
        for d in shape:
            self.u64(d)
        self.f32_vec(data)

    def arrays(self, arrs):
        self.u32(len(arrs))
        for shape, data in arrs:
            self.array(shape, data)

    def finish(self) -> bytes:
        return bytes(self.buf)


# --- hwspec fingerprint (mirror of checkpoint::hwspec_fingerprint) ----

# The coordinator tile sizes live in rust/src/config/apps.rs and the
# clustering-core limits in rust/src/config/hwspec.rs (the Python
# hwspec mirror predates the clustering core); all are part of the
# determinism contract (shard shapes / datapath sizing), hence
# fingerprinted.
KMEANS_MAX_CENTRES = 32
KMEANS_MAX_DIM = 32
GRAD_TILE = 8
FWD_BATCH = 64
TRAIN_CHUNK = 32


def hwspec_fingerprint() -> int:
    payload = bytearray()
    for v in [
        hwspec.V_RAIL,
        hwspec.H_SLOPE,
        hwspec.H_CLIP_IN,
        hwspec.ERR_MAX,
        hwspec.G_MIN,
        hwspec.G_MAX,
    ]:
        payload += struct.pack("<f", v)
    for v in [
        hwspec.OUT_BITS,
        hwspec.ERR_BITS,
        hwspec.LUT_SIZE,
        hwspec.CORE_INPUTS,
        hwspec.CORE_NEURONS,
        KMEANS_MAX_CENTRES,
        KMEANS_MAX_DIM,
        GRAD_TILE,
        FWD_BATCH,
        TRAIN_CHUNK,
    ]:
        payload += struct.pack("<Q", v)
    return fnv64(bytes(payload))


# --- the fixture state (iris_ae at epoch 3) ---------------------------

FORMAT_VERSION = 1
APP = "iris_ae"
KIND_AUTOENCODER = 1
LAYERS = [4, 2, 4]
SEED = 42
LR = 0.5
BATCH = 2
STAGE = 0
EPOCHS_DONE = 3
N_SAMPLES = 6
SAMPLES_SEEN = EPOCHS_DONE * N_SAMPLES
RNG = [
    0x0123_4567_89AB_CDEF,
    0x0FED_CBA9_8765_4321,
    0x1122_3344_5566_7788,
    0x8877_6655_4433_2211,
]
ORDER = [3, 1, 0, 2, 5, 4]
LOSS_CURVE = [0.5, 0.25, 0.125]


def ramp(shape, base):
    """Deterministic dyadic-rational fill: base + i/64."""
    n = 1
    for d in shape:
        n *= d
    return [base + i / 64.0 for i in range(n)]


# Live conductance pairs [gp0, gn0, gp1, gn1]; shapes follow the
# (inputs+bias) x neurons convention of init_conductances.
PARAMS = [
    ([5, 2], ramp([5, 2], 0.25)),
    ([5, 2], ramp([5, 2], 0.125)),
    ([3, 4], ramp([3, 4], 0.5)),
    ([3, 4], ramp([3, 4], 0.0625)),
]
ENCODER = []  # plain (non-DR) app


def encode_state() -> bytes:
    w = Writer()
    w.magic(b"RSCK")
    w.u32(FORMAT_VERSION)
    w.bytes_field(APP.encode())
    w.u8(KIND_AUTOENCODER)
    w.index_vec(LAYERS)
    w.u64(hwspec_fingerprint())
    w.u64(SEED)
    w.f32(LR)
    w.u64(BATCH)
    w.u64(STAGE)
    w.u64(EPOCHS_DONE)
    w.u64(SAMPLES_SEEN)
    w.u64(N_SAMPLES)
    for s in RNG:
        w.u64(s)
    w.index_vec(ORDER)
    w.f32_vec(LOSS_CURVE)
    return w.finish()


def encode_params() -> bytes:
    w = Writer()
    w.magic(b"RSPW")
    w.u32(FORMAT_VERSION)
    w.arrays(ENCODER)
    w.arrays(PARAMS)
    return w.finish()


def main():
    root = os.path.join(os.path.dirname(__file__), "..", "..")
    name = f"ckpt-s{STAGE:03d}-e{EPOCHS_DONE:06d}"
    out = os.path.join(root, "rust", "tests", "data", "golden_ckpt", name)
    os.makedirs(out, exist_ok=True)

    state = encode_state()
    params = encode_params()
    manifest = (
        "restream-checkpoint v1\n"
        f"app {APP}\n"
        f"stage {STAGE} epoch {EPOCHS_DONE}\n"
        f"file state.bin {len(state)} {fnv64(state):016x}\n"
        f"file params.bin {len(params)} {fnv64(params):016x}\n"
    )

    with open(os.path.join(out, "state.bin"), "wb") as f:
        f.write(state)
    with open(os.path.join(out, "params.bin"), "wb") as f:
        f.write(params)
    with open(os.path.join(out, "MANIFEST"), "w") as f:
        f.write(manifest)

    print(f"wrote {out}")
    print(f"  state.bin  {len(state)} bytes  fnv {fnv64(state):016x}")
    print(f"  params.bin {len(params)} bytes  fnv {fnv64(params):016x}")
    print(f"  hwspec fingerprint {hwspec_fingerprint():016x}")


if __name__ == "__main__":
    main()
