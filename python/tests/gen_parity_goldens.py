"""Generate golden values for ``rust/tests/backend_parity.rs``.

Ports the crate's xoshiro256++ PRNG (``rust/src/testing/rng.rs``) to
Python bit-for-bit, draws the same input tensors the Rust test draws,
runs them through the jnp oracles in ``compile.kernels.ref`` and prints
Rust array literals for the expected outputs.

The script also cross-checks that a sequential float32 accumulation
(the order ``crossbar::ideal`` uses) agrees with the jax result to well
under the comparison tolerance, and that no quantised output sits close
enough to a rounding boundary for the two accumulation orders to land
on different codes.

Run from ``python/``:

    python -m tests.gen_parity_goldens
"""

import numpy as np

import jax

jax.config.update("jax_enable_x64", False)

from compile import hwspec as hw
from compile.kernels import ref

MASK = (1 << 64) - 1


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """Bit-exact twin of ``rust/src/testing/rng.rs`` (xoshiro256++)."""

    def __init__(self, seed):
        s = []
        sm = seed & MASK
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def unit(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform_f32(self, lo, hi):
        # Rust widens the f32 bounds to f64, samples, then narrows.
        lo64, hi64 = float(np.float32(lo)), float(np.float32(hi))
        return np.float32(lo64 + (hi64 - lo64) * self.unit())

    def vec_uniform(self, n, lo, hi):
        return np.array(
            [self.uniform_f32(lo, hi) for _ in range(n)], dtype=np.float32
        )


# Shapes kept deliberately small: the goldens are embedded as literals.
SEED = 2024
B, N_IN, N_OUT = 4, 6, 5  # N_IN includes the bias row
K, D, KB = 4, 3, 8        # kmeans: K centres, D dims, KB samples
LR = np.float32(0.7)


def draw_inputs():
    """Draw in the exact order the Rust test draws."""
    rng = Rng(SEED)
    x = rng.vec_uniform(B * N_IN, -0.5, 0.5).reshape(B, N_IN)
    gp = rng.vec_uniform(N_IN * N_OUT, 0.001, 1.0).reshape(N_IN, N_OUT)
    gn = rng.vec_uniform(N_IN * N_OUT, 0.001, 1.0).reshape(N_IN, N_OUT)
    delta = rng.vec_uniform(B * N_OUT, -1.0, 1.0).reshape(B, N_OUT)
    kx = rng.vec_uniform(KB * D, -0.5, 0.5).reshape(KB, D)
    kc = rng.vec_uniform(K * D, -0.5, 0.5).reshape(K, D)
    return x, gp, gn, delta, kx, kc


def seq_fwd_dp(x, gp, gn):
    """crossbar::ideal::fwd accumulation order, in strict float32."""
    w = (gp - gn).astype(np.float32)
    dp = np.zeros((B, N_OUT), dtype=np.float32)
    for b in range(B):
        for i in range(N_IN):
            for j in range(N_OUT):
                dp[b, j] = np.float32(
                    dp[b, j] + np.float32(x[b, i] * w[i, j])
                )
    return dp


def boundary_margin_unit(dp, bits):
    levels = (1 << bits) - 1
    act = np.clip(dp * hw.H_SLOPE, -hw.V_RAIL, hw.V_RAIL)
    code = (act + hw.V_RAIL) * levels
    return np.min(np.abs(code - np.round(code) - 0.5))


def boundary_margin_err(v):
    mag_levels = float(2 ** (hw.ERR_BITS - 1) - 1)
    code = np.clip(np.abs(v), 0, hw.ERR_MAX) / hw.ERR_MAX * mag_levels
    return np.min(np.abs(code - np.round(code) - 0.5))


def lit(name, arr):
    # repr(float(v)) is the f64 repr of the f32 value; parsing that
    # decimal back as f32 recovers the exact original bits.
    flat = np.asarray(arr, dtype=np.float32).ravel()
    body = ", ".join(repr(float(v)) for v in flat)
    return f"const {name}: [f32; {len(flat)}] = [{body}];"


def main():
    x, gp, gn, delta, kx, kc = draw_inputs()

    y, dp = ref.crossbar_fwd(x, gp, gn)
    back = ref.crossbar_bwd(delta, gp, gn)
    gp2, gn2 = ref.weight_update(gp, gn, x, delta, dp, LR)
    dists = ref.kmeans_distances(kx, kc)
    assign = np.argmin(np.asarray(dists), axis=1)
    acc = np.zeros((K, D), dtype=np.float32)
    counts = np.zeros(K, dtype=np.float32)
    for i, a in enumerate(assign):
        acc[a] += kx[i]
        counts[a] += 1

    # --- cross-checks -----------------------------------------------------
    dp_seq = seq_fwd_dp(x, gp, gn)
    gap = np.max(np.abs(dp_seq - np.asarray(dp)))
    print(f"// max |dp_jax - dp_sequential| = {gap:.3e}")
    assert gap < 1e-5, "accumulation orders diverged beyond tolerance"
    m_out = boundary_margin_unit(np.asarray(dp), hw.OUT_BITS)
    m_err = min(
        boundary_margin_err(np.asarray(delta) @ np.asarray(gp - gn).T),
        boundary_margin_err(
            np.asarray(delta) * np.asarray(ref.activation_deriv_lut(dp))
        ),
    )
    # the f'(DP) LUT index must not straddle a bin edge either
    lut_code = (np.asarray(dp) + hw.H_CLIP_IN) / (2 * hw.H_CLIP_IN) * (
        hw.LUT_SIZE - 1
    )
    m_lut = np.min(np.abs(lut_code - np.round(lut_code) - 0.5))
    print(
        f"// quantiser boundary margins: out {m_out:.4f}, err {m_err:.4f}, "
        f"lut {m_lut:.4f}"
    )
    assert min(m_out, m_err, m_lut) > 1e-3, "golden sits on a rounding edge"
    ties = np.min(
        np.abs(
            np.sort(np.asarray(dists), axis=1)[:, 1]
            - np.sort(np.asarray(dists), axis=1)[:, 0]
        )
    )
    print(f"// kmeans nearest-vs-second margin: {ties:.4f}")
    assert ties > 1e-4, "kmeans assignment is a near-tie"

    # --- emit Rust literals ----------------------------------------------
    print(lit("GOLD_Y", y))
    print(lit("GOLD_DP", dp))
    print(lit("GOLD_BWD", back))
    print(lit("GOLD_GP2", gp2))
    print(lit("GOLD_GN2", gn2))
    print(lit("GOLD_ASSIGN", assign.astype(np.float32)))
    print(lit("GOLD_ACC", acc))
    print(lit("GOLD_COUNTS", counts))


if __name__ == "__main__":
    main()
