"""The Rust and Python hwspec files are twin sources of truth; this test
pins them together by parsing the Rust constants."""

import os
import re

from compile import hwspec as hw

RUST = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "src", "config",
    "hwspec.rs",
)


def rust_consts():
    text = open(RUST).read()
    out = {}
    for m in re.finditer(
        r"pub const (\w+):\s*\w+\s*=\s*([0-9.eE_+-]+);", text
    ):
        out[m.group(1)] = float(m.group(2).replace("_", ""))
    return out


def test_hwspec_constants_match():
    rust = rust_consts()
    expected = {
        "V_RAIL": hw.V_RAIL,
        "H_SLOPE": hw.H_SLOPE,
        "H_CLIP_IN": hw.H_CLIP_IN,
        "OUT_BITS": hw.OUT_BITS,
        "ERR_BITS": hw.ERR_BITS,
        "ERR_MAX": hw.ERR_MAX,
        "LUT_SIZE": hw.LUT_SIZE,
        "CORE_INPUTS": hw.CORE_INPUTS,
        "CORE_NEURONS": hw.CORE_NEURONS,
        "G_MIN": hw.G_MIN,
        "G_MAX": hw.G_MAX,
    }
    for name, want in expected.items():
        assert name in rust, f"{name} missing from hwspec.rs"
        assert abs(rust[name] - want) < 1e-9, (
            f"{name}: rust {rust[name]} != python {want}"
        )
