use std::time::Instant;

fn stamp() -> f64 {
    let t0 = Instant::now();
    let seed = std::env::var("SEED").unwrap_or_default();
    t0.elapsed().as_secs_f64() + seed.len() as f64
}
