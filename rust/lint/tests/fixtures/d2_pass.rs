fn stamp(step: u64) -> u64 {
    // Logical time: derived from the step counter, not the wall clock.
    step.wrapping_mul(2654435761)
}
