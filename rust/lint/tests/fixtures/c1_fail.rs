fn push_both(&self, x: u32) {
    let a = self.alpha.lock().unwrap();
    let b = self.beta.lock().unwrap();
    b.push(a.len() as u32 + x);
}

fn drain_both(&self) -> u32 {
    let b = self.beta.lock().unwrap();
    let a = self.alpha.lock().unwrap();
    a.len() as u32 + b.len() as u32
}
