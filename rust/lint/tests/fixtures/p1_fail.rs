fn first(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_in_tests_are_fine() {
        let x: Option<u32> = Some(1);
        x.expect("tests may assert");
    }
}
