fn peek(p: *const u8) -> u8 {
    // SAFETY: the caller guarantees `p` points to a live, aligned
    // byte for the duration of the call.
    unsafe { *p }
}
