fn total(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

fn shifted(xs: &[f32]) -> f32 {
    xs.iter().fold(1.0f32, |acc, x| acc + x)
}

fn backwards(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in (0..xs.len()).rev() {
        acc += xs[i];
    }
    acc
}
