fn take(a: Option<u32>) -> u32 {
    // lint: allow(P1)
    a.unwrap()
}
