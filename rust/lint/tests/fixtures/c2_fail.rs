fn peek(p: *const u8) -> u8 {
    // Reads the byte behind the pointer.
    unsafe { *p }
}
