use std::collections::HashMap;

fn tally(xs: &[(String, u32)]) -> Vec<(String, u32)> {
    let mut counts: HashMap<String, u32> = HashMap::new();
    for (k, v) in xs {
        *counts.entry(k.clone()).or_insert(0) += v;
    }
    let mut out = Vec::new();
    for kv in &counts {
        out.push((kv.0.clone(), *kv.1));
    }
    out
}
