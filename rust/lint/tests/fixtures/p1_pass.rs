fn first(v: &[u32]) -> Result<u32, String> {
    v.first()
        .copied()
        .ok_or_else(|| "empty batch in request".to_string())
}
