fn total(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, x| acc + x)
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| x * y)
        .fold(0.0f64, |acc, p| acc + p)
}
