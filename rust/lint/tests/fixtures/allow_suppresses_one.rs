fn take(a: Option<u32>, b: Option<u32>) -> (u32, u32) {
    // lint: allow(P1) — the caller checked is_some() on both args
    let x = a.unwrap();
    let y = b.unwrap();
    (x, y)
}
