//! Fixture-backed self-test: one passing and one violating snippet
//! per rule, plus the allow-comment contract. These are the same
//! entry points the binary uses, so a rule that rots here rots
//! visibly.

use restream_lint::{lock_cycles, scan_file, FileScan, Rule};

fn scan(name: &str, src: &str, rule: Rule) -> FileScan {
    scan_file(name, src, &[rule])
}

fn count(scan: &FileScan, rule: &str) -> usize {
    scan.findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn d1_hash_iteration() {
    let pass = scan(
        "d1_pass.rs",
        include_str!("fixtures/d1_pass.rs"),
        Rule::D1,
    );
    assert_eq!(count(&pass, "D1"), 0, "{:?}", pass.findings);
    let fail = scan(
        "d1_fail.rs",
        include_str!("fixtures/d1_fail.rs"),
        Rule::D1,
    );
    assert_eq!(count(&fail, "D1"), 1, "{:?}", fail.findings);
    assert_eq!(fail.findings[0].line, 9);
    assert!(fail.findings[0].message.contains("counts"));
}

#[test]
fn d2_wall_clock_and_env() {
    let pass = scan(
        "d2_pass.rs",
        include_str!("fixtures/d2_pass.rs"),
        Rule::D2,
    );
    assert_eq!(count(&pass, "D2"), 0, "{:?}", pass.findings);
    let fail = scan(
        "d2_fail.rs",
        include_str!("fixtures/d2_fail.rs"),
        Rule::D2,
    );
    // Instant::now on line 4, env::var on line 5.
    assert_eq!(count(&fail, "D2"), 2, "{:?}", fail.findings);
    let lines: Vec<u32> = fail.findings.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![4, 5]);
}

#[test]
fn d3_accumulation_shape() {
    let pass = scan(
        "d3_pass.rs",
        include_str!("fixtures/d3_pass.rs"),
        Rule::D3,
    );
    assert_eq!(count(&pass, "D3"), 0, "{:?}", pass.findings);
    let fail = scan(
        "d3_fail.rs",
        include_str!("fixtures/d3_fail.rs"),
        Rule::D3,
    );
    // `.sum()`, `fold(1.0, …)`, and the `.rev()` loop header.
    assert_eq!(count(&fail, "D3"), 3, "{:?}", fail.findings);
}

#[test]
fn c1_lock_order_cycle() {
    let pass = scan(
        "c1_pass.rs",
        include_str!("fixtures/c1_pass.rs"),
        Rule::C1,
    );
    assert!(lock_cycles(&pass.lock_edges).is_empty());
    let fail = scan(
        "c1_fail.rs",
        include_str!("fixtures/c1_fail.rs"),
        Rule::C1,
    );
    let cycles = lock_cycles(&fail.lock_edges);
    assert_eq!(cycles.len(), 1, "{cycles:?}");
    assert!(cycles[0].message.contains("alpha"));
    assert!(cycles[0].message.contains("beta"));
}

#[test]
fn c2_safety_comment() {
    let pass = scan(
        "c2_pass.rs",
        include_str!("fixtures/c2_pass.rs"),
        Rule::C2,
    );
    assert_eq!(count(&pass, "C2"), 0, "{:?}", pass.findings);
    let fail = scan(
        "c2_fail.rs",
        include_str!("fixtures/c2_fail.rs"),
        Rule::C2,
    );
    assert_eq!(count(&fail, "C2"), 1, "{:?}", fail.findings);
}

#[test]
fn p1_request_path_panics() {
    let pass = scan(
        "p1_pass.rs",
        include_str!("fixtures/p1_pass.rs"),
        Rule::P1,
    );
    assert_eq!(count(&pass, "P1"), 0, "{:?}", pass.findings);
    let fail = scan(
        "p1_fail.rs",
        include_str!("fixtures/p1_fail.rs"),
        Rule::P1,
    );
    // Exactly the shipping-code unwrap; the cfg(test) expect is
    // skipped.
    assert_eq!(count(&fail, "P1"), 1, "{:?}", fail.findings);
    assert_eq!(fail.findings[0].line, 2);
}

#[test]
fn allow_comment_suppresses_exactly_one_finding() {
    let scan = scan(
        "allow_suppresses_one.rs",
        include_str!("fixtures/allow_suppresses_one.rs"),
        Rule::P1,
    );
    assert_eq!(count(&scan, "P1"), 1, "{:?}", scan.findings);
    assert_eq!(scan.findings[0].line, 4);
    assert_eq!(count(&scan, "A0"), 0);
}

#[test]
fn malformed_allow_is_reported_and_suppresses_nothing() {
    let scan = scan(
        "allow_malformed.rs",
        include_str!("fixtures/allow_malformed.rs"),
        Rule::P1,
    );
    assert_eq!(count(&scan, "A0"), 1, "{:?}", scan.findings);
    assert_eq!(count(&scan, "P1"), 1, "{:?}", scan.findings);
}
