//! The `restream-lint` binary: walk the tree, run the rules, report.
//!
//! Scans `rust/src/**/*.rs` and the lint's own `rust/lint/src` (the
//! enforcer holds itself to the contract), prints findings as
//! `file:line: RULE message` sorted by location, and exits 1 when
//! there are findings, 2 on I/O errors.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use restream_lint::{config, lock_cycles, scan_file, Finding, LockEdge};

fn main() -> ExitCode {
    match run() {
        Ok(0) => ExitCode::SUCCESS,
        Ok(_) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("restream-lint: {e}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<usize, String> {
    // CARGO_MANIFEST_DIR is <workspace>/rust/lint; the compile-time
    // `env!` keeps the binary runnable from any working directory.
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest
        .parent()
        .and_then(Path::parent)
        .ok_or("cannot locate the workspace root")?;
    let mut files = Vec::new();
    collect_rs(&root.join("rust").join("src"), &mut files)?;
    collect_rs(&root.join("rust").join("lint").join("src"), &mut files)?;
    files.sort();

    let mut findings: Vec<Finding> = Vec::new();
    let mut edges: Vec<LockEdge> = Vec::new();
    let mut scanned = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .map_err(|e| format!("{}: {e}", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path)
            .map_err(|e| format!("{rel}: {e}"))?;
        let rules = config::rules_for(&rel);
        let scan = scan_file(&rel, &src, &rules);
        findings.extend(scan.findings);
        edges.extend(scan.lock_edges);
        scanned += 1;
    }
    findings.extend(lock_cycles(&edges));

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.message.as_str()))
    });
    for f in &findings {
        println!("{}:{}: {} {}", f.file, f.line, f.rule, f.message);
    }
    if findings.is_empty() {
        println!(
            "restream-lint: clean ({scanned} files, {} lock edges)",
            edges.len()
        );
    } else {
        eprintln!(
            "restream-lint: {} finding(s) across {scanned} files",
            findings.len()
        );
    }
    Ok(findings.len())
}

/// Recursively collect `.rs` files, sorted traversal for stable output.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        entries.push(entry.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}
