//! The tagged-module map: which rules apply to which source paths.
//!
//! Paths are workspace-root-relative prefixes (`rust/src/...`), so the
//! map reads like the DESIGN.md table that documents it. A file picks
//! up every rule whose prefix list matches; C2 (SAFETY comments) is
//! unconditional.

use crate::rules::Rule;

/// Determinism-tagged modules (rules D1, D2): everything on the path
/// from input bytes to result bytes. The serving/chip front ends are
/// deliberately *not* here — their wall-clock reads (batching windows,
/// latency splits) are the product, not a hazard — and neither is
/// `metrics`, the sanctioned report-side home of `Stopwatch`.
/// `telemetry` *is* here: its snapshots must serialise identically for
/// identical state (D1), and its only clock is the `metrics::Stopwatch`
/// doorway (D2), so the lint holds it to both.
pub const DETERMINISM: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/runtime/",
    "rust/src/mapper/",
    "rust/src/checkpoint/",
    "rust/src/nn/",
    "rust/src/kmeans/",
    "rust/src/cluster/",
    "rust/src/chip/residency.rs",
    "rust/src/telemetry/",
    "rust/lint/src/",
];

/// Kernel files (rule D3): float accumulation loops whose order is the
/// bit-identity contract. `.sum()` is banned here because its
/// reduction order is an implementation detail of the iterator chain;
/// the canonical spelling is `fold(0.0, |acc, x| acc + x)`.
pub const KERNEL: &[&str] = &[
    "rust/src/runtime/native.rs",
    "rust/src/nn/",
    "rust/src/kmeans/",
    "rust/src/crossbar/",
    "rust/src/coordinator/",
];

/// Lock-order-audited modules (rule C1): everything that takes a
/// `Mutex` on a request or training path.
pub const LOCK_ORDER: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/serve/",
    "rust/src/chip/",
];

/// Request-path modules (rule P1): code that answers external
/// requests must return typed errors, never panic. The lint's own
/// source holds itself to the same bar.
pub const REQUEST_PATH: &[&str] = &[
    "rust/src/serve/",
    "rust/src/cluster/",
    "rust/src/chip/",
    "rust/lint/src/",
];

fn matches(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// The rule set for one workspace-root-relative file path.
pub fn rules_for(rel: &str) -> Vec<Rule> {
    let mut rules = Vec::new();
    if matches(rel, DETERMINISM) {
        rules.push(Rule::D1);
        rules.push(Rule::D2);
    }
    if matches(rel, KERNEL) {
        rules.push(Rule::D3);
    }
    if matches(rel, LOCK_ORDER) {
        rules.push(Rule::C1);
    }
    rules.push(Rule::C2);
    if matches(rel, REQUEST_PATH) {
        rules.push(Rule::P1);
    }
    rules
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_gets_the_determinism_rules() {
        let r = rules_for("rust/src/coordinator/mod.rs");
        assert!(r.contains(&Rule::D1));
        assert!(r.contains(&Rule::D2));
        assert!(r.contains(&Rule::D3));
        assert!(r.contains(&Rule::C1));
        assert!(!r.contains(&Rule::P1));
    }

    #[test]
    fn serve_is_request_path_not_kernel() {
        let r = rules_for("rust/src/serve/mod.rs");
        assert!(r.contains(&Rule::P1));
        assert!(r.contains(&Rule::C1));
        assert!(!r.contains(&Rule::D2));
        assert!(!r.contains(&Rule::D3));
    }

    #[test]
    fn everything_gets_c2() {
        assert!(rules_for("rust/src/cli/mod.rs").contains(&Rule::C2));
        assert!(rules_for("rust/src/metrics/mod.rs").contains(&Rule::C2));
    }

    #[test]
    fn telemetry_is_determinism_tagged_but_not_kernel() {
        let r = rules_for("rust/src/telemetry/registry.rs");
        assert!(r.contains(&Rule::D1));
        assert!(r.contains(&Rule::D2));
        assert!(!r.contains(&Rule::D3));
        assert!(!r.contains(&Rule::P1));
    }

    #[test]
    fn the_lint_lints_itself() {
        let r = rules_for("rust/lint/src/rules.rs");
        assert!(r.contains(&Rule::D1));
        assert!(r.contains(&Rule::D2));
        assert!(r.contains(&Rule::P1));
    }
}
