//! A minimal Rust lexer: token stream + comment stream, no AST.
//!
//! The rules in [`crate::rules`] are token-pattern matchers, so all
//! the lexer owes them is (a) never mistaking string/comment *content*
//! for code, and (b) stable line numbers. It handles the constructs
//! that would otherwise break that promise: nested block comments, raw
//! and byte strings, char literals vs. lifetimes, and longest-match
//! multi-char operators. Everything else is a plain token.

/// What kind of token a [`Tok`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (suffix included, e.g. `0.0f32`).
    Number,
    /// String literal of any flavour (quotes included).
    Str,
    /// Char literal (quotes included).
    Char,
    /// Lifetime (`'a`), leading quote included.
    Lifetime,
    /// Operator or delimiter, longest-match (`::`, `..=`, `{`, …).
    Punct,
}

/// One lexed token.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

/// One comment (line or block). Contiguous `//` lines stay separate
/// here; rule C2 merges them into blocks itself.
#[derive(Clone, Debug)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line_start: u32,
    /// 1-based line the comment ends on (block comments may span).
    pub line_end: u32,
    /// Comment text without the `//` / `/*` markers.
    pub text: String,
    /// True when no code token precedes the comment on its first line.
    pub own_line: bool,
}

/// The lexer's full output for one file.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

const PUNCT3: [&str; 4] = ["..=", "<<=", ">>=", "..."];
const PUNCT2: [&str; 19] = [
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=",
    "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src`. Never fails: malformed input degrades to junk tokens,
/// which at worst means a missed finding, never a crash.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut code_on_line = false;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            code_on_line = false;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // Line comment.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i + 2;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments.push(Comment {
                line_start: line,
                line_end: line,
                text: src[i + 2..j].to_string(),
                own_line: !code_on_line,
            });
            i = j;
            continue;
        }
        // Block comment (nesting per the Rust reference).
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            let inner_end = if depth == 0 { j - 2 } else { j };
            comments.push(Comment {
                line_start: start_line,
                line_end: line,
                text: src[i + 2..inner_end].to_string(),
                own_line: !code_on_line,
            });
            i = j;
            continue;
        }
        code_on_line = true;
        // Raw / byte-raw strings: r"…", r#"…"#, br"…", b r is not a
        // thing; `r#ident` (raw identifier) falls through to Ident.
        if c == b'r' || c == b'b' {
            let mut k = i;
            if b[k] == b'b' {
                k += 1;
            }
            let is_raw = k < n && b[k] == b'r';
            if is_raw {
                k += 1;
            }
            let mut hashes = 0usize;
            while k < n && b[k] == b'#' {
                hashes += 1;
                k += 1;
            }
            let raw_str = is_raw && k < n && b[k] == b'"';
            let byte_str =
                c == b'b' && !is_raw && hashes == 0 && k < n && b[k] == b'"';
            if raw_str {
                // Scan for `"` followed by `hashes` hashes.
                let mut j = k + 1;
                let start_line = line;
                loop {
                    if j >= n {
                        break;
                    }
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    if b[j] == b'"' {
                        let mut h = 0usize;
                        while j + 1 + h < n && h < hashes && b[j + 1 + h] == b'#'
                        {
                            h += 1;
                        }
                        if h == hashes {
                            j += 1 + hashes;
                            break;
                        }
                    }
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: src[i..j.min(n)].to_string(),
                    line: start_line,
                });
                i = j.min(n);
                continue;
            }
            if byte_str {
                // Fall through to the plain-string scanner below with
                // the `b` prefix consumed as part of the token.
                let (j, nl) = scan_string(b, k);
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: src[i..j].to_string(),
                    line,
                });
                line += nl;
                i = j;
                continue;
            }
            // Not a string: plain identifier starting with r/b.
        }
        if c == b'"' {
            let (j, nl) = scan_string(b, i);
            toks.push(Tok {
                kind: TokKind::Str,
                text: src[i..j].to_string(),
                line,
            });
            line += nl;
            i = j;
            continue;
        }
        if c == b'\'' {
            // Lifetime vs char literal. `'a'` is a char, `'a` (no
            // closing quote right after the ident char) is a lifetime.
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: skip `\x`, then scan to `'`.
                let mut j = i + 3;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Char,
                    text: src[i..(j + 1).min(n)].to_string(),
                    line,
                });
                i = (j + 1).min(n);
                continue;
            }
            if i + 1 < n
                && is_ident_byte(b[i + 1])
                && !(i + 2 < n && b[i + 2] == b'\'')
            {
                let mut j = i + 1;
                while j < n && is_ident_byte(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: src[i..j].to_string(),
                    line,
                });
                i = j;
                continue;
            }
            let mut j = i + 1;
            while j < n && b[j] != b'\'' {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Char,
                text: src[i..(j + 1).min(n)].to_string(),
                line,
            });
            i = (j + 1).min(n);
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && is_ident_byte(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n {
                let d = b[j];
                if is_ident_byte(d) {
                    j += 1;
                } else if d == b'.'
                    && j + 1 < n
                    && b[j + 1].is_ascii_digit()
                {
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                kind: TokKind::Number,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        if c >= 0x80 {
            // Non-ASCII outside strings/comments (only ever seen in
            // malformed input): skip the byte, never slice mid-char.
            i += 1;
            continue;
        }
        // Punct, longest match first.
        let rest = &src[i..];
        let mut matched = false;
        for p in PUNCT3 {
            if rest.starts_with(p) {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: p.to_string(),
                    line,
                });
                i += 3;
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        for p in PUNCT2 {
            if rest.starts_with(p) {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: p.to_string(),
                    line,
                });
                i += 2;
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: src[i..i + 1].to_string(),
            line,
        });
        i += 1;
    }
    Lexed { toks, comments }
}

/// Scan a plain `"…"` string starting at the opening quote; returns
/// (index past the closing quote, newlines crossed).
fn scan_string(b: &[u8], open: usize) -> (usize, u32) {
    let n = b.len();
    let mut j = open + 1;
    let mut nl = 0u32;
    while j < n {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return (j + 1, nl),
            b'\n' => {
                nl += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    (n, nl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let l = lex("let x = \"a.unwrap()\"; // .unwrap() here too\n");
        assert!(l.toks.iter().all(|t| t.text != "unwrap"));
        assert_eq!(l.comments.len(), 1);
        assert!(!l.comments[0].own_line);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let l = lex("/* outer /* inner */ still */ let y = 1;");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(
            l.toks.iter().map(|t| t.text.as_str()).collect::<Vec<_>>(),
            vec!["let", "y", "=", "1", ";"]
        );
    }

    #[test]
    fn raw_strings_swallow_quotes_and_hashes() {
        let l = lex("let s = r#\"he said \"hi\" // not a comment\"#;");
        assert_eq!(l.comments.len(), 0);
        assert_eq!(l.toks.len(), 5); // let s = <str> ;
        assert_eq!(l.toks[3].kind, TokKind::Str);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; }");
        let lifes: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .collect();
        let chars: Vec<_> =
            l.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(lifes.len(), 2);
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn longest_match_operators() {
        assert_eq!(texts("a..=b"), vec!["a", "..=", "b"]);
        assert_eq!(texts("a::b"), vec!["a", "::", "b"]);
        assert_eq!(texts("0..10"), vec!["0", "..", "10"]);
        assert_eq!(texts("x.0"), vec!["x", ".", "0"]);
    }

    #[test]
    fn number_suffixes_stay_one_token() {
        assert_eq!(texts("0.0f32 + 1_000usize"),
                   vec!["0.0f32", "+", "1_000usize"]);
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let l = lex("/* a\nb */\nlet x = 1;\n\"s\ntr\"\nfinal");
        let last = &l.toks[l.toks.len() - 1];
        assert_eq!(last.text, "final");
        assert_eq!(last.line, 6);
    }
}
