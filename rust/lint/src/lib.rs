//! `restream-lint`: the determinism/concurrency contract of the
//! `restream` tree, enforced as a static-analysis pass.
//!
//! The crate is dependency-free by design (offline builds): instead of
//! `syn` it ships a minimal lexer ([`lexer`]) and runs token-pattern
//! rules ([`rules`]) over a tagged-module map ([`config`]). The
//! binary walks `rust/src` plus this crate's own source, prints
//! `file:line: RULE message` for every finding, and exits nonzero if
//! there are any.
//!
//! See DESIGN.md, "Determinism contract & static enforcement", for
//! what each rule guards and why.

pub mod config;
pub mod lexer;
pub mod rules;

pub use rules::{lock_cycles, scan_file, FileScan, Finding, LockEdge, Rule};
