//! The determinism/concurrency rules, as token-pattern matchers over
//! [`crate::lexer`] output.
//!
//! Every rule has a stable ID and an escape hatch: a comment of the
//! form `// lint: allow(P1) — reason` (with the applicable rule ID)
//! suppresses findings of that rule on its own line (trailing
//! comment) or on the next token-bearing line (own-line comment). An
//! allow without a reason, or naming an unknown rule, is itself a
//! finding (`A0`) and suppresses nothing — the justification *is*
//! the point.
//!
//! `#[cfg(test)]` items are skipped wholesale: the rules police
//! shipping code, not test asserts.

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::{lex, Comment, Tok, TokKind};

/// A rule ID. See DESIGN.md "Determinism contract & static
/// enforcement" for the rationale behind each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// No `HashMap`/`HashSet` iteration in determinism-tagged modules.
    D1,
    /// No wall-clock / environment reads in determinism-tagged
    /// modules (report timing goes through `metrics::Stopwatch`).
    D2,
    /// Float accumulations in kernel files use the canonical
    /// left-to-right fold; no `.sum()`, no exotic fold inits, no
    /// reversed reduction ranges.
    D3,
    /// No cycles in the lock-acquisition-order graph.
    C1,
    /// `unsafe` requires an adjacent `// SAFETY:` comment block.
    C2,
    /// No `unwrap()`/`expect()`/`panic!` in request-path modules.
    P1,
}

impl Rule {
    /// The stable ID printed in findings and used in allow comments.
    pub fn id(&self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::D2 => "D2",
            Rule::D3 => "D3",
            Rule::C1 => "C1",
            Rule::C2 => "C2",
            Rule::P1 => "P1",
        }
    }

    /// Parse an ID as written in an allow comment.
    pub fn parse(s: &str) -> Option<Rule> {
        match s {
            "D1" => Some(Rule::D1),
            "D2" => Some(Rule::D2),
            "D3" => Some(Rule::D3),
            "C1" => Some(Rule::C1),
            "C2" => Some(Rule::C2),
            "P1" => Some(Rule::P1),
            _ => None,
        }
    }
}

/// One reported violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Workspace-root-relative path.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// Rule ID (`D1`…`P1`, or `A0` for a malformed allow comment).
    pub rule: &'static str,
    pub message: String,
}

/// One acquired-while-holding observation: a `.lock()` on `acquired`
/// reached while a guard on `held` is live. Rule C1 runs cycle
/// detection over the whole tree's edges ([`lock_cycles`]).
#[derive(Clone, Debug)]
pub struct LockEdge {
    pub held: String,
    pub acquired: String,
    pub file: String,
    pub line: u32,
}

/// Per-file scan result.
pub struct FileScan {
    pub findings: Vec<Finding>,
    pub lock_edges: Vec<LockEdge>,
}

/// A pre-suppression finding: (line, rule, message).
type Raw = (u32, Rule, String);

/// Scan one file under the given rules. `file` is the label findings
/// carry (workspace-root-relative path in the binary; fixtures use
/// their own names).
pub fn scan_file(file: &str, src: &str, rules: &[Rule]) -> FileScan {
    let lexed = lex(src);
    let ranges = skip_ranges(&lexed.toks);
    let toks: Vec<Tok> = lexed
        .toks
        .iter()
        .filter(|t| !ranges.iter().any(|(a, b)| *a <= t.line && t.line <= *b))
        .cloned()
        .collect();
    let (allows, mut findings) = allow_map(file, &toks, &lexed.comments);
    let mut raw: Vec<Raw> = Vec::new();
    if rules.contains(&Rule::D1) {
        rule_d1(&toks, &mut raw);
    }
    if rules.contains(&Rule::D2) {
        rule_d2(&toks, &mut raw);
    }
    if rules.contains(&Rule::D3) {
        rule_d3(&toks, &mut raw);
    }
    if rules.contains(&Rule::C2) {
        rule_c2(&toks, &lexed.comments, &mut raw);
    }
    if rules.contains(&Rule::P1) {
        rule_p1(&toks, &mut raw);
    }
    for (line, rule, message) in raw {
        let suppressed = allows
            .get(rule.id())
            .map(|lines| lines.contains(&line))
            .unwrap_or(false);
        if !suppressed {
            findings.push(Finding {
                file: file.to_string(),
                line,
                rule: rule.id(),
                message,
            });
        }
    }
    let lock_edges = if rules.contains(&Rule::C1) {
        c1_edges(file, &toks)
    } else {
        Vec::new()
    };
    FileScan { findings, lock_edges }
}

/// Line ranges covered by `#[cfg(test)]` items (attribute line through
/// the item's closing brace or semicolon).
fn skip_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let is_cfg_test = i + 6 < toks.len()
            && toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]";
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + 7;
        // Skip any further attributes between the cfg and the item.
        while j < toks.len() && toks[j].text == "#" {
            let mut depth = 0i32;
            j += 1;
            while j < toks.len() {
                if toks[j].text == "[" {
                    depth += 1;
                } else if toks[j].text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // Skip the item itself: to a top-level `;` or matching `}`.
        let mut depth = 0i32;
        while j < toks.len() {
            let t = &toks[j].text;
            if t == "{" {
                depth += 1;
            } else if t == "}" {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            } else if t == ";" && depth == 0 {
                j += 1;
                break;
            }
            j += 1;
        }
        let end_line = if j > 0 && j - 1 < toks.len() {
            toks[j - 1].line
        } else {
            start_line
        };
        out.push((start_line, end_line));
        i = j;
    }
    out
}

/// Parse allow comments into rule → covered-lines, and report
/// malformed ones (`A0`). `toks` must already be test-filtered so an
/// own-line allow covers the next *linted* line.
fn allow_map(
    file: &str,
    toks: &[Tok],
    comments: &[Comment],
) -> (BTreeMap<&'static str, BTreeSet<u32>>, Vec<Finding>) {
    let mut allows: BTreeMap<&'static str, BTreeSet<u32>> = BTreeMap::new();
    let mut bad = Vec::new();
    let tok_lines: BTreeSet<u32> = toks.iter().map(|t| t.line).collect();
    for c in comments {
        let text = c.text.as_str();
        let Some(at) = text.find("lint:") else {
            continue;
        };
        let after = text[at + 5..].trim_start();
        let Some(args) = after.strip_prefix("allow(") else {
            continue;
        };
        let Some(close) = args.find(')') else {
            bad.push(Finding {
                file: file.to_string(),
                line: c.line_start,
                rule: "A0",
                message: "unclosed `lint: allow(` comment".to_string(),
            });
            continue;
        };
        let mut rules = Vec::new();
        let mut ok = true;
        for part in args[..close].split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match Rule::parse(part) {
                Some(r) => rules.push(r),
                None => {
                    bad.push(Finding {
                        file: file.to_string(),
                        line: c.line_start,
                        rule: "A0",
                        message: format!(
                            "allow names unknown rule '{part}'"
                        ),
                    });
                    ok = false;
                }
            }
        }
        let reason = args[close + 1..]
            .trim_start_matches(|ch: char| {
                ch.is_whitespace()
                    || ch == '—'
                    || ch == '–'
                    || ch == '-'
                    || ch == ':'
                    || ch == ','
            })
            .trim();
        if reason.is_empty() {
            bad.push(Finding {
                file: file.to_string(),
                line: c.line_start,
                rule: "A0",
                message: "allow without a reason — the justification \
                          is the point"
                    .to_string(),
            });
            ok = false;
        }
        if !ok {
            continue;
        }
        let covered: Vec<u32> = if c.own_line {
            // Covers exactly the next token-bearing line.
            tok_lines
                .iter()
                .find(|l| **l > c.line_end)
                .map(|l| vec![*l])
                .unwrap_or_default()
        } else {
            (c.line_start..=c.line_end).collect()
        };
        for r in rules {
            let entry = allows.entry(r.id()).or_default();
            for l in &covered {
                entry.insert(*l);
            }
        }
    }
    (allows, bad)
}

const ITER_METHODS: [&str; 8] = [
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter",
    "drain", "retain",
];

/// D1: iteration over a `HashMap`/`HashSet`-typed binding or field.
/// Detection is name-based: any `let` binding or `name: Type` decl
/// whose statement segment mentions `HashMap`/`HashSet` marks `name`,
/// then `.iter()`-family calls and `for … in name` on marked names
/// are flagged.
fn rule_d1(toks: &[Tok], out: &mut Vec<Raw>) {
    let mut hashvars: BTreeSet<&str> = BTreeSet::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident
            || (toks[i].text != "HashMap" && toks[i].text != "HashSet")
        {
            continue;
        }
        // Walk back to the start of the statement segment.
        let mut seg: Vec<&Tok> = Vec::new();
        let mut j = i;
        while j > 0 {
            j -= 1;
            let t = toks[j].text.as_str();
            if t == ";" || t == "{" || t == "}" || t == "(" || t == "," {
                break;
            }
            seg.push(&toks[j]);
            if seg.len() > 40 {
                break;
            }
        }
        seg.reverse();
        let mut name: Option<&str> = None;
        for (s, tok) in seg.iter().enumerate() {
            if tok.text == "let" {
                let mut t2 = s + 1;
                if t2 < seg.len() && seg[t2].text == "mut" {
                    t2 += 1;
                }
                if t2 < seg.len() && seg[t2].kind == TokKind::Ident {
                    name = Some(seg[t2].text.as_str());
                }
                break;
            }
        }
        if name.is_none()
            && seg.len() >= 2
            && seg[0].kind == TokKind::Ident
            && seg[1].text == ":"
        {
            name = Some(seg[0].text.as_str());
        }
        if let Some(nm) = name {
            hashvars.insert(nm);
        }
    }
    for i in 0..toks.len() {
        if toks[i].kind == TokKind::Ident
            && hashvars.contains(toks[i].text.as_str())
            && i + 2 < toks.len()
            && toks[i + 1].text == "."
            && ITER_METHODS.contains(&toks[i + 2].text.as_str())
        {
            out.push((
                toks[i + 2].line,
                Rule::D1,
                format!(
                    "`{}.{}` iterates a Hash collection in a \
                     determinism-tagged module; use BTreeMap/BTreeSet \
                     or sort first",
                    toks[i].text, toks[i + 2].text
                ),
            ));
        }
        if toks[i].kind == TokKind::Ident && toks[i].text == "for" {
            let mut j = i + 1;
            while j < toks.len()
                && toks[j].text != "in"
                && toks[j].text != "{"
            {
                j += 1;
            }
            if j >= toks.len() || toks[j].text != "in" {
                continue;
            }
            let mut expr: Vec<&Tok> = Vec::new();
            j += 1;
            while j < toks.len() && toks[j].text != "{" {
                expr.push(&toks[j]);
                j += 1;
                if expr.len() > 6 {
                    break;
                }
            }
            let core: Vec<&&Tok> = expr
                .iter()
                .filter(|t| t.text != "&" && t.text != "mut")
                .collect();
            if core.len() == 1
                && core[0].kind == TokKind::Ident
                && hashvars.contains(core[0].text.as_str())
            {
                out.push((
                    core[0].line,
                    Rule::D1,
                    format!(
                        "`for … in {}` iterates a Hash collection in a \
                         determinism-tagged module; use \
                         BTreeMap/BTreeSet or sort first",
                        core[0].text
                    ),
                ));
            }
        }
    }
}

const ENV_FNS: [&str; 6] =
    ["var", "vars", "var_os", "args", "args_os", "temp_dir"];

/// D2: wall-clock or environment reads. `env!` (compile-time) does
/// not match — the matcher requires `env::<fn>`.
fn rule_d2(toks: &[Tok], out: &mut Vec<Raw>) {
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident {
            continue;
        }
        let t = toks[i].text.as_str();
        if t == "Instant"
            && i + 2 < toks.len()
            && toks[i + 1].text == "::"
            && toks[i + 2].text == "now"
        {
            out.push((
                toks[i].line,
                Rule::D2,
                "`Instant::now` in a determinism-tagged module; time \
                 report code with metrics::Stopwatch or annotate why \
                 this read cannot affect results"
                    .to_string(),
            ));
        }
        if t == "SystemTime" {
            out.push((
                toks[i].line,
                Rule::D2,
                "`SystemTime` in a determinism-tagged module"
                    .to_string(),
            ));
        }
        if t == "env"
            && i + 2 < toks.len()
            && toks[i + 1].text == "::"
            && ENV_FNS.contains(&toks[i + 2].text.as_str())
        {
            out.push((
                toks[i].line,
                Rule::D2,
                format!(
                    "environment read `env::{}` in a \
                     determinism-tagged module",
                    toks[i + 2].text
                ),
            ));
        }
    }
}

/// Is this token a zero literal (`0`, `0.0`, optionally suffixed)?
fn is_zero_literal(tok: &Tok) -> bool {
    if tok.kind != TokKind::Number {
        return false;
    }
    let t: String = tok.text.chars().filter(|c| *c != '_').collect();
    let suffix = if let Some(s) = t.strip_prefix("0.0") {
        s
    } else if let Some(s) = t.strip_prefix('0') {
        s
    } else {
        return false;
    };
    suffix.is_empty()
        || matches!(
            suffix,
            "f32" | "f64" | "i8" | "i16" | "i32" | "i64" | "i128"
                | "isize" | "u8" | "u16" | "u32" | "u64" | "u128"
                | "usize"
        )
}

/// D3: float-accumulation shape in kernel files. Flags `.sum(`,
/// `.sum::<`, `.fold(` whose init is not a zero literal, and `for`
/// headers containing `.rev()`.
fn rule_d3(toks: &[Tok], out: &mut Vec<Raw>) {
    for i in 0..toks.len() {
        if toks[i].text == "." && i + 2 < toks.len() {
            let name = toks[i + 1].text.as_str();
            let after = toks[i + 2].text.as_str();
            if name == "sum" && (after == "(" || after == "::") {
                out.push((
                    toks[i + 1].line,
                    Rule::D3,
                    "`.sum()` reassociates at the iterator's whim; \
                     spell the reduction as the canonical \
                     `fold(0.0, |acc, x| acc + x)`"
                        .to_string(),
                ));
            }
            if name == "fold" && after == "(" {
                let mut arg: Vec<&Tok> = Vec::new();
                let mut j = i + 3;
                let mut depth = 0i32;
                while j < toks.len() {
                    let t = toks[j].text.as_str();
                    if t == "(" || t == "[" || t == "{" {
                        depth += 1;
                    } else if t == ")" || t == "]" || t == "}" {
                        if depth == 0 {
                            break;
                        }
                        depth -= 1;
                    } else if t == "," && depth == 0 {
                        break;
                    }
                    arg.push(&toks[j]);
                    j += 1;
                }
                let canonical =
                    arg.len() == 1 && is_zero_literal(arg[0]);
                if !canonical {
                    out.push((
                        toks[i + 1].line,
                        Rule::D3,
                        "`.fold` with a non-zero init in a kernel \
                         file; the canonical reduction starts from a \
                         literal zero"
                            .to_string(),
                    ));
                }
            }
        }
        if toks[i].kind == TokKind::Ident && toks[i].text == "for" {
            let mut hdr: Vec<&Tok> = Vec::new();
            let mut j = i + 1;
            while j < toks.len() && toks[j].text != "{" {
                hdr.push(&toks[j]);
                j += 1;
                if hdr.len() > 30 {
                    break;
                }
            }
            for h in 0..hdr.len().saturating_sub(2) {
                if hdr[h].text == "."
                    && hdr[h + 1].text == "rev"
                    && hdr[h + 2].text == "("
                {
                    out.push((
                        hdr[h + 1].line,
                        Rule::D3,
                        "reversed range in a kernel loop; if this is \
                         a deliberate non-reduction walk (e.g. the \
                         backprop layer order), annotate it"
                            .to_string(),
                    ));
                }
            }
        }
    }
}

/// The post-`.lock()` method chain that still counts as "just the
/// guard": error adapters, nothing that consumes or forwards it.
const GUARD_ADAPTERS: [&str; 3] = ["unwrap", "expect", "unwrap_or_else"];

/// A live lock guard during the C1 scan.
struct Held {
    name: String,
    /// Guard-let (lives to end of scope) vs statement temporary.
    scope: bool,
    depth: i32,
}

/// C1 per-file pass: collect acquired-while-holding edges. A
/// `let g = x.lock()<adapters>;` holds `x` until its scope closes; any
/// other `.lock()` holds only within its statement (a `;` or a closing
/// brace releases it — tail expressions have no semicolon).
fn c1_edges(file: &str, toks: &[Tok]) -> Vec<LockEdge> {
    let mut edges = Vec::new();
    let mut held: Vec<Held> = Vec::new();
    let mut depth = 0i32;
    let mut i = 0usize;
    while i < toks.len() {
        let t = toks[i].text.as_str();
        match t {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                held.retain(|h| h.scope && h.depth <= depth);
            }
            ";" => held.retain(|h| h.scope),
            _ => {}
        }
        let is_lock = t == "lock"
            && i > 0
            && toks[i - 1].text == "."
            && i + 1 < toks.len()
            && toks[i + 1].text == "(";
        if is_lock {
            let recv = lock_receiver(toks, i);
            for h in &held {
                edges.push(LockEdge {
                    held: h.name.clone(),
                    acquired: recv.clone(),
                    file: file.to_string(),
                    line: toks[i].line,
                });
            }
            let scope = is_guard_let(toks, i);
            held.push(Held { name: recv, scope, depth });
        }
        i += 1;
    }
    edges
}

/// The lock's receiver name: last plain identifier before the `.lock`,
/// walking back over `self`/`.`/`::` chains.
fn lock_receiver(toks: &[Tok], lock_idx: usize) -> String {
    let mut j = lock_idx.saturating_sub(1);
    loop {
        if j == 0 {
            break;
        }
        j -= 1;
        let t = toks[j].text.as_str();
        if toks[j].kind == TokKind::Ident && t != "self" {
            return t.to_string();
        }
        if t == "." || t == "::" || t == "self" {
            continue;
        }
        break;
    }
    "<lock>".to_string()
}

/// Does this `.lock()` bind a scope-long guard? True when the
/// statement starts with `let` and everything after the lock call, up
/// to the `;`, is an adapter chain (`.unwrap()`, `.expect(…)`,
/// `.unwrap_or_else(…)`, `?`).
fn is_guard_let(toks: &[Tok], lock_idx: usize) -> bool {
    // Find the statement start.
    let mut start = lock_idx;
    let mut j = lock_idx;
    while j > 0 {
        j -= 1;
        let t = toks[j].text.as_str();
        if t == ";" || t == "{" || t == "}" {
            break;
        }
        start = j;
    }
    if toks[start].text != "let" {
        return false;
    }
    // Skip the lock's own argument parens.
    let mut j = lock_idx + 1;
    if j < toks.len() && toks[j].text == "(" {
        let mut depth = 1i32;
        j += 1;
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "(" => depth += 1,
                ")" => depth -= 1,
                _ => {}
            }
            j += 1;
        }
    }
    // Adapter-only chain to the semicolon.
    while j < toks.len() && toks[j].text != ";" {
        if toks[j].text == "?" {
            j += 1;
            continue;
        }
        if toks[j].text == "."
            && j + 1 < toks.len()
            && GUARD_ADAPTERS.contains(&toks[j + 1].text.as_str())
        {
            j += 2;
            if j < toks.len() && toks[j].text == "(" {
                let mut depth = 1i32;
                j += 1;
                while j < toks.len() && depth > 0 {
                    match toks[j].text.as_str() {
                        "(" => depth += 1,
                        ")" => depth -= 1,
                        _ => {}
                    }
                    j += 1;
                }
            }
            continue;
        }
        return false;
    }
    true
}

/// C1 global pass: cycle detection on the acquired-while-holding
/// graph from every scanned file's [`LockEdge`]s.
pub fn lock_cycles(edges: &[LockEdge]) -> Vec<Finding> {
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in edges {
        adj.entry(e.held.as_str())
            .or_default()
            .insert(e.acquired.as_str());
    }
    let mut nodes: BTreeSet<&str> = BTreeSet::new();
    for (&a, bs) in &adj {
        nodes.insert(a);
        for &b in bs.iter() {
            nodes.insert(b);
        }
    }
    let mut findings = Vec::new();
    // DFS three-colour cycle detection, deterministic order.
    let mut color: BTreeMap<&str, u8> =
        nodes.iter().map(|n| (*n, 0u8)).collect();
    let mut stack: Vec<&str> = Vec::new();
    for &n in &nodes {
        if color.get(n) == Some(&0) {
            dfs(n, &adj, &mut color, &mut stack, edges, &mut findings);
        }
    }
    findings
}

fn dfs<'a>(
    v: &'a str,
    adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    color: &mut BTreeMap<&'a str, u8>,
    stack: &mut Vec<&'a str>,
    edges: &[LockEdge],
    findings: &mut Vec<Finding>,
) {
    color.insert(v, 1);
    stack.push(v);
    if let Some(next) = adj.get(v) {
        for &w in next {
            match color.get(w) {
                Some(&1) => {
                    // Grey: the stack from w to here is a cycle.
                    let from = stack
                        .iter()
                        .position(|x| *x == w)
                        .unwrap_or(0);
                    let mut path: Vec<&str> =
                        stack[from..].to_vec();
                    path.push(w);
                    let site = edges
                        .iter()
                        .find(|e| e.held == v && e.acquired == w);
                    let (file, line) = match site {
                        Some(e) => (e.file.clone(), e.line),
                        None => ("<unknown>".to_string(), 0),
                    };
                    findings.push(Finding {
                        file,
                        line,
                        rule: "C1",
                        message: format!(
                            "lock-order cycle: {} (fix by acquiring \
                             these locks in one global order)",
                            path.join(" -> ")
                        ),
                    });
                }
                Some(&0) => {
                    dfs(w, adj, color, stack, edges, findings);
                }
                _ => {}
            }
        }
    }
    stack.pop();
    color.insert(v, 2);
}

/// C2: every `unsafe` needs a contiguous `// SAFETY:` comment block
/// ending directly above it (or a trailing one on the same line).
fn rule_c2(toks: &[Tok], comments: &[Comment], out: &mut Vec<Raw>) {
    // Merge contiguous comment lines into blocks so a long SAFETY
    // block counts as adjacent via its *last* line.
    let mut blocks: Vec<(u32, u32, bool)> = Vec::new();
    for c in comments {
        let has = c.text.contains("SAFETY:");
        match blocks.last_mut() {
            Some(b) if c.line_start <= b.1 + 1 => {
                b.1 = b.1.max(c.line_end);
                b.2 = b.2 || has;
            }
            _ => blocks.push((c.line_start, c.line_end, has)),
        }
    }
    for t in toks {
        if t.kind == TokKind::Ident && t.text == "unsafe" {
            let ln = t.line;
            let ok = blocks.iter().any(|(s, e, has)| {
                *has && *e + 2 >= ln && *s <= ln
            });
            if !ok {
                out.push((
                    ln,
                    Rule::C2,
                    "`unsafe` without an adjacent `// SAFETY:` \
                     comment explaining why the obligations hold"
                        .to_string(),
                ));
            }
        }
    }
}

/// P1: `.unwrap()`, `.expect(…)`, `panic!` in request-path code.
/// `unwrap_or_else` and friends don't match — only the panicking
/// forms.
fn rule_p1(toks: &[Tok], out: &mut Vec<Raw>) {
    for i in 0..toks.len() {
        if toks[i].text == "."
            && i + 2 < toks.len()
            && (toks[i + 1].text == "unwrap"
                || toks[i + 1].text == "expect")
            && toks[i + 2].text == "("
        {
            out.push((
                toks[i + 1].line,
                Rule::P1,
                format!(
                    "`.{}()` in a request-path module; return a typed \
                     error instead",
                    toks[i + 1].text
                ),
            ));
        }
        if toks[i].kind == TokKind::Ident
            && toks[i].text == "panic"
            && i + 1 < toks.len()
            && toks[i + 1].text == "!"
        {
            out.push((
                toks[i].line,
                Rule::P1,
                "`panic!` in a request-path module; return a typed \
                 error instead"
                    .to_string(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Rule; 6] =
        [Rule::D1, Rule::D2, Rule::D3, Rule::C1, Rule::C2, Rule::P1];

    fn lines_of(scan: &FileScan, rule: &str) -> Vec<u32> {
        scan.findings
            .iter()
            .filter(|f| f.rule == rule)
            .map(|f| f.line)
            .collect()
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "fn a() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn b() { y.unwrap(); z.unwrap(); }\n\
                   }\n";
        let scan = scan_file("t.rs", src, &ALL);
        assert_eq!(lines_of(&scan, "P1"), vec![1]);
    }

    #[test]
    fn trailing_allow_covers_only_its_line() {
        let src = "fn a() {\n\
                   x.unwrap(); // lint: allow(P1) — guarded above\n\
                   y.unwrap();\n\
                   }\n";
        let scan = scan_file("t.rs", src, &ALL);
        assert_eq!(lines_of(&scan, "P1"), vec![3]);
    }

    #[test]
    fn allow_without_reason_is_a_finding_and_suppresses_nothing() {
        let src = "fn a() {\n\
                   // lint: allow(P1)\n\
                   x.unwrap();\n\
                   }\n";
        let scan = scan_file("t.rs", src, &ALL);
        assert_eq!(lines_of(&scan, "P1"), vec![3]);
        assert_eq!(lines_of(&scan, "A0"), vec![2]);
    }

    #[test]
    fn unknown_rule_in_allow_is_a_finding() {
        let src = "// lint: allow(Z9) — no such rule\nfn a() {}\n";
        let scan = scan_file("t.rs", src, &ALL);
        assert_eq!(lines_of(&scan, "A0"), vec![1]);
    }

    #[test]
    fn zero_literals() {
        let z = |s: &str| {
            is_zero_literal(&Tok {
                kind: TokKind::Number,
                text: s.to_string(),
                line: 1,
            })
        };
        assert!(z("0"));
        assert!(z("0.0"));
        assert!(z("0.0f32"));
        assert!(z("0f64"));
        assert!(z("0usize"));
        assert!(z("0.0_f64"));
        assert!(!z("0.5"));
        assert!(!z("1"));
        assert!(!z("00"));
    }

    #[test]
    fn guard_let_vs_statement_temporary() {
        // Guard-let: the lock is held across the next statement.
        let src = "fn a(&self) {\n\
                   let g = self.alpha.lock().unwrap();\n\
                   self.beta.lock().unwrap().push(1);\n\
                   }\n";
        let scan = scan_file("t.rs", src, &[Rule::C1]);
        assert_eq!(scan.lock_edges.len(), 1);
        assert_eq!(scan.lock_edges[0].held, "alpha");
        assert_eq!(scan.lock_edges[0].acquired, "beta");
        // Statement temporary: released at the `;`, no edge.
        let src = "fn a(&self) {\n\
                   self.alpha.lock().unwrap().push(1);\n\
                   self.beta.lock().unwrap().push(2);\n\
                   }\n";
        let scan = scan_file("t.rs", src, &[Rule::C1]);
        assert!(scan.lock_edges.is_empty());
    }

    #[test]
    fn tail_expression_guard_is_released_by_the_brace() {
        // Regression: a `.lock()` in a tail expression (no `;`) must
        // not leak into the next function.
        let src = "fn a(&self) -> usize {\n\
                   self.alpha.lock().unwrap().len()\n\
                   }\n\
                   fn b(&self) {\n\
                   self.alpha.lock().unwrap().clear();\n\
                   }\n";
        let scan = scan_file("t.rs", src, &[Rule::C1]);
        assert!(scan.lock_edges.is_empty());
    }

    #[test]
    fn lock_cycles_found_and_ordered_pairs_pass() {
        let edge = |a: &str, b: &str| LockEdge {
            held: a.to_string(),
            acquired: b.to_string(),
            file: "t.rs".to_string(),
            line: 1,
        };
        let cyclic = [edge("a", "b"), edge("b", "a")];
        let finds = lock_cycles(&cyclic);
        assert_eq!(finds.len(), 1);
        assert!(finds[0].message.contains("a -> b -> a")
            || finds[0].message.contains("b -> a -> b"));
        let acyclic = [edge("a", "b"), edge("b", "c"), edge("a", "c")];
        assert!(lock_cycles(&acyclic).is_empty());
    }
}
