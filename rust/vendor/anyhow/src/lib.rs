//! Offline shim of the `anyhow` API surface this workspace uses.
//!
//! The build environment has no network access and no guarantee that the
//! real `anyhow` crate is present in a registry, so the workspace vendors
//! this drop-in subset as a path dependency: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the [`Context`] extension
//! trait. Semantics follow the real crate where the workspace relies on
//! them:
//!
//! * `Error` does **not** implement `std::error::Error` (exactly like the
//!   real `anyhow::Error`), which is what makes the blanket
//!   `From<E: std::error::Error>` conversion — and therefore `?` on any
//!   concrete error type — possible.
//! * `Display` shows the outermost message; the alternate form (`{:#}`)
//!   shows the whole context chain separated by `": "`.
//! * `Debug` (what `unwrap`/`expect`/`fn main() -> Result<()>` print)
//!   shows the message followed by a `Caused by:` list.

use std::fmt;

/// `Result` specialised to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus a chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `": "`-joined context chain (the `{:#}` rendering).
    fn joined(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.joined())
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    /// Wrap the error with an outer message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built outer message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_forms() {
        let e: Error = io_err().into();
        let e = e.context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn macros_build_messages() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 7 {
                bail!("unlucky");
            }
            Err(anyhow!("fell through with {}", n))
        }
        assert_eq!(f(99).unwrap_err().to_string(), "n too big: 99");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
    }

    #[test]
    fn with_context_chains() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
    }
}
