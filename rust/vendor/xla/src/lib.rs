//! API stub of the `xla` crate (xla-rs), vendored so the `pjrt` cargo
//! feature *compiles* in an offline build environment that has neither
//! the crate nor the `xla_extension` native library.
//!
//! Every operation that would need a real PJRT client returns
//! [`Error::Unavailable`] at runtime; the type and method signatures
//! match the subset of xla-rs 0.1.x that `restream::runtime::pjrt`
//! uses, so swapping this path dependency for the published crate (plus
//! an `XLA_EXTENSION_DIR` install) re-enables real artifact execution
//! without touching the runtime code. The default build of the
//! workspace never compiles this crate — the native backend is the
//! default compute path (see `DESIGN.md`, "Backend selection").

use std::fmt;

/// Stub error: always "PJRT unavailable" plus the attempted operation.
#[derive(Debug)]
pub enum Error {
    /// The stub cannot perform real XLA work.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Error::Unavailable(op) = self;
        write!(
            f,
            "{op}: PJRT is stubbed in this build — link the real `xla` \
             crate (and its xla_extension library) to execute artifacts"
        )
    }
}

impl std::error::Error for Error {}

/// `Result` specialised to the stub [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// Host literal: shape plus row-major f32 data.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

/// Types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    /// The literal's dimensions.
    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the literal with new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Unavailable("Literal::reshape size mismatch"));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Destructure a tuple literal. The stub never produces tuples, so
    /// this only ever reports unavailability.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

/// Device buffer handle (never holds real device memory in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Download the buffer into a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client — unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation for this client.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    /// Execute with host literals; one buffer row per replica.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-resident buffers.
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }

    /// The client this executable was compiled for.
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file — needs the real XLA text parser.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip_on_host() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn device_operations_report_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stubbed"));
    }
}
