//! Paper Fig 15: memristor switching waveforms under a ±2.5 V drive —
//! reproduces the device-model validation plot as a printed series.

use restream::device::{Memristor, MemristorParams};

fn main() {
    restream::benchutil::section(
        "Fig 15 — memristor switching waveform (Yakopcic model, Yu/Wong device)",
    );
    let params = MemristorParams::default();
    let mut m = Memristor::fresh(params);
    // one 40 us sine period at 2.5 V amplitude, like the paper's drive
    let period = 40e-6;
    let dt = 1e-9;
    let steps = (period / dt) as usize;
    println!("{:>9} {:>8} {:>12} {:>8}", "t (us)", "V (V)", "I (uA)", "x");
    let mut peak_i: f64 = 0.0;
    for s in 0..steps {
        let t = s as f64 * dt;
        let v = 2.5 * (std::f64::consts::TAU * t / period).sin();
        m.step(v, dt);
        peak_i = peak_i.max(m.current(v).abs());
        if s % (steps / 20) == 0 {
            println!(
                "{:>9.2} {:>8.3} {:>12.3} {:>8.4}",
                t * 1e6,
                v,
                m.current(v) * 1e6,
                m.x
            );
        }
    }
    println!("\npeak |I| = {:.1} uA", peak_i * 1e6);
    println!("state after positive half-wave sweep: x = {:.4}", m.x);

    // the paper's headline device facts
    let on = Memristor::with_state(params, 1.0);
    let off = Memristor::with_state(params, params.x_min);
    println!(
        "R_on = {:.1} kOhm, R_off/R_on = {:.0} (paper: 10 kOhm, 1000)",
        on.resistance() / 1e3,
        off.resistance() / on.resistance()
    );
    let mut fresh = Memristor::fresh(params);
    fresh.pulse(2.5, 20e-6, 1e-9);
    println!(
        "x after 20 us at +2.5 V: {:.3} (paper: full range switched)",
        fresh.x
    );
}
