//! Paper Table II (+ section VI.E): memristor neural core per-step time
//! and power, the clustering core's area/power/timing, and the crossbar
//! circuit-fidelity evidence behind the 400x200 core sizing.

use restream::config::SystemConfig;
use restream::cores::ClusterCore;
use restream::crossbar::circuit::{CircuitCrossbar, CircuitParams};
use restream::report;

fn main() {
    restream::benchutil::section("Table II — neural core step time/power");
    print!("{}", report::table2());
    println!("(paper: 0.27us/0.794mW, 0.80us/0.706mW, 1.00us/6.513mW, 0.0004mW)");

    restream::benchutil::section("section VI.E — clustering core");
    let sys = SystemConfig::default();
    let core = ClusterCore::configure(20, 32, sys.clock_hz).unwrap();
    let (t, e) = core.recognition_cost();
    println!(
        "area {:.3} mm^2, power {:.2} mW (paper: 0.039 mm^2, 1.36 mW)",
        restream::power::cluster_core::AREA_MM2,
        restream::power::cluster_core::POWER_W * 1e3
    );
    println!(
        "per-sample assignment: {:.2} us / {:.2e} J; 1000-sample epoch: {:.2} us",
        t * 1e6,
        e,
        core.epoch_time_s(1000) * 1e6
    );

    restream::benchutil::section(
        "section IV.A — crossbar sizing: circuit-vs-ideal error",
    );
    let p = CircuitParams::default();
    println!("{:>12} {:>14} {:>14}", "rows x cols", "g=0.02 err %", "g=1.0 err %");
    for (r, c) in [(50usize, 25usize), (100, 50), (200, 100), (400, 200)] {
        let v = vec![0.5; r];
        let hi_r = CircuitCrossbar::new(r, c, vec![0.02; r * c], p);
        let lo_r = CircuitCrossbar::new(r, c, vec![1.0; r * c], p);
        println!(
            "{:>12} {:>14.2} {:>14.2}",
            format!("{r}x{c}"),
            hi_r.relative_error(&v) * 100.0,
            lo_r.relative_error(&v) * 100.0
        );
    }
    println!(
        "(paper: \"400x200 crossbar has very little impact of sneak paths \
         for the memristor device considered (high resistance values)\")"
    );
}
