//! §Perf: data-parallel scaling of the sharded execution layer.
//!
//! Measures throughput (samples/s) of the three sharded engine
//! operations — batched recognition, k-means epochs, anomaly scoring —
//! at 1/2/4/8 workers on the native backend, prints per-shard timings,
//! and writes the machine-readable trajectory to `BENCH_parallel.json`
//! — relative to the bench's working directory, which under
//! `cargo bench` is the crate root `rust/`; override with
//! `$BENCH_PARALLEL_OUT` (CI and `make bench-parallel` pin it to the
//! repo root). CI's `bench-smoke` job runs this at reduced scale and
//! gates on the 4-worker vs 1-worker geometric-mean speedup staying
//! ≥ 1.0.
//!
//! Scale knobs: `$PERF_PARALLEL_SAMPLES` (default 1024) and
//! `$PERF_PARALLEL_REPEATS` (default 3; wall times are best-of-N to
//! shave scheduler noise).
//!
//! Determinism note: every configuration computes bit-identical
//! results (see `coordinator::pool`); this bench only measures how
//! fast the fixed computation goes.

use restream::benchutil::{best_wall, env_usize, section};
use restream::config::apps;
use restream::coordinator::{init_conductances, Engine};
use restream::testing::Rng;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

struct OpResult {
    op: String,
    workers: usize,
    wall_s: f64,
    samples_per_s: f64,
}

fn print_shards(engine: &Engine) {
    let Some(rep) = engine.last_parallel_report() else {
        return;
    };
    println!(
        "    {} shards, busy {:.1} ms over wall {:.1} ms:",
        rep.shards.len(),
        rep.busy_s() * 1e3,
        rep.wall_s * 1e3
    );
    for s in rep.shards.iter().take(8) {
        println!(
            "      shard {:>3} [{:>6}..{:>6})  {:>9.2} ms",
            s.shard,
            s.range.0,
            s.range.1,
            s.wall_s * 1e3
        );
    }
    if rep.shards.len() > 8 {
        println!("      ... {} more shards", rep.shards.len() - 8);
    }
}

fn record(
    results: &mut Vec<OpResult>,
    op: &str,
    workers: usize,
    wall_s: f64,
    samples: usize,
) {
    let samples_per_s = samples as f64 / wall_s.max(1e-12);
    println!(
        "bench parallel/{op}/w{workers} {:>10.2} ms  {:>10.0} samples/s",
        wall_s * 1e3,
        samples_per_s
    );
    results.push(OpResult {
        op: op.to_string(),
        workers,
        wall_s,
        samples_per_s,
    });
}

/// Geometric mean over ops of (4-worker samples/s) / (1-worker
/// samples/s); 1.0 when no (1, 4) pair exists.
fn speedup_geomean_4v1(results: &[OpResult]) -> f64 {
    let mut ops: Vec<&str> = results.iter().map(|r| r.op.as_str()).collect();
    ops.dedup();
    let mut log_sum = 0.0f64;
    let mut n = 0usize;
    for op in ops {
        let at = |w: usize| {
            results
                .iter()
                .find(|r| r.op == op && r.workers == w)
                .map(|r| r.samples_per_s)
        };
        if let (Some(s1), Some(s4)) = (at(1), at(4)) {
            if s1 > 0.0 && s4 > 0.0 {
                log_sum += (s4 / s1).ln();
                n += 1;
            }
        }
    }
    if n == 0 {
        1.0
    } else {
        (log_sum / n as f64).exp()
    }
}

fn json_report(
    results: &[OpResult],
    samples: usize,
    repeats: usize,
    geomean: f64,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"perf_parallel\",\n  \"samples\": {samples},\n  \
         \"repeats\": {repeats},\n"
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"op\": \"{}\", \"workers\": {}, \"wall_s\": {:.6}, \
             \"samples_per_s\": {:.2}}}{sep}\n",
            r.op, r.workers, r.wall_s, r.samples_per_s
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"speedup_geomean_4v1\": {geomean:.4}\n"));
    s.push_str("}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("PERF_PARALLEL_SAMPLES", 1024).max(1);
    let repeats = env_usize("PERF_PARALLEL_REPEATS", 3).max(1);
    let mut results: Vec<OpResult> = Vec::new();
    println!(
        "perf_parallel: {samples} samples, best of {repeats}, workers {:?}",
        WORKER_COUNTS
    );

    section("sharded batched recognition (mnist_class, b=64)");
    {
        let net = apps::network("mnist_class").unwrap();
        let params = init_conductances(net.layers, 0);
        let mut rng = Rng::seeded(1);
        let xs: Vec<Vec<f32>> = (0..samples)
            .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
            .collect();
        for &w in &WORKER_COUNTS {
            let engine = Engine::native().with_workers(w);
            let wall = best_wall(repeats, || {
                engine.infer(net, &params, &xs).unwrap();
            });
            record(&mut results, "infer/mnist_class", w, wall, samples);
            if w == *WORKER_COUNTS.last().unwrap() {
                print_shards(&engine);
            }
        }
    }

    section("sharded k-means epochs (mnist_kmeans, 2 epochs)");
    {
        let app = apps::kmeans_app("mnist_kmeans").unwrap();
        // k-means tiles are light; use a bigger batch so shard work
        // dominates dispatch.
        let n = samples * 8;
        let epochs = 2usize;
        let mut rng = Rng::seeded(2);
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| rng.vec_uniform(app.dims, -0.5, 0.5))
            .collect();
        for &w in &WORKER_COUNTS {
            let engine = Engine::native().with_workers(w);
            let wall = best_wall(repeats, || {
                engine.kmeans(app, &xs, epochs, 3).unwrap();
            });
            record(
                &mut results,
                "kmeans/mnist_kmeans",
                w,
                wall,
                n * epochs,
            );
            if w == *WORKER_COUNTS.last().unwrap() {
                print_shards(&engine);
            }
        }
    }

    section("sharded anomaly scoring (kdd_ae)");
    {
        let net = apps::network("kdd_ae").unwrap();
        let params = init_conductances(net.layers, 4);
        let mut rng = Rng::seeded(5);
        let n = samples * 4;
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
            .collect();
        for &w in &WORKER_COUNTS {
            let engine = Engine::native().with_workers(w);
            let wall = best_wall(repeats, || {
                engine.anomaly_scores(net, &params, &xs).unwrap();
            });
            record(&mut results, "anomaly_scores/kdd_ae", w, wall, n);
            if w == *WORKER_COUNTS.last().unwrap() {
                print_shards(&engine);
            }
        }
    }

    let geomean = speedup_geomean_4v1(&results);
    section("summary");
    println!("speedup geomean (4 workers vs 1): {geomean:.2}x");
    let out_path = std::env::var("BENCH_PARALLEL_OUT")
        .unwrap_or_else(|_| "BENCH_parallel.json".to_string());
    std::fs::write(&out_path, json_report(&results, samples, repeats, geomean))?;
    println!("wrote {out_path}");
    Ok(())
}
