//! Paper Figs 22–25: speedup and energy efficiency of the chip vs the
//! Tesla K20 for training (22/23) and recognition (24/25).

use restream::config::SystemConfig;
use restream::report;

fn main() {
    let sys = SystemConfig::default();
    restream::benchutil::section(
        "Figs 22/23 — training speedup & energy efficiency vs K20",
    );
    print!("{}", report::vs_gpu_table(&sys, true));
    println!("(paper: up to 30x speedup; 1e4..1e6x energy efficiency)");

    restream::benchutil::section(
        "Figs 24/25 — recognition speedup & energy efficiency vs K20",
    );
    print!("{}", report::vs_gpu_table(&sys, false));
    println!("(paper: up to 50x speedup; 1e5..1e6x energy efficiency)");

    // headline assertions
    let train = report::vs_gpu(&sys, true);
    let recog = report::vs_gpu(&sys, false);
    let max_speedup_t = train.iter().map(|v| v.speedup).fold(0.0, f64::max);
    let max_speedup_r = recog.iter().map(|v| v.speedup).fold(0.0, f64::max);
    let max_eff = train
        .iter()
        .chain(&recog)
        .map(|v| v.energy_eff)
        .fold(0.0, f64::max);
    println!(
        "\nmax training speedup {max_speedup_t:.0}x, max recognition \
         speedup {max_speedup_r:.0}x, max energy efficiency {max_eff:.1e}x"
    );
    assert!(train.iter().all(|v| v.speedup > 1.0));
    assert!(recog.iter().all(|v| v.speedup > 1.0));
    assert!(max_eff > 1e4);
}
