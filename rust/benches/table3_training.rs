//! Paper Table III: per-input training cost (cores, time, compute/IO/
//! total energy) for every application, next to the paper's values.

use restream::config::SystemConfig;
use restream::{report, sim};

/// The paper's Table III rows: (app, cores, time us, compute J, io J,
/// total J). Apps are matched by our registry names.
const PAPER: &[(&str, usize, f64, f64, f64, f64)] = &[
    ("mnist_class", 57, 7.29, 4.18e-7, 8.48e-9, 4.26e-7),
    ("mnist_dr", 57, 17.99, 8.37e-7, 8.57e-9, 8.45e-7),
    ("isolet_dr", 132, 24.41, 1.97e-6, 2.68e-8, 1.99e-6),
    ("isolet_class", 132, 8.86, 9.67e-7, 2.67e-8, 9.94e-7),
    ("kdd_ae", 1, 4.15, 7.33e-9, 4.51e-9, 1.18e-8),
    ("mnist_kmeans", 1, 0.42, 9.67e-10, 4.47e-12, 9.71e-10),
    ("isolet_kmeans", 1, 0.42, 9.67e-10, 4.47e-12, 9.71e-10),
];

fn main() {
    restream::benchutil::section("Table III — training cost per input");
    let sys = SystemConfig::default();
    print!("{}", report::table3(&sys));
    println!("\npaper values for reference:");
    println!(
        "{:>14} {:>7} {:>10} {:>12} {:>10} {:>12}",
        "app", "#cores", "time(us)", "compute(J)", "IO(J)", "total(J)"
    );
    for (app, cores, t, c, io, tot) in PAPER {
        println!(
            "{app:>14} {cores:>7} {t:>10.2} {c:>12.2e} {io:>10.2e} {tot:>12.2e}"
        );
    }
    // shape assertions mirrored from the test suite
    let rows = sim::table3(&sys);
    let by = |n: &str| rows.iter().find(|r| r.app == n).unwrap();
    assert!(by("mnist_kmeans").time_s < by("kdd_ae").time_s);
    assert!(by("kdd_ae").time_s < by("mnist_class").time_s);
    assert!(by("isolet_class").total_j > by("mnist_class").total_j);
    println!("\nshape checks (ordering of rows, compute >> kmeans): OK");
}
