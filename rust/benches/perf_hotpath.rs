//! §Perf harness: wall-clock microbenchmarks of the L3 hot paths —
//! per-sample training step, batched recognition, the NoC scheduler, the
//! cost simulator, and the pure-Rust crossbar math. The before/after
//! numbers recorded in EXPERIMENTS.md §Perf come from this binary.

use restream::benchutil::{report, section, time};
use restream::config::{apps, SystemConfig};
use restream::coordinator::{init_conductances, Engine};
use restream::crossbar::ideal;
use restream::mapper::{map_network, place};
use restream::noc::Schedule;
use restream::runtime::ArrayF32;
use restream::testing::Rng;
use restream::{datasets, sim};

fn main() -> anyhow::Result<()> {
    let sys = SystemConfig::default();
    let engine = Engine::open_default()?;

    section("hot path: per-sample train step (PJRT execute + host I/O)");
    for app in ["iris_class", "kdd_ae", "mnist_class"] {
        let net = apps::network(app).unwrap();
        let exe = engine.rt.load(&net.train_artifact())?;
        let params = init_conductances(net.layers, 0);
        let dims = net.layers[0];
        let outs = net.layers[net.layers.len() - 1];
        let mut rng = Rng::seeded(0);
        let x = ArrayF32::row(rng.vec_uniform(dims, -0.5, 0.5));
        let t = ArrayF32::row(rng.vec_uniform(outs, -0.4, 0.4));
        let lr = ArrayF32::scalar(0.5);
        let mut current = params.clone();
        let timing = time(3, 30, || {
            let mut ins = current.clone();
            ins.push(x.clone());
            ins.push(t.clone());
            ins.push(lr.clone());
            let mut o = exe.run(&ins).unwrap();
            o.pop();
            current = o;
        });
        report(&format!("train_step/{app}"), &timing);
    }

    section("hot path: chunked train (scan c=32, per-sample amortised)");
    for app in ["iris_class", "kdd_ae", "mnist_class"] {
        let net = apps::network(app).unwrap();
        let name = format!("{}_trainchunk_c{}", net.name, apps::TRAIN_CHUNK);
        let exe = engine.rt.load(&name)?;
        let params = init_conductances(net.layers, 0);
        let dims = net.layers[0];
        let outs = net.layers[net.layers.len() - 1];
        let k = apps::TRAIN_CHUNK;
        let mut rng = Rng::seeded(0);
        let xs = ArrayF32::matrix(k, dims, rng.vec_uniform(k * dims, -0.5, 0.5))
            .unwrap();
        let ts = ArrayF32::matrix(k, outs, rng.vec_uniform(k * outs, -0.4, 0.4))
            .unwrap();
        let lr = ArrayF32::scalar(0.5);
        let mut current = params.clone();
        let timing = time(2, 15, || {
            let mut ins = current.clone();
            ins.push(xs.clone());
            ins.push(ts.clone());
            ins.push(lr.clone());
            let mut o = exe.run(&ins).unwrap();
            o.pop();
            current = o;
        });
        report(&format!("train_chunk/{app}"), &timing);
        println!(
            "    -> {:.1} us/sample amortised ({}x chunk)",
            timing.per_iter_us() / k as f64,
            k
        );
    }

    section("hot path: batched recognition (b=64)");
    for app in ["kdd_ae", "mnist_class", "isolet_class"] {
        let net = apps::network(app).unwrap();
        let params = init_conductances(net.layers, 0);
        let ds = datasets::class_blobs("b", net.layers[0], 2, 64, 0.3, 0);
        let xs = ds.rows();
        let timing = time(2, 10, || {
            engine.infer(net, &params, &xs).unwrap();
        });
        report(&format!("infer_b64/{app}"), &timing);
        println!(
            "    -> {:.0} samples/s",
            64.0 / timing.mean_s
        );
    }

    section("architecture model: mapper + placement + schedule");
    for app in ["mnist_class", "isolet_class"] {
        let net = apps::network(app).unwrap();
        let timing = time(3, 50, || {
            let map = map_network(net, &sys).unwrap();
            for stage in &map.stages {
                let p = place(stage, &sys);
                let s = Schedule::build(&p.fwd_transfers, sys.link_bits);
                std::hint::black_box(s.makespan_slots());
            }
        });
        report(&format!("map_place_schedule/{app}"), &timing);
    }
    let timing = time(3, 50, || {
        std::hint::black_box(sim::table3(&sys));
        std::hint::black_box(sim::table4(&sys));
    });
    report("sim/tables_3_and_4", &timing);

    section("pure-Rust crossbar math (oracle path)");
    let mut rng = Rng::seeded(1);
    let (b, n_in, n_out) = (1usize, 785usize, 300usize);
    let x = rng.vec_uniform(b * n_in, -0.5, 0.5);
    let gp = rng.vec_uniform(n_in * n_out, 0.001, 1.0);
    let gn = rng.vec_uniform(n_in * n_out, 0.001, 1.0);
    let timing = time(3, 50, || {
        std::hint::black_box(ideal::fwd(&x, &gp, &gn, b, n_in, n_out, 3));
    });
    report("ideal_fwd/785x300", &timing);
    let delta = rng.vec_uniform(b * n_out, -1.0, 1.0);
    let timing = time(3, 50, || {
        std::hint::black_box(ideal::bwd(&delta, &gp, &gn, b, n_in, n_out));
    });
    report("ideal_bwd/785x300", &timing);

    Ok(())
}
