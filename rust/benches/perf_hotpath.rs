//! §Perf harness: wall-clock microbenchmarks of the L3 hot paths —
//! per-sample training step, chunked training, batched recognition, the
//! NoC scheduler, the cost simulator, and the raw crossbar math. The
//! before/after numbers recorded in EXPERIMENTS.md §Perf come from this
//! binary.
//!
//! Runs on whichever backend `RESTREAM_BACKEND` selects (default:
//! native, so no artifacts are needed); with `--features pjrt` plus
//! `make artifacts` the same harness times the PJRT artifact path for a
//! direct comparison.

use restream::benchutil::{report, section, time};
use restream::config::{apps, SystemConfig};
use restream::coordinator::{init_conductances, Engine};
use restream::crossbar::ideal;
use restream::mapper::{map_network, place};
use restream::noc::Schedule;
use restream::runtime::ArrayF32;
use restream::testing::Rng;
use restream::{datasets, sim};

fn main() -> anyhow::Result<()> {
    let sys = SystemConfig::default();
    let engine = Engine::open_default()?;
    let backend = engine.backend();
    println!("backend: {}", backend.name());

    section("hot path: per-sample train step (backend dispatch + math)");
    for app in ["iris_class", "kdd_ae", "mnist_class"] {
        let net = apps::network(app).unwrap();
        let graph = net.train_artifact();
        let dims = net.layers[0];
        let outs = net.layers[net.layers.len() - 1];
        let mut rng = Rng::seeded(0);
        let x = ArrayF32::row(rng.vec_uniform(dims, -0.5, 0.5));
        let t = ArrayF32::row(rng.vec_uniform(outs, -0.4, 0.4));
        let mut current = init_conductances(net.layers, 0);
        let timing = time(3, 30, || {
            let params = std::mem::take(&mut current);
            let (next, _) =
                backend.train_step(&graph, params, &x, &t, 0.5).unwrap();
            current = next;
        });
        report(&format!("train_step/{app}"), &timing);
    }

    section("hot path: chunked train (per-sample scan, amortised)");
    for app in ["iris_class", "kdd_ae", "mnist_class"] {
        let net = apps::network(app).unwrap();
        let chunk_graph =
            format!("{}_trainchunk_c{}", net.name, apps::TRAIN_CHUNK);
        let k = backend.chunk_size(&chunk_graph);
        if k == 0 {
            println!("  (backend offers no chunked variant of {app})");
            continue;
        }
        let dims = net.layers[0];
        let outs = net.layers[net.layers.len() - 1];
        let mut rng = Rng::seeded(0);
        let xs =
            ArrayF32::matrix(k, dims, rng.vec_uniform(k * dims, -0.5, 0.5))
                .unwrap();
        let ts =
            ArrayF32::matrix(k, outs, rng.vec_uniform(k * outs, -0.4, 0.4))
                .unwrap();
        let mut current = init_conductances(net.layers, 0);
        let timing = time(2, 15, || {
            let params = std::mem::take(&mut current);
            let (next, _) = backend
                .train_chunk(&chunk_graph, params, &xs, &ts, 0.5)
                .unwrap();
            current = next;
        });
        report(&format!("train_chunk/{app}"), &timing);
        println!(
            "    -> {:.1} us/sample amortised ({}x chunk)",
            timing.per_iter_us() / k as f64,
            k
        );
    }

    section("hot path: batched recognition (b=64)");
    for app in ["kdd_ae", "mnist_class", "isolet_class"] {
        let net = apps::network(app).unwrap();
        let params = init_conductances(net.layers, 0);
        let ds = datasets::class_blobs("b", net.layers[0], 2, 64, 0.3, 0);
        let xs = ds.rows();
        let timing = time(2, 10, || {
            engine.infer(net, &params, &xs).unwrap();
        });
        report(&format!("infer_b64/{app}"), &timing);
        println!("    -> {:.0} samples/s", 64.0 / timing.mean_s);
    }

    section("architecture model: mapper + placement + schedule");
    for app in ["mnist_class", "isolet_class"] {
        let net = apps::network(app).unwrap();
        let timing = time(3, 50, || {
            let map = map_network(net, &sys).unwrap();
            for stage in &map.stages {
                let p = place(stage, &sys);
                let s = Schedule::build(&p.fwd_transfers, sys.link_bits);
                std::hint::black_box(s.makespan_slots());
            }
        });
        report(&format!("map_place_schedule/{app}"), &timing);
    }
    let timing = time(3, 50, || {
        std::hint::black_box(sim::table3(&sys));
        std::hint::black_box(sim::table4(&sys));
    });
    report("sim/tables_3_and_4", &timing);

    section("raw crossbar math (kernel level)");
    let mut rng = Rng::seeded(1);
    let (n_in, n_out) = (785usize, 300usize);
    let gp = rng.vec_uniform(n_in * n_out, 0.001, 1.0);
    let gn = rng.vec_uniform(n_in * n_out, 0.001, 1.0);
    for b in [1usize, 64] {
        let x = rng.vec_uniform(b * n_in, -0.5, 0.5);
        let timing = time(3, 50, || {
            std::hint::black_box(ideal::fwd(&x, &gp, &gn, b, n_in, n_out, 3));
        });
        report(&format!("ideal_fwd/785x300/b{b}"), &timing);
        if b > 1 {
            println!(
                "    -> {:.2} us/sample batched",
                timing.per_iter_us() / b as f64
            );
        }
    }
    let delta = rng.vec_uniform(n_out, -1.0, 1.0);
    let timing = time(3, 50, || {
        std::hint::black_box(ideal::bwd(&delta, &gp, &gn, 1, n_in, n_out));
    });
    report("ideal_bwd/785x300", &timing);

    Ok(())
}
