//! §Perf: data-parallel scaling of mini-batch training.
//!
//! Measures training throughput (samples/s) of the sharded gradient
//! path — `Engine::train_with` on the mnist_class stack — at 1/2/4/8
//! workers on the native backend, prints the per-shard busy profile,
//! and writes the machine-readable trajectory to `BENCH_train.json` —
//! relative to the bench's working directory, which under `cargo bench`
//! is the crate root `rust/`; override with `$BENCH_TRAIN_OUT` (CI and
//! `make bench-train` pin it to the repo root). CI's `bench-smoke` job
//! runs this at reduced scale and gates on the 4-worker vs 1-worker
//! speedup staying ≥ 1.0.
//!
//! Scale knobs: `$PERF_TRAIN_SAMPLES` (default 256),
//! `$PERF_TRAIN_BATCH` (default 64) and `$PERF_TRAIN_REPEATS`
//! (default 3; wall times are best-of-N to shave scheduler noise).
//!
//! Determinism note: every configuration trains bit-identical
//! conductances (see `tests/train_determinism.rs`); this bench only
//! measures how fast the fixed computation goes.

use restream::benchutil::{best_wall, env_usize, section};
use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions, TrainReport};
use restream::testing::Rng;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

struct TrainResult {
    workers: usize,
    wall_s: f64,
    samples_per_s: f64,
}

fn print_shards(rep: &TrainReport) {
    if rep.shard_busy_s.is_empty() {
        return;
    }
    println!(
        "    grad phase {:.1} ms + apply {:.1} ms over {} shards/batch:",
        rep.grad_wall_s * 1e3,
        rep.apply_wall_s * 1e3,
        rep.shard_busy_s.len()
    );
    for (s, busy) in rep.shard_busy_s.iter().enumerate().take(8) {
        println!("      shard {s:>3}  busy {:>9.2} ms", busy * 1e3);
    }
    if rep.shard_busy_s.len() > 8 {
        println!("      ... {} more shards", rep.shard_busy_s.len() - 8);
    }
}

/// (4-worker samples/s) / (1-worker samples/s); 1.0 when either is
/// missing.
fn speedup_4v1(results: &[TrainResult]) -> f64 {
    let at = |w: usize| {
        results
            .iter()
            .find(|r| r.workers == w)
            .map(|r| r.samples_per_s)
    };
    match (at(1), at(4)) {
        (Some(s1), Some(s4)) if s1 > 0.0 => s4 / s1,
        _ => 1.0,
    }
}

fn json_report(
    results: &[TrainResult],
    samples: usize,
    batch: usize,
    repeats: usize,
    speedup: f64,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"perf_train\",\n  \"app\": \"mnist_class\",\n  \
         \"samples\": {samples},\n  \"batch\": {batch},\n  \
         \"repeats\": {repeats},\n"
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"op\": \"train/mnist_class\", \"workers\": {}, \
             \"wall_s\": {:.6}, \"samples_per_s\": {:.2}}}{sep}\n",
            r.workers, r.wall_s, r.samples_per_s
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"speedup_4v1\": {speedup:.4}\n"));
    s.push_str("}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("PERF_TRAIN_SAMPLES", 256).max(1);
    let batch = env_usize("PERF_TRAIN_BATCH", 64).max(2);
    let repeats = env_usize("PERF_TRAIN_REPEATS", 3).max(1);
    let mut results: Vec<TrainResult> = Vec::new();
    println!(
        "perf_train: {samples} samples, mini-batch {batch}, best of \
         {repeats}, workers {:?}",
        WORKER_COUNTS
    );

    section("sharded mini-batch training (mnist_class)");
    let net = apps::network("mnist_class").unwrap();
    let mut rng = Rng::seeded(1);
    let xs: Vec<Vec<f32>> = (0..samples)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    let ts: Vec<Vec<f32>> =
        (0..samples).map(|_| rng.vec_uniform(10, -0.4, 0.4)).collect();
    for &w in &WORKER_COUNTS {
        let engine = Engine::native().with_workers(w);
        let mut last_report: Option<TrainReport> = None;
        let wall = best_wall(repeats, || {
            let ts = ts.clone();
            let run = engine
                .fit(net, &xs, move |i| ts[i].clone(), 1, 0.3, 7,
                     &TrainOptions::new().batch(batch))
                .unwrap();
            last_report = run.reports.into_iter().next_back();
        });
        let samples_per_s = samples as f64 / wall.max(1e-12);
        println!(
            "bench train/mnist_class/w{w} {:>10.2} ms  {:>10.0} samples/s",
            wall * 1e3,
            samples_per_s
        );
        results.push(TrainResult { workers: w, wall_s: wall, samples_per_s });
        if w == *WORKER_COUNTS.last().unwrap() {
            if let Some(rep) = &last_report {
                print_shards(rep);
            }
        }
    }

    let speedup = speedup_4v1(&results);
    section("summary");
    println!("4-worker vs 1-worker training speedup: {speedup:.2}x");
    let out_path = std::env::var("BENCH_TRAIN_OUT")
        .unwrap_or_else(|_| "BENCH_train.json".to_string());
    std::fs::write(
        &out_path,
        json_report(&results, samples, batch, repeats, speedup),
    )?;
    println!("wrote {out_path}");
    Ok(())
}
