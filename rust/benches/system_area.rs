//! Paper section VI.F: total system area budget, plus the per-app core
//! demand that justifies the 144-core provisioning.

use restream::config::{apps, SystemConfig};
use restream::mapper::map_network;
use restream::report;

fn main() {
    restream::benchutil::section("section VI.F — system area budget");
    let sys = SystemConfig::default();
    print!("{}", report::chip_summary(&sys));

    restream::benchutil::section("per-application core demand");
    println!("{:>14} {:>8} {:>8}", "app", "#cores", "stages");
    for net in apps::NETWORKS {
        let map = map_network(net, &sys).unwrap();
        println!(
            "{:>14} {:>8} {:>8}",
            net.name,
            map.cores_used(),
            map.stages.len()
        );
        assert!(map.cores_used() <= sys.neural_cores);
    }
    println!(
        "\nlargest app fits the {}-core chip (paper: 132 of 144 used by \
         ISOLET)",
        sys.neural_cores
    );
}
