//! Paper Fig 16: learning curve for on-chip supervised training on the
//! Iris dataset (4-10-1 network) through the full artifact path.

use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions};
use restream::{datasets, metrics};

fn main() -> anyhow::Result<()> {
    restream::benchutil::section("Fig 16 — Iris supervised learning curve");
    let net = apps::network("iris_class").unwrap();
    let engine = Engine::open_default()?;
    let ds = datasets::iris(0);
    let (train, test) = ds.split(0.8, 0);
    let xs = train.rows();
    let run = engine.fit(
        net, &xs, |i| train.target(i, 1), 30, 1.0, 0,
        &TrainOptions::new(),
    )?;
    let (params, rep) = (&run.params, run.last_report().unwrap());
    println!("{:>6} {:>10}", "epoch", "MSE loss");
    for (e, l) in rep.loss_curve.iter().enumerate() {
        println!("{e:>6} {l:>10.5}");
    }
    let preds = engine.classify(net, params, &test.rows())?;
    let truth: Vec<usize> = test.y.iter().map(|&y| y.min(1)).collect();
    println!(
        "\nfinal loss {:.4} (from {:.4}); test accuracy {:.3}",
        rep.loss_curve.last().unwrap(),
        rep.loss_curve[0],
        metrics::accuracy(&preds, &truth)
    );
    println!("(paper: error converges over training iterations)");
    Ok(())
}
