//! §Perf: multi-tenant serving — many resident apps on one chip vs
//! dedicated single-app servers.
//!
//! Sweeps resident sets of growing size (prefixes of the app list)
//! and, for each set, measures:
//!
//! * **multi** — every app served concurrently from one shared
//!   `chip::ChipScheduler` (per-app queues + batchers, deficit-round-
//!   robin dispatch onto one engine), `--clients`-per-app closed-loop
//!   load;
//! * **dedicated** — the same apps served one after another, each from
//!   its own dedicated `serve::Server` under the identical load; the
//!   baseline throughput divides total requests by the *sum* of the
//!   dedicated walls (N sequential single-app servers).
//!
//! Batching makes co-residency nearly free: the shared dispatcher
//! executes the same batches the dedicated servers would, just
//! interleaved, so aggregate multi-tenant throughput should stay close
//! to the dedicated aggregate. CI's bench-smoke job runs this at
//! reduced scale and fails when the full-set ratio drops below 0.8x.
//! A final forced-swap row serves the full set on a deliberately tiny
//! chip (4 cores) to price the reconfiguration path.
//!
//! Writes the machine-readable summary to `BENCH_multiapp.json`
//! (override with `$BENCH_MULTIAPP_OUT`; CI and `make bench-multiapp`
//! pin it to the repo root). Scale knobs: `$PERF_MULTIAPP_REQUESTS`
//! (per client, default 128) and `$PERF_MULTIAPP_CLIENTS` (per app,
//! default 4).
//!
//! Determinism note: per-app results are bit-identical to a dedicated
//! server in every configuration (`tests/multiapp_determinism.rs`);
//! this bench only measures how fast the answers come back.

use std::time::Instant;

use restream::chip::{ChipApp, ChipConfig, ChipScheduler};
use restream::config::apps;
use restream::coordinator::{init_conductances, Engine};
use restream::serve::{Client, ServeConfig, Server};
use restream::testing::Rng;

use restream::benchutil::{env_usize, section};

const APPS: [&str; 3] = ["iris_ae", "kdd_ae", "iris_class"];

struct Row {
    n_apps: usize,
    apps: Vec<String>,
    multi_rps: f64,
    dedicated_rps: f64,
    ratio: f64,
    occupancy_pct: f64,
    swaps: usize,
    reconfig_total_us: f64,
}

/// Deterministic per-app request pool.
fn pool_for(dims: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seeded(0xBEEF ^ (seed << 8));
    (0..256).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

/// Hammer one submission handle from `clients` closed-loop threads
/// (`requests` each) and return the load-generator wall (s).
fn drive(client_proto: &Client, pool: &[Vec<f32>], clients: usize,
         requests: usize) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = client_proto.clone();
            let rows: Vec<Vec<f32>> = (0..requests)
                .map(|r| pool[(c * 131 + r) % pool.len()].clone())
                .collect();
            std::thread::spawn(move || {
                for x in rows {
                    client.call(x).expect("bench request failed");
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("bench client thread panicked");
    }
    t0.elapsed().as_secs_f64()
}

fn chip_app(name: &str) -> ChipApp {
    let net = apps::network(name).unwrap().clone();
    let params = init_conductances(net.layers, 0);
    ChipApp { net, params }
}

/// N dedicated single-app servers, run one after another; returns the
/// summed wall (s) over `set`.
fn dedicated_wall(set: &[&str], pools: &[Vec<Vec<f32>>], clients: usize,
                  requests: usize) -> f64 {
    let mut total = 0.0;
    for (a, name) in set.iter().enumerate() {
        let app = chip_app(name);
        let server = Server::start(
            Engine::native(),
            app.net,
            app.params,
            ServeConfig::default(),
        );
        total += drive(&server.client(), &pools[a], clients, requests);
        server.shutdown();
    }
    total
}

/// One shared scheduler hosting the whole set, all apps loaded
/// concurrently; returns (wall, occupancy %, swaps, reconfig s).
fn multi_wall(set: &[&str], pools: &[Vec<Vec<f32>>], clients: usize,
              requests: usize, cfg: ChipConfig)
    -> (f64, f64, usize, f64) {
    let hosted: Vec<ChipApp> = set.iter().map(|n| chip_app(n)).collect();
    let chip = ChipScheduler::start(Engine::native(), hosted, cfg)
        .expect("chip scheduler failed to start");
    let t0 = Instant::now();
    let handles: Vec<_> = set
        .iter()
        .enumerate()
        .map(|(a, name)| {
            let client = chip.client(name).unwrap();
            let pool = pools[a].clone();
            std::thread::spawn(move || {
                drive(&client, &pool, clients, requests);
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("bench app-load thread panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = chip.shutdown();
    (wall, report.occupancy_pct, report.swaps, report.reconfig_total_s)
}

fn json_row(r: &Row) -> String {
    let names: Vec<String> =
        r.apps.iter().map(|a| format!("\"{a}\"")).collect();
    format!(
        "{{\"n_apps\": {}, \"apps\": [{}], \"multi_rps\": {:.2}, \
         \"dedicated_rps\": {:.2}, \"ratio\": {:.4}, \
         \"occupancy_pct\": {:.2}, \"swaps\": {}, \
         \"reconfig_total_us\": {:.2}}}",
        r.n_apps,
        names.join(", "),
        r.multi_rps,
        r.dedicated_rps,
        r.ratio,
        r.occupancy_pct,
        r.swaps,
        r.reconfig_total_us
    )
}

fn main() -> anyhow::Result<()> {
    let requests = env_usize("PERF_MULTIAPP_REQUESTS", 128).max(1);
    let clients = env_usize("PERF_MULTIAPP_CLIENTS", 4).max(1);
    let pools: Vec<Vec<Vec<f32>>> = APPS
        .iter()
        .enumerate()
        .map(|(a, name)| {
            let dims = apps::network(name).unwrap().layers[0];
            pool_for(dims, a as u64)
        })
        .collect();
    println!(
        "perf_multiapp: apps {APPS:?}, {clients} clients/app, \
         {requests} requests/client"
    );

    section("resident-set sweep (shared chip vs dedicated servers)");
    let mut rows = Vec::new();
    for n in 1..=APPS.len() {
        let set: Vec<&str> = APPS[..n].to_vec();
        let total_requests = (n * clients * requests) as f64;
        let ded_wall = dedicated_wall(&set, &pools, clients, requests);
        let (wall, occupancy_pct, swaps, reconfig_s) = multi_wall(
            &set,
            &pools,
            clients,
            requests,
            ChipConfig::default(),
        );
        let row = Row {
            n_apps: n,
            apps: set.iter().map(|s| s.to_string()).collect(),
            multi_rps: total_requests / wall.max(1e-12),
            dedicated_rps: total_requests / ded_wall.max(1e-12),
            ratio: ded_wall / wall.max(1e-12),
            occupancy_pct,
            swaps,
            reconfig_total_us: reconfig_s * 1e6,
        };
        println!(
            "bench multiapp/n{}  multi {:>9.0} req/s  dedicated \
             {:>9.0} req/s  ratio {:.2}x  occupancy {:>5.1}%  \
             {} swaps",
            row.n_apps,
            row.multi_rps,
            row.dedicated_rps,
            row.ratio,
            row.occupancy_pct,
            row.swaps
        );
        rows.push(row);
    }

    section("forced swapping (full set on a 4-core chip)");
    let set: Vec<&str> = APPS.to_vec();
    let tiny = ChipConfig {
        sys: restream::config::SystemConfig {
            neural_cores: 4,
            ..Default::default()
        },
        ..ChipConfig::default()
    };
    let (wall, _, swaps, reconfig_s) =
        multi_wall(&set, &pools, clients, requests, tiny);
    let swap_rps = (set.len() * clients * requests) as f64
        / wall.max(1e-12);
    println!(
        "bench multiapp/swap4  {swap_rps:>9.0} req/s  {swaps} swaps  \
         reconfig charged {:.1} us",
        reconfig_s * 1e6
    );

    section("summary");
    let full = rows.last().expect("at least one set");
    println!(
        "{}-resident aggregate vs {} dedicated sequential servers: \
         {:.2}x",
        full.n_apps, full.n_apps, full.ratio
    );
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str(&format!(
        "  \"bench\": \"perf_multiapp\",\n  \
         \"requests_per_client\": {requests},\n  \
         \"clients_per_app\": {clients},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!("    {}{sep}\n", json_row(r)));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"swap_demo\": {{\"chip_cores\": 4, \"rps\": {swap_rps:.2}, \
         \"swaps\": {swaps}, \"reconfig_total_us\": {:.2}}},\n",
        reconfig_s * 1e6
    ));
    json.push_str(&format!(
        "  \"n_apps_full\": {},\n  \"ratio_full_set\": {:.4}\n",
        full.n_apps, full.ratio
    ));
    json.push_str("}\n");
    let out_path = std::env::var("BENCH_MULTIAPP_OUT")
        .unwrap_or_else(|_| "BENCH_multiapp.json".to_string());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}
