//! Paper Fig 21: impact of the memristor system constraints (3-bit
//! neuron outputs, 8-bit errors, ≤400 synapses/neuron) on application
//! accuracy — constrained chip numerics vs unconstrained float software,
//! trained identically (pure-Rust paths, same seeds and sample order).
//!
//! Networks are scaled-down versions of the Table I configurations so
//! the sweep completes in bench time; the *delta* between bars is the
//! experiment, exactly as in the paper.

use restream::datasets;
use restream::nn::{Constraint, Mlp};
use restream::testing::Rng;

struct Row {
    app: &'static str,
    unconstrained: f64,
    constrained: f64,
}

fn train_pair(
    layers: &[usize],
    xs: &[Vec<f32>],
    ts: &[Vec<f32>],
    ys: &[usize],
    epochs: usize,
    lr: f32,
) -> (f64, f64) {
    let order: Vec<usize> = (0..xs.len()).collect();
    let mut accs = [0.0f64; 2];
    for (k, c) in [Constraint::None, Constraint::Chip].iter().enumerate() {
        let mut rng = Rng::seeded(11);
        let mut net = Mlp::init(layers, *c, &mut rng);
        for _ in 0..epochs {
            net.train_epoch(xs, ts, lr, &order);
        }
        accs[k] = net.accuracy(xs, ys);
    }
    (accs[0], accs[1])
}

fn main() {
    restream::benchutil::section(
        "Fig 21 — accuracy with vs without hardware constraints",
    );
    let mut rows = Vec::new();

    // MNIST-shaped classification (reduced: 784->64->10, 400 samples)
    {
        let ds = datasets::mnist(400, 0);
        let xs = ds.rows();
        let ts: Vec<Vec<f32>> = (0..ds.len()).map(|i| ds.target(i, 10)).collect();
        let (u, c) = train_pair(&[784, 64, 10], &xs, &ts, &ds.y, 4, 0.5);
        rows.push(Row { app: "MNIST class", unconstrained: u, constrained: c });
    }
    // ISOLET-shaped classification (reduced: 617->64->26, 390 samples)
    {
        let ds = datasets::isolet(390, 0);
        let xs = ds.rows();
        let ts: Vec<Vec<f32>> = (0..ds.len()).map(|i| ds.target(i, 26)).collect();
        let (u, c) = train_pair(&[617, 64, 26], &xs, &ts, &ds.y, 4, 0.5);
        rows.push(Row { app: "ISOLET class", unconstrained: u, constrained: c });
    }
    // Iris (the paper's circuit-level demo, full size)
    {
        let ds = datasets::iris(0);
        let xs = ds.rows();
        let ys: Vec<usize> = ds.y.iter().map(|&y| y.min(1)).collect();
        let ts: Vec<Vec<f32>> = ys
            .iter()
            .map(|&y| vec![if y == 1 { 0.4 } else { -0.4 }])
            .collect();
        let (u, c) = train_pair(&[4, 10, 1], &xs, &ts, &ys, 15, 1.0);
        rows.push(Row { app: "Iris class", unconstrained: u, constrained: c });
    }
    // KDD anomaly (AUC-like proxy via separation accuracy at the best
    // threshold over the chip-constrained AE vs float AE)
    {
        use restream::metrics;
        let k = datasets::kdd(800, 250, 250, 0);
        let xs = k.train.rows();
        let order: Vec<usize> = (0..xs.len()).collect();
        let mut aucs = [0.0f64; 2];
        for (i, c) in [Constraint::None, Constraint::Chip].iter().enumerate() {
            let mut rng = Rng::seeded(5);
            let mut net = Mlp::init(&[41, 15, 41], *c, &mut rng);
            let ts: Vec<Vec<f32>> = xs
                .iter()
                .map(|x| x.iter().map(|v| v.clamp(-0.5, 0.5)).collect())
                .collect();
            for _ in 0..3 {
                net.train_epoch(&xs, &ts, 0.8, &order);
            }
            let scores: Vec<f64> = (0..k.test.len())
                .map(|s| {
                    let x = k.test.sample(s);
                    let r = net.forward(x);
                    x.iter()
                        .zip(&r)
                        .map(|(a, b)| (a.clamp(-0.5, 0.5) - b).abs() as f64)
                        .sum()
                })
                .collect();
            aucs[i] = metrics::auc(&metrics::roc_sweep(&scores, &k.test_attack, 100));
        }
        rows.push(Row { app: "KDD anomaly (AUC)", unconstrained: aucs[0], constrained: aucs[1] });
    }

    println!("{:>20} {:>14} {:>12} {:>8}", "app", "unconstrained",
             "constrained", "delta");
    for r in &rows {
        println!(
            "{:>20} {:>14.3} {:>12.3} {:>8.3}",
            r.app,
            r.unconstrained,
            r.constrained,
            r.unconstrained - r.constrained
        );
    }
    println!(
        "\n(paper: constrained implementations \"still give competitive \
         performances\" — deltas small)"
    );
}
