//! §Perf: multi-chip cluster scaling — one hot app replicated across a
//! growing fleet of chips behind the cluster router.
//!
//! The paper's throughput story is per chip; serving recognition
//! traffic from millions of users takes a fleet. This bench asks the
//! only question the cluster layer adds: **does replicating a hot app
//! across N chips buy ~N× aggregate throughput?** For each fleet size
//! in {1, 2, 4} it
//!
//! * hosts the hot app (`mnist_class`, the heaviest recognition
//!   network) replicated fleet-wide, one single-worker engine per chip
//!   so fleet size — not engine parallelism — is the variable;
//! * hammers the cluster router with `--clients` closed-loop threads
//!   (`requests` each) through `ClusterClient`'s least-loaded routing;
//! * records aggregate req/s, the best of `$PERF_CLUSTER_REPEATS`
//!   fresh-cluster runs.
//!
//! Routing is the only addition over a dedicated chip, so throughput
//! should scale near-linearly while per-request results stay
//! bit-identical to a dedicated server (`tests/cluster_determinism.rs`
//! pins that; this bench only measures speed). CI's bench-smoke job
//! runs this at reduced scale and fails when the 4-chip fleet does not
//! reach at least 2× the 1-chip throughput.
//!
//! Writes the machine-readable summary to `BENCH_cluster.json`
//! (override with `$BENCH_CLUSTER_OUT`; CI and `make bench-cluster`
//! pin it to the repo root). Scale knobs: `$PERF_CLUSTER_REQUESTS`
//! (per client, default 64), `$PERF_CLUSTER_CLIENTS` (default 8) and
//! `$PERF_CLUSTER_REPEATS` (default 3).

use std::time::Instant;

use restream::cluster::{Cluster, ClusterApp, ClusterConfig};
use restream::config::apps;
use restream::coordinator::{init_conductances, Engine};
use restream::testing::Rng;

use restream::benchutil::{env_usize, section};

/// The replicated hot app: the deepest recognition network keeps the
/// chips compute-bound, so routing overhead cannot hide the scaling.
const HOT_APP: &str = "mnist_class";

/// Fleet sizes swept (the CI gate compares the last to the first).
const FLEETS: [usize; 3] = [1, 2, 4];

struct Row {
    chips: usize,
    rps: f64,
    wall_s: f64,
    routed: Vec<u64>,
}

/// Deterministic request pool shared by every fleet size.
fn request_pool(dims: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::seeded(0xC1057E4);
    (0..256).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

/// Start a fresh `chips`-wide fleet hosting the hot app replicated on
/// every chip, drive it closed-loop, and return (wall s, routed/chip).
fn drive_fleet(
    chips: usize,
    pool: &[Vec<f32>],
    clients: usize,
    requests: usize,
) -> (f64, Vec<u64>) {
    let net = apps::network(HOT_APP).unwrap().clone();
    let params = init_conductances(net.layers, 0);
    let cluster = Cluster::start(
        vec![ClusterApp::new(net, params).replicated(chips)],
        ClusterConfig { chips, ..ClusterConfig::default() },
        |_chip| Ok(Engine::native().with_workers(1)),
    )
    .expect("cluster failed to start");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = cluster.client(HOT_APP).unwrap();
            let rows: Vec<Vec<f32>> = (0..requests)
                .map(|r| pool[(c * 131 + r) % pool.len()].clone())
                .collect();
            std::thread::spawn(move || {
                for x in rows {
                    client.call(x).expect("bench request failed");
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("bench client thread panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = cluster.shutdown();
    let routed = report.chips.iter().map(|c| c.routed).collect();
    (wall, routed)
}

fn json_row(r: &Row) -> String {
    let routed: Vec<String> =
        r.routed.iter().map(|n| n.to_string()).collect();
    format!(
        "{{\"chips\": {}, \"rps\": {:.2}, \"wall_s\": {:.4}, \
         \"routed\": [{}]}}",
        r.chips,
        r.rps,
        r.wall_s,
        routed.join(", ")
    )
}

fn main() -> anyhow::Result<()> {
    let requests = env_usize("PERF_CLUSTER_REQUESTS", 64).max(1);
    let clients = env_usize("PERF_CLUSTER_CLIENTS", 8).max(1);
    let repeats = env_usize("PERF_CLUSTER_REPEATS", 3).max(1);
    let dims = apps::network(HOT_APP).unwrap().layers[0];
    let pool = request_pool(dims);
    let total = (clients * requests) as f64;
    println!(
        "perf_cluster: hot app {HOT_APP}, {clients} clients x \
         {requests} requests, best of {repeats}"
    );

    section("fleet sweep (hot app replicated fleet-wide)");
    let mut rows = Vec::new();
    for &chips in &FLEETS {
        let mut best_wall = f64::INFINITY;
        let mut best_routed = Vec::new();
        for _ in 0..repeats {
            let (wall, routed) =
                drive_fleet(chips, &pool, clients, requests);
            if wall < best_wall {
                best_wall = wall;
                best_routed = routed;
            }
        }
        let row = Row {
            chips,
            rps: total / best_wall.max(1e-12),
            wall_s: best_wall,
            routed: best_routed,
        };
        println!(
            "bench cluster/chips{}  {:>9.0} req/s  wall {:.3}s  \
             routed {:?}",
            row.chips, row.rps, row.wall_s, row.routed
        );
        rows.push(row);
    }

    section("summary");
    let base = &rows[0];
    let top = rows.last().expect("at least one fleet size");
    let speedup = top.rps / base.rps.max(1e-12);
    println!(
        "{}-chip fleet vs 1 chip: {:.2}x aggregate throughput",
        top.chips, speedup
    );
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str(&format!(
        "  \"bench\": \"perf_cluster\",\n  \
         \"hot_app\": \"{HOT_APP}\",\n  \
         \"requests_per_client\": {requests},\n  \
         \"clients\": {clients},\n  \
         \"repeats\": {repeats},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!("    {}{sep}\n", json_row(r)));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"rps_1chip\": {:.2},\n  \"rps_4chip\": {:.2},\n  \
         \"speedup_4v1\": {:.4}\n",
        base.rps, top.rps, speedup
    ));
    json.push_str("}\n");
    let out_path = std::env::var("BENCH_CLUSTER_OUT")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}
