//! Paper Table IV: per-input recognition cost for every application,
//! next to the paper's values.

use restream::config::SystemConfig;
use restream::{report, sim};

/// Paper Table IV rows: (app, time us, compute J, io J, total J).
const PAPER: &[(&str, f64, f64, f64, f64)] = &[
    ("mnist_class", 0.77, 1.42e-8, 8.43e-9, 2.26e-8),
    ("mnist_dr", 0.77, 1.42e-8, 8.43e-9, 2.26e-8),
    ("isolet_dr", 0.77, 3.28e-8, 2.67e-8, 5.94e-8),
    ("isolet_class", 0.77, 3.28e-8, 2.67e-8, 5.94e-8),
    ("kdd_ae", 0.77, 2.48e-10, 4.48e-9, 4.73e-9),
    ("mnist_kmeans", 0.32, 8.89e-10, 3.69e-12, 8.93e-10),
    ("isolet_kmeans", 0.32, 8.89e-10, 3.69e-12, 8.93e-10),
];

fn main() {
    restream::benchutil::section("Table IV — recognition cost per input");
    let sys = SystemConfig::default();
    print!("{}", report::table4(&sys));
    println!("\npaper values for reference:");
    println!(
        "{:>14} {:>10} {:>12} {:>10} {:>12}",
        "app", "time(us)", "compute(J)", "IO(J)", "total(J)"
    );
    for (app, t, c, io, tot) in PAPER {
        println!("{app:>14} {t:>10.2} {c:>12.2e} {io:>10.2e} {tot:>12.2e}");
    }
    let rows = sim::table4(&sys);
    let by = |n: &str| rows.iter().find(|r| r.app == n).unwrap();
    // recognition is sub-10us everywhere; kmeans rows are the cheapest
    for r in &rows {
        assert!(r.time_s < 20e-6, "{} {}", r.app, r.time_s);
    }
    assert!(by("mnist_kmeans").total_j < by("kdd_ae").total_j);
    println!("\nshape checks: OK");
}
