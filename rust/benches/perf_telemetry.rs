//! §Perf: telemetry overhead of the tracing/metrics layer.
//!
//! Runs the same closed-loop serving replay as `perf_serving` twice —
//! once with tracing disabled (the `TraceSink` no-op path) and once
//! with a live `Tracer` recording one span per request into the ring
//! buffer — and reports the throughput delta as `overhead_pct`.
//! CI's `bench-smoke` job fails when the overhead exceeds 5%
//! (EXPERIMENTS.md §perf_telemetry): tracing is supposed to be a
//! cheap observer, and this bench is the regression fence that keeps
//! it one.
//!
//! Each arm is measured `$PERF_TELEMETRY_REPEATS` times (default 3),
//! interleaved so thermal/scheduler drift hits both arms equally, and
//! the best run per arm is compared — overhead is a property of the
//! code, not of the noisiest run. Results land in
//! `BENCH_telemetry.json` (override with `$BENCH_TELEMETRY_OUT`).
//!
//! Scale knobs: `$PERF_TELEMETRY_REQUESTS` (per client, default 256),
//! `$PERF_TELEMETRY_APP` (default `mnist_class`).
//!
//! Determinism note: the traced and untraced arms compute bit-identical
//! per-request results (`tests/telemetry_determinism.rs` pins this);
//! only throughput may differ, and this bench bounds by how much.

use std::sync::Arc;
use std::time::{Duration, Instant};

use restream::benchutil::{env_usize, section};
use restream::config::{apps, Network};
use restream::coordinator::{init_conductances, Engine};
use restream::runtime::ArrayF32;
use restream::serve::{ServeConfig, Server};
use restream::telemetry::{Registry, Tracer, DEFAULT_TRACE_CAPACITY};
use restream::testing::Rng;

const CLIENTS: usize = 4;
const MAX_WAIT_US: u64 = 200;

/// One closed-loop run: start a server (traced or not), hammer it from
/// `CLIENTS` threads (`requests` each), and return throughput in
/// requests/s.
fn run_once(
    net: &Network,
    params: &[ArrayF32],
    pool: &[Vec<f32>],
    requests: usize,
    trace: Option<Arc<Tracer>>,
) -> f64 {
    let cfg = ServeConfig {
        max_wait: Duration::from_micros(MAX_WAIT_US),
        trace,
        ..ServeConfig::default()
    };
    let server =
        Server::start(Engine::native(), net.clone(), params.to_vec(), cfg);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let client = server.client();
            let rows: Vec<Vec<f32>> = (0..requests)
                .map(|r| pool[(c * 131 + r) % pool.len()].clone())
                .collect();
            std::thread::spawn(move || {
                for x in rows {
                    client.call(x).expect("serve request failed");
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("load-generator client panicked");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    server.shutdown();
    (CLIENTS * requests) as f64 / wall_s.max(1e-12)
}

struct Summary {
    app: String,
    requests: usize,
    repeats: usize,
    rps_off: f64,
    rps_on: f64,
    overhead_pct: f64,
    spans: u64,
    dropped: u64,
}

fn json_report(s: &Summary) -> String {
    format!(
        "{{\n  \"bench\": \"perf_telemetry\",\n  \"app\": \"{}\",\n  \
         \"requests_per_client\": {},\n  \"clients\": {CLIENTS},\n  \
         \"repeats\": {},\n  \"trace_capacity\": {DEFAULT_TRACE_CAPACITY},\n  \
         \"rps_untraced\": {:.2},\n  \"rps_traced\": {:.2},\n  \
         \"spans_last_traced_run\": {},\n  \
         \"spans_dropped_last_traced_run\": {},\n  \
         \"overhead_pct\": {:.3}\n}}\n",
        s.app,
        s.requests,
        s.repeats,
        s.rps_off,
        s.rps_on,
        s.spans,
        s.dropped,
        s.overhead_pct
    )
}

fn main() -> anyhow::Result<()> {
    let requests = env_usize("PERF_TELEMETRY_REQUESTS", 256).max(1);
    let repeats = env_usize("PERF_TELEMETRY_REPEATS", 3).max(1);
    let app = std::env::var("PERF_TELEMETRY_APP")
        .unwrap_or_else(|_| "mnist_class".to_string());
    let net = apps::network(&app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
    let params = init_conductances(net.layers, 0);
    let mut rng = Rng::seeded(0x7E1E);
    let pool: Vec<Vec<f32>> = (0..256)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    println!(
        "perf_telemetry: {app}, {CLIENTS} clients x {requests} requests, \
         best of {repeats} interleaved repeats per arm"
    );

    section("interleaved arms: tracing off vs on");
    let mut rps_off = 0.0f64;
    let mut rps_on = 0.0f64;
    let mut spans = 0u64;
    let mut dropped = 0u64;
    for rep in 0..repeats {
        let off = run_once(net, &params, &pool, requests, None);
        let reg = Registry::new();
        let tracer = Tracer::new(DEFAULT_TRACE_CAPACITY, &reg);
        let on =
            run_once(net, &params, &pool, requests, Some(tracer.clone()));
        spans = tracer.spans();
        dropped = tracer.dropped();
        println!(
            "bench telemetry/rep{rep}  off {off:>9.0} req/s  \
             on {on:>9.0} req/s"
        );
        rps_off = rps_off.max(off);
        rps_on = rps_on.max(on);
    }

    section("summary");
    let overhead_pct = (rps_off - rps_on) / rps_off.max(1e-12) * 100.0;
    println!(
        "best untraced {rps_off:.0} req/s, best traced {rps_on:.0} req/s \
         -> overhead {overhead_pct:.2}% (gate: <= 5%)"
    );
    println!(
        "last traced run recorded {spans} span(s), {dropped} dropped \
         from the ring"
    );

    let out_path = std::env::var("BENCH_TELEMETRY_OUT")
        .unwrap_or_else(|_| "BENCH_telemetry.json".to_string());
    let summary = Summary {
        app,
        requests,
        repeats,
        rps_off,
        rps_on,
        overhead_pct,
        spans,
        dropped,
    };
    std::fs::write(&out_path, json_report(&summary))?;
    println!("wrote {out_path}");
    Ok(())
}
