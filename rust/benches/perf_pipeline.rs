//! §Perf: layer-pipelined streaming vs the sequential and
//! data-parallel engines.
//!
//! Measures batched-recognition throughput (samples/s) on three
//! streaming apps under four execution configurations — sequential
//! (data-parallel, 1 worker), data-parallel over 4 workers, layer
//! pipeline over one core-group chain, and the hybrid
//! pipeline-of-replicas — prints the per-stage occupancy/stall table
//! of the pipelined runs, and writes the machine-readable comparison
//! to `BENCH_pipeline.json` — relative to the bench's working
//! directory, which under `cargo bench` is the crate root `rust/`;
//! override with `$BENCH_PIPELINE_OUT` (CI and `make bench-pipeline`
//! pin it to the repo root). CI's `bench-smoke` job runs this at
//! reduced scale and gates on the best per-app pipeline-vs-sequential
//! speedup staying ≥ 1.2.
//!
//! Scale knobs: `$PERF_PIPELINE_SAMPLES` (default 1024) and
//! `$PERF_PIPELINE_REPEATS` (default 3; wall times are best-of-N to
//! shave scheduler noise).
//!
//! Determinism note: every configuration computes bit-identical
//! outputs (`tests/pipeline_determinism.rs` pins this); the bench only
//! measures how fast the fixed computation streams.

use restream::benchutil::{best_wall, env_usize, section};
use restream::config::apps;
use restream::coordinator::{init_conductances, Engine, ExecMode};
use restream::testing::Rng;

/// The streaming apps under test; deep uneven stacks (mnist_class),
/// deep wide stacks (isolet_class) and a shallow balanced one
/// (kdd_ae), so the stage-imbalance spread is visible in one report.
const APPS: [&str; 3] = ["mnist_class", "isolet_class", "kdd_ae"];

struct RunResult {
    app: String,
    mode: String,
    workers: usize,
    stages: usize,
    wall_s: f64,
    samples_per_s: f64,
}

fn record(
    results: &mut Vec<RunResult>,
    app: &str,
    mode: &str,
    workers: usize,
    stages: usize,
    wall_s: f64,
    samples: usize,
) {
    let samples_per_s = samples as f64 / wall_s.max(1e-12);
    println!(
        "bench pipeline/{app}/{mode}/w{workers}/s{stages} \
         {:>10.2} ms  {:>10.0} samples/s",
        wall_s * 1e3,
        samples_per_s
    );
    results.push(RunResult {
        app: app.to_string(),
        mode: mode.to_string(),
        workers,
        stages,
        wall_s,
        samples_per_s,
    });
}

/// Per-app speedup of the 1-worker pipeline over the 1-worker
/// sequential engine — the number the CI gate watches.
fn pipeline_speedups(results: &[RunResult]) -> Vec<(String, f64)> {
    APPS.iter()
        .filter_map(|&app| {
            let at = |mode: &str| {
                results
                    .iter()
                    .find(|r| r.app == app && r.mode == mode && r.workers == 1)
                    .map(|r| r.samples_per_s)
            };
            match (at("seq"), at("pipeline")) {
                (Some(s), Some(p)) if s > 0.0 => {
                    Some((app.to_string(), p / s))
                }
                _ => None,
            }
        })
        .collect()
}

fn json_report(
    results: &[RunResult],
    speedups: &[(String, f64)],
    best: f64,
    samples: usize,
    repeats: usize,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"perf_pipeline\",\n  \"samples\": {samples},\n  \
         \"repeats\": {repeats},\n"
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"app\": \"{}\", \"mode\": \"{}\", \"workers\": {}, \
             \"stages\": {}, \"wall_s\": {:.6}, \
             \"samples_per_s\": {:.2}}}{sep}\n",
            r.app, r.mode, r.workers, r.stages, r.wall_s, r.samples_per_s
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"speedup_pipeline_vs_seq\": {\n");
    for (i, (app, speedup)) in speedups.iter().enumerate() {
        let sep = if i + 1 < speedups.len() { "," } else { "" };
        s.push_str(&format!("    \"{app}\": {speedup:.4}{sep}\n"));
    }
    s.push_str("  },\n");
    s.push_str(&format!("  \"best_pipeline_speedup\": {best:.4}\n"));
    s.push_str("}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("PERF_PIPELINE_SAMPLES", 1024).max(1);
    let repeats = env_usize("PERF_PIPELINE_REPEATS", 3).max(1);
    let mut results: Vec<RunResult> = Vec::new();
    println!(
        "perf_pipeline: {samples} samples, best of {repeats}, apps {APPS:?}"
    );

    for app in APPS {
        let net = apps::network(app).unwrap();
        let n_layers = net.layers.len() - 1;
        let params = init_conductances(net.layers, 0);
        let mut rng = Rng::seeded(0x9156 ^ net.layers[0] as u64);
        let xs: Vec<Vec<f32>> = (0..samples)
            .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
            .collect();
        section(&format!(
            "{app}: {} layers, one pipeline stage per layer",
            n_layers
        ));
        // (label, exec mode, workers); stage count is one per layer,
        // the deepest pipeline the app admits.
        let configs: [(&str, ExecMode, usize); 4] = [
            ("seq", ExecMode::DataParallel, 1),
            ("dp", ExecMode::DataParallel, 4),
            ("pipeline", ExecMode::Pipelined, 1),
            ("hybrid", ExecMode::Hybrid, 4),
        ];
        for (label, exec, workers) in configs {
            let engine = Engine::native()
                .with_workers(workers)
                .with_exec(exec)
                .with_pipeline_stages(n_layers);
            let wall = best_wall(repeats, || {
                engine.infer(net, &params, &xs).unwrap();
            });
            record(
                &mut results,
                app,
                label,
                workers,
                if exec == ExecMode::DataParallel { 0 } else { n_layers },
                wall,
                samples,
            );
            if label == "pipeline" {
                if let Some(rep) = engine.last_pipeline_report() {
                    for line in rep.summary().lines().skip(1) {
                        println!("    {}", line.trim_start());
                    }
                }
            }
        }
    }

    let speedups = pipeline_speedups(&results);
    let best = speedups.iter().map(|(_, s)| *s).fold(0.0f64, f64::max);
    section("summary");
    for (app, speedup) in &speedups {
        println!("pipeline vs sequential, {app}: {speedup:.2}x");
    }
    println!("best pipeline speedup: {best:.2}x");
    let out_path = std::env::var("BENCH_PIPELINE_OUT")
        .unwrap_or_else(|_| "BENCH_pipeline.json".to_string());
    std::fs::write(
        &out_path,
        json_report(&results, &speedups, best, samples, repeats),
    )?;
    println!("wrote {out_path}");
    Ok(())
}
