//! Paper Figs 18–20: KDD anomaly detection — reconstruction-distance
//! histograms for normal vs attack packets and the detection/false-
//! positive threshold sweep, at the paper's training scale (5292 normal
//! packets).

use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions};
use restream::{datasets, metrics};

fn main() -> anyhow::Result<()> {
    restream::benchutil::section("Figs 18-20 — KDD anomaly detection");
    let net = apps::network("kdd_ae").unwrap();
    let engine = Engine::open_default()?;
    let k = datasets::kdd(5292, 800, 800, 0);
    let xs = k.train.rows();
    let xs_t = xs.clone();
    let run = engine.fit(
        net, &xs, move |i| xs_t[i].clone(), 3, 0.8, 0,
        &TrainOptions::new(),
    )?;
    let (params, rep) = (&run.params, run.last_report().unwrap());
    println!("trained 41->15->41 AE on {} normal packets; loss {:.4} -> {:.4}",
             xs.len(), rep.loss_curve[0], rep.loss_curve.last().unwrap());

    let scores = engine.anomaly_scores(net, params, &k.test.rows())?;
    let (mut normal, mut attack) = (Vec::new(), Vec::new());
    for (s, &a) in scores.iter().zip(&k.test_attack) {
        if a { attack.push(*s) } else { normal.push(*s) }
    }
    let hi = scores.iter().cloned().fold(0.0, f64::max);
    let bins = 14;
    println!("\nFig 18 — distance histogram, normal packets:");
    for (b, n) in metrics::histogram(&normal, 0.0, hi, bins).iter().enumerate() {
        println!("  {:>5.2} {:>5} {}", b as f64 * hi / bins as f64, n,
                 "#".repeat(n / 4));
    }
    println!("Fig 19 — distance histogram, attack packets:");
    for (b, n) in metrics::histogram(&attack, 0.0, hi, bins).iter().enumerate() {
        println!("  {:>5.2} {:>5} {}", b as f64 * hi / bins as f64, n,
                 "#".repeat(n / 4));
    }

    println!("\nFig 20 — detection rate vs decision threshold:");
    let pts = metrics::roc_sweep(&scores, &k.test_attack, 140);
    println!("{:>10} {:>10} {:>10}", "threshold", "detect %", "false %");
    for p in pts.iter().step_by(10) {
        println!("{:>10.3} {:>10.1} {:>10.1}",
                 p.threshold, p.tpr * 100.0, p.fpr * 100.0);
    }
    println!(
        "\nAUC {:.3}; detection at 4% FPR = {:.1}% (paper: 96.6% at 4%)",
        metrics::auc(&pts),
        100.0 * metrics::tpr_at_fpr(&pts, 0.04)
    );
    Ok(())
}
