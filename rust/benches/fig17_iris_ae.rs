//! Paper Fig 17: distribution of the Iris classes in the 2-D feature
//! space learnt by the 4→2→4 autoencoder — printed as a character
//! scatter plot plus per-class centroids.

use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions};
use restream::datasets;

fn main() -> anyhow::Result<()> {
    restream::benchutil::section("Fig 17 — Iris AE 4->2->4 feature space");
    let net = apps::network("iris_ae").unwrap();
    let engine = Engine::open_default()?;
    let ds = datasets::iris(0);
    let xs = ds.rows();
    let xs_t = xs.clone();
    let run = engine.fit(
        net, &xs, move |i| xs_t[i].clone(), 40, 0.8, 1,
        &TrainOptions::new(),
    )?;
    let codes = engine.encode(net, &run.params, &xs)?;

    // character scatter: 24x50 grid over the code range
    const W: usize = 50;
    const H: usize = 20;
    let mut grid = vec![b' '; W * H];
    let glyph = [b's', b'v', b'g']; // setosa, versicolor, virginica
    for (i, c) in codes.iter().enumerate() {
        let gx = (((c[0] + 0.5) as f64).clamp(0.0, 0.999) * W as f64) as usize;
        let gy = (((c[1] + 0.5) as f64).clamp(0.0, 0.999) * H as f64) as usize;
        grid[gy * W + gx] = glyph[ds.y[i]];
    }
    for row in grid.chunks(W) {
        println!("|{}|", String::from_utf8_lossy(row));
    }
    for (c, name) in datasets::IRIS_CLASSES.iter().enumerate() {
        let pts: Vec<&Vec<f32>> = codes
            .iter()
            .zip(&ds.y)
            .filter(|(_, &y)| y == c)
            .map(|(p, _)| p)
            .collect();
        let mx = pts.iter().map(|p| p[0] as f64).sum::<f64>() / pts.len() as f64;
        let my = pts.iter().map(|p| p[1] as f64).sum::<f64>() / pts.len() as f64;
        println!("{name:>11} centroid: ({mx:>6.3}, {my:>6.3})");
    }
    println!("(paper: same-class data appears closely in the feature space)");
    Ok(())
}
