//! §Perf: checkpoint save/restore cost vs the work it protects.
//!
//! Measures, on the mnist_class stack (the largest single-stage app):
//!
//! * **save bandwidth** — encode + atomic commit of a full
//!   [`TrainState`] (MB/s over the payload bytes),
//! * **restore bandwidth** — manifest verify + decode back into a
//!   `TrainState`,
//! * **recovery-time objective** — restore seconds vs the wall seconds
//!   of one training epoch: a checkpoint is only worth taking if
//!   restoring it costs (much) less than recomputing the epoch it
//!   saves, so CI's `bench-smoke` gates on `restore_s < epoch_s`.
//!
//! Writes `BENCH_ckpt.json` (override the path with `$BENCH_CKPT_OUT`).
//! Scale knobs: `$PERF_CKPT_SAMPLES` (default 64, the epoch size),
//! `$PERF_CKPT_REPEATS` (default 3; times are best-of-N).
//!
//! Determinism note: restore is bit-exact (`tests/
//! checkpoint_determinism.rs`); this bench only measures how fast the
//! fixed bytes move.

use restream::benchutil::{best_wall, env_usize, section};
use restream::checkpoint::{self, TrainState};
use restream::config::apps;
use restream::coordinator::{init_conductances, Engine, TrainOptions};
use restream::testing::Rng;

fn json_report(
    payload_bytes: u64,
    save_s: f64,
    restore_s: f64,
    epoch_s: f64,
    samples: usize,
    repeats: usize,
) -> String {
    let mb = payload_bytes as f64 / (1024.0 * 1024.0);
    format!(
        "{{\n  \"bench\": \"perf_ckpt\",\n  \"app\": \"mnist_class\",\n  \
         \"samples\": {samples},\n  \"repeats\": {repeats},\n  \
         \"payload_bytes\": {payload_bytes},\n  \
         \"save_s\": {save_s:.6},\n  \
         \"save_mb_s\": {:.2},\n  \
         \"restore_s\": {restore_s:.6},\n  \
         \"restore_mb_s\": {:.2},\n  \
         \"epoch_s\": {epoch_s:.6},\n  \
         \"rto_ratio\": {:.4}\n}}\n",
        mb / save_s.max(1e-12),
        mb / restore_s.max(1e-12),
        restore_s / epoch_s.max(1e-12),
    )
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("PERF_CKPT_SAMPLES", 64).max(1);
    let repeats = env_usize("PERF_CKPT_REPEATS", 3).max(1);
    let net = apps::network("mnist_class").unwrap();
    println!(
        "perf_ckpt: {} ({:?}), {samples}-sample epoch, best of {repeats}",
        net.name, net.layers
    );

    // A realistic full-size state: live conductances plus a cursor
    // mid-run (the order permutation scales with the dataset).
    let mut state = TrainState::fresh(net, 7, 0.3, 16);
    state.epochs_done = 3;
    state.samples_seen = 3 * samples;
    state.n_samples = samples;
    state.rng = Rng::seeded(7).state();
    state.order = (0..samples).rev().collect();
    state.loss_curve = vec![0.5, 0.4, 0.3];
    state.params = init_conductances(net.layers, 7);
    let payload_bytes = state.payload_bytes();

    let dir = std::env::temp_dir()
        .join(format!("restream-perf-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    section("save (encode + atomic commit)");
    let save_s = best_wall(repeats, || {
        checkpoint::save(&dir, &state).unwrap();
    });
    println!(
        "bench ckpt/save {:>10.2} ms  {:>8.1} MB/s  ({payload_bytes} \
         payload bytes)",
        save_s * 1e3,
        payload_bytes as f64 / (1024.0 * 1024.0) / save_s.max(1e-12)
    );

    section("restore (verify + decode)");
    let path = checkpoint::latest(&dir)?.expect("checkpoint saved above");
    let mut restored = None;
    let restore_s = best_wall(repeats, || {
        restored = Some(checkpoint::load(&path).unwrap());
    });
    assert_eq!(restored.as_ref(), Some(&state), "restore must be bit-exact");
    println!(
        "bench ckpt/restore {:>10.2} ms  {:>8.1} MB/s",
        restore_s * 1e3,
        payload_bytes as f64 / (1024.0 * 1024.0) / restore_s.max(1e-12)
    );

    section("recovery-time objective (restore vs one epoch)");
    let mut rng = Rng::seeded(1);
    let xs: Vec<Vec<f32>> = (0..samples)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    let ts: Vec<Vec<f32>> =
        (0..samples).map(|_| rng.vec_uniform(10, -0.4, 0.4)).collect();
    let engine = Engine::native().with_workers(4);
    let epoch_s = best_wall(repeats, || {
        let ts = ts.clone();
        engine
            .fit(net, &xs, move |i| ts[i].clone(), 1, 0.3, 7,
                 &TrainOptions::new().batch(16))
            .unwrap();
    });
    let ratio = restore_s / epoch_s.max(1e-12);
    println!(
        "one {samples}-sample epoch: {:.2} ms; restore costs {:.4} of \
         an epoch",
        epoch_s * 1e3,
        ratio
    );

    let out_path = std::env::var("BENCH_CKPT_OUT")
        .unwrap_or_else(|_| "BENCH_ckpt.json".to_string());
    std::fs::write(
        &out_path,
        json_report(payload_bytes, save_s, restore_s, epoch_s, samples,
                    repeats),
    )?;
    println!("wrote {out_path}");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
