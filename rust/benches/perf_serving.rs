//! §Perf: serving throughput/latency of the micro-batching front end.
//!
//! A closed-loop load generator drives `restream::serve` (DESIGN.md
//! "Serving layer"): N client threads each issue single-sample
//! requests back-to-back, so at most N requests are ever in flight and
//! micro-batch sizes track the client count. Measures aggregate
//! throughput (requests/s) and server-side p50/p99 latency across
//! client counts × batching windows, and writes the machine-readable
//! summary to `BENCH_serving.json` — relative to the bench's working
//! directory (under `cargo bench` that is the crate root `rust/`);
//! override with `$BENCH_SERVING_OUT` (CI and `make bench-serving`
//! pin it to the repo root).
//!
//! The headline comparison: micro-batched serving at 8 clients vs the
//! 1-client `max_batch = 1` sequential baseline. Every dispatch pads
//! to the chip's 64-sample tile, so a sequential single-sample server
//! wastes 63/64 of each tile — coalescing is what the hardware model
//! rewards. CI's `bench-smoke` job runs this at reduced scale and
//! fails when `speedup_8v1` drops below 1.0.
//!
//! Scale knobs: `$PERF_SERVING_REQUESTS` (per client, default 128) and
//! `$PERF_SERVING_APP` (default `mnist_class`).
//!
//! Determinism note: every configuration computes bit-identical
//! per-request results (see `tests/serving_determinism.rs`); this
//! bench only measures how fast the answers come back.

use std::time::{Duration, Instant};

use restream::benchutil::{env_usize, section};
use restream::config::{apps, Network};
use restream::coordinator::{init_conductances, Engine};
use restream::runtime::ArrayF32;
use restream::serve::{ServeConfig, Server};
use restream::testing::Rng;

const CLIENT_COUNTS: [usize; 4] = [1, 2, 4, 8];
const WAITS_US: [u64; 3] = [0, 200, 1000];

struct Row {
    clients: usize,
    max_batch: usize,
    max_wait_us: u64,
    throughput_rps: f64,
    p50_us: f64,
    p99_us: f64,
    mean_batch: f64,
}

/// One closed-loop run: start a server, hammer it from `clients`
/// threads (`requests` each), and fold the server's own report into a
/// result row.
fn run_config(
    net: &Network,
    params: &[ArrayF32],
    pool: &[Vec<f32>],
    clients: usize,
    requests: usize,
    max_batch: usize,
    max_wait_us: u64,
) -> Row {
    let cfg = ServeConfig {
        max_batch,
        max_wait: Duration::from_micros(max_wait_us),
        ..ServeConfig::default()
    };
    let server =
        Server::start(Engine::native(), net.clone(), params.to_vec(), cfg);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = server.client();
            // Each client replays a distinct deterministic slice of the
            // sample pool.
            let rows: Vec<Vec<f32>> = (0..requests)
                .map(|r| pool[(c * 131 + r) % pool.len()].clone())
                .collect();
            std::thread::spawn(move || {
                for x in rows {
                    client.call(x).expect("serve request failed");
                }
            })
        })
        .collect();
    for handle in handles {
        handle.join().expect("load-generator client panicked");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = server.shutdown();
    Row {
        clients,
        max_batch,
        max_wait_us,
        throughput_rps: report.requests as f64 / wall_s.max(1e-12),
        p50_us: report.total.p50_us,
        p99_us: report.total.p99_us,
        mean_batch: report.mean_batch(),
    }
}

fn print_row(r: &Row) {
    println!(
        "bench serving/c{}/b{}/w{}us {:>9.0} req/s  p50 {:>9.1} us  \
         p99 {:>9.1} us  mean batch {:>5.1}",
        r.clients,
        r.max_batch,
        r.max_wait_us,
        r.throughput_rps,
        r.p50_us,
        r.p99_us,
        r.mean_batch
    );
}

fn json_row(r: &Row) -> String {
    format!(
        "{{\"clients\": {}, \"max_batch\": {}, \"max_wait_us\": {}, \
         \"throughput_rps\": {:.2}, \"p50_us\": {:.2}, \"p99_us\": {:.2}, \
         \"mean_batch\": {:.3}}}",
        r.clients,
        r.max_batch,
        r.max_wait_us,
        r.throughput_rps,
        r.p50_us,
        r.p99_us,
        r.mean_batch
    )
}

fn json_report(
    app: &str,
    requests: usize,
    baseline: &Row,
    results: &[Row],
    speedup_8v1: f64,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"perf_serving\",\n  \"app\": \"{app}\",\n  \
         \"requests_per_client\": {requests},\n"
    ));
    s.push_str(&format!("  \"baseline\": {},\n", json_row(baseline)));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 < results.len() { "," } else { "" };
        s.push_str(&format!("    {}{sep}\n", json_row(r)));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"baseline_1client_rps\": {:.2},\n",
        baseline.throughput_rps
    ));
    let batched8 = best_8client_rps(results);
    s.push_str(&format!("  \"batched_8client_rps\": {batched8:.2},\n"));
    s.push_str(&format!("  \"speedup_8v1\": {speedup_8v1:.4}\n"));
    s.push_str("}\n");
    s
}

/// Best throughput over the 8-client batched configurations.
fn best_8client_rps(results: &[Row]) -> f64 {
    results
        .iter()
        .filter(|r| r.clients == 8)
        .map(|r| r.throughput_rps)
        .fold(0.0, f64::max)
}

fn main() -> anyhow::Result<()> {
    let requests = env_usize("PERF_SERVING_REQUESTS", 128).max(1);
    let app = std::env::var("PERF_SERVING_APP")
        .unwrap_or_else(|_| "mnist_class".to_string());
    let net = apps::network(&app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
    let params = init_conductances(net.layers, 0);
    let mut rng = Rng::seeded(0xBEEF);
    let pool: Vec<Vec<f32>> = (0..256)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    println!(
        "perf_serving: {app}, {requests} requests/client, clients {:?}, \
         waits {:?} us",
        CLIENT_COUNTS, WAITS_US
    );

    section("baseline: 1 client, max_batch 1 (sequential dispatch)");
    let baseline = run_config(net, &params, &pool, 1, requests, 1, 0);
    print_row(&baseline);

    section("micro-batched sweep (max_batch 64 = chip tile)");
    let mut results = Vec::new();
    for &clients in &CLIENT_COUNTS {
        for &wait_us in &WAITS_US {
            let row = run_config(
                net,
                &params,
                &pool,
                clients,
                requests,
                apps::FWD_BATCH,
                wait_us,
            );
            print_row(&row);
            results.push(row);
        }
    }

    section("summary");
    let speedup_8v1 =
        best_8client_rps(&results) / baseline.throughput_rps.max(1e-12);
    println!(
        "batched 8-client vs sequential 1-client throughput: \
         {speedup_8v1:.2}x"
    );
    let out_path = std::env::var("BENCH_SERVING_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    std::fs::write(
        &out_path,
        json_report(&app, requests, &baseline, &results, speedup_8v1),
    )?;
    println!("wrote {out_path}");
    Ok(())
}
