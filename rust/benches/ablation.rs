//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. output-ADC precision (the paper fixes 3 bits — what does the
//!    accuracy/traffic trade look like?),
//! 2. memristor programming stochasticity (robustness of the trained
//!    conductances),
//! 3. crossbar core geometry (the 400x200 sizing, section IV.A),
//! 4. NoC link width (8-bit links, section V.C).

use restream::config::{apps, SystemConfig};
use restream::mapper::{map_layer_with, map_network, place};
use restream::nn::{Constraint, Mlp};
use restream::noc::Schedule;
use restream::testing::Rng;
use restream::{benchutil, datasets};

fn iris_setup() -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<usize>) {
    let ds = datasets::iris(0);
    let xs = ds.rows();
    let ys: Vec<usize> = ds.y.iter().map(|&y| y.min(1)).collect();
    let ts = ys
        .iter()
        .map(|&y| vec![if y == 1 { 0.4f32 } else { -0.4 }])
        .collect();
    (xs, ts, ys)
}

fn main() {
    let sys = SystemConfig::default();

    benchutil::section("ablation 1 — output ADC precision (Iris, 4-10-1)");
    let (xs, ts, ys) = iris_setup();
    let order: Vec<usize> = (0..xs.len()).collect();
    println!("{:>6} {:>10} {:>16}", "bits", "accuracy", "NoC bits/neuron");
    for bits in 1..=6u32 {
        let mut rng = Rng::seeded(3);
        let mut net = Mlp::init(&[4, 10, 1], Constraint::Chip, &mut rng);
        net.chip_out_bits = bits;
        for _ in 0..15 {
            net.train_epoch(&xs, &ts, 1.0, &order);
        }
        println!("{:>6} {:>10.3} {:>16}", bits, net.accuracy(&xs, &ys), bits);
    }
    println!("(the paper picks 3 bits: the knee where accuracy saturates \
              while NoC traffic stays minimal)");

    benchutil::section("ablation 2 — conductance programming noise");
    println!("{:>8} {:>10}", "sigma", "accuracy");
    let trained = {
        let mut rng = Rng::seeded(3);
        let mut net = Mlp::init(&[4, 10, 1], Constraint::Chip, &mut rng);
        for _ in 0..15 {
            net.train_epoch(&xs, &ts, 1.0, &order);
        }
        net
    };
    for sigma in [0.0, 0.01, 0.02, 0.05, 0.10, 0.20] {
        // average over a few noise draws
        let mut acc = 0.0;
        for seed in 0..5 {
            let mut noisy = trained.clone();
            let mut rng = Rng::seeded(100 + seed);
            noisy.perturb_conductances(sigma, &mut rng);
            acc += noisy.accuracy(&xs, &ys);
        }
        println!("{:>8.2} {:>10.3}", sigma, acc / 5.0);
    }
    println!("(differential pairs cancel common-mode drift: accuracy \
              degrades gracefully)");

    benchutil::section("ablation 3 — crossbar core geometry (cores needed)");
    println!(
        "{:>12} {:>14} {:>14}",
        "geometry", "mnist L0 cores", "isolet L1 cores"
    );
    for (rows, neurons) in
        [(100, 25), (200, 50), (400, 100), (800, 200), (1600, 400)]
    {
        let mnist = map_layer_with(0, 784, 300, rows, neurons)
            .map(|m| m.cores_used())
            .unwrap_or(0);
        let isolet = map_layer_with(0, 2000, 1000, rows, neurons)
            .map(|m| m.cores_used())
            .unwrap_or(0);
        println!(
            "{:>12} {:>14} {:>14}",
            format!("{rows}x{}", 2 * neurons),
            mnist,
            isolet
        );
    }
    println!("(bigger cores cut the core count quadratically, but section \
              IV.A: sneak-path error grows with size — 400x200 is the \
              paper's compromise; see table2_core_steps for the error \
              sweep)");

    benchutil::section("ablation 4 — NoC link width (mnist fwd makespan)");
    let net = apps::network("mnist_class").unwrap();
    let map = map_network(net, &sys).unwrap();
    let placement = place(&map.stages[0], &sys);
    println!("{:>8} {:>16}", "bits", "makespan slots");
    for bits in [2usize, 4, 8, 16, 32] {
        let sched = Schedule::build(&placement.fwd_transfers, bits);
        sched.validate().unwrap();
        println!("{:>8} {:>16}", bits, sched.makespan_slots());
    }
    println!("(the paper's 8-bit links: makespan scales ~1/width until \
              hop latency dominates; wider links cost area/power \
              linearly)");
}
