//! NVIDIA Tesla K20 baseline cost model (paper section VI.F).
//!
//! The paper compares against aggregate K20 throughput/power; we model it
//! as a roofline: per-kernel time = max(compute, memory) + launch
//! overhead, energy = time x board power. Stochastic (batch-1) BP — the
//! algorithm both the paper and the chip run — is memory- and
//! launch-bound on a GPU, which is precisely where the crossbar
//! architecture's advantage comes from (weights never move).

use crate::config::Network;

/// K20 datasheet + era constants.
pub mod k20 {
    /// Peak single-precision throughput (FLOP/s).
    pub const PEAK_FLOPS: f64 = 3.52e12;
    /// Peak memory bandwidth (B/s).
    pub const MEM_BW_BPS: f64 = 208e9;
    /// Board power (W) — the paper uses the 225 W TDP.
    pub const POWER_W: f64 = 225.0;
    /// Die area (mm^2), 28 nm — paper section VI.F.
    pub const AREA_MM2: f64 = 561.0;
    /// Kernel launch + driver overhead per kernel (s), K20/CUDA-5 era.
    pub const LAUNCH_S: f64 = 10e-6;
    /// Achievable fraction of peak FLOPs for GEMV-shaped kernels.
    pub const GEMV_EFF: f64 = 0.12;
    /// Achievable fraction of peak memory bandwidth.
    pub const BW_EFF: f64 = 0.75;
}

/// Cost of one GPU operation batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuCost {
    pub time_s: f64,
    pub energy_j: f64,
}

/// Roofline time for one kernel: flops + bytes + one launch.
fn kernel_time(flops: f64, bytes: f64) -> f64 {
    let compute = flops / (k20::PEAK_FLOPS * k20::GEMV_EFF);
    let memory = bytes / (k20::MEM_BW_BPS * k20::BW_EFF);
    compute.max(memory) + k20::LAUNCH_S
}

fn cost(time_s: f64) -> GpuCost {
    GpuCost { time_s, energy_j: time_s * k20::POWER_W }
}

fn layer_train_time(n_in: usize, n_out: usize) -> f64 {
    let params = ((n_in + 1) * n_out) as f64;
    let w_bytes = params * 4.0;
    kernel_time(2.0 * params, w_bytes)        // forward
        + kernel_time(2.0 * params, w_bytes)  // backward
        + kernel_time(2.0 * params, 2.0 * w_bytes) // update (r+w)
}

/// Per-sample stochastic-BP training cost for a network.
///
/// Per layer: forward GEMV, backward GEMV, rank-1 update — three kernels,
/// each traversing the layer's weight matrix once (read) and the update
/// additionally writing it back. DR apps train layer-by-layer exactly as
/// the chip does (each stage a 2-layer n->h->n autoencoder), so one
/// training item passes every stage per iteration on both platforms.
pub fn train_cost(net: &Network) -> GpuCost {
    use crate::config::AppKind;
    let mut t = 0.0;
    if net.kind == AppKind::DimReduction {
        for (n_in, n_hid) in net.dr_stages() {
            t += layer_train_time(n_in, n_hid); // encoder
            t += layer_train_time(n_hid, n_in); // temporary decoder
        }
    } else {
        for (n_in, n_out) in net.layer_shapes() {
            t += layer_train_time(n_in, n_out);
        }
    }
    cost(t)
}

/// Per-sample recognition cost (forward only).
pub fn recognition_cost(net: &Network) -> GpuCost {
    let mut t = 0.0;
    for (n_in, n_out) in net.layer_shapes() {
        let params = ((n_in + 1) * n_out) as f64;
        t += kernel_time(2.0 * params, params * 4.0);
    }
    cost(t)
}

/// Per-sample k-means cost (distance + argmin kernels over k centres of
/// d dims). Tiny compute, launch-dominated — as it is in practice.
pub fn kmeans_cost(dims: usize, clusters: usize) -> GpuCost {
    let flops = 3.0 * (dims * clusters) as f64;
    let bytes = ((dims * clusters) as f64 + dims as f64) * 4.0;
    cost(kernel_time(flops, bytes) + kernel_time(clusters as f64, clusters as f64 * 4.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::apps;

    #[test]
    fn training_costs_scale_with_network_size() {
        let small = train_cost(apps::network("kdd_ae").unwrap());
        let big = train_cost(apps::network("isolet_class").unwrap());
        assert!(big.time_s > 5.0 * small.time_s);
        assert!(big.energy_j > small.energy_j);
    }

    #[test]
    fn small_nets_are_launch_bound() {
        // kdd_ae: 2 layers x 3 kernels x 10us = 60us floor.
        let c = train_cost(apps::network("kdd_ae").unwrap());
        assert!(c.time_s >= 6.0 * k20::LAUNCH_S);
        assert!(c.time_s < 8.0 * k20::LAUNCH_S, "t={}", c.time_s);
    }

    #[test]
    fn big_nets_are_memory_bound() {
        // isolet weights ~2.9M params: memory term dominates launches.
        let net = apps::network("isolet_class").unwrap();
        let c = train_cost(net);
        let launch_floor = 15.0 * k20::LAUNCH_S;
        assert!(c.time_s > 1.5 * launch_floor, "t={}", c.time_s);
    }

    #[test]
    fn energy_is_time_times_board_power() {
        let c = recognition_cost(apps::network("mnist_class").unwrap());
        assert!((c.energy_j - c.time_s * 225.0).abs() < 1e-12);
    }

    #[test]
    fn kmeans_is_launch_dominated() {
        let c = kmeans_cost(20, 26);
        assert!(c.time_s > k20::LAUNCH_S && c.time_s < 5.0 * k20::LAUNCH_S);
    }
}
