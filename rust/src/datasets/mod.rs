//! Datasets (paper section V.A): Iris, MNIST, ISOLET, KDD.
//!
//! No network access exists in the build environment, so MNIST / ISOLET /
//! KDD are deterministic synthetic generators with the *same tensor
//! shapes, class counts and class structure* as the originals (see
//! DESIGN.md substitutions — every architecture result depends only on
//! shapes; accuracy-shape results need class structure, not real pixels).
//! Iris is synthesised from the published per-class feature statistics of
//! the real Fisher data, which preserves its near-linear separability.
//!
//! All features are normalised into the chip's input range
//! `[-V_RAIL, V_RAIL]`; classifier targets are `±0.4`-scaled one-hot
//! vectors (inside the rail with headroom, so they are reachable).

mod gen;
mod iris;
mod kdd;

pub use gen::class_blobs;
pub use iris::{iris, IRIS_CLASSES};
pub use kdd::{kdd, KddSplit};

use crate::config::hwspec as hw;
use crate::testing::Rng;

/// A labelled dataset with features in the chip input range.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Row-major features: `n x dims`.
    pub x: Vec<f32>,
    /// Class labels (empty for unlabelled data).
    pub y: Vec<usize>,
    pub dims: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        if self.dims == 0 { 0 } else { self.x.len() / self.dims }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.dims..(i + 1) * self.dims]
    }

    /// Targets for classifier training: one-hot at ±0.4 (multi-class) or
    /// a single ±0.4 output (binary with one output neuron).
    pub fn target(&self, i: usize, outputs: usize) -> Vec<f32> {
        let mut t = vec![-0.4f32; outputs];
        if outputs == 1 {
            t[0] = if self.y[i] > 0 { 0.4 } else { -0.4 };
        } else {
            t[self.y[i]] = 0.4;
        }
        t
    }

    /// Deterministic train/test split (shuffle then cut).
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seeded(seed);
        rng.shuffle(&mut idx);
        let cut = ((n as f64) * train_frac) as usize;
        let build = |ids: &[usize], tag: &str| Dataset {
            name: format!("{}_{tag}", self.name),
            x: ids.iter().flat_map(|&i| self.sample(i).to_vec()).collect(),
            y: if self.y.is_empty() {
                Vec::new()
            } else {
                ids.iter().map(|&i| self.y[i]).collect()
            },
            dims: self.dims,
            classes: self.classes,
        };
        (build(&idx[..cut], "train"), build(&idx[cut..], "test"))
    }

    /// Samples as a vector of row vectors (for `memory::SampleStream`).
    pub fn rows(&self) -> Vec<Vec<f32>> {
        (0..self.len()).map(|i| self.sample(i).to_vec()).collect()
    }
}

/// Clamp-normalise a raw feature matrix into the rail range per feature.
pub(crate) fn normalise(x: &mut [f32], dims: usize) {
    let n = x.len() / dims;
    for d in 0..dims {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for i in 0..n {
            let v = x[i * dims + d];
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let span = (hi - lo).max(1e-9);
        for i in 0..n {
            let v = &mut x[i * dims + d];
            *v = ((*v - lo) / span - 0.5) * (2.0 * hw::V_RAIL) * 0.98;
        }
    }
}

/// Synthetic MNIST: 784-dim, 10 classes, smooth class-template blobs.
pub fn mnist(n: usize, seed: u64) -> Dataset {
    class_blobs("mnist", 784, 10, n, 0.35, seed)
}

/// Synthetic ISOLET: 617-dim, 26 classes (spoken-letter cepstra shapes).
pub fn isolet(n: usize, seed: u64) -> Dataset {
    class_blobs("isolet", 617, 26, n, 0.30, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_have_table1_shapes() {
        let m = mnist(200, 1);
        assert_eq!((m.dims, m.classes), (784, 10));
        assert_eq!(m.len(), 200);
        let i = isolet(130, 1);
        assert_eq!((i.dims, i.classes), (617, 26));
    }

    #[test]
    fn features_respect_rail_range() {
        let m = mnist(100, 2);
        assert!(m.x.iter().all(|v| v.abs() <= hw::V_RAIL + 1e-6));
    }

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(mnist(50, 7).x, mnist(50, 7).x);
        assert_ne!(mnist(50, 7).x, mnist(50, 8).x);
    }

    #[test]
    fn split_partitions_without_loss() {
        let m = mnist(100, 3);
        let (tr, te) = m.split(0.8, 0);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.dims, 784);
        assert_eq!(tr.y.len(), 80);
    }

    #[test]
    fn targets_are_reachable_one_hots() {
        let m = mnist(10, 4);
        let t = m.target(0, 10);
        assert_eq!(t.len(), 10);
        assert_eq!(t.iter().filter(|&&v| v > 0.0).count(), 1);
        assert!((t[m.y[0]] - 0.4).abs() < 1e-6);
    }
}
