//! Iris dataset, synthesised from the published per-class statistics of
//! the real Fisher data (mean and standard deviation of each of the four
//! features per class). 50 samples per class, Gaussian around the class
//! means — this preserves the property the paper's Figs 16/17 rely on:
//! setosa linearly separable, versicolor/virginica adjacent.

use super::{normalise, Dataset};
use crate::testing::Rng;

/// Published class statistics of the real Iris data:
/// (mean[4], std[4]) for setosa, versicolor, virginica — features are
/// sepal length, sepal width, petal length, petal width (cm).
const STATS: [([f64; 4], [f64; 4]); 3] = [
    ([5.006, 3.428, 1.462, 0.246], [0.352, 0.379, 0.174, 0.105]),
    ([5.936, 2.770, 4.260, 1.326], [0.516, 0.314, 0.470, 0.198]),
    ([6.588, 2.974, 5.552, 2.026], [0.636, 0.322, 0.552, 0.275]),
];

/// Class names in label order (0, 1, 2).
pub const IRIS_CLASSES: [&str; 3] = ["setosa", "versicolor", "virginica"];

/// The 150-sample Iris dataset (50 per class), deterministic.
pub fn iris(seed: u64) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0x1815);
    let mut x = Vec::with_capacity(150 * 4);
    let mut y = Vec::with_capacity(150);
    for (c, (mean, std)) in STATS.iter().enumerate() {
        for _ in 0..50 {
            for d in 0..4 {
                x.push(rng.normal(mean[d], std[d]) as f32);
            }
            y.push(c);
        }
    }
    normalise(&mut x, 4);
    Dataset { name: "iris".into(), x, y, dims: 4, classes: 3 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_150_samples_50_per_class() {
        let d = iris(0);
        assert_eq!(d.len(), 150);
        for c in 0..3 {
            assert_eq!(d.y.iter().filter(|&&y| y == c).count(), 50);
        }
    }

    #[test]
    fn setosa_is_linearly_separable_on_petal_length() {
        // The hallmark of the real data: setosa petal length (feature 2)
        // never overlaps the other classes.
        let d = iris(0);
        let max_setosa = (0..150)
            .filter(|&i| d.y[i] == 0)
            .map(|i| d.sample(i)[2])
            .fold(f32::NEG_INFINITY, f32::max);
        let min_other = (0..150)
            .filter(|&i| d.y[i] != 0)
            .map(|i| d.sample(i)[2])
            .fold(f32::INFINITY, f32::min);
        assert!(max_setosa < min_other,
                "setosa max {max_setosa} vs others min {min_other}");
    }
}
