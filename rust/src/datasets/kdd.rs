//! Synthetic KDD-99-like network-traffic data for the anomaly-detection
//! experiment (paper section VI.C).
//!
//! 41 features. "Normal" packets form one coherent mass (a mixture of a
//! few nearby modes — different normal services); "attack" packets come
//! from several modes shifted off the normal manifold with heavier
//! per-feature distortion. The paper trains the 41→15→41 autoencoder on
//! 5292 normal packets only, then thresholds reconstruction distance.

use super::{normalise, Dataset};
use crate::testing::Rng;

const DIMS: usize = 41;

/// Train/test split for the anomaly experiment.
#[derive(Clone, Debug)]
pub struct KddSplit {
    /// Normal-only training set (paper: 5292 normal packets).
    pub train: Dataset,
    /// Mixed test set.
    pub test: Dataset,
    /// Test labels: false = normal, true = attack.
    pub test_attack: Vec<bool>,
}

fn mode(rng: &mut Rng, centre: &[f64; DIMS], spread: f64) -> Vec<f32> {
    centre
        .iter()
        .map(|&c| (c + rng.normal(0.0, spread)) as f32)
        .collect()
}

/// Generate the anomaly corpus. `n_train` normal training packets,
/// `n_test_normal` + `n_test_attack` test packets.
pub fn kdd(n_train: usize, n_test_normal: usize, n_test_attack: usize,
           seed: u64) -> KddSplit {
    let mut rng = Rng::seeded(seed ^ 0x6DD5);
    // Normal manifold: 4 nearby service modes around a base point.
    let base: [f64; DIMS] = std::array::from_fn(|_| rng.uniform(-0.6, 0.6));
    let normal_modes: Vec<[f64; DIMS]> = (0..4)
        .map(|_| std::array::from_fn(|d| base[d] + rng.normal(0.0, 0.25)))
        .collect();
    // Attack modes: shifted well off the normal manifold in a random
    // subset of features (scans, floods, U2R each distort differently).
    let attack_modes: Vec<[f64; DIMS]> = (0..5)
        .map(|_| {
            std::array::from_fn(|d| {
                let shift = if rng.unit() < 0.15 {
                    rng.uniform(0.35, 0.9) * if rng.unit() < 0.5 { -1.0 } else { 1.0 }
                } else {
                    0.0
                };
                base[d] + shift + rng.normal(0.0, 0.3)
            })
        })
        .collect();

    let draw_normal = |rng: &mut Rng| {
        let m = &normal_modes[rng.below(normal_modes.len())];
        mode(rng, m, 0.25)
    };
    let draw_attack = |rng: &mut Rng| {
        let m = &attack_modes[rng.below(attack_modes.len())];
        mode(rng, m, 0.45)
    };

    // Build one big matrix first so normalisation is computed over the
    // union (as a preprocessing pipeline over captured traffic would).
    let total = n_train + n_test_normal + n_test_attack;
    let mut x = Vec::with_capacity(total * DIMS);
    for _ in 0..n_train + n_test_normal {
        x.extend(draw_normal(&mut rng));
    }
    for _ in 0..n_test_attack {
        x.extend(draw_attack(&mut rng));
    }
    normalise(&mut x, DIMS);

    let slice = |lo: usize, hi: usize, name: &str| Dataset {
        name: name.to_string(),
        x: x[lo * DIMS..hi * DIMS].to_vec(),
        y: Vec::new(),
        dims: DIMS,
        classes: 0,
    };
    let train = slice(0, n_train, "kdd_train");
    // interleave normal + attack test samples deterministically
    let test_n = slice(n_train, n_train + n_test_normal, "kdd_test_norm");
    let test_a = slice(n_train + n_test_normal, total, "kdd_test_att");
    let mut test_x = Vec::new();
    let mut test_attack = Vec::new();
    let max_len = n_test_normal.max(n_test_attack);
    for i in 0..max_len {
        if i < n_test_normal {
            test_x.extend_from_slice(test_n.sample(i));
            test_attack.push(false);
        }
        if i < n_test_attack {
            test_x.extend_from_slice(test_a.sample(i));
            test_attack.push(true);
        }
    }
    let test = Dataset {
        name: "kdd_test".into(),
        x: test_x,
        y: Vec::new(),
        dims: DIMS,
        classes: 0,
    };
    KddSplit { train, test, test_attack }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sized_corpus() {
        let k = kdd(5292, 500, 500, 0);
        assert_eq!(k.train.len(), 5292);
        assert_eq!(k.test.len(), 1000);
        assert_eq!(k.test_attack.iter().filter(|&&a| a).count(), 500);
        assert_eq!(k.train.dims, 41);
    }

    #[test]
    fn attacks_sit_off_the_normal_manifold() {
        let k = kdd(500, 200, 200, 1);
        // centroid of normal training data
        let mut c = vec![0.0f64; 41];
        for i in 0..k.train.len() {
            for (d, v) in k.train.sample(i).iter().enumerate() {
                c[d] += *v as f64;
            }
        }
        for v in &mut c {
            *v /= k.train.len() as f64;
        }
        let dist = |s: &[f32]| -> f64 {
            s.iter()
                .zip(&c)
                .map(|(a, b)| (*a as f64 - b).abs())
                .sum::<f64>()
        };
        let (mut dn, mut da, mut nn, mut na) = (0.0, 0.0, 0, 0);
        for i in 0..k.test.len() {
            if k.test_attack[i] {
                da += dist(k.test.sample(i));
                na += 1;
            } else {
                dn += dist(k.test.sample(i));
                nn += 1;
            }
        }
        let (dn, da) = (dn / nn as f64, da / na as f64);
        assert!(da > 1.5 * dn, "attack {da} vs normal {dn}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(kdd(100, 10, 10, 5).train.x, kdd(100, 10, 10, 5).train.x);
    }
}
