//! Class-structured synthetic feature generator.
//!
//! Each class gets a smooth random template (low-frequency mixture of
//! cosines over the feature index — image-like/cepstrum-like spatial
//! correlation); samples are template + white noise, normalised into the
//! chip input range. This preserves exactly what the paper's
//! accuracy-shape experiments need: distinct, partially overlapping
//! class manifolds of the right dimensionality.

use super::{normalise, Dataset};
use crate::testing::Rng;

/// Generate `n` samples of `dims`-dim features over `classes` classes.
/// `noise` is the per-feature noise std relative to template amplitude.
pub fn class_blobs(
    name: &str,
    dims: usize,
    classes: usize,
    n: usize,
    noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0xDA7A);
    // Smooth class templates: sum of K random cosines over feature index.
    let k = 6;
    let mut templates = vec![0.0f64; classes * dims];
    for c in 0..classes {
        for _ in 0..k {
            let freq = rng.uniform(0.5, 8.0);
            let phase = rng.uniform(0.0, std::f64::consts::TAU);
            let amp = rng.uniform(0.4, 1.0);
            for d in 0..dims {
                let t = d as f64 / dims as f64;
                templates[c * dims + d] +=
                    amp * (std::f64::consts::TAU * freq * t + phase).cos();
            }
        }
    }
    let mut x = vec![0.0f32; n * dims];
    let mut y = vec![0usize; n];
    for i in 0..n {
        let c = i % classes; // balanced classes
        y[i] = c;
        for d in 0..dims {
            x[i * dims + d] =
                (templates[c * dims + d] + rng.normal(0.0, noise * 2.0)) as f32;
        }
    }
    normalise(&mut x, dims);
    Dataset { name: name.to_string(), x, y, dims, classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_balanced() {
        let d = class_blobs("t", 32, 4, 100, 0.3, 0);
        for c in 0..4 {
            assert_eq!(d.y.iter().filter(|&&y| y == c).count(), 25);
        }
    }

    #[test]
    fn classes_are_separated_in_feature_space() {
        // Within-class distance must be well below between-class distance,
        // otherwise accuracy experiments degenerate to chance.
        let d = class_blobs("t", 64, 3, 90, 0.3, 1);
        let centroid = |c: usize| -> Vec<f64> {
            let mut m = vec![0.0; 64];
            let mut k = 0;
            for i in 0..d.len() {
                if d.y[i] == c {
                    for (j, v) in d.sample(i).iter().enumerate() {
                        m[j] += *v as f64;
                    }
                    k += 1;
                }
            }
            m.iter().map(|v| v / k as f64).collect()
        };
        let c0 = centroid(0);
        let c1 = centroid(1);
        let between: f64 = c0
            .iter()
            .zip(&c1)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        // mean distance of class-0 samples to own centroid
        let mut within = 0.0;
        let mut k = 0;
        for i in 0..d.len() {
            if d.y[i] == 0 {
                within += d
                    .sample(i)
                    .iter()
                    .zip(&c0)
                    .map(|(a, b)| (*a as f64 - b) * (*a as f64 - b))
                    .sum::<f64>()
                    .sqrt();
                k += 1;
            }
        }
        within /= k as f64;
        assert!(between > 0.5 * within,
                "between {between} within {within}");
    }
}
