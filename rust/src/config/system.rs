//! Chip floorplan configuration (paper section VI.F).
//!
//! The evaluated system: 144 memristor neural cores + one digital
//! clustering core + one RISC configuration core + DMA, connected by a
//! statically routed 2-D mesh at 200 MHz, fed from 3-D stacked DRAM.

/// Full-system configuration. `Default` reproduces the paper's chip.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Number of memristor neural cores.
    pub neural_cores: usize,
    /// Mesh width (cores are laid out row-major on a mesh_w x mesh_h grid;
    /// the clustering core, RISC core and the memory port occupy extra
    /// mesh stops).
    pub mesh_w: usize,
    pub mesh_h: usize,
    /// Digital clock for routing + clustering core (Hz).
    pub clock_hz: f64,
    /// NoC link width in bits (section V.C: 8 bits per link).
    pub link_bits: usize,
    /// Input buffer bytes (section VI.F: 4 kB).
    pub input_buffer_bytes: usize,
    /// Output buffer bytes (section VI.F: 1 kB).
    pub output_buffer_bytes: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            neural_cores: 144,
            mesh_w: 12,
            mesh_h: 12,
            clock_hz: 200e6,
            link_bits: 8,
            input_buffer_bytes: 4 * 1024,
            output_buffer_bytes: 1024,
        }
    }
}

impl SystemConfig {
    /// Digital clock period in seconds.
    pub fn cycle_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// Mesh coordinates of neural core `id` (row-major).
    pub fn core_xy(&self, id: usize) -> (usize, usize) {
        (id % self.mesh_w, id / self.mesh_w)
    }

    /// Mesh stop used as the memory/DMA port (edge of the mesh, (0,0)).
    pub fn memory_port(&self) -> (usize, usize) {
        (0, 0)
    }

    /// Mesh stop of the clustering core (opposite corner, so NC traffic
    /// and clustering traffic do not share the same hot links).
    pub fn cluster_xy(&self) -> (usize, usize) {
        (self.mesh_w - 1, self.mesh_h - 1)
    }

    /// Sanity: the mesh must hold every neural core.
    pub fn validate(&self) -> Result<(), String> {
        if self.neural_cores > self.mesh_w * self.mesh_h {
            return Err(format!(
                "{} cores do not fit a {}x{} mesh",
                self.neural_cores, self.mesh_w, self.mesh_h
            ));
        }
        if self.link_bits == 0 || self.clock_hz <= 0.0 {
            return Err("degenerate link/clock config".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_chip() {
        let c = SystemConfig::default();
        assert_eq!(c.neural_cores, 144);
        assert_eq!((c.mesh_w, c.mesh_h), (12, 12));
        assert!(c.validate().is_ok());
        assert!((c.cycle_s() - 5e-9).abs() < 1e-15);
    }

    #[test]
    fn core_xy_roundtrip() {
        let c = SystemConfig::default();
        assert_eq!(c.core_xy(0), (0, 0));
        assert_eq!(c.core_xy(13), (1, 1));
        assert_eq!(c.core_xy(143), (11, 11));
    }

    #[test]
    fn oversubscribed_mesh_rejected() {
        let c = SystemConfig { neural_cores: 145, ..Default::default() };
        assert!(c.validate().is_err());
    }
}
