//! Application registry — paper Table I, Rust mirror of
//! `python/compile/apps.py`. Artifact names constructed here must match
//! the names `aot.py` writes.

/// Stochastic-BP training batch (per-sample, as on chip).
pub const TRAIN_BATCH: usize = 1;
/// Recognition batch streamed by the coordinator.
pub const FWD_BATCH: usize = 64;
/// Batched-training variant exported for the end-to-end example.
pub const BIG_TRAIN_BATCH: usize = 16;
/// Samples scanned inside one chunked train artifact (`*_trainchunk_cK`).
pub const TRAIN_CHUNK: usize = 32;
/// Tile (samples per shard job) of the data-parallel mini-batch
/// gradient phase (`Backend::grad_batch`): each tile of a mini-batch is
/// one worker-pool job, mirroring how the clustering core's batch-sized
/// passes shard `Engine::kmeans`. Shard boundaries depend only on the
/// mini-batch size and this tile — never the worker count — which is
/// what makes mini-batch training bit-identical at any pool size.
pub const GRAD_TILE: usize = 8;

/// What kind of workload an application is (drives mapping + reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppKind {
    /// Supervised classifier trained with full BP.
    Classifier,
    /// Plain autoencoder (trained directly as a 2-layer net).
    Autoencoder,
    /// Deep dimensionality-reduction stack trained layer-by-layer.
    DimReduction,
    /// k-means on the clustering core (input dims already reduced).
    Kmeans,
}

/// A neural-network application (one row of Table I).
#[derive(Clone, Debug)]
pub struct Network {
    pub name: &'static str,
    pub layers: &'static [usize],
    pub kind: AppKind,
    /// Number of classes for classifiers (argmax decode), 0 otherwise.
    pub classes: usize,
}

/// A clustering application: (feature dims, cluster count).
#[derive(Clone, Debug)]
pub struct App {
    pub name: &'static str,
    pub dims: usize,
    pub clusters: usize,
}

/// Table I networks.
pub const NETWORKS: &[Network] = &[
    Network { name: "iris_class", layers: &[4, 10, 1], kind: AppKind::Classifier, classes: 2 },
    Network { name: "iris_ae", layers: &[4, 2, 4], kind: AppKind::Autoencoder, classes: 0 },
    Network { name: "kdd_ae", layers: &[41, 15, 41], kind: AppKind::Autoencoder, classes: 0 },
    Network { name: "mnist_class", layers: &[784, 300, 200, 100, 10], kind: AppKind::Classifier, classes: 10 },
    Network { name: "mnist_dr", layers: &[784, 300, 200, 100, 20], kind: AppKind::DimReduction, classes: 0 },
    Network { name: "isolet_class", layers: &[617, 2000, 1000, 500, 250, 26], kind: AppKind::Classifier, classes: 26 },
    Network { name: "isolet_dr", layers: &[617, 2000, 1000, 500, 250, 20], kind: AppKind::DimReduction, classes: 0 },
];

/// Clustering-core problems (dims after dimensionality reduction).
pub const KMEANS_APPS: &[App] = &[
    App { name: "mnist_kmeans", dims: 20, clusters: 10 },
    App { name: "isolet_kmeans", dims: 20, clusters: 26 },
];

/// Look up a network by name.
pub fn network(name: &str) -> Option<&'static Network> {
    NETWORKS.iter().find(|n| n.name == name)
}

/// Look up a clustering app by name.
pub fn kmeans_app(name: &str) -> Option<&'static App> {
    KMEANS_APPS.iter().find(|a| a.name == name)
}

impl Network {
    /// Per-layer (n_in, n_out) pairs; n_in excludes the bias row.
    pub fn layer_shapes(&self) -> Vec<(usize, usize)> {
        self.layers.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// Total differential synapse pairs, bias rows included.
    pub fn synapses(&self) -> usize {
        self.layer_shapes().iter().map(|(i, o)| (i + 1) * o).sum()
    }

    /// Total neurons over all layers.
    pub fn neurons(&self) -> usize {
        self.layers[1..].iter().sum()
    }

    /// Layerwise-pretraining stages for DR apps: (n_in, n_hidden) pairs.
    pub fn dr_stages(&self) -> Vec<(usize, usize)> {
        self.layer_shapes()
    }

    /// Artifact name of the per-sample training graph.
    pub fn train_artifact(&self) -> String {
        format!("{}_train_b{}", self.name, TRAIN_BATCH)
    }

    /// Artifact name of the recognition graph.
    pub fn fwd_artifact(&self) -> String {
        format!("{}_fwd_b{}", self.name, FWD_BATCH)
    }

    /// Artifact name of a DR pretraining stage.
    pub fn stage_artifact(&self, stage: usize) -> String {
        format!("{}_stage{}_train_b{}", self.name, stage, TRAIN_BATCH)
    }

    /// Artifact name of the gradient-batch graph (`model.mlp_grad_batch`,
    /// one [`GRAD_TILE`]-sample tile of a data-parallel mini-batch).
    pub fn grad_artifact(&self) -> String {
        format!("{}_grad_t{}", self.name, GRAD_TILE)
    }

    /// Artifact name of a DR pretraining stage's gradient-batch graph.
    pub fn stage_grad_artifact(&self, stage: usize) -> String {
        format!("{}_stage{}_grad_t{}", self.name, stage, GRAD_TILE)
    }
}

impl App {
    /// Artifact name of the clustering step graph.
    pub fn step_artifact(&self) -> String {
        format!("{}_step_b{}", self.name, FWD_BATCH)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_complete() {
        assert_eq!(NETWORKS.len(), 7);
        assert_eq!(KMEANS_APPS.len(), 2);
        assert!(network("mnist_class").is_some());
        assert!(network("nope").is_none());
    }

    #[test]
    fn layer_shapes_and_synapses() {
        let n = network("mnist_class").unwrap();
        assert_eq!(n.layer_shapes(), vec![(784, 300), (300, 200), (200, 100), (100, 10)]);
        assert_eq!(n.synapses(), 785 * 300 + 301 * 200 + 201 * 100 + 101 * 10);
        assert_eq!(n.neurons(), 610);
    }

    #[test]
    fn artifact_names_match_python_side() {
        let n = network("kdd_ae").unwrap();
        assert_eq!(n.train_artifact(), "kdd_ae_train_b1");
        assert_eq!(n.fwd_artifact(), "kdd_ae_fwd_b64");
        assert_eq!(n.grad_artifact(), "kdd_ae_grad_t8");
        let d = network("mnist_dr").unwrap();
        assert_eq!(d.stage_artifact(2), "mnist_dr_stage2_train_b1");
        assert_eq!(d.stage_grad_artifact(2), "mnist_dr_stage2_grad_t8");
        let k = kmeans_app("isolet_kmeans").unwrap();
        assert_eq!(k.step_artifact(), "isolet_kmeans_step_b64");
    }

    #[test]
    fn kmeans_apps_fit_clustering_core() {
        use crate::config::hwspec;
        for a in KMEANS_APPS {
            assert!(a.dims <= hwspec::KMEANS_MAX_DIM);
            assert!(a.clusters <= hwspec::KMEANS_MAX_CENTRES);
        }
    }
}
