//! Hardware constraint constants — Rust mirror of `python/compile/hwspec.py`.
//!
//! Every number traces to the paper; see the Python twin for the full
//! citations. `python/tests/test_hwspec_mirror.py` asserts the two files
//! agree, so change both together.

/// Op-amp output rails (volts); also the numeric range of activations.
pub const V_RAIL: f32 = 0.5;

/// h(x) linear-region slope: h(x) = x/4 for |x| < 2 (paper Eq. 3).
pub const H_SLOPE: f32 = 0.25;
/// h(x) input clip point.
pub const H_CLIP_IN: f32 = 2.0;

/// Neuron-output ADC precision (paper section IV.A).
pub const OUT_BITS: u32 = 3;
/// Error ADC precision: 1 sign + 7 magnitude bits (paper section III.F).
pub const ERR_BITS: u32 = 8;
/// Error ADC full-scale range.
pub const ERR_MAX: f32 = 1.0;
/// f'(DP) lookup-table entries (training unit, section III.F step 3).
pub const LUT_SIZE: usize = 64;

/// Crossbar rows: 400 inputs per neural core, bias row included.
pub const CORE_INPUTS: usize = 400;
/// Differential neurons per core (400x200 crossbar = 100 neuron pairs).
pub const CORE_NEURONS: usize = 100;

/// Normalised conductance bounds (R_off/R_on ~ 1000, section III.A).
pub const G_MIN: f32 = 0.001;
pub const G_MAX: f32 = 1.0;

/// Maximum representable weight |g+ - g-|.
pub const W_MAX: f32 = G_MAX - G_MIN;

/// Clustering core limits (paper section IV.B).
pub const KMEANS_MAX_CENTRES: usize = 32;
pub const KMEANS_MAX_DIM: usize = 32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_range_consistent() {
        assert!(G_MIN > 0.0 && G_MIN < G_MAX);
        assert!((W_MAX - (G_MAX - G_MIN)).abs() < 1e-9);
    }

    #[test]
    fn activation_clip_maps_to_rail() {
        // h(H_CLIP_IN) must land exactly on the rail: 2 * 0.25 = 0.5.
        assert!((H_CLIP_IN * H_SLOPE - V_RAIL).abs() < 1e-9);
    }

    #[test]
    fn crossbar_is_400x200() {
        // 100 differential neurons = 200 physical columns.
        assert_eq!(CORE_INPUTS, 400);
        assert_eq!(CORE_NEURONS * 2, 200);
    }
}
