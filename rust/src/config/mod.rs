//! System, hardware and application configuration.
//!
//! [`hwspec`] mirrors `python/compile/hwspec.py` (the two files are the
//! twin sources of truth for the chip's numeric constraints — keep them in
//! lock-step); [`apps`] mirrors `python/compile/apps.py` (paper Table I);
//! [`system`] describes the chip floorplan (paper section VI.F).

pub mod apps;
pub mod hwspec;
pub mod system;

pub use apps::{App, AppKind, Network};
pub use system::SystemConfig;
