//! `.meta` sidecar parser: the exact parameter/result shapes `aot.py`
//! recorded for each artifact. Format, one line per tensor:
//!
//! ```text
//! input 0 f32[1x41]
//! output 0 f32[42x15]
//! ```

use std::path::Path;

use super::ArrayF32;

/// Parsed artifact signature.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Meta {
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta, String> {
        let mut m = Meta::default();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts.next().ok_or(format!("line {ln}: empty"))?;
            let idx: usize = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or(format!("line {ln}: bad index"))?;
            let ty = parts.next().ok_or(format!("line {ln}: no type"))?;
            let shape = parse_shape(ty).ok_or(format!("line {ln}: bad type {ty}"))?;
            let list = match kind {
                "input" => &mut m.inputs,
                "output" => &mut m.outputs,
                other => return Err(format!("line {ln}: unknown kind {other}")),
            };
            if idx != list.len() {
                return Err(format!("line {ln}: out-of-order index {idx}"));
            }
            list.push(shape);
        }
        Ok(m)
    }

    pub fn parse_file(path: &Path) -> Result<Meta, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Check a host input set against the recorded signature.
    pub fn validate_inputs(&self, inputs: &[ArrayF32]) -> Result<(), String> {
        if inputs.len() != self.inputs.len() {
            return Err(format!(
                "{} inputs given, artifact wants {}",
                inputs.len(),
                self.inputs.len()
            ));
        }
        for (i, (a, want)) in inputs.iter().zip(&self.inputs).enumerate() {
            if &a.shape != want {
                return Err(format!(
                    "input {i}: shape {:?}, artifact wants {:?}",
                    a.shape, want
                ));
            }
        }
        Ok(())
    }
}

fn parse_shape(ty: &str) -> Option<Vec<usize>> {
    let body = ty.strip_prefix("f32[")?.strip_suffix(']')?;
    if body == "scalar" {
        return Some(vec![]);
    }
    body.split('x').map(|d| d.parse().ok()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let m = Meta::parse(
            "input 0 f32[1x41]\ninput 1 f32[1x1]\noutput 0 f32[42x15]\n",
        )
        .unwrap();
        assert_eq!(m.inputs, vec![vec![1, 41], vec![1, 1]]);
        assert_eq!(m.outputs, vec![vec![42, 15]]);
    }

    #[test]
    fn rejects_out_of_order_and_garbage() {
        assert!(Meta::parse("input 1 f32[2]").is_err());
        assert!(Meta::parse("frob 0 f32[2]").is_err());
        assert!(Meta::parse("input 0 i8[2]").is_err());
    }

    #[test]
    fn validate_inputs_catches_drift() {
        let m = Meta::parse("input 0 f32[1x4]").unwrap();
        assert!(m.validate_inputs(&[ArrayF32::row(vec![0.0; 4])]).is_ok());
        assert!(m.validate_inputs(&[ArrayF32::row(vec![0.0; 5])]).is_err());
        assert!(m.validate_inputs(&[]).is_err());
    }
}
