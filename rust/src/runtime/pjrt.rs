//! PJRT backend (cargo feature `pjrt`): loads the AOT artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the CPU PJRT client
//! from the Rust request path.
//!
//! Interchange is HLO *text* — jax >= 0.5 emits HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py` and DESIGN.md).
//! Each artifact ships a `.meta` sidecar with its exact parameter/result
//! shapes; [`Executable::run`] validates inputs against it, so a
//! python/rust drift fails loudly at the call site instead of inside XLA.
//!
//! Compiled executables are cached per runtime, and parameters can stay
//! device-resident across calls via [`Executable::run_buffers`] — the
//! training hot loop only uploads the sample, not the weights.
//!
//! Thread safety: [`Backend`] requires `Send + Sync` (the coordinator's
//! worker pool shares one backend across shard threads). The only
//! mutable state here is the executable cache, which [`Runtime`] guards
//! behind a `Mutex`; compiled [`Executable`]s are shared as `Arc`s and
//! execution itself takes `&self`. The real `xla` crate's handle types
//! wrap thread-safe PJRT C-API objects, matching the vendored stub's
//! plain owned structs.
//!
//! The default build links `rust/vendor/xla`, an API stub whose device
//! operations report unavailability at runtime; swap that path
//! dependency for the published `xla` crate (plus an installed
//! `xla_extension`) to execute artifacts for real. Graph-level
//! [`Backend`] calls go through artifacts; the kernel-level entry
//! points inherit the bit-compatible host reference, which is exactly
//! what the artifacts are integration-tested against.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::backend::{Backend, FwdMode, GradBatch, KmeansStep};
use super::{ArrayF32, Meta};

/// A loaded, compiled artifact.
pub struct Executable {
    pub name: String,
    pub meta: Meta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host arrays; returns host arrays per the meta shapes.
    pub fn run(&self, inputs: &[ArrayF32]) -> Result<Vec<ArrayF32>> {
        self.meta.validate_inputs(inputs).map_err(|e| anyhow!(e))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(ArrayF32::to_literal)
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        self.unpack(result)
    }

    /// Execute with device-resident buffers (no host round-trip for the
    /// inputs). Returns the raw output buffers of the result tuple.
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer])
        -> Result<Vec<xla::PjRtBuffer>> {
        let out = self.exe.execute_b(inputs)?;
        let row = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no replica output"))?;
        Ok(row)
    }

    /// Upload a host array to the device.
    pub fn to_device(&self, a: &ArrayF32) -> Result<xla::PjRtBuffer> {
        let client = self.exe.client();
        let dims: Vec<usize> = a.shape.clone();
        Ok(client.buffer_from_host_buffer::<f32>(&a.data, &dims, None)?)
    }

    /// Download a device buffer into a host array with `shape`.
    pub fn to_host(&self, b: &xla::PjRtBuffer, shape: &[usize])
        -> Result<ArrayF32> {
        let lit = b.to_literal_sync()?;
        let data = lit.to_vec::<f32>()?;
        ArrayF32::new(shape.to_vec(), data).map_err(|e| anyhow!(e))
    }

    fn unpack(&self, result: xla::Literal) -> Result<Vec<ArrayF32>> {
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: {} outputs, meta says {}",
                self.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, shape)| {
                let data = lit.to_vec::<f32>()?;
                ArrayF32::new(shape.clone(), data).map_err(|e| anyhow!(e))
            })
            .collect()
    }
}

/// Artifact loader + executable cache over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Open a runtime over an artifacts directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!(
                "artifacts directory {} missing — run `make artifacts`",
                dir.display()
            );
        }
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Open at the conventional location: `$RESTREAM_ARTIFACTS` or
    /// `./artifacts`.
    pub fn open_default() -> Result<Self> {
        // lint: allow(D2) — $RESTREAM_ARTIFACTS is an explicit config
        // knob naming *where* compiled artifacts live, read once at
        // construction; it never influences what an executable
        // computes.
        let dir = std::env::var("RESTREAM_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Load (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let hlo = self.dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.dir.join(format!("{name}.meta"));
        let meta = Meta::parse_file(&meta_path)
            .map_err(|e| anyhow!("meta for {name}: {e}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = Arc::new(Executable {
            name: name.to_string(),
            meta,
            exe,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// The artifact-executing backend. Graph-level operations map one-to-one
/// onto the AOT artifacts `python/compile/aot.py` exports; the `graph`
/// argument of each [`Backend`] call is the artifact name.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn new(rt: Runtime) -> Self {
        PjrtBackend { rt }
    }

    /// Open over `$RESTREAM_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        Ok(PjrtBackend::new(Runtime::open_default()?))
    }

    /// The underlying artifact runtime (for artifact-level tooling).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train_step(
        &self,
        graph: &str,
        params: Vec<ArrayF32>,
        x: &ArrayF32,
        t: &ArrayF32,
        lr: f32,
    ) -> Result<(Vec<ArrayF32>, f32)> {
        let exe = self.rt.load(graph)?;
        let n_params = params.len();
        let mut ins = params;
        ins.push(x.clone());
        ins.push(t.clone());
        ins.push(ArrayF32::scalar(lr));
        let mut outs = exe.run(&ins)?;
        let loss = outs
            .pop()
            .ok_or_else(|| anyhow!("{graph} returned nothing"))?;
        ensure!(
            outs.len() == n_params,
            "{graph} returned {} params, expected {n_params}",
            outs.len()
        );
        Ok((outs, loss.data[0]))
    }

    /// K is recorded in the chunk artifact's meta (`xs` is the third
    /// input from the end: `params…, xs, ts, lr`). Artifact trees that
    /// predate chunking simply fall back to the per-sample path.
    fn chunk_size(&self, chunk_graph: &str) -> usize {
        match self.rt.load(chunk_graph) {
            Ok(exe) if exe.meta.inputs.len() >= 3 => {
                exe.meta.inputs[exe.meta.inputs.len() - 3][0]
            }
            _ => 0,
        }
    }

    fn train_chunk(
        &self,
        graph: &str,
        params: Vec<ArrayF32>,
        xs: &ArrayF32,
        ts: &ArrayF32,
        lr: f32,
    ) -> Result<(Vec<ArrayF32>, Vec<f32>)> {
        let exe = self.rt.load(graph)?;
        let n_params = params.len();
        let mut ins = params;
        ins.push(xs.clone());
        ins.push(ts.clone());
        ins.push(ArrayF32::scalar(lr));
        let mut outs = exe.run(&ins)?;
        let losses = outs
            .pop()
            .ok_or_else(|| anyhow!("{graph} returned nothing"))?;
        ensure!(
            outs.len() == n_params,
            "{graph} returned {} params, expected {n_params}",
            outs.len()
        );
        Ok((outs, losses.data))
    }

    /// The artifact's tile is fixed at lowering time; report it (`xs`
    /// is the second input from the end: `params…, xs, ts`) so the
    /// coordinator can reject ragged mini-batch configurations before
    /// training starts rather than erroring mid-epoch. A load failure
    /// propagates — unlike [`Backend::chunk_size`]'s fallback-to-0
    /// (where a missing chunk artifact legitimately means "use the
    /// per-sample path"), there is no gradient path without this
    /// artifact, so swallowing the error would only defer it to the
    /// first mini-batch.
    fn grad_tile(&self, grad_graph: &str) -> Result<usize> {
        let exe = self.rt.load(grad_graph)?;
        ensure!(
            exe.meta.inputs.len() >= 2,
            "{grad_graph}: meta lists {} inputs, expected params…, xs, ts",
            exe.meta.inputs.len()
        );
        Ok(exe.meta.inputs[exe.meta.inputs.len() - 2][0])
    }

    /// Gradient tile through the `{app}_grad_tK` artifact
    /// (`model.mlp_grad_batch`): inputs `params…, xs, ts`, outputs one
    /// per-layer accumulator each plus the per-sample losses. The
    /// artifact's tile size is fixed at lowering time; the coordinator
    /// pre-checks it via [`Backend::grad_tile`], and the meta sidecar
    /// validation still rejects any ragged shard loudly at the call.
    /// The companion weight update stays on the trait's host default
    /// ([`Backend::apply_grads`]) — it is cheap elementwise math shared
    /// bit-for-bit by every backend, and keeping it on the host spares
    /// a per-mini-batch artifact round-trip of every conductance matrix.
    fn grad_batch(
        &self,
        graph: &str,
        params: &[ArrayF32],
        xs: &ArrayF32,
        ts: &ArrayF32,
    ) -> Result<GradBatch> {
        let exe = self.rt.load(graph)?;
        let mut ins = params.to_vec();
        ins.push(xs.clone());
        ins.push(ts.clone());
        let mut outs = exe.run(&ins)?;
        let losses = outs
            .pop()
            .ok_or_else(|| anyhow!("{graph} returned nothing"))?;
        ensure!(
            outs.len() == params.len() / 2,
            "{graph} returned {} gradient arrays, expected {}",
            outs.len(),
            params.len() / 2
        );
        Ok(GradBatch { grads: outs, losses: losses.data })
    }

    fn forward_batch(
        &self,
        graph: &str,
        _mode: FwdMode,
        params: &[ArrayF32],
        xs: &ArrayF32,
    ) -> Result<Vec<ArrayF32>> {
        let exe = self.rt.load(graph)?;
        let mut ins = params.to_vec();
        ins.push(xs.clone());
        exe.run(&ins)
    }

    fn kmeans_batch(
        &self,
        graph: &str,
        xs: &ArrayF32,
        centres: &ArrayF32,
    ) -> Result<KmeansStep> {
        let exe = self.rt.load(graph)?;
        let outs = exe.run(&[xs.clone(), centres.clone()])?;
        ensure!(outs.len() == 3, "{graph}: expected (assign, acc, counts)");
        let (k, dims) = (centres.shape[0], centres.shape[1]);
        // assignments travel as f32 (see model.kmeans_step); exact ints
        let assign = outs[0].data.iter().map(|&v| v as usize).collect();
        Ok(KmeansStep {
            assign,
            acc: outs[1].data.clone(),
            counts: outs[2].data.clone(),
            k,
            dims,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_fails_with_hint() {
        let err = match Runtime::open("/nonexistent/artifacts") {
            Err(e) => e,
            Ok(_) => panic!("open should fail on a missing directory"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
