//! The pluggable compute backend: every functional-math operation the
//! coordinator, the CLI, the integration tests and the benches perform
//! goes through this trait.
//!
//! Two levels of entry point:
//!
//! * **Kernel-level** — [`Backend::forward`], [`Backend::backward`],
//!   [`Backend::weight_update`], [`Backend::kmeans_step`]: the four L1
//!   kernels (differential crossbar fwd/bwd, training-pulse update, the
//!   clustering-core pass). Default implementations run the bit-exact
//!   host reference (`crossbar::ideal` + the k-means datapath), the same
//!   math `python/compile/kernels/ref.py` specifies.
//! * **Graph-level** — [`Backend::train_step`], [`Backend::train_chunk`],
//!   [`Backend::forward_batch`], [`Backend::kmeans_batch`]: the composed
//!   training/recognition graphs the streaming coordinator drives. The
//!   `graph` argument is the artifact name (`iris_class_train_b1`, …);
//!   the [native backend](super::NativeBackend) ignores it and composes
//!   the kernels in-process, while the PJRT backend (cargo feature
//!   `pjrt`) uses it to select the matching AOT-lowered HLO artifact.
//!
//! Both backends implement the same per-sample stochastic-BP semantics
//! (paper section III.E), so reports, loss curves and trained weights
//! are interchangeable — `tests/backend_parity.rs` pins the kernel
//! semantics to goldens generated from `ref.py`.

use anyhow::Result;

use super::native;
use super::ArrayF32;
use crate::config::apps;

/// Output convention of [`Backend::forward_batch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwdMode {
    /// Final-layer outputs only: `[y]` — classifiers and DR encoder
    /// stacks (`*_fwd_b64` artifacts of those apps).
    Final,
    /// Autoencoder convention: `[reconstruction, bottleneck code]`.
    ReconAndCode,
}

impl FwdMode {
    /// The forward-graph output convention of an application kind —
    /// the single source of the AppKind→outputs mapping (mirrors which
    /// graph `aot.py` exports per app).
    pub fn for_kind(kind: crate::config::AppKind) -> FwdMode {
        if kind == crate::config::AppKind::Autoencoder {
            FwdMode::ReconAndCode
        } else {
            FwdMode::Final
        }
    }
}

/// Result of [`Backend::grad_batch`]: the per-layer gradient
/// accumulators of a mini-batch (or one shard of one), with the
/// training pulse withheld so a data-parallel caller can reduce several
/// shards' accumulators and apply one weight update per mini-batch
/// ([`Backend::apply_grads`]).
#[derive(Clone, Debug)]
pub struct GradBatch {
    /// One accumulator per *layer* (not per conductance matrix), shaped
    /// like that layer's `gp`/`gn` (`(n_in+1, n_out)`, bias row
    /// included): `sum_b x_b^T @ quantize_err(delta_b * f'(dp_b))`,
    /// summed over the batch rows in order. The update applies `+dw/2`
    /// to `g+` and `-dw/2` to `g-`, so one accumulator drives both
    /// halves of the differential pair.
    pub grads: Vec<ArrayF32>,
    /// Per-sample pre-update mean squared errors, in batch-row order.
    pub losses: Vec<f32>,
}

/// Result of one clustering-core pass over a batch (Fig 13 datapath):
/// per-sample assignments plus the centre-accumulator registers, so the
/// coordinator can fold batches into an epoch and divide at the end.
#[derive(Clone, Debug)]
pub struct KmeansStep {
    /// Winning centre per sample.
    pub assign: Vec<usize>,
    /// Per-centre coordinate accumulators, `k x dims` row-major.
    pub acc: Vec<f32>,
    /// Per-centre member counts (f32 to mirror the artifact signature).
    pub counts: Vec<f32>,
    /// Number of centres.
    pub k: usize,
    /// Feature dimensionality.
    pub dims: usize,
}

/// A compute backend for the chip's functional math.
///
/// # Thread safety
///
/// `Backend` requires `Send + Sync`: the coordinator's worker pool
/// (`coordinator::pool`) calls the graph-level operations concurrently
/// from its shard workers, sharing one backend by reference.
/// Implementations must be internally synchronised — [`NativeBackend`]
/// is a stateless unit struct, and the `pjrt` backend guards its
/// executable cache behind `Arc<Mutex<…>>` (the compiler enforces the
/// bound on every implementor; `backends_are_thread_safe` below pins
/// it explicitly).
pub trait Backend: Send + Sync {
    /// Short identifier ("native", "pjrt") for logs and reports.
    fn name(&self) -> &'static str;

    // ----- kernel-level entry points (the four L1 kernels) -----

    /// Differential-crossbar forward: `x` is `(batch, n_in)` including
    /// the bias row voltage, `gp`/`gn` are `(n_in, n_out)`. Returns the
    /// quantised neuron outputs `y` and the raw dot products `dp`, both
    /// `(batch, n_out)` — mirroring `ref.crossbar_fwd`.
    fn forward(
        &self,
        x: &ArrayF32,
        gp: &ArrayF32,
        gn: &ArrayF32,
        out_bits: u32,
    ) -> Result<(ArrayF32, ArrayF32)> {
        native::crossbar_forward(x, gp, gn, out_bits)
    }

    /// Error back-propagation through the transposed crossbar plus the
    /// 8-bit error ADC: `delta` is `(batch, n_out)`, the result is
    /// `(batch, n_in)` *including* the bias row — `ref.crossbar_bwd`.
    fn backward(
        &self,
        delta: &ArrayF32,
        gp: &ArrayF32,
        gn: &ArrayF32,
    ) -> Result<ArrayF32> {
        native::crossbar_backward(delta, gp, gn)
    }

    /// Training-pulse conductance update (`ref.weight_update`): returns
    /// the clipped `(gp', gn')`. Gradients are accumulated over the
    /// batch dimension, so `batch > 1` performs mini-batch SGD.
    fn weight_update(
        &self,
        gp: &ArrayF32,
        gn: &ArrayF32,
        x: &ArrayF32,
        delta: &ArrayF32,
        dp: &ArrayF32,
        lr: f32,
    ) -> Result<(ArrayF32, ArrayF32)> {
        native::crossbar_update(gp, gn, x, delta, dp, lr)
    }

    /// One clustering-core pass (`ref.kmeans_distances` + argmin +
    /// accumulate): `x` is `(batch, dims)`, `centres` is `(k, dims)`.
    fn kmeans_step(
        &self,
        x: &ArrayF32,
        centres: &ArrayF32,
    ) -> Result<KmeansStep> {
        native::kmeans_pass(x, centres)
    }

    // ----- graph-level composed operations -----

    /// One stochastic-BP step over a batch (`model.mlp_train_step`):
    /// consumes the parameter list `[gp0, gn0, gp1, gn1, …]`, returns
    /// the updated parameters and the mean squared-error loss of the
    /// batch *before* the update.
    fn train_step(
        &self,
        graph: &str,
        params: Vec<ArrayF32>,
        x: &ArrayF32,
        t: &ArrayF32,
        lr: f32,
    ) -> Result<(Vec<ArrayF32>, f32)> {
        let _ = graph;
        let mut params = params;
        let loss = native::train_step(&mut params, x, t, lr)?;
        Ok((params, loss))
    }

    /// Samples per [`Backend::train_chunk`] call for a chunk graph name,
    /// or 0 if the backend has no chunked variant of it and the
    /// coordinator must stay on the per-sample path.
    fn chunk_size(&self, chunk_graph: &str) -> usize {
        let _ = chunk_graph;
        0
    }

    /// Scan `chunk_size` samples of per-sample stochastic BP in one call
    /// (`model.mlp_train_chunk`): semantically identical to calling
    /// [`Backend::train_step`] on each row of `xs`/`ts` in order.
    /// Returns updated parameters plus the per-sample losses.
    fn train_chunk(
        &self,
        graph: &str,
        params: Vec<ArrayF32>,
        xs: &ArrayF32,
        ts: &ArrayF32,
        lr: f32,
    ) -> Result<(Vec<ArrayF32>, Vec<f32>)> {
        let _ = graph;
        let mut params = params;
        let losses = native::train_chunk(&mut params, xs, ts, lr)?;
        Ok((params, losses))
    }

    /// Per-layer gradient sums of a mini-batch (or one shard of one)
    /// with the weight update *withheld* (`model.mlp_grad_batch`): the
    /// same forward/backward dataflow as [`Backend::train_step`], but
    /// the per-layer `x^T @ quantize_err(delta * f'(dp))` accumulators
    /// are returned for the caller to reduce and apply
    /// ([`Backend::apply_grads`]).
    ///
    /// Contract (pinned by `grad_then_apply_equals_train_step` below):
    /// `grad_batch` on a single sample followed by `apply_grads` is
    /// **bitwise identical** to [`Backend::train_step`] on that sample
    /// — batch size 1 recovers the paper's per-sample stochastic BP
    /// exactly. Rows of `xs`/`ts` contribute to the accumulators in
    /// order, so a fixed shard split reduces deterministically.
    fn grad_batch(
        &self,
        graph: &str,
        params: &[ArrayF32],
        xs: &ArrayF32,
        ts: &ArrayF32,
    ) -> Result<GradBatch> {
        let _ = graph;
        native::grad_batch(params, xs, ts)
    }

    /// Fixed gradient-tile constraint of `grad_graph`: the exact number
    /// of samples every [`Backend::grad_batch`] call must carry, or
    /// `Ok(0)` when the backend accepts any shard shape (the native
    /// path). An `Err` means the gradient graph itself is unusable
    /// (missing/corrupt artifact) — mini-batch training cannot proceed
    /// at all. The coordinator consults this **before** training
    /// starts, so both a ragged mini-batch/dataset combination and a
    /// broken artifact fail fast instead of erroring mid-epoch with
    /// updates already applied.
    fn grad_tile(&self, grad_graph: &str) -> Result<usize> {
        let _ = grad_graph;
        Ok(0)
    }

    /// Fire one training pulse from (possibly shard-summed) gradient
    /// accumulators: `dw = lr * acc`, `g+ += dw/2`, `g- -= dw/2`,
    /// clipped to the device conductance range — the update tail of the
    /// `weight_update` kernel with the accumulation factored out. This
    /// is cheap elementwise host math shared verbatim by every backend
    /// (the artifact path computes gradients on device but pulses the
    /// crossbar model identically), which is what keeps mini-batch
    /// results backend-portable.
    fn apply_grads(
        &self,
        graph: &str,
        params: Vec<ArrayF32>,
        grads: &[ArrayF32],
        lr: f32,
    ) -> Result<Vec<ArrayF32>> {
        let _ = graph;
        native::apply_grads(params, grads, lr)
    }

    /// Batched recognition through the full crossbar stack
    /// (`model.mlp_infer` / `model.ae_fwd`): `xs` is `(batch, n_in)`;
    /// the output list follows `mode`.
    fn forward_batch(
        &self,
        graph: &str,
        mode: FwdMode,
        params: &[ArrayF32],
        xs: &ArrayF32,
    ) -> Result<Vec<ArrayF32>> {
        let _ = graph;
        native::forward_batch(mode, params, xs)
    }

    /// One clustering-core pass addressed by graph name — the batched
    /// twin of [`Backend::kmeans_step`] (`model.kmeans_step` artifact).
    fn kmeans_batch(
        &self,
        graph: &str,
        xs: &ArrayF32,
        centres: &ArrayF32,
    ) -> Result<KmeansStep> {
        let _ = graph;
        self.kmeans_step(xs, centres)
    }
}

/// The default backend: the reference kernels executed in-process, no
/// artifacts, no Python, no XLA — runs everywhere the crate compiles.
/// Multi-sample calls ([`Backend::train_chunk`], mini-batch
/// [`Backend::train_step`], [`Backend::forward_batch`]) execute batched
/// inner loops, which is what `benches/perf_hotpath.rs` measures.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    /// The native path always offers the chunked hot loop: grouping
    /// samples saves per-step dispatch and keeps the coordinator on the
    /// same streaming path both backends share.
    fn chunk_size(&self, _chunk_graph: &str) -> usize {
        apps::TRAIN_CHUNK
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn rand_params(layers: &[usize], seed: u64) -> Vec<ArrayF32> {
        crate::coordinator::init_conductances(layers, seed)
    }

    #[test]
    fn backends_are_thread_safe() {
        // The worker pool shares one backend across shard threads;
        // pin Send + Sync for every implementor and for the boxed
        // trait object the Engine holds.
        fn assert_send_sync<T: Send + Sync + ?Sized>() {}
        assert_send_sync::<NativeBackend>();
        assert_send_sync::<dyn Backend>();
        assert_send_sync::<Box<dyn Backend>>();
        #[cfg(feature = "pjrt")]
        assert_send_sync::<crate::runtime::PjrtBackend>();
    }

    #[test]
    fn train_chunk_equals_sequential_train_steps() {
        let b: &dyn Backend = &NativeBackend;
        let layers = [4, 6, 2];
        let mut rng = Rng::seeded(11);
        let k = 5;
        let xs = ArrayF32::matrix(k, 4, rng.vec_uniform(k * 4, -0.5, 0.5))
            .unwrap();
        let ts = ArrayF32::matrix(k, 2, rng.vec_uniform(k * 2, -0.4, 0.4))
            .unwrap();
        let (chunked, losses) = b
            .train_chunk("g", rand_params(&layers, 3), &xs, &ts, 0.9)
            .unwrap();
        assert_eq!(losses.len(), k);
        let mut params = rand_params(&layers, 3);
        for i in 0..k {
            let x = ArrayF32::row(xs.row_slice(i).to_vec());
            let t = ArrayF32::row(ts.row_slice(i).to_vec());
            let (next, loss) = b.train_step("g", params, &x, &t, 0.9).unwrap();
            params = next;
            assert_eq!(loss, losses[i], "sample {i}");
        }
        for (a, c) in params.iter().zip(&chunked) {
            assert_eq!(a.data, c.data);
        }
    }

    #[test]
    fn grad_then_apply_equals_train_step() {
        // The batch-1 recovery contract: computing the gradient and
        // firing the pulse separately must be bitwise identical to the
        // fused per-sample step, on shallow and deep stacks.
        let b: &dyn Backend = &NativeBackend;
        for (layers, seed) in
            [(&[4usize, 6, 2][..], 3u64), (&[8, 6, 5, 3][..], 7)]
        {
            let mut rng = Rng::seeded(seed);
            let x = ArrayF32::row(rng.vec_uniform(layers[0], -0.5, 0.5));
            let t = ArrayF32::row(
                rng.vec_uniform(layers[layers.len() - 1], -0.4, 0.4),
            );
            let params = rand_params(layers, seed);
            let (ref_params, ref_loss) =
                b.train_step("g", params.clone(), &x, &t, 0.8).unwrap();
            let gb = b.grad_batch("g", &params, &x, &t).unwrap();
            assert_eq!(gb.losses.len(), 1);
            assert_eq!(gb.losses[0], ref_loss, "{layers:?}");
            assert_eq!(gb.grads.len(), layers.len() - 1);
            let applied =
                b.apply_grads("g", params, &gb.grads, 0.8).unwrap();
            for (l, (a, r)) in applied.iter().zip(&ref_params).enumerate()
            {
                assert_eq!(a.data, r.data, "{layers:?} param {l}");
            }
        }
    }

    #[test]
    fn grad_batch_rows_accumulate_in_order() {
        // A batch's accumulator is the in-order sum of its rows'
        // single-sample accumulators (one summation group, b-major) —
        // the property the mini-batch shard reduction relies on.
        let b: &dyn Backend = &NativeBackend;
        let layers = [4usize, 5, 2];
        let mut rng = Rng::seeded(17);
        let k = 6;
        let xs = ArrayF32::matrix(k, 4, rng.vec_uniform(k * 4, -0.5, 0.5))
            .unwrap();
        let ts = ArrayF32::matrix(k, 2, rng.vec_uniform(k * 2, -0.4, 0.4))
            .unwrap();
        let params = rand_params(&layers, 1);
        let whole = b.grad_batch("g", &params, &xs, &ts).unwrap();
        assert_eq!(whole.losses.len(), k);
        // gradients of the whole batch are finite and nonzero somewhere
        assert!(whole
            .grads
            .iter()
            .all(|g| g.data.iter().all(|v| v.is_finite())));
        // per-sample losses agree with single-sample grad_batch calls
        for i in 0..k {
            let x = ArrayF32::row(xs.row_slice(i).to_vec());
            let t = ArrayF32::row(ts.row_slice(i).to_vec());
            let one = b.grad_batch("g", &params, &x, &t).unwrap();
            assert_eq!(one.losses[0], whole.losses[i], "sample {i}");
        }
    }

    #[test]
    fn apply_grads_shape_mismatch_is_an_error() {
        let b: &dyn Backend = &NativeBackend;
        let params = rand_params(&[4, 3], 0);
        let bad = vec![ArrayF32::zeros(vec![2, 2])];
        assert!(b.apply_grads("g", params.clone(), &bad, 0.5).is_err());
        let too_few: Vec<ArrayF32> = Vec::new();
        assert!(b.apply_grads("g", params, &too_few, 0.5).is_err());
    }

    #[test]
    fn forward_batch_shapes_follow_mode() {
        let b: &dyn Backend = &NativeBackend;
        let params = rand_params(&[4, 2, 4], 1);
        let mut rng = Rng::seeded(2);
        let xs = ArrayF32::matrix(3, 4, rng.vec_uniform(12, -0.5, 0.5))
            .unwrap();
        let outs = b
            .forward_batch("g", FwdMode::ReconAndCode, &params, &xs)
            .unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].shape, vec![3, 4]); // reconstruction
        assert_eq!(outs[1].shape, vec![3, 2]); // bottleneck code
        let outs = b.forward_batch("g", FwdMode::Final, &params, &xs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape, vec![3, 4]);
    }

    #[test]
    fn mini_batch_train_step_accumulates_gradient() {
        // batch 2 with two copies of one sample == single step with
        // doubled learning rate only when updates don't clip; use a tiny
        // lr so the equivalence holds exactly.
        let b: &dyn Backend = &NativeBackend;
        let mut rng = Rng::seeded(5);
        let x1 = rng.vec_uniform(4, -0.5, 0.5);
        let t1 = rng.vec_uniform(2, -0.4, 0.4);
        let mut x2 = x1.clone();
        x2.extend_from_slice(&x1);
        let mut t2 = t1.clone();
        t2.extend_from_slice(&t1);
        let xs = ArrayF32::matrix(2, 4, x2).unwrap();
        let ts = ArrayF32::matrix(2, 2, t2).unwrap();
        let (pa, _) = b
            .train_step(
                "g",
                rand_params(&[4, 2], 9),
                &ArrayF32::row(x1),
                &ArrayF32::row(t1),
                2e-3,
            )
            .unwrap();
        let (pb, _) = b
            .train_step("g", rand_params(&[4, 2], 9), &xs, &ts, 1e-3)
            .unwrap();
        for (a, bb) in pa.iter().zip(&pb) {
            for (va, vb) in a.data.iter().zip(&bb.data) {
                assert!((va - vb).abs() < 1e-6, "{va} vs {vb}");
            }
        }
    }
}
