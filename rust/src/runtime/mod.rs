//! Functional-math runtime: the pluggable [`Backend`] trait plus its
//! implementations and the host-side tensor/metadata types.
//!
//! * [`Backend`] — the compute abstraction: four kernel-level entry
//!   points (crossbar `forward` / `backward` / `weight_update`,
//!   `kmeans_step`) plus the composed graph-level training/recognition
//!   operations the streaming coordinator drives.
//! * [`NativeBackend`] — the default: the reference kernels executed
//!   in-process, batched, with no artifacts, Python or XLA anywhere.
//! * `PjrtBackend` (cargo feature `pjrt`) — executes the AOT-lowered
//!   HLO artifacts `python/compile/aot.py` writes, through the CPU PJRT
//!   client; `pjrt.rs` documents the HLO text interchange contract.
//! * [`ArrayF32`] / [`Meta`] — the dense host tensor crossing the
//!   backend boundary and the artifact signature sidecar.
//!
//! Backend selection is by construction (`coordinator::Engine::native`,
//! `Engine::named`, or the `RESTREAM_BACKEND` environment variable via
//! `Engine::open_default`); see DESIGN.md "Backend selection".

mod array;
mod backend;
mod meta;
mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use array::ArrayF32;
pub use backend::{Backend, FwdMode, GradBatch, KmeansStep, NativeBackend};
pub use meta::Meta;
pub(crate) use native::{clip_input, with_bias};
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, PjrtBackend, Runtime};
