//! In-process implementations of the [`Backend`](super::Backend) entry
//! points: the reference kernel semantics of
//! `python/compile/kernels/ref.py`, composed into the graph-level ops of
//! `python/compile/model.py`, executed batched on the host.
//!
//! This is the crate's default compute path — no artifacts, no Python,
//! no XLA. The kernel math delegates to [`crate::crossbar::ideal`] (the
//! same routines `nn::Mlp` uses), so the native backend, the pure-Rust
//! reference network and the PJRT artifacts are all bit-compatible;
//! `tests/backend_parity.rs` pins the semantics against goldens
//! generated from `ref.py` itself.

use anyhow::{bail, ensure, Result};

use super::backend::{FwdMode, GradBatch, KmeansStep};
use super::ArrayF32;
use crate::config::hwspec as hw;
use crate::crossbar::{ideal, quant};

/// Shape check: rank-2 array, returning `(rows, cols)`.
fn rank2(a: &ArrayF32, what: &str) -> Result<(usize, usize)> {
    if a.shape.len() != 2 {
        bail!("{what}: expected a rank-2 array, got shape {:?}", a.shape);
    }
    Ok((a.shape[0], a.shape[1]))
}

/// Clip a batch of samples to the op-amp rails (`jnp.clip` twin).
/// Crate-visible so the layer-pipelined driver
/// (`coordinator::pipeline`) applies the identical input conditioning
/// at its first stage.
pub(crate) fn clip_input(x: &ArrayF32) -> ArrayF32 {
    ArrayF32 {
        shape: x.shape.clone(),
        data: x
            .data
            .iter()
            .map(|v| v.clamp(-hw::V_RAIL, hw::V_RAIL))
            .collect(),
    }
}

/// Append the bias column: one input pinned at the positive rail
/// (`model._with_bias` twin). `h` is `(batch, w)`; returns `(batch, w+1)`.
/// Crate-visible so the layer-pipelined driver composes per-layer
/// forwards bit-identically to [`forward_batch`].
pub(crate) fn with_bias(h: &ArrayF32) -> ArrayF32 {
    let (batch, w) = (h.shape[0], h.shape[1]);
    let mut data = Vec::with_capacity(batch * (w + 1));
    for b in 0..batch {
        data.extend_from_slice(&h.data[b * w..(b + 1) * w]);
        data.push(hw::V_RAIL);
    }
    ArrayF32 { shape: vec![batch, w + 1], data }
}

/// Kernel-level crossbar forward (`ref.crossbar_fwd`).
pub(crate) fn crossbar_forward(
    x: &ArrayF32,
    gp: &ArrayF32,
    gn: &ArrayF32,
    out_bits: u32,
) -> Result<(ArrayF32, ArrayF32)> {
    let (batch, n_in) = rank2(x, "x")?;
    let (rows, n_out) = rank2(gp, "gp")?;
    ensure!(rows == n_in, "x has {n_in} columns but gp has {rows} rows");
    ensure!(gn.shape == gp.shape, "gp/gn shape mismatch");
    let (y, dp) =
        ideal::fwd(&x.data, &gp.data, &gn.data, batch, n_in, n_out, out_bits);
    Ok((
        ArrayF32 { shape: vec![batch, n_out], data: y },
        ArrayF32 { shape: vec![batch, n_out], data: dp },
    ))
}

/// Kernel-level crossbar backward (`ref.crossbar_bwd`): the result
/// keeps the bias row, exactly like the reference.
pub(crate) fn crossbar_backward(
    delta: &ArrayF32,
    gp: &ArrayF32,
    gn: &ArrayF32,
) -> Result<ArrayF32> {
    let (batch, n_out) = rank2(delta, "delta")?;
    let (n_in, cols) = rank2(gp, "gp")?;
    ensure!(cols == n_out, "delta has {n_out} columns but gp has {cols}");
    ensure!(gn.shape == gp.shape, "gp/gn shape mismatch");
    let back = ideal::bwd(&delta.data, &gp.data, &gn.data, batch, n_in, n_out);
    Ok(ArrayF32 { shape: vec![batch, n_in], data: back })
}

/// Kernel-level weight update (`ref.weight_update`).
pub(crate) fn crossbar_update(
    gp: &ArrayF32,
    gn: &ArrayF32,
    x: &ArrayF32,
    delta: &ArrayF32,
    dp: &ArrayF32,
    lr: f32,
) -> Result<(ArrayF32, ArrayF32)> {
    let (batch, n_in) = rank2(x, "x")?;
    let (rows, n_out) = rank2(gp, "gp")?;
    ensure!(rows == n_in, "x has {n_in} columns but gp has {rows} rows");
    ensure!(gn.shape == gp.shape, "gp/gn shape mismatch");
    ensure!(
        delta.shape == vec![batch, n_out] && dp.shape == delta.shape,
        "delta/dp must be (batch, n_out)"
    );
    let mut gp2 = gp.clone();
    let mut gn2 = gn.clone();
    ideal::update(
        &mut gp2.data,
        &mut gn2.data,
        &x.data,
        &delta.data,
        &dp.data,
        lr,
        batch,
        n_in,
        n_out,
    );
    Ok((gp2, gn2))
}

/// One clustering-core pass (`model.kmeans_step`): Manhattan argmin
/// assignment plus centre accumulators and counts.
pub(crate) fn kmeans_pass(
    x: &ArrayF32,
    centres: &ArrayF32,
) -> Result<KmeansStep> {
    let (batch, dims) = rank2(x, "x")?;
    let (k, d2) = rank2(centres, "centres")?;
    ensure!(d2 == dims, "samples have {dims} dims but centres have {d2}");
    ensure!(k > 0, "need at least one centre");
    let mut assign = Vec::with_capacity(batch);
    let mut acc = vec![0.0f32; k * dims];
    let mut counts = vec![0.0f32; k];
    for i in 0..batch {
        let s = &x.data[i * dims..(i + 1) * dims];
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let cc = &centres.data[c * dims..(c + 1) * dims];
            let dist = s
                .iter()
                .zip(cc)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, |acc, d| acc + d);
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        assign.push(best);
        counts[best] += 1.0;
        for d in 0..dims {
            acc[best * dims + d] += s[d];
        }
    }
    Ok(KmeansStep { assign, acc, counts, k, dims })
}

/// Check a parameter list `[gp0, gn0, gp1, gn1, …]` and return the
/// number of layers.
fn check_params(params: &[ArrayF32]) -> Result<usize> {
    ensure!(
        !params.is_empty() && params.len() % 2 == 0,
        "parameter list must hold (gp, gn) pairs, got {} arrays",
        params.len()
    );
    for (l, pair) in params.chunks(2).enumerate() {
        rank2(&pair[0], "gp")?;
        ensure!(
            pair[0].shape == pair[1].shape,
            "layer {l}: gp shape {:?} != gn shape {:?}",
            pair[0].shape,
            pair[1].shape
        );
    }
    Ok(params.len() / 2)
}

/// Forward the whole stack, collecting the bias-augmented layer inputs
/// and raw dot products (`model.mlp_forward`). Returns
/// `(acts, dps, output)`.
fn forward_traced(
    params: &[ArrayF32],
    x: &ArrayF32,
) -> Result<(Vec<ArrayF32>, Vec<ArrayF32>, ArrayF32)> {
    let n_layers = check_params(params)?;
    let batch = rank2(x, "x")?.0;
    let mut acts = Vec::with_capacity(n_layers);
    let mut dps = Vec::with_capacity(n_layers);
    let mut h = clip_input(x);
    for l in 0..n_layers {
        let (gp, gn) = (&params[2 * l], &params[2 * l + 1]);
        let (rows, n_out) = (gp.shape[0], gp.shape[1]);
        ensure!(
            rows == h.shape[1] + 1,
            "layer {l}: crossbar has {rows} rows but gets {} inputs + bias",
            h.shape[1]
        );
        let a = with_bias(&h);
        let (y, dp) = ideal::fwd(
            &a.data, &gp.data, &gn.data, batch, rows, n_out, hw::OUT_BITS,
        );
        acts.push(a);
        dps.push(ArrayF32 { shape: vec![batch, n_out], data: dp });
        h = ArrayF32 { shape: vec![batch, n_out], data: y };
    }
    Ok((acts, dps, h))
}

/// One stochastic-BP step over a batch (`model.mlp_train_step`),
/// mutating `params` in place. Gradients accumulate over the batch
/// dimension; `batch = 1` is the paper's per-sample training. Returns
/// the pre-update mean squared error.
pub(crate) fn train_step(
    params: &mut [ArrayF32],
    x: &ArrayF32,
    t: &ArrayF32,
    lr: f32,
) -> Result<f32> {
    let (acts, dps, y) = forward_traced(params, x)?;
    let n_layers = params.len() / 2;
    ensure!(
        t.shape == y.shape,
        "targets have shape {:?} but the net outputs {:?}",
        t.shape,
        y.shape
    );
    let batch = y.shape[0];
    // Eq. 4 + the 8-bit error ADC
    let mut delta: Vec<f32> = t
        .data
        .iter()
        .zip(&y.data)
        .map(|(&ti, &yi)| quant::quantize_err(ti - yi))
        .collect();
    let loss = t
        .data
        .iter()
        .zip(&y.data)
        .map(|(&ti, &yi)| (ti - yi) * (ti - yi))
        .fold(0.0f32, |acc, e| acc + e)
        / t.data.len() as f32;
    // lint: allow(D3) — the backprop layer walk runs output-to-input
    // by definition; it is not a float reduction (each iteration
    // writes its own layer's accumulator).
    for l in (0..n_layers).rev() {
        let rows = acts[l].shape[1];
        let n_out = dps[l].shape[1];
        // back-propagate first, through the *pre-update* conductances
        // (the chip reads the crossbar before pulsing it)
        let prev_delta = if l > 0 {
            let eff = ideal::pulse_factor(&delta, &dps[l].data);
            let (gp, gn) = (&params[2 * l], &params[2 * l + 1]);
            let back =
                ideal::bwd(&eff, &gp.data, &gn.data, batch, rows, n_out);
            // drop each row's bias-column error (`[:, :-1]`)
            let w = rows - 1;
            let mut pd = Vec::with_capacity(batch * w);
            for b in 0..batch {
                pd.extend_from_slice(&back[b * rows..b * rows + w]);
            }
            Some(pd)
        } else {
            None
        };
        let (head, tail) = params.split_at_mut(2 * l + 1);
        let (gp, gn) = (&mut head[2 * l], &mut tail[0]);
        ideal::update(
            &mut gp.data,
            &mut gn.data,
            &acts[l].data,
            &delta,
            &dps[l].data,
            lr,
            batch,
            rows,
            n_out,
        );
        if let Some(pd) = prev_delta {
            delta = pd;
        }
    }
    Ok(loss)
}

/// Per-layer gradient sums of a mini-batch (`model.mlp_grad_batch`):
/// the same forward/backward dataflow as [`train_step`], but the
/// training pulse is *withheld* — the per-layer `x^T @ quantize_err(
/// delta * f'(dp))` accumulators are returned instead of applied, so a
/// data-parallel caller can sum the accumulators of several shards and
/// fire one pulse per mini-batch ([`apply_grads`]).
///
/// Structurally shares `ideal::update`'s math — [`ideal::pulse_factor`]
/// and [`ideal::grad_acc`] are the very functions the fused update
/// composes — so `grad_batch` + [`apply_grads`] on one sample is
/// **bitwise identical** to [`train_step`] on that sample by
/// construction: the recovery-at-batch-1 contract `runtime::backend`
/// documents.
pub(crate) fn grad_batch(
    params: &[ArrayF32],
    xs: &ArrayF32,
    ts: &ArrayF32,
) -> Result<GradBatch> {
    let (acts, dps, y) = forward_traced(params, xs)?;
    let n_layers = params.len() / 2;
    ensure!(
        ts.shape == y.shape,
        "targets have shape {:?} but the net outputs {:?}",
        ts.shape,
        y.shape
    );
    let (batch, n_last) = (y.shape[0], y.shape[1]);
    // per-sample pre-update MSE: at batch 1 this is the same j-ordered
    // sum / n_out reduction train_step performs over t.data
    let mut losses = Vec::with_capacity(batch);
    for b in 0..batch {
        let mut s = 0.0f32;
        for j in 0..n_last {
            let d = ts.data[b * n_last + j] - y.data[b * n_last + j];
            s += d * d;
        }
        losses.push(s / n_last as f32);
    }
    // Eq. 4 + the 8-bit error ADC
    let mut delta: Vec<f32> = ts
        .data
        .iter()
        .zip(&y.data)
        .map(|(&ti, &yi)| quant::quantize_err(ti - yi))
        .collect();
    let mut grads: Vec<ArrayF32> = (0..n_layers)
        .map(|l| ArrayF32::zeros(params[2 * l].shape.clone()))
        .collect();
    // lint: allow(D3) — backprop layer walk (output-to-input), not a
    // float reduction; per-layer accumulators are written in a fixed
    // order.
    for l in (0..n_layers).rev() {
        let rows = acts[l].shape[1];
        let n_out = dps[l].shape[1];
        // the training unit's discretised delta * f'(DP) product — used
        // both for this layer's accumulator and (through the transposed
        // crossbar) for the previous layer's error, exactly as
        // train_step's update/backward pair computes it
        let factor = ideal::pulse_factor(&delta, &dps[l].data);
        grads[l].data =
            ideal::grad_acc(&acts[l].data, &factor, batch, rows, n_out);
        if l > 0 {
            let (gp, gn) = (&params[2 * l], &params[2 * l + 1]);
            let back =
                ideal::bwd(&factor, &gp.data, &gn.data, batch, rows, n_out);
            // drop each row's bias-column error (`[:, :-1]`)
            let w = rows - 1;
            let mut pd = Vec::with_capacity(batch * w);
            for b in 0..batch {
                pd.extend_from_slice(&back[b * rows..b * rows + w]);
            }
            delta = pd;
        }
    }
    Ok(GradBatch { grads, losses })
}

/// Fire one training pulse from summed per-layer gradient accumulators
/// (`grads` as returned by [`grad_batch`], possibly summed over several
/// shards), via [`ideal::apply_acc`] — the same pulse-firing tail
/// `ideal::update` composes, so the mini-batch update and the fused
/// per-sample update share one definition.
pub(crate) fn apply_grads(
    mut params: Vec<ArrayF32>,
    grads: &[ArrayF32],
    lr: f32,
) -> Result<Vec<ArrayF32>> {
    ensure!(
        params.len() == 2 * grads.len(),
        "{} gradient arrays for {} (gp, gn) parameter pairs",
        grads.len(),
        params.len() / 2
    );
    for (l, (pair, g)) in params.chunks_mut(2).zip(grads).enumerate() {
        ensure!(
            pair[0].shape == g.shape,
            "layer {l}: gradient shape {:?} != conductance shape {:?}",
            g.shape,
            pair[0].shape
        );
        let (gp_half, gn_half) = pair.split_at_mut(1);
        ideal::apply_acc(
            &mut gp_half[0].data,
            &mut gn_half[0].data,
            &g.data,
            lr,
        );
    }
    Ok(params)
}

/// Scan per-sample stochastic BP over the rows of `xs`/`ts`
/// (`model.mlp_train_chunk`): bitwise identical to calling
/// [`train_step`] on each row in order. Returns the per-sample losses.
pub(crate) fn train_chunk(
    params: &mut [ArrayF32],
    xs: &ArrayF32,
    ts: &ArrayF32,
    lr: f32,
) -> Result<Vec<f32>> {
    let (k, _) = rank2(xs, "xs")?;
    let (kt, _) = rank2(ts, "ts")?;
    ensure!(k == kt, "{k} samples but {kt} target rows");
    let mut losses = Vec::with_capacity(k);
    for i in 0..k {
        let x = ArrayF32::row(xs.row_slice(i).to_vec());
        let t = ArrayF32::row(ts.row_slice(i).to_vec());
        losses.push(train_step(params, &x, &t, lr)?);
    }
    Ok(losses)
}

/// Batched recognition (`model.mlp_infer` / `model.ae_fwd`): the output
/// list follows the [`FwdMode`] convention of the matching artifact.
pub(crate) fn forward_batch(
    mode: FwdMode,
    params: &[ArrayF32],
    xs: &ArrayF32,
) -> Result<Vec<ArrayF32>> {
    let n_layers = check_params(params)?;
    let batch = rank2(xs, "xs")?.0;
    let mut h = clip_input(xs);
    let mut code: Option<ArrayF32> = None;
    // ae_fwd takes the bottleneck from the encoder's last crossbar
    let code_idx =
        if n_layers > 1 { n_layers / 2 - 1 } else { n_layers - 1 };
    for l in 0..n_layers {
        let (gp, gn) = (&params[2 * l], &params[2 * l + 1]);
        let (rows, n_out) = (gp.shape[0], gp.shape[1]);
        ensure!(
            rows == h.shape[1] + 1,
            "layer {l}: crossbar has {rows} rows but gets {} inputs + bias",
            h.shape[1]
        );
        let a = with_bias(&h);
        let (y, _) = ideal::fwd(
            &a.data, &gp.data, &gn.data, batch, rows, n_out, hw::OUT_BITS,
        );
        h = ArrayF32 { shape: vec![batch, n_out], data: y };
        if mode == FwdMode::ReconAndCode && l == code_idx {
            code = Some(h.clone());
        }
    }
    Ok(match mode {
        FwdMode::Final => vec![h],
        FwdMode::ReconAndCode => {
            let code = code.expect("code layer visited");
            vec![h, code]
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Constraint, Mlp};
    use crate::testing::Rng;

    #[test]
    fn train_step_matches_reference_network() {
        // The native graph-level step and nn::Mlp (chip constraint) are
        // two ports of the same paper equations; one sample must update
        // conductances identically.
        let layers = [4usize, 6, 2];
        let mut rng = Rng::seeded(21);
        let mut mlp = Mlp::init(&layers, Constraint::Chip, &mut rng);
        let mut params: Vec<ArrayF32> = Vec::new();
        for (l, w) in layers.windows(2).enumerate() {
            let (gp, gn) = &mlp.params[l];
            let shape = vec![w[0] + 1, w[1]];
            params.push(ArrayF32::new(shape.clone(), gp.clone()).unwrap());
            params.push(ArrayF32::new(shape, gn.clone()).unwrap());
        }
        let x = rng.vec_uniform(4, -0.5, 0.5);
        let t = rng.vec_uniform(2, -0.4, 0.4);
        let mlp_loss = mlp.train_step(&x, &t, 0.8);
        let native_loss = train_step(
            &mut params,
            &ArrayF32::row(x),
            &ArrayF32::row(t),
            0.8,
        )
        .unwrap();
        assert_eq!(mlp_loss, native_loss);
        for (l, (gp, gn)) in mlp.params.iter().enumerate() {
            assert_eq!(&params[2 * l].data, gp, "layer {l} gp");
            assert_eq!(&params[2 * l + 1].data, gn, "layer {l} gn");
        }
    }

    #[test]
    fn shape_errors_are_descriptive() {
        let mut rng = Rng::seeded(1);
        let mut params =
            crate::coordinator::init_conductances(&[4, 3], 0);
        let bad_x = ArrayF32::row(rng.vec_uniform(7, -0.5, 0.5));
        let t = ArrayF32::row(vec![0.0; 3]);
        let err = train_step(&mut params, &bad_x, &t, 0.5).unwrap_err();
        assert!(err.to_string().contains("crossbar"), "{err}");
    }

    #[test]
    fn kmeans_pass_matches_reference_kmeans() {
        let mut rng = Rng::seeded(9);
        let (k, d, n) = (3, 4, 40);
        let xs = rng.vec_uniform(n * d, -0.5, 0.5);
        let cs = rng.vec_uniform(k * d, -0.5, 0.5);
        let km = crate::kmeans::KMeans { k, dims: d, centres: cs.clone() };
        let step = kmeans_pass(
            &ArrayF32::matrix(n, d, xs.clone()).unwrap(),
            &ArrayF32::matrix(k, d, cs).unwrap(),
        )
        .unwrap();
        for i in 0..n {
            assert_eq!(
                step.assign[i],
                km.assign_one(&xs[i * d..(i + 1) * d]),
                "sample {i}"
            );
        }
        assert_eq!(step.counts.iter().sum::<f32>() as usize, n);
    }
}
