//! Minimal host-side f32 tensor used at the backend boundary.

#[cfg(feature = "pjrt")]
use anyhow::Result;

/// A dense row-major f32 array.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl ArrayF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self, String> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            ));
        }
        Ok(ArrayF32 { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        ArrayF32 { shape, data: vec![0.0; n] }
    }

    /// 1x1 scalar (how `lr` travels to the train-step artifact).
    pub fn scalar(v: f32) -> Self {
        ArrayF32 { shape: vec![1, 1], data: vec![v] }
    }

    /// A `1 x n` row (single-sample batch).
    pub fn row(data: Vec<f32>) -> Self {
        ArrayF32 { shape: vec![1, data.len()], data }
    }

    /// A `b x n` matrix from row-major data.
    pub fn matrix(b: usize, n: usize, data: Vec<f32>) -> Result<Self, String> {
        Self::new(vec![b, n], data)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a rank-2 array.
    pub fn row_slice(&self, i: usize) -> &[f32] {
        let n = self.shape[1];
        &self.data[i * n..(i + 1) * n]
    }

    #[cfg(feature = "pjrt")]
    pub(crate) fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(ArrayF32::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(ArrayF32::new(vec![2, 3], vec![0.0; 6]).is_ok());
    }

    #[test]
    fn helpers() {
        let s = ArrayF32::scalar(0.5);
        assert_eq!(s.shape, vec![1, 1]);
        let r = ArrayF32::row(vec![1.0, 2.0]);
        assert_eq!(r.shape, vec![1, 2]);
        let m = ArrayF32::matrix(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.row_slice(1), &[3.0, 4.0]);
    }
}
