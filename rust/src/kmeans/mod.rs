//! Pure-Rust k-means reference (Manhattan metric, matching the digital
//! clustering core's datapath semantics in `cores::cluster` and the
//! `kmeans_step` artifact): assignment by minimum Manhattan distance,
//! centres recomputed as the accumulator/counter quotient at epoch end.

use crate::testing::Rng;

/// k-means state: `k x dims` centres, row-major.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub k: usize,
    pub dims: usize,
    pub centres: Vec<f32>,
}

impl KMeans {
    /// Initialise centres by sampling k distinct data points (the RISC
    /// core seeds the centre registers at configuration time).
    pub fn init(x: &[f32], n: usize, dims: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(n >= k, "need at least k samples");
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut centres = Vec::with_capacity(k * dims);
        for &i in idx.iter().take(k) {
            centres.extend_from_slice(&x[i * dims..(i + 1) * dims]);
        }
        KMeans { k, dims, centres }
    }

    /// Manhattan distance from sample `s` to centre `c`.
    pub fn distance(&self, s: &[f32], c: usize) -> f32 {
        let cc = &self.centres[c * self.dims..(c + 1) * self.dims];
        s.iter()
            .zip(cc)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, |acc, d| acc + d)
    }

    /// Assign one sample (the clustering core's per-sample operation).
    /// Distances compare in IEEE total order, so a NaN distance (a
    /// poisoned centre or sample coordinate) sorts above every finite
    /// distance and the sample deterministically joins the nearest
    /// *finite* centre — no panic (pre-fix this was
    /// `partial_cmp().unwrap()`, the bug class `Engine::classify` and
    /// `Mlp::accuracy` shared).
    pub fn assign_one(&self, s: &[f32]) -> usize {
        (0..self.k)
            .min_by(|&a, &b| {
                self.distance(s, a).total_cmp(&self.distance(s, b))
            })
            .unwrap_or(0)
    }

    /// One full epoch: assign all samples, recompute centres from the
    /// accumulator registers. Returns (assignments, moved_distance).
    pub fn epoch(&mut self, x: &[f32], n: usize) -> (Vec<usize>, f32) {
        let mut assign = vec![0usize; n];
        let mut acc = vec![0.0f32; self.k * self.dims];
        let mut count = vec![0usize; self.k];
        for i in 0..n {
            let s = &x[i * self.dims..(i + 1) * self.dims];
            let a = self.assign_one(s);
            assign[i] = a;
            count[a] += 1;
            for d in 0..self.dims {
                acc[a * self.dims + d] += s[d];
            }
        }
        let mut moved = 0.0f32;
        for c in 0..self.k {
            if count[c] == 0 {
                continue; // empty cluster keeps its centre (as the core does)
            }
            for d in 0..self.dims {
                let new = acc[c * self.dims + d] / count[c] as f32;
                moved += (new - self.centres[c * self.dims + d]).abs();
                self.centres[c * self.dims + d] = new;
            }
        }
        (assign, moved)
    }

    /// One full epoch through a runtime [`Backend`]'s clustering-core
    /// entry point, streaming the dataset in `batch`-sample passes and
    /// folding the returned accumulator registers — how the coordinator
    /// drives the core. Assignments are identical to [`KMeans::epoch`];
    /// centres agree up to float summation order across batches.
    ///
    /// This is the single-threaded reference driver; the production
    /// path is `coordinator::Engine::kmeans`, which runs the same
    /// per-tile passes sharded over the worker pool with a
    /// deterministic left-to-right register fold.
    ///
    /// [`Backend`]: crate::runtime::Backend
    pub fn epoch_on(
        &mut self,
        backend: &dyn crate::runtime::Backend,
        x: &[f32],
        n: usize,
        batch: usize,
    ) -> anyhow::Result<(Vec<usize>, f32)> {
        use crate::runtime::ArrayF32;
        assert!(batch > 0, "batch must be positive");
        let d = self.dims;
        let centres_arr = ArrayF32::new(vec![self.k, d], self.centres.clone())
            .map_err(anyhow::Error::msg)?;
        let mut assign = Vec::with_capacity(n);
        let mut acc = vec![0.0f32; self.k * d];
        let mut count = vec![0.0f32; self.k];
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            let xa = ArrayF32::new(vec![b, d], x[i * d..(i + b) * d].to_vec())
                .map_err(anyhow::Error::msg)?;
            let step = backend.kmeans_step(&xa, &centres_arr)?;
            assign.extend_from_slice(&step.assign);
            for v in 0..self.k * d {
                acc[v] += step.acc[v];
            }
            for c in 0..self.k {
                count[c] += step.counts[c];
            }
            i += b;
        }
        let mut moved = 0.0f32;
        for c in 0..self.k {
            if count[c] < 0.5 {
                continue; // empty cluster keeps its centre (as the core does)
            }
            for dd in 0..d {
                let new = acc[c * d + dd] / count[c];
                moved += (new - self.centres[c * d + dd]).abs();
                self.centres[c * d + dd] = new;
            }
        }
        Ok((assign, moved))
    }

    /// Run to convergence (or `max_epochs`); returns final assignments
    /// and the epoch count.
    pub fn fit(&mut self, x: &[f32], n: usize, max_epochs: usize, tol: f32)
        -> (Vec<usize>, usize) {
        let mut assign = Vec::new();
        for e in 1..=max_epochs {
            let (a, moved) = self.epoch(x, n);
            assign = a;
            if moved < tol {
                return (assign, e);
            }
        }
        (assign, max_epochs)
    }


    /// Assignments under the current centres (no update) — test helper
    /// exposed for cost comparisons.
    pub fn clone_assign(&self, x: &[f32], n: usize) -> Vec<usize> {
        (0..n)
            .map(|i| self.assign_one(&x[i * self.dims..(i + 1) * self.dims]))
            .collect()
    }
    /// Total within-cluster Manhattan cost.
    pub fn cost(&self, x: &[f32], n: usize, assign: &[usize]) -> f64 {
        (0..n)
            .map(|i| {
                self.distance(&x[i * self.dims..(i + 1) * self.dims], assign[i])
                    as f64
            })
            .fold(0.0f64, |acc, d| acc + d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    fn two_blobs(rng: &mut Rng, n_per: usize) -> (Vec<f32>, usize) {
        let mut x = Vec::new();
        for _ in 0..n_per {
            x.push(rng.uniform_f32(-0.45, -0.25));
            x.push(rng.uniform_f32(-0.45, -0.25));
        }
        for _ in 0..n_per {
            x.push(rng.uniform_f32(0.25, 0.45));
            x.push(rng.uniform_f32(0.25, 0.45));
        }
        (x, 2 * n_per)
    }

    #[test]
    fn separates_two_blobs() {
        let mut rng = Rng::seeded(0);
        let (x, n) = two_blobs(&mut rng, 50);
        let mut km = KMeans::init(&x, n, 2, 2, &mut rng);
        let (assign, _) = km.fit(&x, n, 50, 1e-5);
        // all of blob 1 in one cluster, all of blob 2 in the other
        assert!(assign[..50].iter().all(|&a| a == assign[0]));
        assert!(assign[50..].iter().all(|&a| a == assign[50]));
        assert_ne!(assign[0], assign[50]);
    }

    #[test]
    fn assignment_is_argmin_over_centres() {
        // The assignment phase is exactly optimal for fixed centres
        // (the core's min-search circuit). Note: with the Manhattan
        // metric and *mean* centre updates (the core divides
        // accumulators by counters, Fig 13), the total cost is not
        // guaranteed monotone — medians would be — so the invariant
        // tested here is the per-phase one that actually holds.
        forall("kmeans_argmin", 30, |rng| {
            let n = rng.range(5, 40);
            let dims = rng.range(1, 8);
            let k = rng.range(2, 6).min(n);
            let x = rng.vec_uniform(n * dims, -0.5, 0.5);
            let km = KMeans::init(&x, n, dims, k, rng);
            for i in 0..n {
                let s = &x[i * dims..(i + 1) * dims];
                let a = km.assign_one(s);
                for c in 0..k {
                    if km.distance(s, c) + 1e-6 < km.distance(s, a) {
                        return Err(format!("sample {i}: {c} beats {a}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fit_cost_improves_from_init_on_clustered_data() {
        forall("kmeans_improves", 10, |rng| {
            let (x, n) = {
                let mut v = Vec::new();
                for c in 0..3 {
                    let cx = -0.4 + 0.4 * c as f32;
                    for _ in 0..20 {
                        v.push(cx + rng.uniform_f32(-0.05, 0.05));
                        v.push(cx + rng.uniform_f32(-0.05, 0.05));
                    }
                }
                (v, 60)
            };
            let mut km = KMeans::init(&x, n, 2, 3, rng);
            let (a0, _) = (km.clone_assign(&x, n), ());
            let before = km.cost(&x, n, &a0);
            let (a, _) = km.fit(&x, n, 30, 1e-6);
            let after = km.cost(&x, n, &a);
            if after > before + 1e-6 {
                return Err(format!("cost {before} -> {after}"));
            }
            Ok(())
        });
    }

    #[test]
    fn converged_run_reports_early_epoch() {
        let mut rng = Rng::seeded(4);
        let (x, n) = two_blobs(&mut rng, 30);
        let mut km = KMeans::init(&x, n, 2, 2, &mut rng);
        let (_, epochs) = km.fit(&x, n, 100, 1e-6);
        assert!(epochs < 100, "no convergence in {epochs}");
    }

    #[test]
    fn epoch_on_native_backend_matches_epoch() {
        let backend = crate::runtime::NativeBackend;
        let mut rng = Rng::seeded(17);
        let (x, n) = two_blobs(&mut rng, 40);
        let km0 = KMeans::init(&x, n, 2, 2, &mut rng);
        // one pass covering all samples: bitwise-identical folding
        let mut a = km0.clone();
        let mut b = km0.clone();
        let (assign_ref, moved_ref) = a.epoch(&x, n);
        let (assign_be, moved_be) = b.epoch_on(&backend, &x, n, n).unwrap();
        assert_eq!(assign_ref, assign_be);
        assert_eq!(moved_ref, moved_be);
        assert_eq!(a.centres, b.centres);
        // small batches: assignments exact, centres to summation order
        let mut c = km0.clone();
        let (assign_sm, _) = c.epoch_on(&backend, &x, n, 7).unwrap();
        assert_eq!(assign_ref, assign_sm);
        for (u, v) in a.centres.iter().zip(&c.centres) {
            assert!((u - v).abs() < 1e-5, "{u} vs {v}");
        }
    }

    #[test]
    fn nan_distances_assign_deterministically_without_panicking() {
        // One poisoned centre: its distance is NaN, which total-order
        // sorts above every finite distance, so samples join the
        // healthy centre. Pre-fix this panicked in partial_cmp.
        let km = KMeans {
            k: 2,
            dims: 2,
            centres: vec![f32::NAN, f32::NAN, 0.1, 0.1],
        };
        assert_eq!(km.assign_one(&[0.1, 0.1]), 1);
        // all centres poisoned: deterministic first index, still no panic
        let km = KMeans {
            k: 2,
            dims: 2,
            centres: vec![f32::NAN; 4],
        };
        assert_eq!(km.assign_one(&[0.0, 0.0]), 0);
        // NaN sample against healthy centres: every distance is NaN,
        // ties break to the first centre
        let km = KMeans {
            k: 2,
            dims: 2,
            centres: vec![0.0, 0.0, 0.5, 0.5],
        };
        assert_eq!(km.assign_one(&[f32::NAN, 0.0]), 0);
    }

    #[test]
    fn empty_cluster_keeps_centre() {
        // A centre far away never gets members; it must not NaN out.
        let x = vec![0.0f32, 0.0, 0.1, 0.1];
        let mut km = KMeans {
            k: 2,
            dims: 2,
            centres: vec![0.05, 0.05, 100.0, 100.0],
        };
        let (_, _) = km.epoch(&x, 2);
        assert_eq!(&km.centres[2..], &[100.0, 100.0]);
    }
}
