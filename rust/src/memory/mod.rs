//! 3-D stacked DRAM + DMA front end (paper section II).
//!
//! Training data lives in 3-D stacked DRAM; a DMA engine (configured once
//! by the RISC core) streams samples through TSVs into the chip's 4 kB
//! input buffer. This module models the transfer cost and provides the
//! bounded double-buffered stream the coordinator consumes — the
//! "streaming" in the paper's title.

use crate::power::io;

/// Cost model for one off-chip transfer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferCost {
    pub time_s: f64,
    pub energy_j: f64,
}

/// DMA engine over the stacked-DRAM channel.
#[derive(Clone, Debug)]
pub struct DmaEngine {
    pub bandwidth_bps: f64,
    pub dram_energy_per_bit_j: f64,
    pub tsv_energy_per_bit_j: f64,
}

impl Default for DmaEngine {
    fn default() -> Self {
        DmaEngine {
            bandwidth_bps: io::DRAM_BANDWIDTH_BPS,
            dram_energy_per_bit_j: io::DRAM_ENERGY_PER_BIT_J,
            tsv_energy_per_bit_j: io::TSV_ENERGY_PER_BIT_J,
        }
    }
}

impl DmaEngine {
    /// Cost of moving `bits` across the TSV interface (read + crossing).
    pub fn transfer(&self, bits: u64) -> TransferCost {
        TransferCost {
            time_s: bits as f64 / self.bandwidth_bps,
            energy_j: bits as f64
                * (self.dram_energy_per_bit_j + self.tsv_energy_per_bit_j),
        }
    }

    /// TSV-only energy (the paper's "IO energy" column counts the chip
    /// boundary crossing; DRAM-internal energy is the memory system's).
    pub fn tsv_energy_j(&self, bits: u64) -> f64 {
        bits as f64 * self.tsv_energy_per_bit_j
    }
}

/// A bounded, double-buffered sample stream: the producer (DMA) fills
/// while the consumer (cores) drains, with backpressure when the input
/// buffer is full. Samples are `Vec<f32>` feature vectors.
pub struct SampleStream {
    samples: Vec<Vec<f32>>,
    cursor: usize,
    /// Bytes a sample occupies in the on-chip input buffer (8-bit DAC
    /// codes, one byte per feature).
    pub bytes_per_sample: usize,
    /// Input buffer capacity in samples (backpressure bound).
    pub buffer_samples: usize,
    /// Running transfer cost.
    pub cost: TransferCost,
    dma: DmaEngine,
}

impl SampleStream {
    pub fn new(samples: Vec<Vec<f32>>, input_buffer_bytes: usize) -> Self {
        let bytes = samples.first().map_or(0, |s| s.len());
        SampleStream {
            bytes_per_sample: bytes,
            buffer_samples: if bytes == 0 {
                0
            } else {
                (input_buffer_bytes / bytes).max(1)
            },
            samples,
            cursor: 0,
            cost: TransferCost::default(),
            dma: DmaEngine::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Pull the next sample, accounting its DMA cost. Returns None at
    /// end-of-stream. (Epoch loops call `rewind`.)
    pub fn next_sample(&mut self) -> Option<&[f32]> {
        if self.cursor >= self.samples.len() {
            return None;
        }
        let bits = (self.bytes_per_sample * 8) as u64;
        let c = self.dma.transfer(bits);
        self.cost.time_s += c.time_s;
        self.cost.energy_j += c.energy_j;
        let s = &self.samples[self.cursor];
        self.cursor += 1;
        Some(s)
    }

    pub fn rewind(&mut self) {
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_scales_linearly() {
        let dma = DmaEngine::default();
        let a = dma.transfer(1000);
        let b = dma.transfer(2000);
        assert!((b.time_s - 2.0 * a.time_s).abs() < 1e-18);
        assert!((b.energy_j - 2.0 * a.energy_j).abs() < 1e-24);
    }

    #[test]
    fn tsv_energy_matches_paper_constant() {
        let dma = DmaEngine::default();
        // 0.05 pJ/bit (section V.C)
        assert!((dma.tsv_energy_j(1) - 0.05e-12).abs() < 1e-20);
    }

    #[test]
    fn stream_drains_and_rewinds() {
        let data = vec![vec![0.1f32; 41]; 5];
        let mut s = SampleStream::new(data, 4096);
        assert_eq!(s.buffer_samples, 4096 / 41);
        let mut n = 0;
        while s.next_sample().is_some() {
            n += 1;
        }
        assert_eq!(n, 5);
        assert!(s.next_sample().is_none());
        s.rewind();
        assert!(s.next_sample().is_some());
        // 6 samples pulled in total
        let bits = (41 * 8 * 6) as f64;
        assert!((s.cost.energy_j
            - bits * (io::DRAM_ENERGY_PER_BIT_J + io::TSV_ENERGY_PER_BIT_J))
            .abs() < 1e-18);
    }
}
