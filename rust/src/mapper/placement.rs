//! Physical placement: mesh coordinates for every mapped core and the
//! NoC transfer lists the static scheduler consumes.
//!
//! Cores of a stage are placed row-major starting next to the memory
//! port, which keeps the input-broadcast routes short (the DMA feeds
//! layer 0 every sample). Transfers are generated at neuron-range
//! granularity: a consumer core receives exactly the slice of previous-
//! layer outputs its row segment covers, from whichever producer cores
//! hold those neurons.

use super::StageMap;
use crate::config::hwspec as hw;
use crate::config::SystemConfig;
use crate::noc::{Transfer, Xy};

/// Placement of one stage: mesh stop per (layer, slice) pair.
#[derive(Clone, Debug)]
pub struct Placement {
    /// `coords[layer][slice]` = mesh stop of that core.
    pub coords: Vec<Vec<Xy>>,
    /// Forward-pass transfers, in deterministic scheduling order.
    pub fwd_transfers: Vec<Transfer>,
    /// Backward-pass transfers (errors flow producer<-consumer, 8-bit).
    pub bwd_transfers: Vec<Transfer>,
}

/// Row segment (input indices, bias excluded) a row-split sees.
/// Crate-visible so `sim::pipeline_cost` derives the stage-boundary
/// transfers with the exact segmentation the in-stage placement uses.
pub(crate) fn row_segment(n_in: usize, row_splits: usize, rs: usize)
    -> (usize, usize) {
    // Mirrors mapper::segment on n_in+1 rows; the bias row is pinned to
    // the last split, so data rows divide as evenly as possible.
    let total = n_in + 1;
    let base = total / row_splits;
    let extra = total % row_splits;
    let size = |i: usize| base + usize::from(i < extra);
    let lo: usize = (0..rs).map(size).sum();
    let hi = (lo + size(rs)).min(n_in); // clamp the bias row away
    (lo.min(n_in), hi)
}

/// Place a stage on the mesh and derive its NoC traffic.
///
/// Multi-phase stages (see `StageMap::phases`) are placed per phase —
/// the chip is reconfigured between phases, so mesh stops are reused and
/// cross-phase activations spill through the memory port.
pub fn place(stage: &StageMap, sys: &SystemConfig) -> Placement {
    place_at(stage, sys, 0)
}

/// [`place`] with the stage's cores shifted `core_offset` slots into the
/// mesh's row-major core order. The multi-tenant chip scheduler
/// (`crate::chip`) gives every resident application its own offset so
/// co-resident placements occupy disjoint mesh stops — occupancy made
/// explicit. The memory port keeps its fixed mesh stop, so the derived
/// transfer lists stay valid; callers must keep
/// `core_offset + stage.cores_used()` within the chip's core budget
/// ([`SystemConfig::neural_cores`]).
pub fn place_at(
    stage: &StageMap,
    sys: &SystemConfig,
    core_offset: usize,
) -> Placement {
    // phase index of each layer
    let mut phase_of = vec![0usize; stage.layers.len()];
    for (pi, phase) in stage.phases.iter().enumerate() {
        for &l in phase {
            phase_of[l] = pi;
        }
    }
    let mut coords: Vec<Vec<Xy>> = vec![Vec::new(); stage.layers.len()];
    for phase in &stage.phases {
        let mut next = core_offset;
        for &l in phase {
            for _ in &stage.layers[l].slices {
                coords[l].push(sys.core_xy(next));
                next += 1;
            }
        }
    }

    let mut fwd = Vec::new();
    let mut bwd = Vec::new();
    for (li, layer) in stage.layers.iter().enumerate() {
        let consumers: Vec<usize> = (0..layer.slices.len())
            .filter(|&s| !layer.slices[s].is_combiner)
            .collect();
        if li == 0 {
            // DMA input broadcast from the memory port (8-bit DAC codes).
            for &s in &consumers {
                let sl = &layer.slices[s];
                fwd.push(Transfer {
                    src: sys.memory_port(),
                    dst: coords[li][s],
                    bits: (sl.core.inputs as u64) * 8,
                });
            }
        } else if phase_of[li] != phase_of[li - 1] {
            // Phase boundary: the previous layer's activations were
            // spilled to DRAM (one byte per neuron); re-fill each
            // consumer's row segment from the memory port.
            for &s in &consumers {
                let sl = &layer.slices[s];
                let t = Transfer {
                    src: sys.memory_port(),
                    dst: coords[li][s],
                    bits: (sl.core.inputs as u64) * 8,
                };
                bwd.push(Transfer {
                    src: t.dst,
                    dst: t.src,
                    bits: (sl.core.inputs as u64) * hw::ERR_BITS as u64,
                });
                fwd.push(t);
            }
        } else {
            // Previous layer's outputs: producer neuron ranges
            // intersected with this consumer's row segment.
            let prev = &stage.layers[li - 1];
            for &s in &consumers {
                let sl = &layer.slices[s];
                let (seg_lo, seg_hi) =
                    row_segment(layer.n_in, layer.row_splits, sl.row_split);
                for (ps, p) in prev.slices.iter().enumerate() {
                    // Only the final outputs of the previous layer feed
                    // forward: combiner outputs when it was split, main
                    // outputs otherwise.
                    let is_final = if prev.row_splits > 1 {
                        p.is_combiner
                    } else {
                        !p.is_combiner
                    };
                    if !is_final {
                        continue;
                    }
                    let lo = p.neurons.0.max(seg_lo);
                    let hi = p.neurons.1.min(seg_hi);
                    if lo >= hi {
                        continue;
                    }
                    let t = Transfer {
                        src: coords[li - 1][ps],
                        dst: coords[li][s],
                        bits: (hi - lo) as u64 * hw::OUT_BITS as u64,
                    };
                    bwd.push(Transfer {
                        src: t.dst,
                        dst: t.src,
                        bits: (hi - lo) as u64 * hw::ERR_BITS as u64,
                    });
                    fwd.push(t);
                }
            }
        }
        // Intra-layer combiner traffic (Fig 14): sub-neuron cores feed
        // the combiner cores holding the same neuron range.
        if layer.row_splits > 1 {
            for (cs, comb) in layer.slices.iter().enumerate() {
                if !comb.is_combiner {
                    continue;
                }
                for (ps, p) in layer.slices.iter().enumerate() {
                    if p.is_combiner {
                        continue;
                    }
                    let lo = p.neurons.0.max(comb.neurons.0);
                    let hi = p.neurons.1.min(comb.neurons.1);
                    if lo >= hi {
                        continue;
                    }
                    let t = Transfer {
                        src: coords[li][ps],
                        dst: coords[li][cs],
                        bits: (hi - lo) as u64 * hw::OUT_BITS as u64,
                    };
                    bwd.push(Transfer {
                        src: t.dst,
                        dst: t.src,
                        bits: (hi - lo) as u64 * hw::ERR_BITS as u64,
                    });
                    fwd.push(t);
                }
            }
        }
        // Spill to DRAM when the *next* layer runs in a later phase.
        if li + 1 < stage.layers.len() && phase_of[li + 1] != phase_of[li] {
            for (ps, p) in layer.slices.iter().enumerate() {
                let is_final = if layer.row_splits > 1 {
                    p.is_combiner
                } else {
                    !p.is_combiner
                };
                if !is_final {
                    continue;
                }
                let n = (p.neurons.1 - p.neurons.0) as u64;
                let t = Transfer {
                    src: coords[li][ps],
                    dst: sys.memory_port(),
                    bits: n * 8,
                };
                bwd.push(Transfer {
                    src: t.dst,
                    dst: t.src,
                    bits: n * hw::ERR_BITS as u64,
                });
                fwd.push(t);
            }
        }
    }
    Placement { coords, fwd_transfers: fwd, bwd_transfers: bwd }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::apps;
    use crate::mapper::map_network;

    fn placed(app: &str) -> (Placement, StageMap) {
        let sys = SystemConfig::default();
        let net = apps::network(app).unwrap();
        let map = map_network(net, &sys).unwrap();
        let stage = map.stages[0].clone();
        (place(&stage, &sys), stage)
    }

    #[test]
    fn every_core_gets_a_unique_mesh_stop() {
        let (p, stage) = placed("mnist_class");
        let mut seen = std::collections::HashSet::new();
        let mut n = 0;
        for row in &p.coords {
            for xy in row {
                assert!(seen.insert(*xy), "stop {xy:?} reused");
                n += 1;
            }
        }
        assert_eq!(n, stage.cores_used());
    }

    #[test]
    fn kdd_traffic_is_input_plus_interlayer() {
        let (p, _) = placed("kdd_ae");
        // 2 single-core layers: 1 input transfer + 1 inter-layer.
        assert_eq!(p.fwd_transfers.len(), 2);
        // input: 42 rows * 8 bits
        assert_eq!(p.fwd_transfers[0].bits, 42 * 8);
        // inter-layer: 15 neurons * 3 bits
        assert_eq!(p.fwd_transfers[1].bits, 15 * 3);
        // errors go the other way at 8 bits
        assert_eq!(p.bwd_transfers[0].bits, 15 * 8);
    }

    #[test]
    fn offset_placement_shifts_stops_and_stays_disjoint() {
        // Two co-resident apps: kdd_ae (2 cores) at offset 0 and
        // another kdd_ae at offset 2 must occupy disjoint mesh stops —
        // the multi-tenant scheduler's residency invariant.
        let sys = SystemConfig::default();
        let net = apps::network("kdd_ae").unwrap();
        let map = map_network(net, &sys).unwrap();
        let stage = &map.stages[0];
        let a = place_at(stage, &sys, 0);
        let b = place_at(stage, &sys, 2);
        let stops = |p: &Placement| -> Vec<Xy> {
            p.coords.iter().flatten().copied().collect()
        };
        let sa = stops(&a);
        let sb = stops(&b);
        assert_eq!(sa, vec![sys.core_xy(0), sys.core_xy(1)]);
        assert_eq!(sb, vec![sys.core_xy(2), sys.core_xy(3)]);
        assert!(sa.iter().all(|xy| !sb.contains(xy)), "stops overlap");
        // traffic shape is offset-independent (same bits, same count)
        assert_eq!(a.fwd_transfers.len(), b.fwd_transfers.len());
        for (ta, tb) in a.fwd_transfers.iter().zip(&b.fwd_transfers) {
            assert_eq!(ta.bits, tb.bits);
        }
    }

    #[test]
    fn consumer_receives_exactly_its_row_segment() {
        let (p, stage) = placed("mnist_class");
        // layer 1 consumers (300->200) see 301 rows, no split: each of
        // the 2 consumer cores receives the full 300 outputs of layer 0.
        let l1 = &stage.layers[1];
        assert_eq!(l1.row_splits, 1);
        let into_l1: u64 = p
            .fwd_transfers
            .iter()
            .filter(|t| p.coords[1].contains(&t.dst))
            .map(|t| t.bits)
            .sum();
        // 2 consumer cores x 300 neurons x 3 bits
        assert_eq!(into_l1, 2 * 300 * 3);
    }

    #[test]
    fn split_layer_combiner_collects_all_partials() {
        let (p, stage) = placed("mnist_class");
        // layer 0 is split 2x3 with 3 combiner cores; combiner traffic =
        // 2 row-splits x 300 neurons x 3 bits.
        let l0 = &stage.layers[0];
        assert_eq!(l0.row_splits, 2);
        let comb_stops: Vec<Xy> = l0
            .slices
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_combiner)
            .map(|(i, _)| p.coords[0][i])
            .collect();
        let comb_bits: u64 = p
            .fwd_transfers
            .iter()
            .filter(|t| comb_stops.contains(&t.dst))
            .map(|t| t.bits)
            .sum();
        assert_eq!(comb_bits, 2 * 300 * 3);
    }
}
