//! Network → core mapping (paper section V.B).
//!
//! Neural hardware cannot time-multiplex neurons (weights live in the
//! crossbars), so a network layer must be *spatially* mapped:
//!
//! * more neurons than a core's 100 → **column split** across cores;
//! * more inputs than a core's 400 rows → **neuron split** (paper
//!   Fig 14): each logical neuron becomes `row_splits` sub-neurons plus a
//!   combiner neuron in an extra combining layer. The network is trained
//!   in the split topology, so the mapping happens *before* training.
//! * networks much smaller than a core are packed multi-layer into one
//!   core, looping through the core's own routing switch.
//!
//! DR applications train stage-by-stage (layerwise autoencoder
//! pre-training); the chip is reconfigured between stages, so the
//! reported core count is the maximum over stages, which must fit the
//! 144-core chip.

mod placement;

pub use placement::{place, place_at, Placement};
pub(crate) use placement::row_segment;

use crate::config::hwspec as hw;
use crate::config::{AppKind, Network, SystemConfig};
use crate::cores::NeuralCore;

/// One core's slice of a (possibly split) layer.
#[derive(Clone, Debug)]
pub struct CoreSlice {
    pub core: NeuralCore,
    /// Which row-split segment of the layer inputs this core sees.
    pub row_split: usize,
    /// Neuron range `[lo, hi)` of the (sub-)layer handled here.
    pub neurons: (usize, usize),
    /// True for combiner-stage cores (Fig 14 second stage).
    pub is_combiner: bool,
}

/// Mapping of one network layer (plus its combiner stage if split).
#[derive(Clone, Debug)]
pub struct LayerMap {
    pub layer_idx: usize,
    /// Data inputs (bias excluded) and neurons of the logical layer.
    pub n_in: usize,
    pub n_out: usize,
    pub row_splits: usize,
    pub col_splits: usize,
    pub slices: Vec<CoreSlice>,
}

impl LayerMap {
    pub fn cores_used(&self) -> usize {
        self.slices.len()
    }

    /// Output bits this layer sends into the NoC per evaluation.
    pub fn output_bits(&self) -> u64 {
        self.n_out as u64 * hw::OUT_BITS as u64
    }
}

/// Mapping of one *stage* (the unit of chip reconfiguration: the whole
/// net for classifiers/AEs, one pretraining AE for DR apps).
///
/// When a stage needs more cores than the chip has, its layers are split
/// into sequential *phases*: the chip runs the first layer group over
/// the sample stream (spilling activations to DRAM), reconfigures, and
/// continues — the "reconfigurable" in the paper's title. `phases` holds
/// layer indices per phase; single-phase stages have one entry.
#[derive(Clone, Debug)]
pub struct StageMap {
    pub name: String,
    pub layers: Vec<LayerMap>,
    pub phases: Vec<Vec<usize>>,
}

impl StageMap {
    /// Peak simultaneous core demand = the largest phase.
    pub fn cores_used(&self) -> usize {
        self.phases
            .iter()
            .map(|p| p.iter().map(|&l| self.layers[l].cores_used()).sum())
            .max()
            .unwrap_or(0)
    }

    /// Greedy phase split against a core budget. Errors if any single
    /// layer alone exceeds the budget (truly unmappable).
    fn split_phases(layers: &[LayerMap], budget: usize)
        -> Result<Vec<Vec<usize>>, String> {
        let mut phases = vec![Vec::new()];
        let mut used = 0;
        for (i, l) in layers.iter().enumerate() {
            let need = l.cores_used();
            if need > budget {
                return Err(format!(
                    "layer {i} alone needs {need} cores (budget {budget})"
                ));
            }
            if used + need > budget {
                phases.push(Vec::new());
                used = 0;
            }
            phases.last_mut().unwrap().push(i);
            used += need;
        }
        Ok(phases)
    }
}

/// Full application mapping.
#[derive(Clone, Debug)]
pub struct NetworkMap {
    pub app: String,
    pub stages: Vec<StageMap>,
}

impl NetworkMap {
    /// Peak simultaneous core demand (the paper's "# of cores" column).
    pub fn cores_used(&self) -> usize {
        self.stages.iter().map(StageMap::cores_used).max().unwrap_or(0)
    }
}

/// Map one logical layer: split by rows (neuron splitting, Fig 14) and
/// columns, emitting the combiner stage when rows split.
pub fn map_layer(layer_idx: usize, n_in: usize, n_out: usize)
    -> Result<LayerMap, String> {
    map_layer_with(layer_idx, n_in, n_out, hw::CORE_INPUTS, hw::CORE_NEURONS)
}

/// [`map_layer`] with explicit core geometry — the crossbar-size
/// ablation bench sweeps this (paper section IV.A's sizing argument).
/// Note the produced `NeuralCore` capacity checks still enforce the real
/// chip's geometry, so geometries above 400x100 are counted, not built.
pub fn map_layer_with(
    layer_idx: usize,
    n_in: usize,
    n_out: usize,
    core_inputs: usize,
    core_neurons: usize,
) -> Result<LayerMap, String> {
    if n_in == 0 || n_out == 0 {
        return Err(format!("layer {layer_idx} is degenerate"));
    }
    let rows_needed = n_in + 1; // bias row
    let row_splits = rows_needed.div_ceil(core_inputs);
    let col_splits = n_out.div_ceil(core_neurons);
    let mut slices = Vec::new();
    let mut core_id = 0;
    // main (sub-neuron) cores: row_splits x col_splits grid
    for rs in 0..row_splits {
        let seg_inputs = segment(rows_needed, row_splits, rs);
        for cs in 0..col_splits {
            let lo = cs * core_neurons;
            let hi = ((cs + 1) * core_neurons).min(n_out);
            let core = NeuralCore::assign_with(
                core_id, seg_inputs, hi - lo, core_inputs, core_neurons)?;
            slices.push(CoreSlice {
                core,
                row_split: rs,
                neurons: (lo, hi),
                is_combiner: false,
            });
            core_id += 1;
        }
    }
    // combiner cores: each logical neuron sums its row_splits sub-neurons
    if row_splits > 1 {
        for cs in 0..col_splits {
            let lo = cs * core_neurons;
            let hi = ((cs + 1) * core_neurons).min(n_out);
            // combiner neuron inputs: row_splits partial sums + bias
            let core = NeuralCore::assign_with(
                core_id, row_splits + 1, hi - lo, core_inputs, core_neurons)?;
            slices.push(CoreSlice {
                core,
                row_split: 0,
                neurons: (lo, hi),
                is_combiner: true,
            });
            core_id += 1;
        }
    }
    Ok(LayerMap { layer_idx, n_in, n_out, row_splits, col_splits, slices })
}

/// Even segmentation of `total` rows into `parts`, sized for part `idx`.
fn segment(total: usize, parts: usize, idx: usize) -> usize {
    let base = total / parts;
    let extra = total % parts;
    base + usize::from(idx < extra)
}

/// Map a whole application onto the chip.
pub fn map_network(net: &Network, sys: &SystemConfig) -> Result<NetworkMap, String> {
    let budget = sys.neural_cores;
    let mut stages = Vec::new();
    let push_stage = |name: String, layers: Vec<LayerMap>|
        -> Result<StageMap, String> {
        let phases = StageMap::split_phases(&layers, budget)?;
        Ok(StageMap { name, layers, phases })
    };
    match net.kind {
        AppKind::Classifier | AppKind::Autoencoder => {
            let mut layers = Vec::new();
            for (i, (n_in, n_out)) in net.layer_shapes().iter().enumerate() {
                layers.push(map_layer(i, *n_in, *n_out)?);
            }
            stages.push(push_stage(net.name.to_string(), layers)?);
        }
        AppKind::DimReduction => {
            // layerwise AE pre-training: stage s trains n->h->n
            for (s, (n_in, n_hid)) in net.dr_stages().iter().enumerate() {
                let enc = map_layer(0, *n_in, *n_hid)?;
                let dec = map_layer(1, *n_hid, *n_in)?;
                stages.push(push_stage(
                    format!("{}_stage{}", net.name, s),
                    vec![enc, dec],
                )?);
            }
        }
        AppKind::Kmeans => return Err("k-means maps to the clustering core".into()),
    }
    let map = NetworkMap { app: net.name.to_string(), stages };
    debug_assert!(map.cores_used() <= budget);
    Ok(map)
}

/// Data-parallel shard hint for the coordinator's worker pool
/// (`coordinator::pool`): the number of mesh cores the app's mapping
/// occupies at peak. The software pool shards input batches the way
/// the chip spreads the network over its core mesh, making the pool
/// the execution twin of the placement. Apps that fail to map (a
/// layer larger than the core budget, or clustering-core workloads,
/// which this mapper rejects) fall back to a single shard.
pub fn shard_hint(net: &Network, sys: &SystemConfig) -> usize {
    map_network(net, sys)
        .map(|m| m.cores_used().max(1))
        .unwrap_or(1)
}

/// Placement of one pipeline stage: the contiguous layer group
/// `[layers.0, layers.1)` mapped as its own [`StageMap`] at a fixed
/// core offset in the mesh.
#[derive(Clone, Debug)]
pub struct PipelineStagePlan {
    /// Stage index in stream order.
    pub stage: usize,
    /// Network layer range `[lo, hi)` this stage owns.
    pub layers: (usize, usize),
    /// The stage's core mapping (row/column splits, phases).
    pub map: StageMap,
    /// First mesh core id of the stage's core group (row-major,
    /// [`SystemConfig::core_xy`](crate::config::SystemConfig::core_xy)
    /// resolves coordinates).
    pub core_offset: usize,
}

impl PipelineStagePlan {
    /// Cores the stage occupies.
    pub fn cores_used(&self) -> usize {
        self.map.cores_used()
    }
}

/// Placement of a whole layer pipeline: every stage resident on its own
/// core group so samples stream through without reconfiguration —
/// the execution shape of the follow-up streaming-multicore paper
/// (arXiv:1606.04609). When the stages together overflow the mesh,
/// later stages wrap to core 0 and `resident` turns false: the chip
/// would time-share those core groups (reconfiguration swaps), but the
/// stream semantics — and therefore the results — are unchanged.
#[derive(Clone, Debug)]
pub struct PipelinePlan {
    /// Application name.
    pub app: String,
    /// Per-stage placements, in stream order.
    pub stages: Vec<PipelineStagePlan>,
    /// Sum of per-stage core demands.
    pub total_cores: usize,
    /// True when every stage holds its cores simultaneously.
    pub resident: bool,
}

impl PipelinePlan {
    /// Number of pipeline stages.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }
}

/// Contiguous layer range of stage `s` when `n_layers` layers split
/// into `stages` groups, earlier stages taking the remainder — the
/// same segmentation rule as [`ShardPlan::contiguous`]
/// (`crate::coordinator::ShardPlan`), which is what keeps the stage
/// boundaries a pure function of `(n_layers, stages)`.
pub fn stage_layer_bounds(
    n_layers: usize,
    stages: usize,
    s: usize,
) -> (usize, usize) {
    let stages = stages.clamp(1, n_layers.max(1));
    let mut lo = 0;
    for i in 0..s {
        lo += segment(n_layers, stages, i);
    }
    (lo, lo + segment(n_layers, stages, s))
}

/// Place a layer pipeline: split the net's layers into `stages`
/// contiguous groups (clamped to `1..=n_layers`; a group absorbs
/// several layers when one layer underfills a stage), map each group as
/// its own [`StageMap`], and hand every stage a dedicated core group at
/// cumulative offsets. Errors when any single layer exceeds the core
/// budget (truly unmappable) and for clustering workloads, which have
/// no layer pipeline.
pub fn plan_pipeline(
    net: &Network,
    sys: &SystemConfig,
    stages: usize,
) -> Result<PipelinePlan, String> {
    if net.kind == AppKind::Kmeans {
        return Err("k-means maps to the clustering core".into());
    }
    let shapes = net.layer_shapes();
    let n_layers = shapes.len();
    let stages = stages.clamp(1, n_layers.max(1));
    let budget = sys.neural_cores;
    let mut plans = Vec::with_capacity(stages);
    let mut offset = 0usize;
    let mut total_cores = 0usize;
    let mut resident = true;
    for s in 0..stages {
        let (lo, hi) = stage_layer_bounds(n_layers, stages, s);
        let mut layers = Vec::with_capacity(hi - lo);
        for l in lo..hi {
            let (n_in, n_out) = shapes[l];
            layers.push(map_layer(l - lo, n_in, n_out)?);
        }
        let phases = StageMap::split_phases(&layers, budget)?;
        let map = StageMap {
            name: format!("{}_pipe{}", net.name, s),
            layers,
            phases,
        };
        let cores = map.cores_used();
        if offset + cores > budget {
            // This stage cannot sit next to its predecessors: wrap to
            // core 0 and mark the pipeline time-shared.
            resident = false;
            offset = 0;
        }
        plans.push(PipelineStagePlan {
            stage: s,
            layers: (lo, hi),
            map,
            core_offset: offset,
        });
        offset += cores;
        total_cores += cores;
    }
    Ok(PipelinePlan {
        app: net.name.to_string(),
        stages: plans,
        total_cores,
        resident,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::apps;
    use crate::testing::{forall, Rng};

    #[test]
    fn small_layer_uses_one_core() {
        let m = map_layer(0, 41, 15).unwrap();
        assert_eq!(m.cores_used(), 1);
        assert_eq!(m.row_splits, 1);
        assert_eq!(m.col_splits, 1);
        assert_eq!(m.slices[0].core.inputs, 42);
    }

    #[test]
    fn column_split_only() {
        // 300 inputs, 300 neurons: 1 row split, 3 column splits.
        let m = map_layer(0, 300, 300).unwrap();
        assert_eq!(m.row_splits, 1);
        assert_eq!(m.col_splits, 3);
        assert_eq!(m.cores_used(), 3);
        assert!(m.slices.iter().all(|s| !s.is_combiner));
    }

    #[test]
    fn neuron_split_adds_combiner_stage() {
        // 784 inputs -> 785 rows -> 2 row splits (Fig 14).
        let m = map_layer(0, 784, 300).unwrap();
        assert_eq!(m.row_splits, 2);
        assert_eq!(m.col_splits, 3);
        // 2x3 sub-neuron cores + 3 combiner cores
        assert_eq!(m.cores_used(), 9);
        assert_eq!(m.slices.iter().filter(|s| s.is_combiner).count(), 3);
    }

    #[test]
    fn every_neuron_placed_exactly_once_per_row_split() {
        forall("mapper_cover", 60, |rng: &mut Rng| {
            let n_in = rng.range(1, 2500);
            let n_out = rng.range(1, 2500);
            let m = map_layer(0, n_in, n_out)?;
            for rs in 0..m.row_splits {
                let mut covered = vec![0usize; n_out];
                for s in m.slices.iter().filter(|s| !s.is_combiner && s.row_split == rs) {
                    for n in s.neurons.0..s.neurons.1 {
                        covered[n] += 1;
                    }
                }
                if covered.iter().any(|&c| c != 1) {
                    return Err(format!(
                        "row split {rs} coverage broken for {n_in}x{n_out}"
                    ));
                }
            }
            // no core over capacity (NeuralCore::assign enforces, but
            // double-check the invariant end-to-end)
            for s in &m.slices {
                if s.core.inputs > hw::CORE_INPUTS || s.core.neurons > hw::CORE_NEURONS {
                    return Err("core over capacity".into());
                }
            }
            // row segments cover all inputs + bias
            let total: usize = (0..m.row_splits)
                .map(|rs| {
                    m.slices
                        .iter()
                        .find(|s| !s.is_combiner && s.row_split == rs)
                        .map(|s| s.core.inputs)
                        .unwrap_or(0)
                })
                .sum();
            if total != n_in + 1 {
                return Err(format!("segments sum {total} != {}", n_in + 1));
            }
            Ok(())
        });
    }

    #[test]
    fn table3_core_counts_have_paper_shape() {
        let sys = SystemConfig::default();
        let mnist = map_network(apps::network("mnist_class").unwrap(), &sys).unwrap();
        let isolet = map_network(apps::network("isolet_class").unwrap(), &sys).unwrap();
        let kdd = map_network(apps::network("kdd_ae").unwrap(), &sys).unwrap();
        // Paper Table III: KDD 1 core, MNIST tens, ISOLET highest & near
        // the 144-core budget.
        assert_eq!(kdd.cores_used(), 2); // 41->15 and 15->41 layers
        assert!(mnist.cores_used() > 10 && mnist.cores_used() < 60,
                "mnist {}", mnist.cores_used());
        assert!(isolet.cores_used() > mnist.cores_used());
        assert!(isolet.cores_used() <= 144, "isolet {}", isolet.cores_used());
    }

    #[test]
    fn shard_hint_mirrors_core_demand() {
        let sys = SystemConfig::default();
        for net in apps::NETWORKS {
            let hint = shard_hint(net, &sys);
            let cores =
                map_network(net, &sys).map(|m| m.cores_used()).unwrap_or(0);
            assert_eq!(hint, cores.max(1), "{}", net.name);
            assert!(hint >= 1 && hint <= sys.neural_cores, "{}", net.name);
        }
        // a single-core app parallelises 1-way, the paper's big nets
        // many-way — the pool scales with the placement
        assert_eq!(shard_hint(apps::network("kdd_ae").unwrap(), &sys), 2);
        assert!(shard_hint(apps::network("mnist_class").unwrap(), &sys) > 10);
    }

    #[test]
    fn pipeline_plans_cover_layers_with_disjoint_core_groups() {
        let sys = SystemConfig::default();
        for net in apps::NETWORKS {
            let n_layers = net.layers.len() - 1;
            for stages in [1, 2, n_layers, n_layers + 3] {
                let p = plan_pipeline(net, &sys, stages).unwrap();
                assert!(p.n_stages() >= 1 && p.n_stages() <= n_layers);
                // stages own the layers contiguously, in stream order
                let mut next = 0;
                for st in &p.stages {
                    assert_eq!(st.layers.0, next, "{} s{}", net.name, st.stage);
                    assert!(st.layers.1 > st.layers.0, "{}", net.name);
                    next = st.layers.1;
                }
                assert_eq!(next, n_layers, "{}", net.name);
                assert_eq!(
                    p.total_cores,
                    p.stages.iter().map(|s| s.cores_used()).sum::<usize>()
                );
                if p.resident {
                    // resident pipelines hold disjoint core ranges
                    let mut spans: Vec<(usize, usize)> = p
                        .stages
                        .iter()
                        .map(|s| {
                            (s.core_offset, s.core_offset + s.cores_used())
                        })
                        .collect();
                    spans.sort_unstable();
                    for w in spans.windows(2) {
                        assert!(w[0].1 <= w[1].0, "{} overlaps", net.name);
                    }
                    assert!(spans.last().unwrap().1 <= sys.neural_cores);
                }
            }
        }
        // the deep ISOLET stack cannot hold every stage resident at once
        let isolet = apps::network("isolet_class").unwrap();
        let full = plan_pipeline(isolet, &sys, isolet.layers.len() - 1);
        assert!(!full.unwrap().resident);
        // stage boundaries are the even-segmentation rule, verbatim
        assert_eq!(stage_layer_bounds(5, 2, 0), (0, 3));
        assert_eq!(stage_layer_bounds(5, 2, 1), (3, 5));
        assert_eq!(stage_layer_bounds(2, 9, 1), (1, 2));
    }

    #[test]
    fn dr_apps_fit_via_stage_reconfiguration() {
        let sys = SystemConfig::default();
        for name in ["mnist_dr", "isolet_dr"] {
            let net = apps::network(name).unwrap();
            let m = map_network(net, &sys).unwrap();
            assert!(m.stages.len() == net.layers.len() - 1);
            assert!(m.cores_used() <= sys.neural_cores);
        }
    }
}
