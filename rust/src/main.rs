//! `restream` — CLI launcher for the ReStream chip simulator.
//!
//! Subcommands (hand-rolled parser; no clap in the offline registry):
//!
//! ```text
//! restream chip                          chip inventory + area budget
//! restream report --table 2|3|4         regenerate a paper table
//! restream report --vs-gpu train|recog  Figs 22-25 series
//! restream report --occupancy all|A,B,…  multi-tenant occupancy table
//! restream train   --app NAME [--epochs N] [--lr F] [--seed N]
//!                  [--batch N] [--checkpoint DIR [--every N] [--resume]]
//! restream infer   --app NAME [--seed N]
//! restream cluster --app NAME [--epochs N]
//! restream anomaly [--epochs N]
//! restream serve   --app NAME [--source stdin|replay] [--max-batch N]
//!                  [--max-wait-us N] [--clients N] [--requests N]
//! restream serve   --apps A,B,C [--max-batch N] [--max-wait-us N]
//!                  [--clients N] [--requests N]
//! ```
//!
//! `serve` runs the micro-batching request server (`restream::serve`,
//! DESIGN.md "Serving layer"): `--source stdin` reads one
//! whitespace/comma-separated sample per line and prints `<id> <out…>`
//! lines (summary on stderr); the default `--source replay` drives the
//! server closed-loop from `--clients` threads issuing `--requests`
//! deterministic requests each and prints the latency/throughput
//! summary. `serve --apps` hosts every listed app as a resident of one
//! simulated chip (`restream::chip`, DESIGN.md "Multi-tenant serving")
//! and prints the `MultiServeReport` — per-app latency, occupancy,
//! swaps and the modeled reconfiguration time charged.
//!
//! Every functional-math subcommand accepts `--backend native|pjrt`
//! (default: `$RESTREAM_BACKEND` or `native`) and `--workers N`
//! (default: `$RESTREAM_WORKERS` or 1) — the worker-pool size the
//! batched operations shard over; results are bit-identical at any
//! worker count. `train --batch N` selects the mini-batch size: 1
//! (default) is the paper's per-sample stochastic BP, N > 1 runs
//! data-parallel gradient accumulation over the pool with one weight
//! update per mini-batch — also bit-identical at any `--workers` for a
//! fixed N. `train --checkpoint DIR` commits a verified snapshot of
//! the full training state every `--every N` epochs (default 1) and
//! `--resume` restarts from the latest complete one, continuing
//! **bit-identically** to the uninterrupted run (`restream::checkpoint`,
//! DESIGN.md "Fault tolerance"). The native backend needs no artifacts;
//! `pjrt` needs the crate built with `--features pjrt` plus
//! `make artifacts`.

use std::collections::HashMap;
use std::process::ExitCode;

use restream::config::{apps, SystemConfig};
use restream::coordinator::Engine;
use restream::serve::{ServeConfig, Server};
use restream::{datasets, metrics, report};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("restream: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Parse `--key value` pairs after the subcommand. A flag followed by
/// another flag (or by nothing) is a bare boolean switch and parses as
/// `true` — `--resume` and `--resume true` are equivalent.
fn flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut m = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(k) = it.next() {
        let key = k
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got {k}"))?;
        let v = match it.peek() {
            Some(next) if !next.starts_with("--") => {
                it.next().unwrap().clone()
            }
            _ => "true".to_string(),
        };
        m.insert(key.to_string(), v);
    }
    Ok(m)
}

fn get<T: std::str::FromStr>(f: &HashMap<String, String>, key: &str,
                             default: T) -> Result<T, String> {
    match f.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| format!("bad value for --{key}: {v}")),
    }
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let sys = SystemConfig::default();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let f = flags(&args[1..]).map_err(anyhow::Error::msg)?;
    match cmd.as_str() {
        "chip" => print!("{}", report::chip_summary(&sys)),
        "report" => {
            if let Some(t) = f.get("table") {
                match t.as_str() {
                    "2" => print!("{}", report::table2()),
                    "3" => print!("{}", report::table3(&sys)),
                    "4" => print!("{}", report::table4(&sys)),
                    other => anyhow::bail!("unknown table {other}"),
                }
            } else if let Some(which) = f.get("vs-gpu") {
                print!("{}", report::vs_gpu_table(&sys, which == "train"));
            } else if let Some(spec) = f.get("occupancy") {
                print!(
                    "{}",
                    report::occupancy_table(&sys, spec)
                        .map_err(anyhow::Error::msg)?
                );
            } else {
                anyhow::bail!(
                    "report needs --table N, --vs-gpu train|recog or \
                     --occupancy all|app,app,…"
                );
            }
        }
        "train" => cmd_train(&f)?,
        "infer" => cmd_infer(&f)?,
        "cluster" => cmd_cluster(&f)?,
        "anomaly" => cmd_anomaly(&f)?,
        "serve" => cmd_serve(&f)?,
        other => {
            print_usage();
            anyhow::bail!("unknown command {other}");
        }
    }
    Ok(())
}

/// Engine over the backend picked by `--backend` (or the environment),
/// sharding batched operations over `--workers` pool threads (default:
/// `$RESTREAM_WORKERS`, else 1). Results are bit-identical at any
/// worker count — see DESIGN.md "Parallel execution".
fn engine_for(f: &HashMap<String, String>) -> anyhow::Result<Engine> {
    let engine = match f.get("backend") {
        Some(name) => Engine::named(name),
        None => Engine::open_default(),
    }?;
    let workers: usize =
        get(f, "workers", restream::coordinator::default_workers())
            .map_err(anyhow::Error::msg)?;
    Ok(engine.with_workers(workers))
}

fn dataset_for(app: &str, n: usize, seed: u64) -> anyhow::Result<datasets::Dataset> {
    Ok(match app {
        a if a.starts_with("iris") => datasets::iris(seed),
        a if a.starts_with("mnist") => datasets::mnist(n, seed),
        a if a.starts_with("isolet") => datasets::isolet(n, seed),
        other => anyhow::bail!("no dataset generator for {other}"),
    })
}

fn cmd_train(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let app: String = get(f, "app", "iris_class".to_string())
        .map_err(anyhow::Error::msg)?;
    let epochs: usize = get(f, "epochs", 5).map_err(anyhow::Error::msg)?;
    let lr: f32 = get(f, "lr", 1.0).map_err(anyhow::Error::msg)?;
    let seed: u64 = get(f, "seed", 0).map_err(anyhow::Error::msg)?;
    let n: usize = get(f, "samples", 512).map_err(anyhow::Error::msg)?;
    // mini-batch size: 1 = the paper's per-sample stochastic BP;
    // N > 1 = data-parallel gradient accumulation over the worker pool
    // (bit-identical at any --workers value for a fixed N)
    let batch: usize = get(f, "batch", 1).map_err(anyhow::Error::msg)?;
    // checkpoint policy: --checkpoint DIR commits a verified snapshot
    // every --every epochs; --resume restarts from the latest complete
    // one (bit-identical to the uninterrupted run)
    let every: usize = get(f, "every", 1).map_err(anyhow::Error::msg)?;
    let resume: bool = get(f, "resume", false).map_err(anyhow::Error::msg)?;
    let ckpt = match f.get("checkpoint") {
        Some(dir) => Some(restream::coordinator::CheckpointOpts {
            dir: dir.into(),
            every: every.max(1),
            resume,
            stop_after: None,
        }),
        None if resume => {
            anyhow::bail!("--resume needs --checkpoint DIR")
        }
        None => None,
    };
    let net = apps::network(&app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
    let engine = engine_for(f)?;
    let ds = dataset_for(&app, n, seed)?;
    let (train_ds, test_ds) = ds.split(0.8, seed);
    let xs = train_ds.rows();

    use restream::config::AppKind;
    match net.kind {
        AppKind::DimReduction => {
            let (_, reports) = match &ckpt {
                Some(opts) => engine.train_dr_checkpointed(
                    net, &xs, epochs, lr, seed, batch, opts)?,
                None => engine.train_dr(net, &xs, epochs, lr, seed, batch)?,
            };
            for (s, r) in reports.iter().enumerate() {
                println!(
                    "stage {s}: {} epochs, final loss {:.5}, {:.2}s",
                    r.epochs,
                    r.loss_curve.last().unwrap_or(&f32::NAN),
                    r.wall_s
                );
                print_train_parallel(r);
            }
        }
        AppKind::Autoencoder => {
            let xs2 = xs.clone();
            let targets = move |i: usize| xs2[i].clone();
            let (_, r) = match &ckpt {
                Some(opts) => engine.train_checkpointed(
                    net, &xs, targets, epochs, lr, seed, batch, opts)?,
                None => engine.train_with(
                    net, &xs, targets, epochs, lr, seed, batch)?,
            };
            print_curve(&r);
            print_train_parallel(&r);
        }
        _ => {
            let outs = net.layers[net.layers.len() - 1];
            let targets = |i: usize| train_ds.target(i, outs);
            let (params, r) = match &ckpt {
                Some(opts) => engine.train_checkpointed(
                    net, &xs, targets, epochs, lr, seed, batch, opts)?,
                None => engine.train_with(
                    net, &xs, targets, epochs, lr, seed, batch)?,
            };
            print_curve(&r);
            print_train_parallel(&r);
            let preds = engine.classify(net, &params, &test_ds.rows())?;
            // single-output nets are binary (class 0 vs rest)
            let truth: Vec<usize> = if outs == 1 {
                test_ds.y.iter().map(|&y| y.min(1)).collect()
            } else {
                test_ds.y.clone()
            };
            println!(
                "test accuracy: {:.3}",
                metrics::accuracy(&preds, &truth)
            );
        }
    }
    Ok(())
}

/// Per-shard stats of a data-parallel training run (only informative
/// for `--batch N > 1`).
fn print_train_parallel(r: &restream::coordinator::TrainReport) {
    if r.recovered_shards > 0 {
        println!(
            "worker recovery: {} shard(s) reassigned after worker death",
            r.recovered_shards
        );
    }
    if r.batch <= 1 || r.shard_busy_s.is_empty() {
        return;
    }
    let busy: f64 = r.shard_busy_s.iter().sum();
    println!(
        "parallel training: batch {}, {} workers, {} shards/mini-batch, \
         grad {:.3}s (shard busy {:.3}s) + apply {:.3}s",
        r.batch,
        r.workers,
        r.shard_busy_s.len(),
        r.grad_wall_s,
        busy,
        r.apply_wall_s
    );
}

fn print_curve(r: &restream::coordinator::TrainReport) {
    for (e, l) in r.loss_curve.iter().enumerate() {
        println!("epoch {e:>3}  loss {l:.5}");
    }
    println!(
        "{} samples in {:.2}s ({:.0} samples/s)",
        r.samples_seen,
        r.wall_s,
        r.samples_seen as f64 / r.wall_s.max(1e-9)
    );
}

fn cmd_infer(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let app: String = get(f, "app", "iris_class".to_string())
        .map_err(anyhow::Error::msg)?;
    let seed: u64 = get(f, "seed", 0).map_err(anyhow::Error::msg)?;
    let net = apps::network(&app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
    let engine = engine_for(f)?;
    let ds = dataset_for(&app, 256, seed)?;
    let params = restream::coordinator::init_conductances(net.layers, seed);
    let start = std::time::Instant::now();
    let outs = engine.infer(net, &params, &ds.rows())?;
    let dt = start.elapsed().as_secs_f64();
    println!(
        "{} samples through {} in {:.3}s ({:.0}/s, untrained weights)",
        outs.len(),
        net.fwd_artifact(),
        dt,
        outs.len() as f64 / dt
    );
    print_parallel_report(&engine);
    Ok(())
}

/// Per-shard stats of the last sharded operation, printed by every
/// subcommand that runs one (only informative above 1 worker).
fn print_parallel_report(engine: &Engine) {
    if engine.workers() <= 1 {
        return;
    }
    if let Some(rep) = engine.last_parallel_report() {
        println!(
            "parallel: {} workers, {} shards, shard busy {:.3}s \
             over wall {:.3}s",
            rep.workers,
            rep.shards.len(),
            rep.busy_s(),
            rep.wall_s
        );
    }
}

fn cmd_cluster(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let app: String = get(f, "app", "mnist_kmeans".to_string())
        .map_err(anyhow::Error::msg)?;
    let epochs: usize = get(f, "epochs", 10).map_err(anyhow::Error::msg)?;
    let seed: u64 = get(f, "seed", 0).map_err(anyhow::Error::msg)?;
    let ka = apps::kmeans_app(&app)
        .ok_or_else(|| anyhow::anyhow!("unknown clustering app {app}"))?;
    let engine = engine_for(f)?;
    // cluster synthetic features of the right dimensionality
    let ds = datasets::class_blobs(&app, ka.dims, ka.clusters, 512, 0.3, seed);
    let (_, assign) = engine.kmeans(ka, &ds.rows(), epochs, seed)?;
    println!(
        "purity over {} samples, k={}: {:.3}",
        ds.len(),
        ka.clusters,
        metrics::purity(&assign, &ds.y, ka.clusters, ds.classes)
    );
    print_parallel_report(&engine);
    Ok(())
}

fn cmd_anomaly(f: &HashMap<String, String>) -> anyhow::Result<()> {
    let epochs: usize = get(f, "epochs", 3).map_err(anyhow::Error::msg)?;
    let seed: u64 = get(f, "seed", 0).map_err(anyhow::Error::msg)?;
    let net = apps::network("kdd_ae").unwrap();
    let engine = engine_for(f)?;
    let k = datasets::kdd(2000, 400, 400, seed);
    let xs = k.train.rows();
    let xs2 = xs.clone();
    let (params, r) = engine.train(
        net, &xs, move |i| xs2[i].clone(), epochs, 0.8, seed)?;
    print_curve(&r);
    let scores = engine.anomaly_scores(net, &params, &k.test.rows())?;
    let pts = metrics::roc_sweep(&scores, &k.test_attack, 200);
    println!(
        "AUC {:.3}; detection at 4% FPR: {:.1}% (paper: 96.6%)",
        metrics::auc(&pts),
        100.0 * metrics::tpr_at_fpr(&pts, 0.04)
    );
    print_parallel_report(&engine);
    Ok(())
}

/// The micro-batching request server (DESIGN.md "Serving layer"):
/// requests stream in over stdin or a synthetic closed-loop replay,
/// coalesce into tile-aligned batches, and execute on the pooled
/// engine. Prints the aggregate `ServeReport` when the stream ends.
fn cmd_serve(f: &HashMap<String, String>) -> anyhow::Result<()> {
    if let Some(apps_list) = f.get("apps") {
        return cmd_serve_multi(f, apps_list);
    }
    let app: String = get(f, "app", "iris_class".to_string())
        .map_err(anyhow::Error::msg)?;
    let max_batch: usize =
        get(f, "max-batch", apps::FWD_BATCH).map_err(anyhow::Error::msg)?;
    let max_wait_us: u64 =
        get(f, "max-wait-us", 200).map_err(anyhow::Error::msg)?;
    let clients: usize = get(f, "clients", 4).map_err(anyhow::Error::msg)?;
    let requests: usize =
        get(f, "requests", 256).map_err(anyhow::Error::msg)?;
    let seed: u64 = get(f, "seed", 0).map_err(anyhow::Error::msg)?;
    let source: String = get(f, "source", "replay".to_string())
        .map_err(anyhow::Error::msg)?;
    let net = apps::network(&app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?
        .clone();
    let engine = engine_for(f)?;
    let params = restream::coordinator::init_conductances(net.layers, seed);
    let dims = net.layers[0];
    let cfg = ServeConfig {
        max_batch,
        max_wait: std::time::Duration::from_micros(max_wait_us),
        queue_capacity: None,
    };
    let banner = format!(
        "serving {app} ({dims} dims): max batch {}, max wait {max_wait_us} us, \
         queue {} samples (4 kB input buffer), {} workers",
        cfg.max_batch.max(1),
        restream::coordinator::stream::buffer_capacity(dims),
        engine.workers()
    );
    if source == "stdin" {
        // stdout carries only `<id> <out…>` / `err <msg>` lines
        eprintln!("{banner}");
    } else {
        println!("{banner}");
    }
    let server = Server::start(engine, net, params, cfg);
    match source.as_str() {
        "stdin" => serve_stdin(&server)?,
        "replay" => serve_replay(&server, clients, requests, seed)?,
        other => anyhow::bail!("--source must be stdin or replay, got {other}"),
    }
    let report = server.shutdown();
    if source == "stdin" {
        // keep stdout clean for the response lines
        eprint!("{}", report.summary());
    } else {
        print!("{}", report.summary());
    }
    Ok(())
}

/// Multi-tenant serving (`restream serve --apps a,b,c`; DESIGN.md
/// "Multi-tenant serving"): every listed app becomes a resident of one
/// simulated chip behind a `chip::ChipScheduler` — per-app bounded
/// queues and batchers, deficit-round-robin dispatch onto one shared
/// worker pool, overflow beyond the 144-core mesh served via modeled
/// reconfiguration swaps. Drives a closed-loop replay (`--clients`
/// threads per app, `--requests` each) and prints the
/// `MultiServeReport`: per-app p50/p99, occupancy, swap count and the
/// reconfiguration time charged.
fn cmd_serve_multi(
    f: &HashMap<String, String>,
    apps_list: &str,
) -> anyhow::Result<()> {
    use restream::chip::{ChipApp, ChipConfig, ChipScheduler};
    let max_batch: usize =
        get(f, "max-batch", apps::FWD_BATCH).map_err(anyhow::Error::msg)?;
    let max_wait_us: u64 =
        get(f, "max-wait-us", 200).map_err(anyhow::Error::msg)?;
    let clients: usize = get(f, "clients", 4).map_err(anyhow::Error::msg)?;
    let requests: usize =
        get(f, "requests", 256).map_err(anyhow::Error::msg)?;
    let seed: u64 = get(f, "seed", 0).map_err(anyhow::Error::msg)?;
    let names: Vec<&str> = apps_list
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        anyhow::bail!("--apps needs a comma-separated app list");
    }
    let mut hosted = Vec::with_capacity(names.len());
    for name in &names {
        let net = apps::network(name)
            .ok_or_else(|| anyhow::anyhow!("unknown app {name}"))?
            .clone();
        let params = restream::coordinator::init_conductances(
            net.layers, seed,
        );
        hosted.push(ChipApp { net, params });
    }
    let engine = engine_for(f)?;
    let workers = engine.workers();
    let cfg = ChipConfig {
        max_batch,
        max_wait: std::time::Duration::from_micros(max_wait_us),
        ..ChipConfig::default()
    };
    println!(
        "multi-tenant serve: {} apps ({}), max batch {}, max wait \
         {max_wait_us} us, {clients} clients/app x {requests} requests, \
         {workers} workers",
        names.len(),
        names.join(","),
        cfg.max_batch.max(1),
    );
    let chip = ChipScheduler::start(engine, hosted, cfg)?;
    let mut handles = Vec::new();
    for (a, name) in names.iter().enumerate() {
        for c in 0..clients.max(1) {
            let client = chip.client(name)?;
            let dims = client.dims();
            let client_seed =
                seed ^ ((a as u64) << 32) ^ ((c as u64) << 17);
            handles.push(std::thread::spawn(
                move || -> anyhow::Result<()> {
                    let mut rng =
                        restream::testing::Rng::seeded(client_seed);
                    for _ in 0..requests {
                        client.call(rng.vec_uniform(dims, -0.5, 0.5))?;
                    }
                    Ok(())
                },
            ));
        }
    }
    for h in handles {
        h.join().expect("replay client thread panicked")?;
    }
    print!("{}", chip.shutdown().summary());
    Ok(())
}

/// Closed-loop synthetic load: `clients` threads each issue `requests`
/// deterministic uniform samples back-to-back (each waits for its
/// response before sending the next — batch sizes therefore track the
/// number of concurrent clients).
fn serve_replay(
    server: &Server,
    clients: usize,
    requests: usize,
    seed: u64,
) -> anyhow::Result<()> {
    let dims = server.client().dims();
    let handles: Vec<_> = (0..clients.max(1))
        .map(|c| {
            let client = server.client();
            std::thread::spawn(move || -> anyhow::Result<()> {
                let mut rng =
                    restream::testing::Rng::seeded(seed ^ ((c as u64) << 17));
                for _ in 0..requests {
                    client.call(rng.vec_uniform(dims, -0.5, 0.5))?;
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().expect("replay client thread panicked")?;
    }
    Ok(())
}

/// Line protocol: one whitespace/comma-separated f32 sample per stdin
/// line (blank lines and `#` comments skipped); responses print to
/// stdout as `<id> <out…>` in request order, bad lines as `err <msg>`.
fn serve_stdin(server: &Server) -> anyhow::Result<()> {
    use std::io::BufRead;
    let client = server.client();
    // Submission pipelines ahead of printing so requests can coalesce;
    // a single stdin client means responses complete in request order.
    // Bad lines travel the same channel as receipts, so the output
    // lines stay in input-line order.
    let (pending_tx, pending_rx) = std::sync::mpsc::channel::<
        Result<restream::serve::Pending, String>,
    >();
    let printer = std::thread::spawn(move || {
        // write! instead of println!: a downstream `| head -1` closes
        // the pipe mid-stream, and EPIPE must end the protocol
        // cleanly, not panic the process.
        use std::io::Write;
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        for slot in pending_rx {
            let wrote = match slot.map(restream::serve::Pending::wait) {
                Ok(Ok(r)) => {
                    let vals: Vec<String> =
                        r.out.iter().map(|v| v.to_string()).collect();
                    writeln!(out, "{} {}", r.id, vals.join(" "))
                }
                Ok(Err(e)) => writeln!(out, "err {e:#}"),
                Err(msg) => writeln!(out, "err {msg}"),
            };
            if wrote.is_err() {
                break; // consumer hung up; drop remaining receipts
            }
        }
    });
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let text = line.trim();
        if text.is_empty() || text.starts_with('#') {
            continue;
        }
        let parsed: Result<Vec<f32>, _> = text
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(str::parse::<f32>)
            .collect();
        let slot = match parsed {
            Ok(x) => client.submit(x).map_err(|e| format!("{e:#}")),
            Err(e) => Err(format!("bad sample line: {e}")),
        };
        if pending_tx.send(slot).is_err() {
            break; // printer exited (consumer hung up); stop reading
        }
    }
    drop(pending_tx);
    printer.join().expect("printer thread panicked");
    Ok(())
}

fn print_usage() {
    println!(
        "restream — memristor multicore chip simulator\n\
         usage: restream <chip|report|train|infer|cluster|anomaly|serve> \
         [--flags]\n\
         math subcommands take --backend native|pjrt (default native)\n\
         and --workers N (worker-pool size, default $RESTREAM_WORKERS or 1)\n\
         train: --batch N (mini-batch size; 1 = per-sample stochastic BP,\n\
         N > 1 = data-parallel gradient accumulation, bit-identical at\n\
         any --workers)\n\
         train: --checkpoint DIR [--every N] [--resume] (atomic, \
         checksummed\n\
         snapshots every N epochs; --resume continues bit-identically \
         from\n\
         the latest complete one)\n\
         serve: --app NAME --source stdin|replay --max-batch N \
         --max-wait-us N --clients N --requests N\n\
         serve --apps A,B,C: multi-tenant chip scheduler (per-app \
         queues,\n\
         DRR dispatch, modeled reconfiguration swaps; closed-loop \
         replay)\n\
         report --occupancy all|A,B,…: per-app core demand, offsets \
         and fit\n\
         see rust/src/main.rs docs and README.md for details"
    );
}
