//! `restream` — CLI launcher for the ReStream chip simulator.
//!
//! Subcommands (typed hand-rolled parser in `restream::cli`; no clap
//! in the offline registry):
//!
//! ```text
//! restream chip                          chip inventory + area budget
//! restream report --table 2|3|4         regenerate a paper table
//! restream report --vs-gpu train|recog  Figs 22-25 series
//! restream report --occupancy all|A,B,…  multi-tenant occupancy table
//! restream report --metrics [--json]    telemetry registry snapshot
//! restream train   --app NAME [--epochs N] [--lr F] [--seed N]
//!                  [--batch N] [--checkpoint DIR [--every N] [--resume]]
//! restream infer   --app NAME [--seed N]
//! restream cluster --app NAME [--epochs N]
//! restream anomaly [--epochs N]
//! restream serve   --app NAME [--source stdin|replay] [--max-batch N]
//!                  [--max-wait-us N] [--clients N] [--requests N]
//! restream serve   --apps A,B,C [--chips N] [--replicas N]
//!                  [--max-batch N] [--max-wait-us N] [--clients N]
//!                  [--requests N]
//! ```
//!
//! `train` and every `serve` mode additionally take the observability
//! flags `--trace-out FILE` (record request/phase spans, write chrome
//! `trace_event` JSON at shutdown — open in `chrome://tracing` or
//! Perfetto), `--metrics-out FILE` and `--metrics-every-ms N` (append
//! one metrics-snapshot JSON line per period). Tracing never alters
//! results: outputs are bitwise-identical with it on or off
//! (`rust/tests/telemetry_determinism.rs`). `report --metrics` prints
//! the process-wide registry (`--json` for one canonical document).
//!
//! `serve` runs the micro-batching request server (`restream::serve`,
//! DESIGN.md "Serving layer"): `--source stdin` reads one
//! whitespace/comma-separated sample per line and prints `<id> <out…>`
//! lines (summary on stderr); the default `--source replay` drives the
//! server closed-loop from `--clients` threads issuing `--requests`
//! deterministic requests each and prints the latency/throughput
//! summary. `serve --apps` hosts every listed app as a resident of one
//! simulated chip (`restream::chip`, DESIGN.md "Multi-tenant serving")
//! and prints the `MultiServeReport` — per-app latency, occupancy,
//! swaps and the modeled reconfiguration time charged. Adding
//! `--chips N` (above 1) serves the same apps from a fleet of N chips
//! behind one router (`restream::cluster`, DESIGN.md "Cluster layer"):
//! rendezvous-hash placement, `--replicas R` serving replicas per app
//! with least-loaded routing between them, and a `ClusterReport`
//! summary of per-chip routed shares, occupancy and modeled energy.
//! Responses are bit-identical whichever chip serves them.
//!
//! Every functional-math subcommand accepts `--backend native|pjrt`
//! (default: `$RESTREAM_BACKEND` or `native`) and `--workers N`
//! (default: `$RESTREAM_WORKERS` or 1) — the worker-pool size the
//! batched operations shard over; results are bit-identical at any
//! worker count. `--exec parallel|pipeline|hybrid` picks the
//! execution mode of the batched forward passes: data-parallel
//! sharding (default), layer-pipelined streaming over `--stages N`
//! core groups, or pipelined shard replicas — outputs are
//! bit-identical in every mode (DESIGN.md "Pipelined execution"),
//! and the pipelined modes print per-stage occupancy/stall. `train --batch N` selects the mini-batch size: 1
//! (default) is the paper's per-sample stochastic BP, N > 1 runs
//! data-parallel gradient accumulation over the pool with one weight
//! update per mini-batch — also bit-identical at any `--workers` for a
//! fixed N. `train --checkpoint DIR` commits a verified snapshot of
//! the full training state every `--every N` epochs (default 1) and
//! `--resume` restarts from the latest complete one, continuing
//! **bit-identically** to the uninterrupted run (`restream::checkpoint`,
//! DESIGN.md "Fault tolerance"). The native backend needs no artifacts;
//! `pjrt` needs the crate built with `--features pjrt` plus
//! `make artifacts`.

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use restream::cli::{self, Command, ReportCmd, ServeCmd};
use restream::config::{apps, SystemConfig};
use restream::coordinator::{Engine, TrainOptions};
use restream::serve::{ServeConfig, Server};
use restream::telemetry::{
    self, SnapshotWriter, Tracer, DEFAULT_TRACE_CAPACITY,
};
use restream::{datasets, metrics, report};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("restream: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let sys = SystemConfig::default();
    let cmd = match cli::parse(args) {
        Ok(cmd) => cmd,
        Err(e) => {
            if e.starts_with("unknown command") {
                print_usage();
            }
            anyhow::bail!(e);
        }
    };
    match cmd {
        Command::Usage => print_usage(),
        Command::Chip => print!("{}", report::chip_summary(&sys)),
        Command::Report(ReportCmd::Table(2)) => print!("{}", report::table2()),
        Command::Report(ReportCmd::Table(3)) => {
            print!("{}", report::table3(&sys))
        }
        Command::Report(ReportCmd::Table(_)) => {
            print!("{}", report::table4(&sys))
        }
        Command::Report(ReportCmd::VsGpu { train }) => {
            print!("{}", report::vs_gpu_table(&sys, train))
        }
        Command::Report(ReportCmd::Occupancy(spec)) => print!(
            "{}",
            report::occupancy_table(&sys, &spec).map_err(anyhow::Error::msg)?
        ),
        Command::Report(ReportCmd::Metrics { json }) => {
            let snap = telemetry::global().snapshot();
            if json {
                println!("{}", snap.to_json());
            } else {
                print!("{}", snap.summary());
            }
        }
        Command::Train(t) => cmd_train(&t)?,
        Command::Infer(i) => cmd_infer(&i)?,
        Command::Kmeans(k) => cmd_kmeans(&k)?,
        Command::Anomaly(a) => cmd_anomaly(&a)?,
        Command::Serve(ServeCmd::Single(s)) => cmd_serve(&s)?,
        Command::Serve(ServeCmd::Multi(m)) => {
            if m.chips > 1 {
                cmd_serve_cluster(&m)?
            } else {
                cmd_serve_chip(&m)?
            }
        }
    }
    Ok(())
}

/// Live telemetry of one run: the optional request tracer
/// (`--trace-out`) and the optional periodic metrics-snapshot writer
/// (`--metrics-out`). Built before the run starts; [`Telemetry::finish`]
/// writes the chrome trace and the final snapshot line after the
/// report printed.
struct Telemetry {
    tracer: Option<Arc<Tracer>>,
    trace_out: Option<PathBuf>,
    writer: Option<SnapshotWriter>,
}

fn telemetry_start(o: &cli::TelemetryOpts) -> anyhow::Result<Telemetry> {
    let tracer = o
        .trace_out
        .as_ref()
        .map(|_| Tracer::new(DEFAULT_TRACE_CAPACITY, telemetry::global()));
    let writer = match &o.metrics_out {
        Some(path) => Some(SnapshotWriter::spawn(
            std::path::Path::new(path),
            Duration::from_millis(o.metrics_every_ms),
            telemetry::global(),
        )?),
        None => None,
    };
    Ok(Telemetry {
        tracer,
        trace_out: o.trace_out.clone().map(PathBuf::from),
        writer,
    })
}

impl Telemetry {
    /// Handle to thread into `ServeConfig`/`ChipConfig` (`None` when
    /// `--trace-out` was not given — tracing then costs nothing).
    fn tracer(&self) -> Option<Arc<Tracer>> {
        self.tracer.clone()
    }

    /// Write the chrome trace and stop the snapshot writer. Prints one
    /// stderr line per export so stdout stays the report's.
    fn finish(self) -> anyhow::Result<()> {
        if let (Some(t), Some(path)) = (&self.tracer, &self.trace_out) {
            t.write_chrome(path)?;
            eprintln!(
                "trace: {} span(s) recorded ({} dropped) -> {}",
                t.spans(),
                t.dropped(),
                path.display()
            );
        }
        if let Some(w) = self.writer {
            let path = w.path().to_path_buf();
            w.finish();
            eprintln!("metrics: snapshots -> {}", path.display());
        }
        Ok(())
    }
}

/// Engine over the backend picked by `--backend` (or the environment),
/// sharding batched operations over `--workers` pool threads (default:
/// `$RESTREAM_WORKERS`, else 1). Results are bit-identical at any
/// worker count — see DESIGN.md "Parallel execution".
fn engine_for(o: &cli::EngineOpts) -> anyhow::Result<Engine> {
    let engine = match &o.backend {
        Some(name) => Engine::named(name),
        None => Engine::open_default(),
    }?;
    let workers = o
        .workers
        .unwrap_or_else(restream::coordinator::default_workers);
    let mut engine = engine.with_workers(workers);
    if let Some(exec) = o.exec {
        engine = engine.with_exec(exec);
    }
    if let Some(stages) = o.stages {
        engine = engine.with_pipeline_stages(stages);
    }
    Ok(engine)
}

fn dataset_for(app: &str, n: usize, seed: u64) -> anyhow::Result<datasets::Dataset> {
    Ok(match app {
        a if a.starts_with("iris") => datasets::iris(seed),
        a if a.starts_with("mnist") => datasets::mnist(n, seed),
        a if a.starts_with("isolet") => datasets::isolet(n, seed),
        other => anyhow::bail!("no dataset generator for {other}"),
    })
}

fn cmd_train(t: &cli::TrainCmd) -> anyhow::Result<()> {
    let net = apps::network(&t.app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {}", t.app))?;
    let tel = telemetry_start(&t.telemetry)?;
    let engine = engine_for(&t.engine)?;
    let ds = dataset_for(&t.app, t.samples, t.seed)?;
    let (train_ds, test_ds) = ds.split(0.8, t.seed);
    let xs = train_ds.rows();
    // one option set covers per-sample BP, mini-batching, checkpoints
    // and staged dimensionality reduction (`Engine::fit`)
    let mut opts = TrainOptions::new().batch(t.batch);
    if let Some(c) = &t.checkpoint {
        opts = opts.checkpoint(restream::coordinator::CheckpointOpts {
            dir: c.dir.clone().into(),
            every: c.every,
            resume: c.resume,
            stop_after: None,
        });
    }

    use restream::config::AppKind;
    match net.kind {
        AppKind::DimReduction => {
            let run = engine.fit(
                net,
                &xs,
                |_| Vec::new(), // DR derives stage targets itself
                t.epochs,
                t.lr,
                t.seed,
                &opts.dr(),
            )?;
            let mut off_us = 0.0;
            for (s, r) in run.reports.iter().enumerate() {
                println!(
                    "stage {s}: {} epochs, final loss {:.5}, {:.2}s",
                    r.epochs,
                    r.loss_curve.last().unwrap_or(&f32::NAN),
                    r.wall_s
                );
                print_train_parallel(r);
                telemetry::global().record_train(r);
                record_train_phases(
                    &tel,
                    &format!("train/{}/stage{s}", t.app),
                    r,
                    off_us,
                );
                off_us += r.wall_s * 1e6;
            }
        }
        AppKind::Autoencoder => {
            let xs2 = xs.clone();
            let run = engine.fit(
                net,
                &xs,
                move |i| xs2[i].clone(),
                t.epochs,
                t.lr,
                t.seed,
                &opts,
            )?;
            let r = run
                .last_report()
                .expect("a supervised fit yields one report");
            print_curve(r);
            print_train_parallel(r);
            telemetry::global().record_train(r);
            record_train_phases(&tel, &format!("train/{}", t.app), r, 0.0);
        }
        _ => {
            let outs = net.layers[net.layers.len() - 1];
            let targets = |i: usize| train_ds.target(i, outs);
            let run = engine
                .fit(net, &xs, targets, t.epochs, t.lr, t.seed, &opts)?;
            let r = run
                .last_report()
                .expect("a supervised fit yields one report");
            print_curve(r);
            print_train_parallel(r);
            telemetry::global().record_train(r);
            record_train_phases(&tel, &format!("train/{}", t.app), r, 0.0);
            let preds =
                engine.classify(net, &run.params, &test_ds.rows())?;
            // single-output nets are binary (class 0 vs rest)
            let truth: Vec<usize> = if outs == 1 {
                test_ds.y.iter().map(|&y| y.min(1)).collect()
            } else {
                test_ds.y.clone()
            };
            println!(
                "test accuracy: {:.3}",
                metrics::accuracy(&preds, &truth)
            );
        }
    }
    // DR re-encodes and post-train classification follow `--exec`;
    // surface the per-stage occupancy of the last pipelined pass
    print_pipeline_report(&engine);
    if let Some(rep) = engine.last_parallel_report() {
        telemetry::global().record_exec(&rep);
    }
    if let Some(rep) = engine.last_pipeline_report() {
        telemetry::global().record_pipeline(&rep);
    }
    tel.finish()?;
    Ok(())
}

/// Coarse trace spans of one training report: the whole fit, plus the
/// gradient/apply split when mini-batching ran. Timestamps are offsets
/// into the run (`off_us` = where this stage started), so the chrome
/// view lays DR stages end to end.
fn record_train_phases(
    tel: &Telemetry,
    name: &str,
    r: &restream::coordinator::TrainReport,
    off_us: f64,
) {
    let Some(tracer) = &tel.tracer else { return };
    tracer.phase(name, off_us, r.wall_s * 1e6);
    if r.batch > 1 {
        tracer.phase(&format!("{name}/grad"), off_us, r.grad_wall_s * 1e6);
        tracer.phase(
            &format!("{name}/apply"),
            off_us + r.grad_wall_s * 1e6,
            r.apply_wall_s * 1e6,
        );
    }
}

/// Per-shard stats of a data-parallel training run (only informative
/// for `--batch N > 1`).
fn print_train_parallel(r: &restream::coordinator::TrainReport) {
    if r.recovered_shards > 0 {
        println!(
            "worker recovery: {} shard(s) reassigned after worker death",
            r.recovered_shards
        );
    }
    if r.batch <= 1 || r.shard_busy_s.is_empty() {
        return;
    }
    let busy: f64 = r.shard_busy_s.iter().sum();
    println!(
        "parallel training: batch {}, {} workers, {} shards/mini-batch, \
         grad {:.3}s (shard busy {:.3}s) + apply {:.3}s",
        r.batch,
        r.workers,
        r.shard_busy_s.len(),
        r.grad_wall_s,
        busy,
        r.apply_wall_s
    );
}

fn print_curve(r: &restream::coordinator::TrainReport) {
    for (e, l) in r.loss_curve.iter().enumerate() {
        println!("epoch {e:>3}  loss {l:.5}");
    }
    println!(
        "{} samples in {:.2}s ({:.0} samples/s)",
        r.samples_seen,
        r.wall_s,
        r.samples_seen as f64 / r.wall_s.max(1e-9)
    );
}

fn cmd_infer(i: &cli::InferCmd) -> anyhow::Result<()> {
    let net = apps::network(&i.app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {}", i.app))?;
    let engine = engine_for(&i.engine)?;
    let ds = dataset_for(&i.app, 256, i.seed)?;
    let params = restream::coordinator::init_conductances(net.layers, i.seed);
    let start = std::time::Instant::now();
    let outs = engine.infer(net, &params, &ds.rows())?;
    let dt = start.elapsed().as_secs_f64();
    println!(
        "{} samples through {} in {:.3}s ({:.0}/s, untrained weights)",
        outs.len(),
        net.fwd_artifact(),
        dt,
        outs.len() as f64 / dt
    );
    print_parallel_report(&engine);
    print_pipeline_report(&engine);
    Ok(())
}

/// Per-shard stats of the last sharded operation, printed by every
/// subcommand that runs one (only informative above 1 worker).
fn print_parallel_report(engine: &Engine) {
    if engine.workers() <= 1 {
        return;
    }
    if let Some(rep) = engine.last_parallel_report() {
        println!(
            "parallel: {} workers, {} shards, shard busy {:.3}s \
             over wall {:.3}s",
            rep.workers,
            rep.shards.len(),
            rep.busy_s(),
            rep.wall_s
        );
    }
}

/// Per-stage occupancy/stall of the last layer-pipelined operation
/// (`--exec pipeline|hybrid`; DESIGN.md "Pipelined execution").
fn print_pipeline_report(engine: &Engine) {
    if let Some(rep) = engine.last_pipeline_report() {
        println!("{}", rep.summary());
    }
}

fn cmd_kmeans(k: &cli::KmeansCmd) -> anyhow::Result<()> {
    let ka = apps::kmeans_app(&k.app)
        .ok_or_else(|| anyhow::anyhow!("unknown clustering app {}", k.app))?;
    let engine = engine_for(&k.engine)?;
    // cluster synthetic features of the right dimensionality
    let ds =
        datasets::class_blobs(&k.app, ka.dims, ka.clusters, 512, 0.3, k.seed);
    let (_, assign) = engine.kmeans(ka, &ds.rows(), k.epochs, k.seed)?;
    println!(
        "purity over {} samples, k={}: {:.3}",
        ds.len(),
        ka.clusters,
        metrics::purity(&assign, &ds.y, ka.clusters, ds.classes)
    );
    print_parallel_report(&engine);
    Ok(())
}

fn cmd_anomaly(a: &cli::AnomalyCmd) -> anyhow::Result<()> {
    let net = apps::network("kdd_ae").unwrap();
    let engine = engine_for(&a.engine)?;
    let k = datasets::kdd(2000, 400, 400, a.seed);
    let xs = k.train.rows();
    let xs2 = xs.clone();
    let run = engine.fit(
        net,
        &xs,
        move |i| xs2[i].clone(),
        a.epochs,
        0.8,
        a.seed,
        &TrainOptions::new(),
    )?;
    let r = run.last_report().expect("a supervised fit yields one report");
    print_curve(r);
    let scores = engine.anomaly_scores(net, &run.params, &k.test.rows())?;
    let pts = metrics::roc_sweep(&scores, &k.test_attack, 200);
    println!(
        "AUC {:.3}; detection at 4% FPR: {:.1}% (paper: 96.6%)",
        metrics::auc(&pts),
        100.0 * metrics::tpr_at_fpr(&pts, 0.04)
    );
    print_parallel_report(&engine);
    Ok(())
}

/// The micro-batching request server (DESIGN.md "Serving layer"):
/// requests stream in over stdin or a synthetic closed-loop replay,
/// coalesce into tile-aligned batches, and execute on the pooled
/// engine. Prints the aggregate `ServeReport` when the stream ends.
fn cmd_serve(s: &cli::ServeSingleCmd) -> anyhow::Result<()> {
    let net = apps::network(&s.app)
        .ok_or_else(|| anyhow::anyhow!("unknown app {}", s.app))?
        .clone();
    let engine = engine_for(&s.engine)?;
    let params =
        restream::coordinator::init_conductances(net.layers, s.load.seed);
    let dims = net.layers[0];
    let tel = telemetry_start(&s.telemetry)?;
    let cfg = ServeConfig {
        max_batch: s.load.max_batch,
        max_wait: std::time::Duration::from_micros(s.load.max_wait_us),
        queue_capacity: None,
        trace: tel.tracer(),
    };
    let banner = format!(
        "serving {} ({dims} dims): max batch {}, max wait {} us, \
         queue {} samples (4 kB input buffer), {} workers",
        s.app,
        cfg.max_batch.max(1),
        s.load.max_wait_us,
        restream::coordinator::stream::buffer_capacity(dims),
        engine.workers()
    );
    if s.stdin {
        // stdout carries only `<id> <out…>` / `err <msg>` lines
        eprintln!("{banner}");
    } else {
        println!("{banner}");
    }
    let server = Server::start(engine, net, params, cfg);
    if s.stdin {
        serve_stdin(&server)?;
    } else {
        serve_replay(&server, s.load.clients, s.load.requests, s.load.seed)?;
    }
    let report = server.shutdown();
    if s.stdin {
        // keep stdout clean for the response lines
        eprint!("{}", report.summary());
    } else {
        print!("{}", report.summary());
    }
    telemetry::global().record_serve(&s.app, &report);
    tel.finish()?;
    Ok(())
}

/// Multi-tenant serving (`restream serve --apps a,b,c`; DESIGN.md
/// "Multi-tenant serving"): every listed app becomes a resident of one
/// simulated chip behind a `chip::ChipScheduler` — per-app bounded
/// queues and batchers, deficit-round-robin dispatch onto one shared
/// worker pool, overflow beyond the 144-core mesh served via modeled
/// reconfiguration swaps. Drives a closed-loop replay (`--clients`
/// threads per app, `--requests` each) and prints the
/// `MultiServeReport`: per-app p50/p99, occupancy, swap count and the
/// reconfiguration time charged.
fn cmd_serve_chip(m: &cli::ServeMultiCmd) -> anyhow::Result<()> {
    use restream::chip::{ChipApp, ChipConfig, ChipScheduler};
    let mut hosted = Vec::with_capacity(m.apps.len());
    for name in &m.apps {
        let net = apps::network(name)
            .ok_or_else(|| anyhow::anyhow!("unknown app {name}"))?
            .clone();
        let params = restream::coordinator::init_conductances(
            net.layers,
            m.load.seed,
        );
        hosted.push(ChipApp { net, params });
    }
    let engine = engine_for(&m.engine)?;
    let workers = engine.workers();
    let tel = telemetry_start(&m.telemetry)?;
    let cfg = ChipConfig {
        max_batch: m.load.max_batch,
        max_wait: std::time::Duration::from_micros(m.load.max_wait_us),
        trace: tel.tracer(),
        ..ChipConfig::default()
    };
    println!(
        "multi-tenant serve: {} apps ({}), max batch {}, max wait \
         {} us, {} clients/app x {} requests, {workers} workers",
        m.apps.len(),
        m.apps.join(","),
        cfg.max_batch.max(1),
        m.load.max_wait_us,
        m.load.clients,
        m.load.requests,
    );
    let chip = ChipScheduler::start(engine, hosted, cfg)?;
    let mut handles = Vec::new();
    for (a, name) in m.apps.iter().enumerate() {
        for c in 0..m.load.clients.max(1) {
            let client = chip.client(name)?;
            let dims = client.dims();
            let requests = m.load.requests;
            let client_seed =
                m.load.seed ^ ((a as u64) << 32) ^ ((c as u64) << 17);
            handles.push(std::thread::spawn(
                move || -> anyhow::Result<()> {
                    let mut rng =
                        restream::testing::Rng::seeded(client_seed);
                    for _ in 0..requests {
                        client.call(rng.vec_uniform(dims, -0.5, 0.5))?;
                    }
                    Ok(())
                },
            ));
        }
    }
    for h in handles {
        h.join().expect("replay client thread panicked")?;
    }
    let report = chip.shutdown();
    print!("{}", report.summary());
    telemetry::global().record_multi(&report);
    tel.finish()?;
    Ok(())
}

/// Fleet serving (`restream serve --apps a,b,c --chips N`; DESIGN.md
/// "Cluster layer"): the listed apps place over N simulated chips by
/// rendezvous hashing (each with `--replicas R` serving replicas,
/// least-loaded routing between them) behind one `cluster::Cluster`
/// router. Drives the same closed-loop replay as the single-chip path
/// and prints the `ClusterReport`: placement, per-chip routed shares,
/// occupancy and modeled serving energy. Responses are bit-identical
/// whichever chip serves them.
fn cmd_serve_cluster(m: &cli::ServeMultiCmd) -> anyhow::Result<()> {
    use restream::chip::ChipConfig;
    use restream::cluster::{Cluster, ClusterApp, ClusterConfig};
    let mut hosted = Vec::with_capacity(m.apps.len());
    let mut dims = Vec::with_capacity(m.apps.len());
    for name in &m.apps {
        let net = apps::network(name)
            .ok_or_else(|| anyhow::anyhow!("unknown app {name}"))?
            .clone();
        dims.push(net.layers[0]);
        let params = restream::coordinator::init_conductances(
            net.layers,
            m.load.seed,
        );
        hosted.push(ClusterApp::new(net, params).replicated(m.replicas));
    }
    let tel = telemetry_start(&m.telemetry)?;
    let cfg = ClusterConfig {
        chips: m.chips,
        chip: ChipConfig {
            max_batch: m.load.max_batch,
            max_wait: std::time::Duration::from_micros(m.load.max_wait_us),
            trace: tel.tracer(),
            ..ChipConfig::default()
        },
    };
    let workers = m
        .engine
        .workers
        .unwrap_or_else(restream::coordinator::default_workers);
    println!(
        "cluster serve: {} apps ({}) x{} replica(s) over {} chips, \
         max batch {}, max wait {} us, {} clients/app x {} requests, \
         {workers} workers/chip",
        m.apps.len(),
        m.apps.join(","),
        m.replicas,
        m.chips,
        cfg.chip.max_batch.max(1),
        m.load.max_wait_us,
        m.load.clients,
        m.load.requests,
    );
    let cluster =
        Cluster::start(hosted, cfg, |_chip| engine_for(&m.engine))?;
    let mut handles = Vec::new();
    for (a, name) in m.apps.iter().enumerate() {
        for c in 0..m.load.clients.max(1) {
            let client = cluster.client(name)?;
            let dims = dims[a];
            let requests = m.load.requests;
            let client_seed =
                m.load.seed ^ ((a as u64) << 32) ^ ((c as u64) << 17);
            handles.push(std::thread::spawn(
                move || -> anyhow::Result<()> {
                    let mut rng =
                        restream::testing::Rng::seeded(client_seed);
                    for _ in 0..requests {
                        client.call(rng.vec_uniform(dims, -0.5, 0.5))?;
                    }
                    Ok(())
                },
            ));
        }
    }
    for h in handles {
        h.join().expect("replay client thread panicked")?;
    }
    let report = cluster.shutdown();
    print!("{}", report.summary());
    telemetry::global().record_cluster(&report);
    tel.finish()?;
    Ok(())
}

/// Closed-loop synthetic load: `clients` threads each issue `requests`
/// deterministic uniform samples back-to-back (each waits for its
/// response before sending the next — batch sizes therefore track the
/// number of concurrent clients).
fn serve_replay(
    server: &Server,
    clients: usize,
    requests: usize,
    seed: u64,
) -> anyhow::Result<()> {
    let dims = server.client().dims();
    let handles: Vec<_> = (0..clients.max(1))
        .map(|c| {
            let client = server.client();
            std::thread::spawn(move || -> anyhow::Result<()> {
                let mut rng =
                    restream::testing::Rng::seeded(seed ^ ((c as u64) << 17));
                for _ in 0..requests {
                    client.call(rng.vec_uniform(dims, -0.5, 0.5))?;
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().expect("replay client thread panicked")?;
    }
    Ok(())
}

/// Line protocol: one whitespace/comma-separated f32 sample per stdin
/// line (blank lines and `#` comments skipped); responses print to
/// stdout as `<id> <out…>` in request order, bad lines as `err <msg>`.
fn serve_stdin(server: &Server) -> anyhow::Result<()> {
    use std::io::BufRead;
    let client = server.client();
    // Submission pipelines ahead of printing so requests can coalesce;
    // a single stdin client means responses complete in request order.
    // Bad lines travel the same channel as receipts, so the output
    // lines stay in input-line order.
    let (pending_tx, pending_rx) = std::sync::mpsc::channel::<
        Result<restream::serve::Pending, String>,
    >();
    let printer = std::thread::spawn(move || {
        // write! instead of println!: a downstream `| head -1` closes
        // the pipe mid-stream, and EPIPE must end the protocol
        // cleanly, not panic the process.
        use std::io::Write;
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        for slot in pending_rx {
            let wrote = match slot.map(restream::serve::Pending::wait) {
                Ok(Ok(r)) => {
                    let vals: Vec<String> =
                        r.out.iter().map(|v| v.to_string()).collect();
                    writeln!(out, "{} {}", r.id, vals.join(" "))
                }
                Ok(Err(e)) => writeln!(out, "err {e:#}"),
                Err(msg) => writeln!(out, "err {msg}"),
            };
            if wrote.is_err() {
                break; // consumer hung up; drop remaining receipts
            }
        }
    });
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let text = line.trim();
        if text.is_empty() || text.starts_with('#') {
            continue;
        }
        let parsed: Result<Vec<f32>, _> = text
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(str::parse::<f32>)
            .collect();
        let slot = match parsed {
            Ok(x) => client.submit(x).map_err(|e| format!("{e:#}")),
            Err(e) => Err(format!("bad sample line: {e}")),
        };
        if pending_tx.send(slot).is_err() {
            break; // printer exited (consumer hung up); stop reading
        }
    }
    drop(pending_tx);
    printer.join().expect("printer thread panicked");
    Ok(())
}

fn print_usage() {
    println!(
        "restream — memristor multicore chip simulator\n\
         usage: restream <chip|report|train|infer|cluster|anomaly|serve> \
         [--flags]\n\
         math subcommands take --backend native|pjrt (default native)\n\
         and --workers N (worker-pool size, default $RESTREAM_WORKERS or 1)\n\
         and --exec parallel|pipeline|hybrid [--stages N] (execution \
         mode:\n\
         data-parallel sharding, layer-pipelined streaming over N \
         stages,\n\
         or both; bit-identical outputs in every mode)\n\
         train: --batch N (mini-batch size; 1 = per-sample stochastic BP,\n\
         N > 1 = data-parallel gradient accumulation, bit-identical at\n\
         any --workers)\n\
         train: --checkpoint DIR [--every N] [--resume] (atomic, \
         checksummed\n\
         snapshots every N epochs; --resume continues bit-identically \
         from\n\
         the latest complete one)\n\
         serve: --app NAME --source stdin|replay --max-batch N \
         --max-wait-us N --clients N --requests N\n\
         serve --apps A,B,C: multi-tenant chip scheduler (per-app \
         queues,\n\
         DRR dispatch, modeled reconfiguration swaps; closed-loop \
         replay)\n\
         serve --apps A,B,C --chips N [--replicas R]: multi-chip \
         cluster\n\
         (rendezvous placement, replicated hot apps, least-loaded \
         routing;\n\
         responses bit-identical whichever chip serves them)\n\
         report --occupancy all|A,B,…: per-app core demand, offsets \
         and fit\n\
         report --metrics [--json]: process-wide telemetry registry \
         snapshot\n\
         train/serve: --trace-out FILE (chrome trace_event JSON of \
         request\n\
         spans; bit-identical results with tracing on or off), \
         --metrics-out\n\
         FILE [--metrics-every-ms N] (periodic metrics-snapshot JSONL)\n\
         see rust/src/main.rs docs and README.md for details"
    );
}
