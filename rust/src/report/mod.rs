//! Paper-shaped report rendering: the tables and figure series of the
//! evaluation section, printed as aligned text (the benches and the CLI
//! `report` subcommand both go through here).

use crate::chip;
use crate::config::{apps, SystemConfig};
use crate::cores::Step;
use crate::gpu;
use crate::power;
use crate::sim::{self, CostRow};

/// Render a simple aligned table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

fn sci(v: f64) -> String {
    format!("{v:.2e}")
}

fn us(v: f64) -> String {
    format!("{:.2}", v * 1e6)
}

/// Paper Table II: per-step time/power of a neural core.
pub fn table2() -> String {
    let rows = vec![
        vec!["Forward pass (recognition)".into(),
             us(Step::Forward.time_s()),
             format!("{:.3}", Step::Forward.power_w() * 1e3)],
        vec!["Backward pass".into(),
             us(Step::Backward.time_s()),
             format!("{:.3}", Step::Backward.power_w() * 1e3)],
        vec!["Weight update".into(),
             us(Step::Update.time_s()),
             format!("{:.3}", Step::Update.power_w() * 1e3)],
        vec!["Control unit".into(), "-".into(),
             format!("{:.4}", power::neural_core::CTRL_POWER_W * 1e3)],
    ];
    render_table(&["step", "time (us)", "power (mW)"], &rows)
}

fn cost_rows_to_table(rows: &[CostRow]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.app.clone(),
                r.cores.to_string(),
                us(r.time_s),
                sci(r.compute_j),
                sci(r.io_j),
                sci(r.total_j),
            ]
        })
        .collect();
    render_table(
        &["app", "#cores", "time (us)", "compute E (J)", "IO E (J)", "total E (J)"],
        &table,
    )
}

/// Paper Table III: per-sample training cost rows.
pub fn table3(sys: &SystemConfig) -> String {
    cost_rows_to_table(&sim::table3(sys))
}

/// Paper Table IV: per-sample recognition cost rows.
pub fn table4(sys: &SystemConfig) -> String {
    cost_rows_to_table(&sim::table4(sys))
}

/// One Figs 22–25 series entry.
#[derive(Clone, Debug)]
pub struct VsGpu {
    pub app: String,
    pub speedup: f64,
    pub energy_eff: f64,
}

/// Figs 22/23 (training) or 24/25 (recognition): speedup and energy
/// efficiency of the chip vs the K20 for every application.
pub fn vs_gpu(sys: &SystemConfig, train: bool) -> Vec<VsGpu> {
    let rows = if train { sim::table3(sys) } else { sim::table4(sys) };
    rows.iter()
        .map(|r| {
            let g = if let Some(a) = apps::kmeans_app(&r.app) {
                gpu::kmeans_cost(a.dims, a.clusters)
            } else {
                let net = apps::network(&r.app).unwrap();
                if train {
                    gpu::train_cost(net)
                } else {
                    gpu::recognition_cost(net)
                }
            };
            VsGpu {
                app: r.app.clone(),
                speedup: g.time_s / r.time_s,
                energy_eff: g.energy_j / r.total_j,
            }
        })
        .collect()
}

/// Render the Figs 22–25 series as a table.
pub fn vs_gpu_table(sys: &SystemConfig, train: bool) -> String {
    let series = vs_gpu(sys, train);
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|s| {
            vec![s.app.clone(), format!("{:.1}", s.speedup), sci(s.energy_eff)]
        })
        .collect();
    let what = if train { "training" } else { "recognition" };
    format!(
        "{} vs Tesla K20\n{}",
        what,
        render_table(&["app", "speedup (x)", "energy eff (x)"], &rows)
    )
}

/// Multi-tenant occupancy table (`restream report --occupancy`): for a
/// comma-separated app list (or `all`), the per-app core demand, the
/// row-major core offset it would get as a resident (apps are packed
/// greedily in listed order — the chip scheduler's admission rule), its
/// share of the mesh, whether it fits residently or must be served via
/// reconfiguration (swapping), and the modeled reconfiguration cost of
/// (re)deploying it ([`crate::sim::reconfig_cost`]).
pub fn occupancy_table(sys: &SystemConfig, spec: &str)
    -> Result<String, String> {
    let names: Vec<&str> = if spec == "all" {
        apps::NETWORKS.iter().map(|n| n.name).collect()
    } else {
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
    };
    if names.is_empty() {
        return Err(
            "no apps given (--occupancy all or a comma-separated list)"
                .into(),
        );
    }
    let mut footprints = Vec::with_capacity(names.len());
    for name in &names {
        let net = apps::network(name)
            .ok_or_else(|| format!("unknown app {name}"))?;
        footprints.push(chip::footprint(net, sys)?);
    }
    // The scheduler's own initial-admission rule decides who fits —
    // the table can never drift from what serving actually does.
    let cores: Vec<usize> = footprints.iter().map(|fp| fp.cores).collect();
    let slots = chip::greedy_admission(&cores, sys.neural_cores);
    let mut used = 0usize;
    let mut swapped = 0usize;
    let mut rows = Vec::with_capacity(names.len());
    for (fp, slot) in footprints.iter().zip(&slots) {
        let (offset, fit) = match slot {
            Some(offset) => {
                used += fp.cores;
                (offset.to_string(), "resident".to_string())
            }
            None => {
                swapped += 1;
                ("-".to_string(), "reconfig (swap)".to_string())
            }
        };
        rows.push(vec![
            fp.app.clone(),
            fp.cores.to_string(),
            offset,
            format!("{:.1}", 100.0 * fp.cores as f64
                / sys.neural_cores as f64),
            fit,
            format!("{:.1}", fp.reconfig.total_s() * 1e6),
        ]);
    }
    let table = render_table(
        &["app", "#cores", "offset", "mesh %", "fit", "reconfig (us)"],
        &rows,
    );
    Ok(format!(
        "{table}resident: {used}/{} cores ({:.1}% occupancy), {swapped} \
         app(s) served via reconfiguration\n",
        sys.neural_cores,
        100.0 * used as f64 / sys.neural_cores as f64,
    ))
}

/// Section VI.F: chip inventory and area budget.
pub fn chip_summary(sys: &SystemConfig) -> String {
    let mesh_stops = sys.mesh_w * sys.mesh_h + 2;
    format!(
        "ReStream chip: {} neural cores ({}x{} mesh) + clustering core + \
         RISC core + DMA\n\
         neural core:  {:>8.4} mm^2 x {}\n\
         cluster core: {:>8.4} mm^2\n\
         RISC core:    {:>8.4} mm^2\n\
         routers:      {:>8.4} mm^2 ({} stops)\n\
         buffers+DMA:  {:>8.4} mm^2\n\
         total:        {:>8.3} mm^2 (paper: 2.94 mm^2)\n",
        sys.neural_cores,
        sys.mesh_w,
        sys.mesh_h,
        power::neural_core::AREA_MM2,
        sys.neural_cores,
        power::cluster_core::AREA_MM2,
        power::risc_core::AREA_MM2,
        mesh_stops as f64 * power::noc::ROUTER_AREA_MM2,
        mesh_stops,
        power::buffers::AREA_MM2 + power::io::DMA_AREA_MM2,
        power::system_area_mm2(sys.neural_cores, mesh_stops),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_without_panicking() {
        let sys = SystemConfig::default();
        assert!(table2().contains("Weight update"));
        let t3 = table3(&sys);
        assert!(t3.contains("mnist_class") && t3.contains("isolet_kmeans"));
        assert!(table4(&sys).contains("kdd_ae"));
        assert!(chip_summary(&sys).contains("total"));
    }

    #[test]
    fn figs22_25_shapes() {
        let sys = SystemConfig::default();
        let train = vs_gpu(&sys, true);
        let recog = vs_gpu(&sys, false);
        for v in train.iter().chain(&recog) {
            assert!(v.speedup > 1.0, "{} speedup {}", v.app, v.speedup);
            assert!(v.energy_eff > 1e3, "{} eff {}", v.app, v.energy_eff);
        }
        // paper headline: 4-6 orders of magnitude energy efficiency
        let max_eff = train
            .iter()
            .chain(&recog)
            .map(|v| v.energy_eff)
            .fold(0.0, f64::max);
        assert!(max_eff > 1e4, "max eff {max_eff}");
    }

    #[test]
    fn occupancy_table_packs_and_marks_overflow() {
        let sys = SystemConfig::default();
        // small set: everything resident, offsets packed in order
        let t = occupancy_table(&sys, "iris_ae,kdd_ae").unwrap();
        assert!(t.contains("iris_ae"), "{t}");
        assert!(t.contains("resident: 4/144 cores"), "{t}");
        assert!(t.contains("0 app(s) served via reconfiguration"), "{t}");
        // the full registry oversubscribes the chip: someone must swap
        let t = occupancy_table(&sys, "all").unwrap();
        assert!(t.contains("reconfig (swap)"), "{t}");
        // errors are descriptive
        assert!(occupancy_table(&sys, "nope").unwrap_err()
            .contains("unknown app"));
        assert!(occupancy_table(&sys, "").is_err());
    }

    #[test]
    fn render_table_alignment() {
        let t = render_table(&["a", "bb"], &[vec!["1".into(), "2".into()]]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("bb"));
    }
}
