//! Electrical crossbar model: resistive nodal analysis with wire
//! resistance and driver impedance — the repository's SPICE stand-in
//! (DESIGN.md substitutions).
//!
//! Geometry: `rows x cols` memristors. Each row wire is driven from the
//! left through a driver resistance and has a wire-segment resistance
//! between adjacent columns; each column wire has a segment resistance
//! between adjacent rows and ends in a virtually grounded op-amp at the
//! bottom (paper Fig 5). Solving KCL at every internal node yields the
//! column currents including IR drop and sneak-path effects, which the
//! ideal model ignores. The paper uses exactly this fidelity gap to
//! justify the 400x200 core size (section IV.A).
//!
//! Solver: Gauss–Seidel over node voltages. The conductance matrix is an
//! irreducibly diagonally dominant M-matrix (every node has at least one
//! path to a source or ground), so Gauss–Seidel converges monotonically.

/// Electrical parameters for the crossbar solve.
#[derive(Clone, Copy, Debug)]
pub struct CircuitParams {
    /// Wire resistance per crossbar segment (Ohm). ~1-2 Ohm per cell for
    /// 45 nm metal layers.
    pub r_wire: f64,
    /// Row driver output resistance (Ohm).
    pub r_driver: f64,
    /// Memristor on-resistance (Ohm) for conductance normalisation:
    /// normalised g=1 corresponds to 1/r_on.
    pub r_on: f64,
    /// Gauss–Seidel convergence threshold on max node-voltage delta (V).
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
}

impl Default for CircuitParams {
    fn default() -> Self {
        CircuitParams {
            r_wire: 1.5,
            r_driver: 100.0,
            r_on: 10e3,
            tol: 1e-9,
            max_iters: 20_000,
        }
    }
}

/// A crossbar instance holding normalised conductances `g` (row-major
/// `rows x cols`, values in [G_MIN, G_MAX] like the kernel weights).
pub struct CircuitCrossbar {
    pub rows: usize,
    pub cols: usize,
    /// Normalised conductances (1.0 == 1/r_on).
    pub g: Vec<f64>,
    pub params: CircuitParams,
}

/// Result of a circuit solve.
pub struct SolveResult {
    /// Column output currents (A), length `cols`.
    pub col_currents: Vec<f64>,
    /// Gauss–Seidel iterations used.
    pub iters: usize,
}

impl CircuitCrossbar {
    pub fn new(rows: usize, cols: usize, g: Vec<f64>, params: CircuitParams) -> Self {
        assert_eq!(g.len(), rows * cols);
        CircuitCrossbar { rows, cols, g, params }
    }

    /// Ideal column currents: I_j = sum_i V_i * g_ij / r_on (no wire R).
    pub fn ideal_currents(&self, v_in: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[j] += v_in[i] * self.g[i * self.cols + j] / self.params.r_on;
            }
        }
        out
    }

    /// Full nodal solve with wire + driver resistance.
    pub fn solve(&self, v_in: &[f64]) -> SolveResult {
        assert_eq!(v_in.len(), self.rows);
        let (r, c) = (self.rows, self.cols);
        let gw = 1.0 / self.params.r_wire;
        let gd = 1.0 / (self.params.r_driver + self.params.r_wire);
        // Node voltages: vr[i][j] on row wires, vc[i][j] on column wires.
        let mut vr = vec![0.0f64; r * c];
        let mut vc = vec![0.0f64; r * c];
        // Initialise row nodes at the drive voltage (good warm start).
        for i in 0..r {
            for j in 0..c {
                vr[i * c + j] = v_in[i];
            }
        }
        let mut iters = 0;
        loop {
            iters += 1;
            let mut max_d: f64 = 0.0;
            for i in 0..r {
                for j in 0..c {
                    let gm = self.g[i * c + j] / self.params.r_on;
                    // --- row node (i,j) ---
                    let mut num = gm * vc[i * c + j];
                    let mut den = gm;
                    if j == 0 {
                        num += gd * v_in[i];
                        den += gd;
                    } else {
                        num += gw * vr[i * c + j - 1];
                        den += gw;
                    }
                    if j + 1 < c {
                        num += gw * vr[i * c + j + 1];
                        den += gw;
                    }
                    let nv = num / den;
                    max_d = max_d.max((nv - vr[i * c + j]).abs());
                    vr[i * c + j] = nv;
                    // --- column node (i,j) ---
                    let mut num = gm * vr[i * c + j];
                    let mut den = gm;
                    if i > 0 {
                        num += gw * vc[(i - 1) * c + j];
                        den += gw;
                    }
                    if i + 1 < r {
                        num += gw * vc[(i + 1) * c + j];
                        den += gw;
                    } else {
                        // bottom segment into the virtually grounded op-amp
                        den += gw; // + gw * 0.0
                    }
                    let nv = num / den;
                    max_d = max_d.max((nv - vc[i * c + j]).abs());
                    vc[i * c + j] = nv;
                }
            }
            if max_d < self.params.tol || iters >= self.params.max_iters {
                break;
            }
        }
        // Column current = sum of memristor currents into the column.
        // (Summing device currents is well-conditioned even when the wire
        // conductance is orders of magnitude above the device conductance;
        // reading the bottom-segment voltage drop is not.)
        let col_currents = (0..c)
            .map(|j| {
                (0..r)
                    .map(|i| {
                        let gm = self.g[i * c + j] / self.params.r_on;
                        (vr[i * c + j] - vc[i * c + j]) * gm
                    })
                    .fold(0.0f64, |acc, cur| acc + cur)
            })
            .collect();
        SolveResult { col_currents, iters }
    }

    /// Worst-case relative error of the circuit vs the ideal model over
    /// the given drive vector — the sneak-path/IR-drop fidelity metric.
    pub fn relative_error(&self, v_in: &[f64]) -> f64 {
        let ideal = self.ideal_currents(v_in);
        let real = self.solve(v_in).col_currents;
        let mut worst: f64 = 0.0;
        for j in 0..self.cols {
            let denom = ideal[j].abs().max(1e-12);
            worst = worst.max((real[j] - ideal[j]).abs() / denom);
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    fn uniform_xbar(rows: usize, cols: usize, g: f64,
                    params: CircuitParams) -> CircuitCrossbar {
        CircuitCrossbar::new(rows, cols, vec![g; rows * cols], params)
    }

    #[test]
    fn single_cell_is_a_voltage_divider() {
        // One memristor: I = V / (r_driver + 2*r_wire + R_m + r_wire_out)
        let p = CircuitParams::default();
        let xb = uniform_xbar(1, 1, 1.0, p);
        let i = xb.solve(&[0.5]).col_currents[0];
        let expect = 0.5 / (p.r_driver + p.r_wire + p.r_on + p.r_wire);
        assert!((i - expect).abs() / expect < 1e-6, "i={i} expect={expect}");
    }

    #[test]
    fn negligible_wire_resistance_matches_ideal() {
        let p = CircuitParams {
            r_wire: 0.01,
            r_driver: 0.01,
            ..Default::default()
        };
        forall("ideal_limit", 10, |rng: &mut Rng| {
            let (r, c) = (rng.range(2, 8), rng.range(2, 8));
            let g: Vec<f64> = (0..r * c).map(|_| rng.uniform(0.001, 1.0)).collect();
            let xb = CircuitCrossbar::new(r, c, g, p);
            let v: Vec<f64> = (0..r).map(|_| rng.uniform(-0.5, 0.5)).collect();
            let err = xb.relative_error(&v);
            if err > 1e-3 {
                return Err(format!("err {err} at {r}x{c}"));
            }
            Ok(())
        });
    }

    #[test]
    fn error_grows_with_crossbar_size() {
        let p = CircuitParams::default();
        let v64 = vec![0.5; 64];
        let v16 = vec![0.5; 16];
        let small = uniform_xbar(16, 8, 1.0, p).relative_error(&v16);
        let large = uniform_xbar(64, 32, 1.0, p).relative_error(&v64);
        assert!(large > small, "small={small} large={large}");
    }

    #[test]
    fn high_resistance_devices_keep_error_small() {
        // The paper's core-sizing argument: with high-R devices the
        // 400-row crossbar has "very little impact of sneak paths".
        let p = CircuitParams::default();
        // g = 0.02 => R = 500 kOhm devices (high-resistance programming)
        let hi_r = uniform_xbar(100, 50, 0.02, p);
        let err = hi_r.relative_error(&vec![0.5; 100]);
        assert!(err < 0.05, "err {err}");
        // and the same fabric with low-R devices is markedly worse —
        // the reason the paper picks a high-R_on device ([18]).
        let lo_r = uniform_xbar(100, 50, 1.0, p);
        let err_lo = lo_r.relative_error(&vec![0.5; 100]);
        assert!(err_lo > 2.0 * err, "hi {err} lo {err_lo}");
    }

    #[test]
    fn solver_converges_well_before_cap() {
        let p = CircuitParams::default();
        let xb = uniform_xbar(32, 16, 0.5, p);
        let res = xb.solve(&vec![0.25; 32]);
        assert!(res.iters < p.max_iters / 2, "iters {}", res.iters);
    }
}
