//! ADC / DAC / op-amp numerics — bit-exact Rust mirror of
//! `python/compile/kernels/ref.py`. Computed in f32 with the same
//! operation order so Rust-side references and PJRT-executed artifacts
//! agree to float equality (verified by the runtime integration tests).

use crate::config::hwspec as hw;

/// Uniform mid-rise quantiser of [-V_RAIL, V_RAIL] to `2^bits` levels —
/// the neuron-output ADC (paper section IV.A).
pub fn quantize_unit(x: f32, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    let x = x.clamp(-hw::V_RAIL, hw::V_RAIL);
    ((x + hw::V_RAIL) * levels).round() / levels - hw::V_RAIL
}

/// Sign-magnitude error quantiser (1 sign + bits-1 magnitude bits) — the
/// error ADC of the back-propagation circuit (paper section III.F).
pub fn quantize_err(x: f32) -> f32 {
    let mag_levels = ((1u32 << (hw::ERR_BITS - 1)) - 1) as f32;
    let mag = x.abs().clamp(0.0, hw::ERR_MAX);
    let code = (mag / hw::ERR_MAX * mag_levels).round();
    sign_of(x) * code / mag_levels * hw::ERR_MAX
}

/// jnp.sign semantics (sign(0) = 0), needed for bit-parity with ref.py.
fn sign_of(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Op-amp activation h(x) (paper Eq. 3): slope 1/4, clipped at the rails.
pub fn activation(dp: f32) -> f32 {
    (dp * hw::H_SLOPE).clamp(-hw::V_RAIL, hw::V_RAIL)
}

/// f'(DP) via the training unit's 64-entry lookup table (section III.F),
/// matching `ref.activation_deriv_lut`.
pub fn activation_deriv_lut(dp: f32) -> f32 {
    let n = (hw::LUT_SIZE - 1) as f32;
    let idx = ((dp + hw::H_CLIP_IN) / (2.0 * hw::H_CLIP_IN) * n)
        .round()
        .clamp(0.0, n);
    let centre = idx / n * (2.0 * hw::H_CLIP_IN) - hw::H_CLIP_IN;
    let s = 1.0 / (1.0 + (-centre).exp());
    s * (1.0 - s)
}

/// The target activation the op-amp approximates (paper Fig 6).
pub fn sigmoid_shifted(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp()) - 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hwspec as hw;
    use crate::testing::{forall, Rng};

    #[test]
    fn quantize_unit_hits_grid() {
        let levels = (1 << hw::OUT_BITS) - 1;
        for i in 0..=levels {
            let v = i as f32 / levels as f32 - hw::V_RAIL;
            assert!((quantize_unit(v, hw::OUT_BITS) - v).abs() < 1e-6);
        }
    }

    #[test]
    fn quantize_unit_clips() {
        assert_eq!(quantize_unit(7.0, hw::OUT_BITS), hw::V_RAIL);
        assert_eq!(quantize_unit(-7.0, hw::OUT_BITS), -hw::V_RAIL);
    }

    #[test]
    fn quantizers_are_monotone_and_odd() {
        forall("quant_props", 200, |rng: &mut Rng| {
            let a = rng.uniform_f32(-3.0, 3.0);
            let b = rng.uniform_f32(-3.0, 3.0);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            if quantize_unit(lo, hw::OUT_BITS) > quantize_unit(hi, hw::OUT_BITS) {
                return Err("quantize_unit not monotone".into());
            }
            if quantize_err(lo) > quantize_err(hi) {
                return Err("quantize_err not monotone".into());
            }
            if (quantize_err(-a) + quantize_err(a)).abs() > 1e-6 {
                return Err("quantize_err not odd".into());
            }
            Ok(())
        });
    }

    #[test]
    fn error_adc_half_lsb_accuracy_in_range() {
        let lsb = hw::ERR_MAX / ((1 << (hw::ERR_BITS - 1)) - 1) as f32;
        forall("err_adc_acc", 200, |rng: &mut Rng| {
            let x = rng.uniform_f32(-hw::ERR_MAX, hw::ERR_MAX);
            let e = (quantize_err(x) - x).abs();
            if e > lsb / 2.0 + 1e-6 {
                return Err(format!("x={x} err={e}"));
            }
            Ok(())
        });
    }

    #[test]
    fn activation_approximates_shifted_sigmoid() {
        // Paper Fig 6: h(x) closely approximates sigmoid(x) - 0.5.
        let mut max_gap: f32 = 0.0;
        let mut x = -6.0f32;
        while x <= 6.0 {
            max_gap = max_gap.max((activation(x) - sigmoid_shifted(x)).abs());
            x += 0.05;
        }
        assert!(max_gap < 0.12, "gap {max_gap}");
    }

    #[test]
    fn lut_tracks_true_derivative() {
        let mut x = -hw::H_CLIP_IN;
        while x <= hw::H_CLIP_IN {
            let s = 1.0 / (1.0 + (-x).exp());
            assert!((activation_deriv_lut(x) - s * (1.0 - s)).abs() < 0.01);
            x += 0.01;
        }
    }
}
