//! Memristor crossbar models.
//!
//! Three levels of abstraction, matching how the paper itself works:
//!
//! * [`quant`] — the ADC/DAC/op-amp numerics, a bit-exact mirror of
//!   `python/compile/kernels/ref.py` (the L1 kernels' oracle). These are
//!   what make the Rust-side references comparable to the PJRT-executed
//!   artifacts.
//! * [`ideal`] — the mathematical crossbar: dense differential matrix
//!   products (the abstraction the training algorithm sees).
//! * [`circuit`] — the electrical crossbar: nodal analysis with wire
//!   resistance and driver impedance (the paper's SPICE stand-in), used
//!   to justify the 400x200 core sizing (section IV.A).

pub mod circuit;
pub mod ideal;
pub mod quant;

pub use circuit::CircuitCrossbar;
pub use ideal::{fwd, bwd, update};
