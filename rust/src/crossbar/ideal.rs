//! Ideal (mathematical) differential crossbar — dense-matrix Rust mirror
//! of the L1 Pallas kernels. Used as (a) the reference the PJRT artifacts
//! are integration-checked against, (b) the compute engine of the
//! pure-Rust constrained network in `crate::nn`.
//!
//! Matrices are row-major `Vec<f32>`; `gpos`/`gneg` have shape
//! `(n_in, n_out)` with the bias row included by the caller.

use super::quant::{
    activation, activation_deriv_lut, quantize_err, quantize_unit,
};
use crate::config::hwspec as hw;

/// Forward pass: returns `(y, dp)`, each `(batch, n_out)`.
/// Mirrors `kernels.crossbar_fwd` (matmul against g+ - g-, h(), ADC).
pub fn fwd(
    x: &[f32],
    gpos: &[f32],
    gneg: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
    out_bits: u32,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), batch * n_in);
    debug_assert_eq!(gpos.len(), n_in * n_out);
    let mut dp = vec![0.0f32; batch * n_out];
    for b in 0..batch {
        let xr = &x[b * n_in..(b + 1) * n_in];
        let out = &mut dp[b * n_out..(b + 1) * n_out];
        for i in 0..n_in {
            let xi = xr[i];
            if xi == 0.0 {
                continue;
            }
            let gp = &gpos[i * n_out..(i + 1) * n_out];
            let gn = &gneg[i * n_out..(i + 1) * n_out];
            for j in 0..n_out {
                out[j] += xi * (gp[j] - gn[j]);
            }
        }
    }
    let y = dp
        .iter()
        .map(|&d| quantize_unit(activation(d), out_bits))
        .collect();
    (y, dp)
}

/// Backward pass: `(batch, n_out)` errors -> `(batch, n_in)` errors
/// through the transposed crossbar + error ADC (mirrors
/// `kernels.crossbar_bwd`).
pub fn bwd(
    delta: &[f32],
    gpos: &[f32],
    gneg: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * n_in];
    for b in 0..batch {
        let dr = &delta[b * n_out..(b + 1) * n_out];
        let o = &mut out[b * n_in..(b + 1) * n_in];
        for i in 0..n_in {
            let gp = &gpos[i * n_out..(i + 1) * n_out];
            let gn = &gneg[i * n_out..(i + 1) * n_out];
            let mut acc = 0.0f32;
            for j in 0..n_out {
                acc += dr[j] * (gp[j] - gn[j]);
            }
            o[i] = quantize_err(acc);
        }
    }
    out
}

/// The training unit's discretised pulse factor
/// `quantize_err(delta * f'(dp))`, shape `(batch, n_out)` — the single
/// definition shared by the fused [`update`], the backward-pass driver
/// (`runtime::native::train_step`) and the withheld-pulse gradient
/// accumulator (`runtime::native::grad_batch`), so the three cannot
/// drift apart numerically.
pub fn pulse_factor(delta: &[f32], dp: &[f32]) -> Vec<f32> {
    delta
        .iter()
        .zip(dp.iter())
        .map(|(&d, &p)| quantize_err(d * activation_deriv_lut(p)))
        .collect()
}

/// Per-element gradient accumulator
/// `acc[i, j] = sum_b x[b, i] * factor[b, j]` (`b` innermost,
/// ascending) — the batch reduction order every consumer of the
/// update math shares, which is what makes a withheld-pulse gradient
/// plus [`apply_acc`] bitwise identical to the fused [`update`].
pub fn grad_acc(
    x: &[f32],
    factor: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
) -> Vec<f32> {
    let mut acc = vec![0.0f32; n_in * n_out];
    for i in 0..n_in {
        for j in 0..n_out {
            let mut a = 0.0f32;
            for b in 0..batch {
                a += x[b * n_in + i] * factor[b * n_out + j];
            }
            acc[i * n_out + j] = a;
        }
    }
    acc
}

/// Fire the training pulse from an accumulator: `dw = lr * acc`,
/// `g+ += dw/2`, `g- -= dw/2`, clipped to the device range — the
/// pulse-firing tail of [`update`], also used on shard-summed
/// accumulators by the mini-batch path (`Backend::apply_grads`).
pub fn apply_acc(gpos: &mut [f32], gneg: &mut [f32], acc: &[f32], lr: f32) {
    for (k, &a) in acc.iter().enumerate() {
        let dw = lr * a;
        gpos[k] = (gpos[k] + 0.5 * dw).clamp(hw::G_MIN, hw::G_MAX);
        gneg[k] = (gneg[k] - 0.5 * dw).clamp(hw::G_MIN, hw::G_MAX);
    }
}

/// Weight update (training pulse): mutates `gpos`/`gneg` in place.
/// Mirrors `kernels.weight_update`: dw = lr * x^T (delta * f'(dp)) with
/// the product re-discretised and conductances clipped to device range.
/// Composed from [`pulse_factor`] + [`grad_acc`] + [`apply_acc`] — the
/// same three pieces the data-parallel gradient path uses.
pub fn update(
    gpos: &mut [f32],
    gneg: &mut [f32],
    x: &[f32],
    delta: &[f32],
    dp: &[f32],
    lr: f32,
    batch: usize,
    n_in: usize,
    n_out: usize,
) {
    let factor = pulse_factor(delta, dp);
    let acc = grad_acc(x, &factor, batch, n_in, n_out);
    apply_acc(gpos, gneg, &acc, lr);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    fn rand_mat(rng: &mut Rng, r: usize, c: usize, lo: f32, hi: f32) -> Vec<f32> {
        rng.vec_uniform(r * c, lo, hi)
    }

    #[test]
    fn fwd_known_values() {
        // 1x2 input, 2x1 crossbar, no quantisation surprises at 0.
        let x = vec![0.5, -0.5];
        let gp = vec![1.0, 0.2];
        let gn = vec![0.2, 1.0];
        // dp = 0.5*(1-0.2) + (-0.5)*(0.2-1) = 0.4 + 0.4 = 0.8
        let (y, dp) = fwd(&x, &gp, &gn, 1, 2, 1, 16);
        assert!((dp[0] - 0.8).abs() < 1e-6);
        assert!((y[0] - activation(0.8)).abs() < 1e-3);
    }

    #[test]
    fn bwd_is_transpose_of_fwd_linearly() {
        forall("bwd_transpose", 30, |rng: &mut Rng| {
            let (n_in, n_out) = (rng.range(1, 12), rng.range(1, 12));
            let gp = rand_mat(rng, n_in, n_out, 0.001, 1.0);
            let gn = rand_mat(rng, n_in, n_out, 0.001, 1.0);
            // unit error on one output j -> bwd ~ column j of (gp-gn)^T
            let j = rng.below(n_out);
            let mut delta = vec![0.0f32; n_out];
            delta[j] = 0.5;
            let back = bwd(&delta, &gp, &gn, 1, n_in, n_out);
            for i in 0..n_in {
                let expect =
                    quantize_err(0.5 * (gp[i * n_out + j] - gn[i * n_out + j]));
                if (back[i] - expect).abs() > 1e-6 {
                    return Err(format!("i={i} got {} want {expect}", back[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn update_moves_towards_reducing_error() {
        // single neuron, positive input, positive error => weight must grow
        let mut gp = vec![0.3];
        let mut gn = vec![0.3];
        let x = vec![0.5];
        let delta = vec![0.5];
        let dp = vec![0.0];
        update(&mut gp, &mut gn, &x, &delta, &dp, 1.0, 1, 1, 1);
        assert!(gp[0] > 0.3 && gn[0] < 0.3);
    }

    #[test]
    fn update_clips_to_device_range() {
        forall("update_clip", 50, |rng: &mut Rng| {
            let (n_in, n_out) = (rng.range(1, 8), rng.range(1, 8));
            let mut gp = rand_mat(rng, n_in, n_out, 0.001, 1.0);
            let mut gn = rand_mat(rng, n_in, n_out, 0.001, 1.0);
            let x = rand_mat(rng, 1, n_in, -0.5, 0.5);
            let delta = rand_mat(rng, 1, n_out, -1.0, 1.0);
            let dp = rand_mat(rng, 1, n_out, -3.0, 3.0);
            update(&mut gp, &mut gn, &x, &delta, &dp, 1000.0, 1, n_in, n_out);
            for g in gp.iter().chain(gn.iter()) {
                if !(crate::config::hwspec::G_MIN..=crate::config::hwspec::G_MAX)
                    .contains(g)
                {
                    return Err(format!("conductance {g} out of range"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_error_means_no_update() {
        let mut gp = vec![0.4, 0.6];
        let mut gn = vec![0.5, 0.1];
        let (gp0, gn0) = (gp.clone(), gn.clone());
        update(&mut gp, &mut gn, &[0.3], &[0.0, 0.0], &[0.1, 0.2],
               0.5, 1, 1, 2);
        assert_eq!(gp, gp0);
        assert_eq!(gn, gn0);
    }
}
