//! # ReStream — memristor multicore architecture for streaming deep-network training
//!
//! Reproduction of Hasan & Taha, *"A Reconfigurable Low Power High
//! Throughput Architecture for Deep Network Training"*
//! (arXiv:1603.07400, 2016) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1/2 (build time, optional)** — the chip's numerics
//!   (differential memristor crossbar forward / backward / weight-update,
//!   k-means datapath) are authored as Pallas kernels composed into JAX
//!   training graphs and AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — the chip itself: neural cores, the digital
//!   clustering core, the RISC configuration core, the statically routed
//!   2-D mesh NoC, the 3-D stacked DRAM front, the network→core mapper,
//!   the streaming training coordinator, and the power/area/energy
//!   accounting that regenerates every table and figure of the paper.
//!   Functional math executes through the pluggable [`runtime::Backend`]:
//!   the default **native** backend runs the reference kernels in-process
//!   (no artifacts, no Python, no XLA anywhere), while the `pjrt` cargo
//!   feature adds the artifact-executing PJRT backend. Python never runs
//!   on the request path. Batched operations execute data-parallel over
//!   the coordinator's worker pool ([`coordinator::pool`]), sharded the
//!   way the mapper spreads each app over the chip's core mesh —
//!   bit-identical to sequential execution at any worker count — and
//!   training joins the pool through mini-batch gradient accumulation
//!   ([`coordinator::Engine::fit`]; `restream train --batch N`),
//!   bit-identical at any worker count for a fixed batch size. The
//!   batched forward also runs **layer-pipelined**
//!   ([`coordinator::ExecMode`]; `--exec pipeline|hybrid [--stages N]`):
//!   layer groups on disjoint core groups with samples streaming
//!   between them over bounded in-order queues, per-hop NoC cost
//!   modeled by `sim::pipeline_cost`, per-stage occupancy reported —
//!   and still bit-identical to the sequential engine in every mode
//!   ([`testing::ExecModeHarness`]). On top
//!   of the pool sits the serving front end ([`serve`]): a bounded
//!   request queue plus a dynamic micro-batcher that coalesces
//!   independent single-sample requests into tile-aligned batches
//!   (`restream serve` on the CLI), and on top of *that* the
//!   multi-tenant chip scheduler ([`chip`]): many apps resident on one
//!   simulated 144-core mesh — placement-checked with per-app core
//!   offsets, dispatched deficit-round-robin onto one shared pool,
//!   overflow served via modeled reconfiguration swaps
//!   (`restream serve --apps`). Training runs survive crashes through
//!   the [`checkpoint`] subsystem: atomically committed, checksummed
//!   snapshots of the full training state (`restream train
//!   --checkpoint DIR --every N --resume`) that resume
//!   **bit-identically**, and the worker pool recovers a worker death
//!   mid-epoch by reassigning the dead worker's shards — also
//!   bit-identically ([`coordinator::pool`], "Worker-failure
//!   recovery"). Above the chip sits the fleet ([`cluster`]): one
//!   serving front end routing app requests across many simulated
//!   chips — rendezvous-hash placement with capacity-aware spillover,
//!   cross-chip replication of hot apps with least-loaded routing, and
//!   per-chip health/occupancy/energy accounting (`restream serve
//!   --apps A,B --chips N`). All three serving granularities —
//!   [`serve::Server`], [`chip::ChipScheduler`], [`cluster::Cluster`]
//!   — answer one interface, [`serve::Service`], and every response is
//!   bit-identical whichever chip of the fleet serves it. Training's
//!   five historical entry points collapse behind one option set,
//!   [`coordinator::TrainOptions`] ([`coordinator::Engine::fit`]), and
//!   the binary's flags parse through the typed [`cli`] layer. The
//!   whole stack is observable through [`telemetry`]: a process-wide
//!   metrics registry fed by every report path, request-scoped tracing
//!   (`--trace-out` exports chrome `trace_event` JSON), per-report
//!   `to_json()` under one schema, and a periodic snapshot writer —
//!   all bitwise-invisible to the numeric outputs.
//!
//! See `DESIGN.md` for the system inventory, the backend-selection story
//! and the experiment index, and `EXPERIMENTS.md` for paper-vs-measured
//! results.

pub mod benchutil;
pub mod checkpoint;
pub mod chip;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cores;
pub mod crossbar;
pub mod datasets;
pub mod device;
pub mod gpu;
pub mod kmeans;
pub mod mapper;
pub mod memory;
pub mod metrics;
pub mod nn;
pub mod noc;
pub mod power;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod testing;
