//! Tiny timing harness for the `cargo bench` targets (criterion is not
//! in the offline registry, so benches are `harness = false` binaries
//! built on this module).

use std::time::Instant;

/// Timing summary of a measured closure.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Measure `f` `iters` times after `warmup` unmeasured runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut min_s = f64::INFINITY;
    let mut max_s: f64 = 0.0;
    let mut total = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
    }
    Timing { iters, mean_s: total / iters as f64, min_s, max_s }
}

/// Best-of-`repeats` wall clock of `f` (seconds), after one unmeasured
/// warmup run — the policy the scaling benches (`perf_parallel`,
/// `perf_train`) share for noise-resistant whole-operation walls on
/// busy CI runners.
pub fn best_wall<F: FnMut()>(repeats: usize, mut f: F) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..repeats {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Print a bench line in a stable, grep-able format.
pub fn report(name: &str, t: &Timing) {
    println!(
        "bench {name:<40} {:>10.2} us/iter  (min {:.2}, max {:.2}, n={})",
        t.per_iter_us(),
        t.min_s * 1e6,
        t.max_s * 1e6,
        t.iters
    );
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Scale knob from the environment: parse `$key` as a usize, falling
/// back to `default` when unset or unparseable. Shared by the bench
/// binaries (`PERF_PARALLEL_SAMPLES`, `PERF_SERVING_REQUESTS`, …).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_counts_iters_and_orders_stats() {
        let mut n = 0;
        let t = time(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(t.iters, 10);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
    }

    #[test]
    fn env_usize_parses_and_defaults() {
        crate::testing::with_env(
            &[("RESTREAM_BENCH_PROBE", Some("42"))],
            || assert_eq!(env_usize("RESTREAM_BENCH_PROBE", 7), 42),
        );
        crate::testing::with_env(
            &[("RESTREAM_BENCH_PROBE", Some("nope"))],
            || assert_eq!(env_usize("RESTREAM_BENCH_PROBE", 7), 7),
        );
        crate::testing::with_env(&[("RESTREAM_BENCH_PROBE", None)], || {
            assert_eq!(env_usize("RESTREAM_BENCH_PROBE", 7), 7)
        });
    }
}
