//! Energy bookkeeping used by the chip simulator and the benchmarks.

/// Where energy went, in the paper's Table III/IV categories.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Analog + digital compute inside cores (J).
    pub compute_j: f64,
    /// On-chip routing (J).
    pub noc_j: f64,
    /// Off-chip I/O: DRAM + TSV (J).
    pub io_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.noc_j + self.io_j
    }
}

/// Accumulator for a simulated run.
#[derive(Clone, Debug, Default)]
pub struct EnergyAccount {
    pub breakdown: EnergyBreakdown,
    /// Simulated wall-clock time (s).
    pub time_s: f64,
}

impl EnergyAccount {
    pub fn new() -> Self {
        Self::default()
    }

    /// A compute step on `cores` cores running concurrently for `time_s`
    /// at `power_w` each: time advances once, energy scales with cores.
    pub fn compute_step(&mut self, cores: usize, time_s: f64, power_w: f64) {
        self.time_s += time_s;
        self.breakdown.compute_j += cores as f64 * time_s * power_w;
    }

    /// Compute energy that overlaps already-accounted time (no time
    /// advance) — e.g. control FSMs running alongside the crossbar.
    pub fn compute_overlap(&mut self, cores: usize, time_s: f64, power_w: f64) {
        self.breakdown.compute_j += cores as f64 * time_s * power_w;
    }

    /// NoC transfer: `bits` over `hops`, serialised at `bits_per_cycle`.
    pub fn noc_transfer(
        &mut self,
        bits: u64,
        hops: u64,
        bits_per_cycle: u64,
        cycle_s: f64,
        energy_per_bit_hop: f64,
    ) {
        let cycles = bits.div_ceil(bits_per_cycle) + hops; // store-and-forward head latency
        self.time_s += cycles as f64 * cycle_s;
        self.breakdown.noc_j += bits as f64 * hops as f64 * energy_per_bit_hop;
    }

    /// Off-chip transfer of `bits` (DRAM access + TSV crossing).
    pub fn io_transfer(&mut self, bits: u64, bandwidth_bps: f64,
                       energy_per_bit: f64) {
        self.time_s += bits as f64 / bandwidth_bps;
        self.breakdown.io_j += bits as f64 * energy_per_bit;
    }

    /// IO energy without a time advance (DMA overlapped with compute).
    pub fn io_overlap(&mut self, bits: u64, energy_per_bit: f64) {
        self.breakdown.io_j += bits as f64 * energy_per_bit;
    }

    pub fn merge(&mut self, other: &EnergyAccount) {
        self.time_s += other.time_s;
        self.breakdown.compute_j += other.breakdown.compute_j;
        self.breakdown.noc_j += other.breakdown.noc_j;
        self.breakdown.io_j += other.breakdown.io_j;
    }

    /// Scale an account (e.g. per-sample -> per-epoch).
    pub fn scaled(&self, k: f64) -> EnergyAccount {
        EnergyAccount {
            time_s: self.time_s * k,
            breakdown: EnergyBreakdown {
                compute_j: self.breakdown.compute_j * k,
                noc_j: self.breakdown.noc_j * k,
                io_j: self.breakdown.io_j * k,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_step_scales_energy_not_time() {
        let mut a = EnergyAccount::new();
        a.compute_step(10, 1e-6, 1e-3);
        assert!((a.time_s - 1e-6).abs() < 1e-15);
        assert!((a.breakdown.compute_j - 10.0 * 1e-9).abs() < 1e-18);
    }

    #[test]
    fn noc_transfer_serialisation() {
        let mut a = EnergyAccount::new();
        // 64 bits over 3 hops on an 8-bit link at 5 ns.
        a.noc_transfer(64, 3, 8, 5e-9, 1e-12);
        assert!((a.time_s - 11.0 * 5e-9).abs() < 1e-15);
        assert!((a.breakdown.noc_j - 64.0 * 3.0 * 1e-12).abs() < 1e-24);
    }

    #[test]
    fn merge_and_scale() {
        let mut a = EnergyAccount::new();
        a.compute_step(1, 2e-6, 1e-3);
        let mut b = EnergyAccount::new();
        b.io_transfer(1000, 1e9, 1e-12);
        a.merge(&b);
        let s = a.scaled(2.0);
        assert!((s.time_s - 2.0 * (2e-6 + 1e-6)).abs() < 1e-12);
        assert!((s.breakdown.total_j()
            - 2.0 * (2e-9 + 1e-9)).abs() < 1e-15);
    }
}
