//! Area / power / energy constants and accounting (45 nm process).
//!
//! The paper obtains component constants from CACTI (SRAM), Orion (NoC
//! links), McPAT (RISC core) and SPICE (analog crossbar circuits); those
//! tool outputs are baked here as a constants table (see DESIGN.md
//! substitutions). Composition — per-step, per-sample, per-application
//! energy — is computed by [`EnergyAccount`] and `crate::sim`.

mod account;
pub use account::{EnergyAccount, EnergyBreakdown};

/// Per-step timing/power of one memristor neural core — paper Table II.
pub mod neural_core {
    /// Forward (recognition) pass: time (s) and power (W).
    pub const FWD_TIME_S: f64 = 0.27e-6;
    pub const FWD_POWER_W: f64 = 0.794e-3;
    /// Backward (error-propagation) pass.
    pub const BWD_TIME_S: f64 = 0.80e-6;
    pub const BWD_POWER_W: f64 = 0.706e-3;
    /// Weight-update (training-pulse) step.
    pub const UPD_TIME_S: f64 = 1.00e-6;
    pub const UPD_POWER_W: f64 = 6.513e-3;
    /// Control-unit FSM (always-on while the core is active).
    pub const CTRL_POWER_W: f64 = 0.0004e-3;
    /// Core area (mm^2), section VI.E.
    pub const AREA_MM2: f64 = 0.0163;
    /// Crossbar analog settle time (section V.C: 20 ns => 4 cycles at
    /// 200 MHz including margins).
    pub const XBAR_SETTLE_S: f64 = 20e-9;
}

/// Digital k-means clustering core — paper section VI.E.
pub mod cluster_core {
    pub const AREA_MM2: f64 = 0.039;
    pub const POWER_W: f64 = 1.36e-3;
}

/// RISC configuration core (McPAT), used only during configuration.
pub mod risc_core {
    pub const AREA_MM2: f64 = 0.52;
    /// Single-issue in-order core at 200 MHz, 45 nm — active power.
    pub const POWER_W: f64 = 50e-3;
    /// Cycles to configure one core or router (register writes over NoC).
    pub const CONFIG_CYCLES_PER_UNIT: u64 = 64;
}

/// Statically routed mesh NoC (Orion-derived constants).
pub mod noc {
    /// Energy per bit per mesh hop (link + switch), 45 nm, ~200 MHz.
    pub const ENERGY_PER_BIT_HOP_J: f64 = 0.18e-12;
    /// SRAM routing-switch static leakage per router (leakage-less SRAM
    /// arrays per the paper's TrueNorth comparison => effectively zero).
    pub const ROUTER_LEAK_W: f64 = 0.0;
    /// Router area per mesh stop (mm^2). A 5-port 8-bit static switch
    /// with per-slot SRAM images is a few hundred um^2 at 45 nm.
    pub const ROUTER_AREA_MM2: f64 = 0.0002;
}

/// Off-chip I/O through TSVs into 3-D stacked DRAM.
pub mod io {
    /// TSV transfer energy (paper section V.C, ref [26]).
    pub const TSV_ENERGY_PER_BIT_J: f64 = 0.05e-12;
    /// 3-D DRAM access energy per bit (activation + read + on-package
    /// interface, stacked, ~45 nm). Dominates the TSV crossing itself.
    pub const DRAM_ENERGY_PER_BIT_J: f64 = 2.0e-12;
    /// Stacked-DRAM bandwidth available to the DMA engine (B/s).
    pub const DRAM_BANDWIDTH_BPS: f64 = 128.0e9;
    /// DMA engine area (mm^2).
    pub const DMA_AREA_MM2: f64 = 0.01;
}

/// On-chip stream buffers (CACTI, low-operating-power transistors).
pub mod buffers {
    /// 4 kB input + 1 kB output buffer area (mm^2).
    pub const AREA_MM2: f64 = 0.03;
    /// Access energy per byte (J).
    pub const ENERGY_PER_BYTE_J: f64 = 0.5e-12;
}

/// Total chip area for a given neural-core count (paper: 2.94 mm^2 at 144).
pub fn system_area_mm2(neural_cores: usize, mesh_stops: usize) -> f64 {
    neural_cores as f64 * neural_core::AREA_MM2
        + cluster_core::AREA_MM2
        + risc_core::AREA_MM2
        + mesh_stops as f64 * noc::ROUTER_AREA_MM2
        + io::DMA_AREA_MM2
        + buffers::AREA_MM2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_constants() {
        assert!((neural_core::FWD_TIME_S - 0.27e-6).abs() < 1e-12);
        assert!((neural_core::UPD_POWER_W - 6.513e-3).abs() < 1e-9);
    }

    #[test]
    fn system_area_matches_paper_section_vi_f() {
        // 144 NCs + cluster + RISC + routers + DMA + buffers ~= 2.94 mm^2.
        let area = system_area_mm2(144, 146);
        assert!((area - 2.94).abs() < 0.15, "area {area}");
    }

    #[test]
    fn update_is_dominant_power() {
        // The paper's Table II: weight update dominates core power.
        assert!(neural_core::UPD_POWER_W > neural_core::FWD_POWER_W);
        assert!(neural_core::UPD_POWER_W > neural_core::BWD_POWER_W);
    }
}
