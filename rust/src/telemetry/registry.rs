//! Process-wide metrics registry: counters, gauges, and fixed-bucket
//! histograms behind lock-cheap handles.
//!
//! The registry itself holds a single mutex that is touched only at
//! registration and snapshot time; the handles handed back to hot
//! paths are `Arc`-shared atomics, so recording a sample is a handful
//! of relaxed atomic ops and never blocks. Snapshots walk a
//! `BTreeMap`, so two snapshots of the same state serialise to the
//! same bytes — the determinism contract (lint D1) holds because no
//! hash-ordered container is ever iterated.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::json::Json;
use crate::metrics;

/// Schema tag stamped on every serialised snapshot.
pub const METRICS_SCHEMA: &str = "restream.metrics.v1";

/// Histogram bucket layout: log-spaced bounds covering 0.1 µs .. 10 s
/// (8 buckets per decade), one underflow-inclusive first bucket and
/// one overflow bucket past the last bound. Values are microseconds
/// for latency series; dimensionless series (batch sizes) reuse the
/// same grid — only relative resolution matters.
const BOUND_DECADE_LO: i32 = -1;
const BOUND_DECADE_HI: i32 = 7;
const BOUNDS_PER_DECADE: usize = 8;

fn bucket_bounds() -> &'static [f64] {
    static BOUNDS: std::sync::OnceLock<Vec<f64>> =
        std::sync::OnceLock::new();
    BOUNDS.get_or_init(|| {
        let steps =
            (BOUND_DECADE_HI - BOUND_DECADE_LO) as usize * BOUNDS_PER_DECADE;
        (0..=steps)
            .map(|k| {
                let exp = BOUND_DECADE_LO as f64
                    + k as f64 / BOUNDS_PER_DECADE as f64;
                10f64.powf(exp)
            })
            .collect()
    })
}

/// Lock-free add of an f64 stored as bits in an `AtomicU64`.
fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(
            cur,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn atomic_f64_extreme(cell: &AtomicU64, v: f64, want_max: bool) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let seen = f64::from_bits(cur);
        let better = if want_max { v > seen } else { v < seen };
        if !better {
            return;
        }
        match cell.compare_exchange_weak(
            cur,
            v.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Monotonic event count. Cloning shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins float (occupancy %, wall seconds, joules).
/// Cloning shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Accumulate into the value.
    pub fn add(&self, v: f64) {
        atomic_f64_add(&self.0, v);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistCore {
    /// One slot per bound plus a final overflow slot.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl HistCore {
    fn new() -> HistCore {
        let bounds = bucket_bounds();
        HistCore {
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }
}

/// Fixed-bucket histogram with exact count/sum/min/max and
/// bucket-interpolated quantiles. Cloning shares the cells.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCore>);

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram(Arc::new(HistCore::new()))
    }
}

impl Histogram {
    /// A histogram not attached to any registry (report accumulators).
    pub fn standalone() -> Histogram {
        Histogram::default()
    }

    /// Record one sample. Negative and non-finite samples clamp to 0,
    /// so a histogram can never be poisoned by a NaN.
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        let core = &self.0;
        let bounds = bucket_bounds();
        let idx = bounds.partition_point(|&b| b < v);
        if let Some(slot) = core.buckets.get(idx) {
            slot.fetch_add(1, Ordering::Relaxed);
        }
        core.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&core.sum_bits, v);
        atomic_f64_extreme(&core.min_bits, v, false);
        atomic_f64_extreme(&core.max_bits, v, true);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// A point-in-time copy of the distribution.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let core = &self.0;
        let count = core.count.load(Ordering::Relaxed);
        let min = f64::from_bits(core.min_bits.load(Ordering::Relaxed));
        let max = f64::from_bits(core.max_bits.load(Ordering::Relaxed));
        HistogramSnapshot {
            count,
            sum: f64::from_bits(core.sum_bits.load(Ordering::Relaxed)),
            min: if count == 0 { 0.0 } else { min },
            max: if count == 0 { 0.0 } else { max },
            bounds: bucket_bounds().to_vec(),
            buckets: core
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
        }
    }
}

/// Frozen view of one histogram.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Exact sum of samples.
    pub sum: f64,
    /// Exact smallest sample (0 when empty).
    pub min: f64,
    /// Exact largest sample (0 when empty).
    pub max: f64,
    /// Upper bucket bounds; `buckets` has one extra overflow slot.
    pub bounds: Vec<f64>,
    /// Per-bucket sample counts.
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    /// Exact mean (sum/count), 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Bucket-interpolated quantile, `q` in percent (50.0 = median).
    /// Exact at q=100 and for single-sample series; always clamped to
    /// the observed `[min, max]` and monotone in `q`.
    pub fn quantile(&self, q: f64) -> f64 {
        metrics::histogram_quantile(
            &self.bounds,
            &self.buckets,
            self.min,
            self.max,
            q,
        )
    }

    /// Serialise: exact stats, p50/p99, and the non-empty buckets as
    /// `[upper_bound_or_null, count]` pairs.
    pub fn to_json(&self) -> Json {
        let mut cells = Vec::new();
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let le = match self.bounds.get(i) {
                Some(&b) => Json::Num(b),
                None => Json::Null, // overflow bucket
            };
            cells.push(Json::Arr(vec![le, Json::Int(n as i64)]));
        }
        Json::obj()
            .with("count", Json::Int(self.count as i64))
            .with("sum", Json::Num(self.sum))
            .with("min", Json::Num(self.min))
            .with("max", Json::Num(self.max))
            .with("mean", Json::Num(self.mean()))
            .with("p50", Json::Num(self.quantile(50.0)))
            .with("p99", Json::Num(self.quantile(99.0)))
            .with("buckets", Json::Arr(cells))
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    histograms: BTreeMap<String, Histogram>,
}

/// The registry: named series, lock-cheap handles, ordered snapshots.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    /// An empty registry (tests and scoped tracers; production code
    /// uses [`crate::telemetry::global`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Get-or-register the counter `name`.
    pub fn counter(&self, name: &str) -> Counter {
        self.locked()
            .counters
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get-or-register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        self.locked()
            .gauges
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get-or-register the histogram `name`.
    pub fn histogram(&self, name: &str) -> Histogram {
        self.locked()
            .histograms
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// A frozen, name-ordered view of every registered series.
    pub fn snapshot(&self) -> Snapshot {
        let inner = self.locked();
        Snapshot {
            counters: inner
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// Point-in-time view of a [`Registry`], names sorted.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// `(name, value)` for every counter, name-ordered.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` for every gauge, name-ordered.
    pub gauges: Vec<(String, f64)>,
    /// `(name, view)` for every histogram, name-ordered.
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

impl Snapshot {
    /// Serialise under the [`METRICS_SCHEMA`] envelope.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, v) in &self.counters {
            counters.set(name, Json::Int(*v as i64));
        }
        let mut gauges = Json::obj();
        for (name, v) in &self.gauges {
            gauges.set(name, Json::Num(*v));
        }
        let mut histograms = Json::obj();
        for (name, h) in &self.histograms {
            histograms.set(name, h.to_json());
        }
        Json::obj()
            .with("schema", Json::Str(METRICS_SCHEMA.to_string()))
            .with("counters", counters)
            .with("gauges", gauges)
            .with("histograms", histograms)
    }

    /// Human-readable table for `restream report --metrics`.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str("counters:\n");
        for (name, v) in &self.counters {
            out.push_str(&format!("  {name:<32} {v}\n"));
        }
        out.push_str("gauges:\n");
        for (name, v) in &self.gauges {
            out.push_str(&format!("  {name:<32} {v:.4}\n"));
        }
        out.push_str("histograms:\n");
        for (name, h) in &self.histograms {
            out.push_str(&format!(
                "  {name:<32} n={} mean={:.1} p50={:.1} p99={:.1} max={:.1}\n",
                h.count,
                h.mean(),
                h.quantile(50.0),
                h.quantile(99.0),
                h.max,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_share_cells_across_clones() {
        let reg = Registry::new();
        let c = reg.counter("serve.requests");
        reg.counter("serve.requests").add(4);
        c.inc();
        assert_eq!(reg.counter("serve.requests").get(), 5);

        let g = reg.gauge("serve.wall_s");
        g.set(1.5);
        reg.gauge("serve.wall_s").add(0.25);
        assert_eq!(g.get(), 1.75);
    }

    #[test]
    fn histogram_keeps_exact_count_sum_min_max() {
        let h = Histogram::standalone();
        for v in [3.0, 1.0, 12.0, 8.0] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 24.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 12.0);
        assert_eq!(s.mean(), 6.0);
        assert_eq!(s.buckets.iter().sum::<u64>(), 4);
    }

    #[test]
    fn histogram_quantiles_are_monotone_and_clamped() {
        let h = Histogram::standalone();
        for v in [5.0, 50.0, 500.0, 5000.0, 50000.0] {
            h.observe(v);
        }
        let s = h.snapshot();
        let mut prev = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = s.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            assert!((s.min..=s.max).contains(&v));
            prev = v;
        }
        assert_eq!(s.quantile(100.0), 50000.0);
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let h = Histogram::standalone();
        h.observe(42.0);
        let s = h.snapshot();
        assert_eq!(s.quantile(50.0), 42.0);
        assert_eq!(s.quantile(99.0), 42.0);
    }

    #[test]
    fn hostile_samples_clamp_to_zero() {
        let h = Histogram::standalone();
        h.observe(f64::NAN);
        h.observe(-3.0);
        h.observe(f64::INFINITY);
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.quantile(99.0), 0.0);
    }

    #[test]
    fn empty_histogram_snapshot_is_all_zeros() {
        let s = Histogram::standalone().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.quantile(50.0), 0.0);
    }

    #[test]
    fn snapshots_come_out_name_ordered() {
        let reg = Registry::new();
        // register in scrambled order
        for name in ["zeta", "alpha", "mid"] {
            reg.counter(name).inc();
            reg.gauge(&format!("g.{name}")).set(1.0);
            reg.histogram(&format!("h.{name}")).observe(1.0);
        }
        let snap = reg.snapshot();
        let names: Vec<&str> =
            snap.counters.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, ["alpha", "mid", "zeta"]);
        // stable: a second snapshot of unchanged state is identical
        assert_eq!(reg.snapshot(), snap);
        assert_eq!(
            reg.snapshot().to_json().to_string(),
            snap.to_json().to_string()
        );
    }

    #[test]
    fn snapshot_json_round_trips() {
        let reg = Registry::new();
        reg.counter("serve.requests").add(7);
        reg.gauge("serve.wall_s").set(0.125);
        let h = reg.histogram("serve.total_us");
        h.observe(10.0);
        h.observe(90.0);
        let text = reg.snapshot().to_json().to_string();
        let doc = super::super::json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(METRICS_SCHEMA)
        );
        assert_eq!(
            doc.get("counters")
                .and_then(|c| c.get("serve.requests"))
                .and_then(Json::as_i64),
            Some(7)
        );
        let hist = doc
            .get("histograms")
            .and_then(|h| h.get("serve.total_us"))
            .expect("histogram present");
        assert_eq!(hist.get("count").and_then(Json::as_i64), Some(2));
        assert_eq!(hist.get("sum").and_then(Json::as_f64), Some(100.0));
    }
}
