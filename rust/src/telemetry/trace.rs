//! Request-scoped tracing: trace ids minted at `Client::submit`,
//! spans recorded at the reply path into a bounded ring buffer, and a
//! chrome `trace_event`-compatible exporter (`chrome://tracing` /
//! Perfetto "JSON Array with metadata" flavour).
//!
//! Everything here *observes* — span recording happens after the
//! compute result exists and never feeds a value back into batching,
//! dispatch, routing, or the kernels, which is why tracing on vs. off
//! is bitwise-identical in all numeric outputs (pinned by
//! `tests/telemetry_determinism.rs`). All wall-clock reads route
//! through the sanctioned [`metrics::Stopwatch`] doorway, keeping the
//! lint D2 contract intact.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::json::Json;
use super::registry::{Counter, Histogram, Registry};
use crate::metrics;

/// Default ring capacity: enough for every request of a replay run
/// while bounding a long-running serve to a few MB.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// What a [`TraceEvent`] describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// One served request: queued → batched → computed → replied.
    Request,
    /// One dispatched batch (its compute window).
    Batch,
    /// A cluster routing decision (instant event).
    Route,
    /// A coarse phase (training epochs, stage summaries).
    Phase,
}

/// One recorded span/instant. Timestamps are microseconds since the
/// tracer was created.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event class.
    pub kind: EventKind,
    /// App (or phase) name the event belongs to.
    pub name: Arc<str>,
    /// Request trace id, 0 when the event is not request-scoped.
    pub trace_id: u64,
    /// Span start, µs since tracer start.
    pub ts_us: f64,
    /// Span duration in µs (0 for instants).
    pub dur_us: f64,
    /// Request split: time spent queued.
    pub queue_us: f64,
    /// Request split: time spent waiting for the batch to fill.
    pub batch_us: f64,
    /// Request split: time spent in compute.
    pub compute_us: f64,
    /// Batch size (Batch) or chip index (Route); 0 otherwise.
    pub n: u64,
}

/// The tracing backend: mints ids, owns the bounded ring, and feeds
/// the latency histograms of its [`Registry`].
pub struct Tracer {
    anchor: metrics::Stopwatch,
    capacity: usize,
    seq: AtomicU64,
    dropped: AtomicU64,
    requests: AtomicU64,
    ring: Mutex<VecDeque<TraceEvent>>,
    c_requests: Counter,
    c_batches: Counter,
    c_routed: Counter,
    h_queue_us: Histogram,
    h_compute_us: Histogram,
    h_total_us: Histogram,
    h_batch_size: Histogram,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("capacity", &self.capacity)
            .field("spans", &self.spans())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl Tracer {
    /// A tracer whose aggregate series live in `registry`. Capacity 0
    /// is clamped to 1 so the ring always holds the latest event.
    pub fn new(capacity: usize, registry: &Registry) -> Arc<Tracer> {
        Arc::new(Tracer {
            anchor: metrics::Stopwatch::start(),
            capacity: capacity.max(1),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            c_requests: registry.counter("trace.requests"),
            c_batches: registry.counter("trace.batches"),
            c_routed: registry.counter("trace.routed"),
            h_queue_us: registry.histogram("serve.queue_us"),
            h_compute_us: registry.histogram("serve.compute_us"),
            h_total_us: registry.histogram("serve.total_us"),
            h_batch_size: registry.histogram("serve.batch_size"),
        })
    }

    /// Mint the next trace id (ids start at 1; 0 means "untraced").
    pub fn mint(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Microseconds since the tracer was created.
    pub fn now_us(&self) -> f64 {
        self.anchor.elapsed_s() * 1e6
    }

    fn push(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    pub(super) fn record_request(
        &self,
        app: &Arc<str>,
        trace_id: u64,
        queue_us: f64,
        batch_us: f64,
        compute_us: f64,
    ) {
        let total_us = queue_us + batch_us + compute_us;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.c_requests.inc();
        self.h_queue_us.observe(queue_us);
        self.h_compute_us.observe(compute_us);
        self.h_total_us.observe(total_us);
        self.push(TraceEvent {
            kind: EventKind::Request,
            name: app.clone(),
            trace_id,
            ts_us: (self.now_us() - total_us).max(0.0),
            dur_us: total_us,
            queue_us,
            batch_us,
            compute_us,
            n: 1,
        });
    }

    pub(super) fn record_batch(
        &self,
        app: &Arc<str>,
        n: usize,
        compute_us: f64,
    ) {
        self.c_batches.inc();
        self.h_batch_size.observe(n as f64);
        self.push(TraceEvent {
            kind: EventKind::Batch,
            name: app.clone(),
            trace_id: 0,
            ts_us: (self.now_us() - compute_us).max(0.0),
            dur_us: compute_us,
            queue_us: 0.0,
            batch_us: 0.0,
            compute_us,
            n: n as u64,
        });
    }

    pub(super) fn record_route(
        &self,
        app: &Arc<str>,
        trace_id: u64,
        chip: usize,
    ) {
        self.c_routed.inc();
        self.push(TraceEvent {
            kind: EventKind::Route,
            name: app.clone(),
            trace_id,
            ts_us: self.now_us(),
            dur_us: 0.0,
            queue_us: 0.0,
            batch_us: 0.0,
            compute_us: 0.0,
            n: chip as u64,
        });
    }

    /// Record a coarse phase span (training epochs, report windows).
    pub fn phase(&self, name: &str, ts_us: f64, dur_us: f64) {
        self.push(TraceEvent {
            kind: EventKind::Phase,
            name: Arc::from(name),
            trace_id: 0,
            ts_us: ts_us.max(0.0),
            dur_us: dur_us.max(0.0),
            queue_us: 0.0,
            batch_us: 0.0,
            compute_us: 0.0,
            n: 0,
        });
    }

    /// Request spans recorded over the tracer's lifetime (not capped
    /// by the ring).
    pub fn spans(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Events evicted from the ring (oldest-dropped).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Copy of the retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.ring
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .cloned()
            .collect()
    }

    /// Export as a chrome `trace_event` document. Thread ids are
    /// assigned from the sorted set of app names, so the export is
    /// deterministic given the same events.
    pub fn to_chrome_json(&self) -> Json {
        let events = self.events();
        let mut tids: BTreeMap<Arc<str>, i64> = BTreeMap::new();
        for ev in &events {
            let next = tids.len() as i64 + 1;
            tids.entry(ev.name.clone()).or_insert(next);
        }
        // re-number in name order for stability across runs
        for (i, tid) in tids.values_mut().enumerate() {
            *tid = i as i64 + 1;
        }
        let mut out = Vec::with_capacity(events.len() + tids.len());
        for (name, tid) in &tids {
            out.push(
                Json::obj()
                    .with("name", Json::Str("thread_name".to_string()))
                    .with("ph", Json::Str("M".to_string()))
                    .with("pid", Json::Int(1))
                    .with("tid", Json::Int(*tid))
                    .with(
                        "args",
                        Json::obj()
                            .with("name", Json::Str(name.to_string())),
                    ),
            );
        }
        for ev in &events {
            let tid = Json::Int(*tids.get(&ev.name).unwrap_or(&0));
            let base = Json::obj()
                .with("name", Json::Str(ev.name.to_string()))
                .with("pid", Json::Int(1))
                .with("tid", tid)
                .with("ts", Json::Num(ev.ts_us));
            let item = match ev.kind {
                EventKind::Request => base
                    .with("ph", Json::Str("X".to_string()))
                    .with("cat", Json::Str("request".to_string()))
                    .with("dur", Json::Num(ev.dur_us))
                    .with(
                        "args",
                        Json::obj()
                            .with("trace_id", Json::Int(ev.trace_id as i64))
                            .with("queue_us", Json::Num(ev.queue_us))
                            .with("batch_us", Json::Num(ev.batch_us))
                            .with(
                                "compute_us",
                                Json::Num(ev.compute_us),
                            ),
                    ),
                EventKind::Batch => base
                    .with("ph", Json::Str("X".to_string()))
                    .with("cat", Json::Str("dispatch".to_string()))
                    .with("dur", Json::Num(ev.dur_us))
                    .with(
                        "args",
                        Json::obj().with("n", Json::Int(ev.n as i64)),
                    ),
                EventKind::Route => base
                    .with("ph", Json::Str("i".to_string()))
                    .with("cat", Json::Str("route".to_string()))
                    .with("s", Json::Str("t".to_string()))
                    .with(
                        "args",
                        Json::obj()
                            .with("trace_id", Json::Int(ev.trace_id as i64))
                            .with("chip", Json::Int(ev.n as i64)),
                    ),
                EventKind::Phase => base
                    .with("ph", Json::Str("X".to_string()))
                    .with("cat", Json::Str("train".to_string()))
                    .with("dur", Json::Num(ev.dur_us))
                    .with("args", Json::obj()),
            };
            out.push(item);
        }
        Json::obj()
            .with("displayTimeUnit", Json::Str("ms".to_string()))
            .with("traceEvents", Json::Arr(out))
    }

    /// Write the chrome trace to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json().to_string())
    }
}

/// Cheap cloneable recorder handed to one app's reply path. When the
/// tracer is absent every method is a no-op on an `Option` — the
/// disabled path does no clock reads, no allocation, no locking.
#[derive(Clone, Debug, Default)]
pub struct TraceSink {
    inner: Option<(Arc<Tracer>, Arc<str>)>,
}

impl TraceSink {
    /// The no-op sink.
    pub fn disabled() -> TraceSink {
        TraceSink { inner: None }
    }

    /// A sink recording under `app`, or the no-op sink when tracing
    /// is off.
    pub fn for_app(tracer: Option<Arc<Tracer>>, app: &str) -> TraceSink {
        TraceSink {
            inner: tracer.map(|t| (t, Arc::from(app))),
        }
    }

    /// Whether this sink records anywhere.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Record one replied request with its latency split.
    pub fn request(
        &self,
        trace_id: Option<u64>,
        queue_us: f64,
        batch_us: f64,
        compute_us: f64,
    ) {
        if let Some((tracer, app)) = &self.inner {
            tracer.record_request(
                app,
                trace_id.unwrap_or(0),
                queue_us,
                batch_us,
                compute_us,
            );
        }
    }

    /// Record one dispatched batch of `n` requests.
    pub fn batch(&self, n: usize, compute_us: f64) {
        if let Some((tracer, app)) = &self.inner {
            tracer.record_batch(app, n, compute_us);
        }
    }

    /// Record a cluster routing decision.
    pub fn route(&self, trace_id: Option<u64>, chip: usize) {
        if let Some((tracer, app)) = &self.inner {
            tracer.record_route(app, trace_id.unwrap_or(0), chip);
        }
    }

    /// Mint a trace id, or `None` when tracing is off.
    pub fn mint(&self) -> Option<u64> {
        self.inner.as_ref().map(|(t, _)| t.mint())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn request_events(t: &Tracer) -> Vec<TraceEvent> {
        t.events()
            .into_iter()
            .filter(|e| e.kind == EventKind::Request)
            .collect()
    }

    #[test]
    fn sink_records_requests_batches_and_routes() {
        let reg = Registry::new();
        let tracer = Tracer::new(16, &reg);
        let sink = TraceSink::for_app(Some(tracer.clone()), "iris");
        assert!(sink.is_enabled());

        let id = sink.mint();
        assert_eq!(id, Some(1));
        sink.route(id, 3);
        sink.batch(2, 40.0);
        sink.request(id, 10.0, 5.0, 40.0);
        sink.request(None, 1.0, 1.0, 1.0);

        assert_eq!(tracer.spans(), 2);
        assert_eq!(tracer.dropped(), 0);
        let reqs = request_events(&tracer);
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].trace_id, 1);
        assert_eq!(reqs[0].dur_us, 55.0);
        assert_eq!(reqs[1].trace_id, 0);

        let snap = reg.snapshot();
        let get = |n: &str| {
            snap.counters
                .iter()
                .find(|(k, _)| k == n)
                .map(|(_, v)| *v)
        };
        assert_eq!(get("trace.requests"), Some(2));
        assert_eq!(get("trace.batches"), Some(1));
        assert_eq!(get("trace.routed"), Some(1));
    }

    #[test]
    fn disabled_sink_is_a_no_op() {
        let sink = TraceSink::disabled();
        assert!(!sink.is_enabled());
        assert_eq!(sink.mint(), None);
        sink.request(None, 1.0, 1.0, 1.0);
        sink.batch(4, 1.0);
        sink.route(None, 0);
        // Default is the disabled sink too.
        assert!(!TraceSink::default().is_enabled());
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let reg = Registry::new();
        let tracer = Tracer::new(8, &reg);
        let sink = TraceSink::for_app(Some(tracer.clone()), "kdd");
        for _ in 0..20 {
            let id = sink.mint();
            sink.request(id, 1.0, 0.0, 1.0);
        }
        assert_eq!(tracer.spans(), 20);
        assert_eq!(tracer.dropped(), 12);
        let reqs = request_events(&tracer);
        assert_eq!(reqs.len(), 8);
        // oldest dropped: ids 13..=20 remain, in order
        let ids: Vec<u64> = reqs.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, (13..=20).collect::<Vec<u64>>());
    }

    #[test]
    fn chrome_export_is_valid_and_complete() {
        let reg = Registry::new();
        let tracer = Tracer::new(64, &reg);
        let a = TraceSink::for_app(Some(tracer.clone()), "iris");
        let b = TraceSink::for_app(Some(tracer.clone()), "adult");
        a.request(a.mint(), 1.0, 2.0, 3.0);
        b.request(b.mint(), 4.0, 5.0, 6.0);
        b.batch(2, 6.0);
        b.route(Some(9), 1);
        tracer.phase("epoch0", 0.0, 100.0);

        let text = tracer.to_chrome_json().to_string();
        let doc = super::super::json::parse(&text).expect("valid json");
        assert_eq!(
            doc.get("displayTimeUnit").and_then(Json::as_str),
            Some("ms")
        );
        let evs = doc.get("traceEvents").expect("events").items();
        let cat = |c: &str| {
            evs.iter()
                .filter(|e| {
                    e.get("cat").and_then(Json::as_str) == Some(c)
                })
                .count()
        };
        assert_eq!(cat("request"), 2);
        assert_eq!(cat("dispatch"), 1);
        assert_eq!(cat("route"), 1);
        assert_eq!(cat("train"), 1);
        // thread metadata rows name every distinct track
        let meta = evs
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("M")
            })
            .count();
        assert_eq!(meta, 3); // iris, adult, epoch0
    }
}
