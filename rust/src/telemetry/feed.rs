//! Feeders: fold each finished report struct into registry series.
//!
//! All feeding happens at the CLI layer after a run completes — the
//! library paths stay pure and tests can use scoped registries. Names
//! follow `subsystem.metric` (and `subsystem.app.<name>.metric` for
//! per-app series), so snapshots group naturally when sorted.

use super::registry::Registry;
use crate::chip::MultiServeReport;
use crate::cluster::ClusterReport;
use crate::coordinator::{ExecReport, PipelineReport, TrainReport};
use crate::serve::ServeReport;
use crate::sim::PipelineCost;

impl Registry {
    /// Fold one single-app serving report into the registry.
    pub fn record_serve(&self, app: &str, r: &ServeReport) {
        self.counter("serve.requests").add(r.requests as u64);
        self.counter("serve.errors").add(r.errors as u64);
        self.counter("serve.batches").add(r.batches as u64);
        self.gauge("serve.wall_s").add(r.wall_s);
        self.gauge(&format!("serve.app.{app}.rps"))
            .set(r.throughput_rps());
        self.gauge(&format!("serve.app.{app}.p50_us")).set(r.total.p50_us);
        self.gauge(&format!("serve.app.{app}.p99_us")).set(r.total.p99_us);
        self.gauge(&format!("serve.app.{app}.mean_batch"))
            .set(r.mean_batch());
    }

    /// Fold one multi-tenant chip report (and its per-app serves).
    pub fn record_multi(&self, r: &MultiServeReport) {
        self.counter("chip.swaps").add(r.swaps as u64);
        self.counter("chip.evictions").add(r.evictions as u64);
        self.gauge("chip.occupancy_pct").set(r.occupancy_pct);
        self.gauge("chip.reconfig_s").add(r.reconfig_total_s);
        for app in &r.apps {
            self.record_serve(&app.app, &app.serve);
        }
    }

    /// Fold one fleet report (and every chip under it).
    pub fn record_cluster(&self, r: &ClusterReport) {
        self.gauge("cluster.chips").set(r.n_chips as f64);
        self.gauge("cluster.wall_s").set(r.wall_s);
        for chip in &r.chips {
            self.counter("cluster.routed").add(chip.routed);
            self.gauge("cluster.energy_j").add(chip.modeled_energy_j);
            self.record_multi(&chip.serve);
        }
    }

    /// Fold one training run.
    pub fn record_train(&self, r: &TrainReport) {
        self.counter("train.epochs").add(r.epochs as u64);
        self.counter("train.samples").add(r.samples_seen as u64);
        self.counter("pool.recovered_shards")
            .add(r.recovered_shards as u64);
        self.gauge("train.wall_s").add(r.wall_s);
        self.gauge("train.grad_s").add(r.grad_wall_s);
        self.gauge("train.apply_s").add(r.apply_wall_s);
        self.gauge("pool.busy_s")
            .add(r.shard_busy_s.iter().fold(0.0f64, |acc, s| acc + s));
        if let Some(&loss) = r.loss_curve.last() {
            self.gauge("train.last_loss").set(loss as f64);
        }
    }

    /// Fold one sharded-operation report from the worker pool.
    pub fn record_exec(&self, r: &ExecReport) {
        self.counter("pool.shards").add(r.shards.len() as u64);
        self.counter("pool.recovered_shards")
            .add(r.recovered_shards.len() as u64);
        self.gauge("pool.workers").set(r.workers as f64);
        self.gauge("pool.busy_s").add(r.busy_s());
    }

    /// Fold one pipelined-execution report (per-stage busy/stall).
    pub fn record_pipeline(&self, r: &PipelineReport) {
        self.counter("pipeline.samples").add(r.samples as u64);
        self.gauge("pipeline.replicas").set(r.replicas as f64);
        let mut busy = 0.0;
        let mut stall = 0.0;
        let mut idle = 0.0;
        for stage in &r.stages {
            busy += stage.busy_s;
            stall += stage.stall_s;
            idle += stage.idle_s;
            self.gauge(&format!(
                "pipeline.stage{}.occupancy_pct",
                stage.stage
            ))
            .set(stage.occupancy() * 100.0);
        }
        self.gauge("pipeline.busy_s").add(busy);
        self.gauge("pipeline.stall_s").add(stall);
        self.gauge("pipeline.idle_s").add(idle);
    }

    /// Fold the modeled NoC charges of one pipeline placement.
    pub fn record_pipeline_cost(&self, c: &PipelineCost) {
        self.gauge("noc.hop_energy_j").add(c.hop_energy_j);
        self.gauge("noc.hop_s")
            .add(c.hop_time_s.iter().fold(0.0f64, |acc, h| acc + h));
        self.gauge(&format!("noc.app.{}.interval_s", c.app))
            .set(c.interval_s());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StageReport;
    use crate::serve::LatencyStats;

    fn serve_report() -> ServeReport {
        ServeReport {
            requests: 10,
            batches: 2,
            errors: 1,
            wall_s: 0.5,
            total: LatencyStats {
                mean_us: 4.0,
                p50_us: 3.0,
                p99_us: 9.0,
                max_us: 9.0,
            },
            queue: LatencyStats::default(),
            batch_wait: LatencyStats::default(),
            compute: LatencyStats::default(),
        }
    }

    fn counter_of(reg: &Registry, name: &str) -> u64 {
        reg.counter(name).get()
    }

    #[test]
    fn serve_reports_feed_counters_and_per_app_gauges() {
        let reg = Registry::new();
        reg.record_serve("iris", &serve_report());
        reg.record_serve("iris", &serve_report());
        assert_eq!(counter_of(&reg, "serve.requests"), 20);
        assert_eq!(counter_of(&reg, "serve.errors"), 2);
        assert_eq!(reg.gauge("serve.wall_s").get(), 1.0);
        assert_eq!(reg.gauge("serve.app.iris.p99_us").get(), 9.0);
        assert_eq!(reg.gauge("serve.app.iris.mean_batch").get(), 5.0);
    }

    #[test]
    fn train_and_pipeline_reports_feed_stage_gauges() {
        let reg = Registry::new();
        reg.record_train(&TrainReport {
            loss_curve: vec![0.5, 0.25],
            epochs: 2,
            samples_seen: 200,
            wall_s: 1.0,
            batch: 4,
            workers: 2,
            grad_wall_s: 0.6,
            apply_wall_s: 0.1,
            shard_busy_s: vec![0.3, 0.2],
            recovered_shards: 1,
        });
        assert_eq!(counter_of(&reg, "train.epochs"), 2);
        assert_eq!(counter_of(&reg, "pool.recovered_shards"), 1);
        assert_eq!(reg.gauge("pool.busy_s").get(), 0.5);
        assert_eq!(reg.gauge("train.last_loss").get(), 0.25);

        reg.record_pipeline(&PipelineReport {
            op: "fwd".to_string(),
            stages: vec![StageReport {
                stage: 0,
                layers: (0, 2),
                chunks: 4,
                busy_s: 0.08,
                stall_s: 0.02,
                idle_s: 0.0,
            }],
            replicas: 1,
            wall_s: 0.1,
            samples: 64,
        });
        assert_eq!(counter_of(&reg, "pipeline.samples"), 64);
        let occ = reg.gauge("pipeline.stage0.occupancy_pct").get();
        assert!((occ - 80.0).abs() < 1e-9, "occupancy {occ}");
    }
}
