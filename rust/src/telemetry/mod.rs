//! Observability layer: a process-wide metrics registry,
//! request-scoped tracing, and machine-readable export surfaces.
//!
//! Three pieces, used together by the CLI and independently by tests:
//!
//! * **[`Registry`]** — named counters, gauges, and fixed-bucket
//!   histograms behind lock-cheap `Arc`-atomic handles. Snapshots are
//!   `BTreeMap`-ordered, so the same state always serialises to the
//!   same bytes. [`global()`] is the process-wide instance every
//!   finished report feeds (see `feed.rs`); scoped registries keep
//!   tests hermetic.
//! * **[`Tracer`] / [`TraceSink`]** — a trace id minted at
//!   `serve::Client::submit` rides the request through batching, DRR
//!   dispatch, and cluster routing; the reply path records one span
//!   per request (queue/batch/compute split) into a bounded ring
//!   buffer exported as chrome `trace_event` JSON. A [`TraceSink`]
//!   without a tracer is a no-op: no clock reads, no locks, no
//!   allocation — telemetry disabled costs nothing.
//! * **[`SnapshotWriter`]** — a background thread appending one
//!   metrics-snapshot JSON line per period, for long-running serves.
//!
//! **Determinism.** Telemetry only *observes*: spans are recorded
//! after compute completes and no recorded value ever feeds back into
//! batching, dispatch, routing, or kernels, so every numeric output is
//! bitwise-identical with tracing on or off
//! (`tests/telemetry_determinism.rs` pins this). The module is
//! lint-tagged D1/D2: no hash-ordered iteration anywhere, and all
//! wall-clock reads go through the sanctioned
//! [`metrics::Stopwatch`](crate::metrics::Stopwatch) doorway.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::dbg_macro))]

pub mod json;

mod feed;
mod registry;
mod trace;

pub use json::Json;
pub use registry::{
    Counter, Gauge, Histogram, HistogramSnapshot, Registry, Snapshot,
    METRICS_SCHEMA,
};
pub use trace::{
    EventKind, TraceEvent, TraceSink, Tracer, DEFAULT_TRACE_CAPACITY,
};

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::metrics;

/// Schema tag stamped on every report struct's `to_json()` — one
/// version string for `ServeReport`, `MultiServeReport`,
/// `ClusterReport`, `TrainReport`, `ExecReport`, and
/// `PipelineReport`, each discriminated by its `"kind"` member.
pub const REPORT_SCHEMA: &str = "restream.report.v1";

/// Counters pre-registered on the global registry so `report
/// --metrics` shows the full schema (at zero) before any run fed it.
const BASELINE_COUNTERS: &[&str] = &[
    "chip.evictions",
    "chip.swaps",
    "cluster.routed",
    "pipeline.samples",
    "pool.recovered_shards",
    "pool.shards",
    "serve.batches",
    "serve.errors",
    "serve.requests",
    "trace.batches",
    "trace.requests",
    "trace.routed",
    "train.epochs",
    "train.samples",
];

/// Gauges pre-registered on the global registry.
const BASELINE_GAUGES: &[&str] = &[
    "chip.occupancy_pct",
    "chip.reconfig_s",
    "cluster.chips",
    "cluster.energy_j",
    "cluster.wall_s",
    "noc.hop_energy_j",
    "noc.hop_s",
    "pipeline.busy_s",
    "pipeline.idle_s",
    "pipeline.replicas",
    "pipeline.stall_s",
    "pool.busy_s",
    "pool.workers",
    "serve.wall_s",
    "train.apply_s",
    "train.grad_s",
    "train.last_loss",
    "train.wall_s",
];

/// Histograms pre-registered on the global registry.
const BASELINE_HISTOGRAMS: &[&str] = &[
    "serve.batch_size",
    "serve.compute_us",
    "serve.queue_us",
    "serve.total_us",
];

/// The process-wide registry. Everything the CLI runs feeds this; the
/// `report --metrics` surface reads it.
pub fn global() -> &'static Registry {
    static GLOBAL: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(|| {
        let reg = Registry::new();
        for name in BASELINE_COUNTERS {
            reg.counter(name);
        }
        for name in BASELINE_GAUGES {
            reg.gauge(name);
        }
        for name in BASELINE_HISTOGRAMS {
            reg.histogram(name);
        }
        reg
    })
}

/// How often the writer thread polls its stop flag between snapshots.
const WRITER_SLICE: Duration = Duration::from_millis(20);

/// Background thread appending one metrics-snapshot JSON line per
/// period to a JSONL file — the long-running-serve export surface.
/// A final snapshot is always written on [`SnapshotWriter::finish`]
/// (or drop), so even a short run leaves at least one line.
pub struct SnapshotWriter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    path: PathBuf,
}

impl SnapshotWriter {
    /// Start writing snapshots of `registry` to `path` every `every`.
    /// The file is created (truncated) up front so open errors surface
    /// here, not in the thread.
    pub fn spawn(
        path: &Path,
        every: Duration,
        registry: &'static Registry,
    ) -> std::io::Result<SnapshotWriter> {
        use std::io::Write as _;
        let mut file = std::fs::File::create(path)?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let period_s = every.as_secs_f64().max(1e-3);
        let handle = std::thread::Builder::new()
            .name("telemetry-snapshots".to_string())
            .spawn(move || {
                let clock = metrics::Stopwatch::start();
                let mut due_s = period_s;
                let mut write_line = move |file: &mut std::fs::File,
                                           uptime_s: f64| {
                    let line = registry
                        .snapshot()
                        .to_json()
                        .with("uptime_s", Json::Num(uptime_s))
                        .to_string();
                    // Disk-full on a metrics sidecar must not take the
                    // serve down; drop the line.
                    let _ = writeln!(file, "{line}");
                };
                while !thread_stop.load(Ordering::Relaxed) {
                    std::thread::sleep(WRITER_SLICE);
                    let now_s = clock.elapsed_s();
                    if now_s >= due_s {
                        write_line(&mut file, now_s);
                        due_s = now_s + period_s;
                    }
                }
                write_line(&mut file, clock.elapsed_s());
                let _ = file.flush();
            })?;
        Ok(SnapshotWriter {
            stop,
            handle: Some(handle),
            path: path.to_path_buf(),
        })
    }

    /// The file being written.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stop the thread, write the final snapshot line, and join.
    pub fn finish(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for SnapshotWriter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_has_the_baseline_schema_at_zero() {
        let snap = global().snapshot();
        let counter_names: Vec<&str> =
            snap.counters.iter().map(|(k, _)| k.as_str()).collect();
        for name in BASELINE_COUNTERS {
            assert!(
                counter_names.contains(name),
                "missing baseline counter {name}"
            );
        }
        let hist_names: Vec<&str> =
            snap.histograms.iter().map(|(k, _)| k.as_str()).collect();
        for name in BASELINE_HISTOGRAMS {
            assert!(
                hist_names.contains(name),
                "missing baseline histogram {name}"
            );
        }
        // and the whole snapshot serialises + reparses
        let text = snap.to_json().to_string();
        assert!(json::parse(&text).is_ok());
    }

    #[test]
    fn snapshot_writer_appends_parseable_jsonl() {
        let dir = std::env::temp_dir()
            .join(format!("restream-telemetry-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("tmp dir");
        let path = dir.join("metrics.jsonl");
        let writer = SnapshotWriter::spawn(
            &path,
            Duration::from_millis(30),
            global(),
        )
        .expect("spawn writer");
        std::thread::sleep(Duration::from_millis(120));
        writer.finish();

        let text = std::fs::read_to_string(&path).expect("read jsonl");
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.is_empty()).collect();
        assert!(
            lines.len() >= 2,
            "expected periodic + final lines, got {}",
            lines.len()
        );
        for line in lines {
            let doc = json::parse(line).expect("each line parses");
            assert_eq!(
                doc.get("schema").and_then(Json::as_str),
                Some(METRICS_SCHEMA)
            );
            assert!(doc.get("uptime_s").and_then(Json::as_f64).is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
