//! Minimal dependency-free JSON document model: a writer for every
//! machine-readable export surface (report `to_json()`, metrics
//! snapshots, chrome traces) and a parser so tests can pin the schema
//! round trip without pulling serde into the offline registry.
//!
//! Object members keep **insertion order** (a `Vec` of pairs, never a
//! hash map), so every serialisation of the same document is
//! byte-identical — the snapshot-ordering stability contract rides on
//! this.

/// One JSON value. Integers and floats are distinct variants so
/// counters round-trip exactly (`u64` counts never detour through a
/// float) while gauges keep their fractional values.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// An integer (counters, ids, bucket counts).
    Int(i64),
    /// A float (gauges, seconds, microseconds).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, members in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty object to build with [`Json::set`] / [`Json::with`].
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a member (no-op on non-objects). Later duplicates of a
    /// key are kept verbatim — callers control their own keys.
    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(members) = self {
            members.push((key.to_string(), value));
        }
    }

    /// Builder form of [`Json::set`].
    pub fn with(mut self, key: &str, value: Json) -> Json {
        self.set(key, value);
        self
    }

    /// Member lookup (first match), `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v),
            _ => None,
        }
    }

    /// The array items, empty for non-arrays.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(items) => items,
            _ => &[],
        }
    }

    /// The object members, empty for non-objects.
    pub fn entries(&self) -> &[(String, Json)] {
        match self {
            Json::Obj(members) => members,
            _ => &[],
        }
    }

    /// Integer view (exact [`Json::Int`] only).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Numeric view: both [`Json::Int`] and [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(v) => Some(*v as f64),
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(v) => write!(f, "{v}"),
            Json::Num(v) => {
                // Non-finite values have no JSON spelling; zero also
                // normalises -0.0 so output == reparse(output) output.
                if !v.is_finite() || *v == 0.0 {
                    f.write_str(if v.is_finite() { "0" } else { "null" })
                } else {
                    // Rust's shortest-round-trip Display, exponent-free
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(members) => {
                f.write_str("{")?;
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(
    f: &mut std::fmt::Formatter<'_>,
    s: &str,
) -> std::fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Parse one JSON document (trailing content is an error). Depth is
/// bounded, so a hostile document cannot blow the stack.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos, 0)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing content at byte {pos}"));
    }
    Ok(value)
}

const MAX_DEPTH: usize = 64;

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while let Some(&b) = bytes.get(*pos) {
        if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
            *pos += 1;
        } else {
            break;
        }
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8) -> Result<(), String> {
    if bytes.get(*pos) == Some(&b) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at byte {}", b as char, *pos))
    }
}

fn parse_value(
    bytes: &[u8],
    pos: &mut usize,
    depth: usize,
) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err("document nests too deeply".to_string());
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of document".to_string()),
        Some(b'{') => {
            *pos += 1;
            let mut members = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, b':')?;
                let value = parse_value(bytes, pos, depth + 1)?;
                members.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(members));
                    }
                    _ => {
                        return Err(format!(
                            "expected ',' or '}}' at byte {}",
                            *pos
                        ))
                    }
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos, depth + 1)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => {
                        return Err(format!(
                            "expected ',' or ']' at byte {}",
                            *pos
                        ))
                    }
                }
            }
        }
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b't') => parse_literal(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_literal(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_literal(bytes, pos, "null", Json::Null),
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_literal(
    bytes: &[u8],
    pos: &mut usize,
    word: &str,
    value: Json,
) -> Result<Json, String> {
    if bytes[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(value)
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or_else(|| {
                                format!("bad \\u escape at byte {}", *pos)
                            })?;
                        // Surrogate halves fall back to the
                        // replacement char — this parser reads our own
                        // BMP-only output, not the open web.
                        out.push(
                            char::from_u32(hex).unwrap_or('\u{fffd}'),
                        );
                        *pos += 4;
                    }
                    _ => {
                        return Err(format!(
                            "bad escape at byte {}",
                            *pos
                        ))
                    }
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so the
                // byte stream is valid UTF-8 by construction).
                let rest = &bytes[*pos..];
                let s = std::str::from_utf8(rest)
                    .map_err(|_| "invalid utf-8".to_string())?;
                let c = s
                    .chars()
                    .next()
                    .ok_or_else(|| "unterminated string".to_string())?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    let mut fractional = false;
    while let Some(&b) = bytes.get(*pos) {
        match b {
            b'0'..=b'9' | b'-' | b'+' => *pos += 1,
            b'.' | b'e' | b'E' => {
                fractional = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let text = std::str::from_utf8(&bytes[start..*pos])
        .map_err(|_| "invalid utf-8 in number".to_string())?;
    if text.is_empty() {
        return Err(format!("expected a value at byte {start}"));
    }
    if !fractional {
        if let Ok(v) = text.parse::<i64>() {
            return Ok(Json::Int(v));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number {text:?} at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_reparses_a_document() {
        let doc = Json::obj()
            .with("schema", Json::Str("restream.test.v1".to_string()))
            .with("count", Json::Int(42))
            .with("ratio", Json::Num(0.5))
            .with("ok", Json::Bool(true))
            .with("none", Json::Null)
            .with(
                "rows",
                Json::Arr(vec![Json::Int(1), Json::Num(2.25)]),
            );
        let text = doc.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
        // serialisation is stable: write(parse(write(x))) == write(x)
        assert_eq!(back.to_string(), text);
    }

    #[test]
    fn escapes_round_trip() {
        let doc = Json::Str("a \"b\"\n\tc\\d\u{1}".to_string());
        let text = doc.to_string();
        assert_eq!(text, "\"a \\\"b\\\"\\n\\tc\\\\d\\u0001\"");
        assert_eq!(parse(&text).unwrap(), doc);
    }

    #[test]
    fn integers_and_floats_stay_distinct() {
        assert_eq!(parse("7").unwrap(), Json::Int(7));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("7.5").unwrap(), Json::Num(7.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        // non-finite floats serialise as null, zero normalises -0.0
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(-0.0).to_string(), "0");
    }

    #[test]
    fn accessors_read_nested_members() {
        let doc = parse(r#"{"a": {"b": [1, "x"]}, "c": 2.5}"#).unwrap();
        let b = doc.get("a").and_then(|a| a.get("b")).unwrap();
        assert_eq!(b.items()[0].as_i64(), Some(1));
        assert_eq!(b.items()[1].as_str(), Some("x"));
        assert_eq!(doc.get("c").and_then(Json::as_f64), Some(2.5));
        assert_eq!(doc.get("missing"), None);
        assert_eq!(doc.entries().len(), 2);
    }

    #[test]
    fn malformed_documents_are_typed_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("{\"a\":1} trailing").is_err());
        assert!(parse("nope").is_err());
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err());
    }
}
