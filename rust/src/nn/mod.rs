//! Pure-Rust reference neural networks.
//!
//! Two variants of the same MLP:
//!
//! * [`Mlp`] with `Constraint::None` — float32 software baseline
//!   (sigmoid−0.5 activation, exact derivative, unbounded weights):
//!   the "without constraints" bars of paper Fig 21.
//! * `Constraint::Chip` — the memristor chip's numerics, computed with
//!   `crate::crossbar::ideal` (bit-compatible with the L1 kernels): 3-bit
//!   output ADC, 8-bit error ADC, f'(DP) LUT, conductance-bounded
//!   weights. Used for Fig 21's "with constraints" bars, for baselines,
//!   and as the oracle the PJRT runtime path is integration-tested
//!   against.
//!
//! Both train with the paper's stochastic BP (section III.E).

use crate::config::hwspec as hw;
use crate::crossbar::{ideal, quant};
use crate::testing::Rng;

/// Numeric regime of a network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Constraint {
    /// Unconstrained float32 software network.
    None,
    /// Chip constraints (quantisers + conductance bounds).
    Chip,
}

/// A multi-layer perceptron in differential-conductance representation.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<usize>,
    /// Per layer: (gpos, gneg), each `(n_in+1) x n_out` row-major.
    pub params: Vec<(Vec<f32>, Vec<f32>)>,
    pub constraint: Constraint,
    /// Output ADC precision for the chip path (default `hw::OUT_BITS`);
    /// swept by the precision ablation bench.
    pub chip_out_bits: u32,
}

impl Mlp {
    /// Initialise like `model.init_params` (python twin): conductances
    /// near the low end with a small random differential weight.
    pub fn init(layers: &[usize], constraint: Constraint, rng: &mut Rng) -> Self {
        let base = hw::G_MIN + 0.12;
        let mut params = Vec::new();
        for w in layers.windows(2) {
            let (n_in, n_out) = (w[0], w[1]);
            let rows = n_in + 1;
            let scale = 1.0 / (n_in as f32).sqrt();
            let mut gp = vec![0.0f32; rows * n_out];
            let mut gn = vec![0.0f32; rows * n_out];
            for i in 0..rows * n_out {
                let wv = rng.uniform_f32(-scale, scale);
                gp[i] = (base + 0.5 * wv).clamp(hw::G_MIN, hw::G_MAX);
                gn[i] = (base - 0.5 * wv).clamp(hw::G_MIN, hw::G_MAX);
            }
            params.push((gp, gn));
        }
        Mlp {
            layers: layers.to_vec(),
            params,
            constraint,
            chip_out_bits: hw::OUT_BITS,
        }
    }

    /// Build a network from runtime parameter arrays (the
    /// `[gp0, gn0, ...]` layout of `coordinator::init_conductances`) —
    /// used to cross-check the PJRT path against this bit-compatible
    /// Rust path in the integration tests.
    pub fn from_params(layers: &[usize],
                       params: &[crate::runtime::ArrayF32]) -> Self {
        assert_eq!(params.len(), 2 * (layers.len() - 1));
        let pairs = params
            .chunks(2)
            .map(|c| (c[0].data.clone(), c[1].data.clone()))
            .collect();
        Mlp {
            layers: layers.to_vec(),
            params: pairs,
            constraint: Constraint::Chip,
            chip_out_bits: hw::OUT_BITS,
        }
    }

    fn out_bits(&self) -> u32 {
        match self.constraint {
            Constraint::None => 24, // effectively unquantised
            Constraint::Chip => self.chip_out_bits,
        }
    }

    fn quantize_err(&self, e: f32) -> f32 {
        match self.constraint {
            Constraint::None => e,
            Constraint::Chip => quant::quantize_err(e),
        }
    }

    fn deriv(&self, dp: f32) -> f32 {
        match self.constraint {
            Constraint::None => {
                let s = 1.0 / (1.0 + (-dp).exp());
                s * (1.0 - s)
            }
            Constraint::Chip => quant::activation_deriv_lut(dp),
        }
    }

    /// Forward pass for one sample. Returns (activations-with-bias per
    /// layer input, dp per layer, output).
    fn forward_traced(&self, x: &[f32])
        -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<f32>) {
        let mut acts = Vec::new();
        let mut dps = Vec::new();
        let mut h: Vec<f32> = x
            .iter()
            .map(|v| v.clamp(-hw::V_RAIL, hw::V_RAIL))
            .collect();
        for (l, (gp, gn)) in self.params.iter().enumerate() {
            let n_in = self.layers[l] + 1;
            let n_out = self.layers[l + 1];
            let mut a = h.clone();
            a.push(hw::V_RAIL); // bias input at the positive rail
            let (y, dp) = ideal::fwd(&a, gp, gn, 1, n_in, n_out, self.out_bits());
            acts.push(a);
            dps.push(dp);
            h = y;
        }
        (acts, dps, h)
    }

    /// Inference output for one sample.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        self.forward_traced(x).2
    }

    /// Inference for one sample through a runtime [`Backend`]'s
    /// kernel-level `forward` entry point instead of the built-in
    /// ideal-crossbar calls — proves any backend's crossbar kernel is
    /// sufficient to rebuild this network. For the native backend the
    /// result is bitwise identical to [`Mlp::forward`].
    ///
    /// This is the per-sample reference driver; the batched production
    /// path is `coordinator::Engine::infer`, which shards samples over
    /// the worker pool (bit-identical at any worker count).
    ///
    /// [`Backend`]: crate::runtime::Backend
    pub fn forward_on(
        &self,
        backend: &dyn crate::runtime::Backend,
        x: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        use crate::runtime::ArrayF32;
        let mut h: Vec<f32> = x
            .iter()
            .map(|v| v.clamp(-hw::V_RAIL, hw::V_RAIL))
            .collect();
        for (l, (gp, gn)) in self.params.iter().enumerate() {
            let n_in = self.layers[l] + 1;
            let n_out = self.layers[l + 1];
            let mut a = h;
            a.push(hw::V_RAIL); // bias input at the positive rail
            let gp_a = ArrayF32::new(vec![n_in, n_out], gp.clone())
                .map_err(anyhow::Error::msg)?;
            let gn_a = ArrayF32::new(vec![n_in, n_out], gn.clone())
                .map_err(anyhow::Error::msg)?;
            let (y, _) = backend.forward(
                &ArrayF32::row(a),
                &gp_a,
                &gn_a,
                self.out_bits(),
            )?;
            h = y.data;
        }
        Ok(h)
    }

    /// One stochastic-BP step (paper section III.E); returns the sample
    /// squared-error loss *before* the update.
    pub fn train_step(&mut self, x: &[f32], t: &[f32], lr: f32) -> f32 {
        let (acts, dps, y) = self.forward_traced(x);
        let n_layers = self.params.len();
        let mut delta: Vec<f32> = t
            .iter()
            .zip(&y)
            .map(|(&ti, &yi)| self.quantize_err(ti - yi))
            .collect();
        let loss = t
            .iter()
            .zip(&y)
            .map(|(&ti, &yi)| (ti - yi) * (ti - yi))
            .fold(0.0f32, |acc, e| acc + e)
            / t.len() as f32;
        // lint: allow(D3) — backprop layer walk (output-to-input), not
        // a float reduction; it mirrors native.rs's grad loop.
        for l in (0..n_layers).rev() {
            let n_in = self.layers[l] + 1;
            let n_out = self.layers[l + 1];
            let prev_delta = if l > 0 {
                // discretised delta * f'(dp) product drives the backward
                // column DACs, then the transposed crossbar (Fig 9)
                let eff: Vec<f32> = delta
                    .iter()
                    .zip(&dps[l])
                    .map(|(&d, &p)| self.quantize_err(d * self.deriv(p)))
                    .collect();
                let (gp, gn) = &self.params[l];
                let mut back = ideal::bwd(&eff, gp, gn, 1, n_in, n_out);
                back.pop(); // drop the bias-row error
                if self.constraint == Constraint::None {
                    // undo the chip-path quantisation for the float net
                    back = {
                        let (gp, gn) = &self.params[l];
                        let mut raw = vec![0.0f32; n_in];
                        for i in 0..n_in {
                            let mut acc = 0.0;
                            for j in 0..n_out {
                                acc += eff[j] * (gp[i * n_out + j] - gn[i * n_out + j]);
                            }
                            raw[i] = acc;
                        }
                        raw.pop();
                        raw
                    };
                }
                Some(back)
            } else {
                None
            };
            let (gp, gn) = &mut self.params[l];
            match self.constraint {
                Constraint::Chip => ideal::update(
                    gp, gn, &acts[l], &delta, &dps[l], lr, 1, n_in, n_out,
                ),
                Constraint::None => {
                    // plain gradient step on the differential pair
                    for i in 0..n_in {
                        for j in 0..n_out {
                            let f = delta[j]
                                * {
                                    let s = 1.0 / (1.0 + (-dps[l][j]).exp());
                                    s * (1.0 - s)
                                };
                            let dw = lr * acts[l][i] * f;
                            gp[i * n_out + j] += 0.5 * dw;
                            gn[i * n_out + j] -= 0.5 * dw;
                        }
                    }
                }
            }
            if let Some(d) = prev_delta {
                delta = d;
            }
        }
        loss
    }

    /// Train one epoch over a dataset (sample order given by `order`).
    pub fn train_epoch(
        &mut self,
        xs: &[Vec<f32>],
        ts: &[Vec<f32>],
        lr: f32,
        order: &[usize],
    ) -> f32 {
        let mut loss = 0.0;
        for &i in order {
            loss += self.train_step(&xs[i], &ts[i], lr);
        }
        loss / order.len().max(1) as f32
    }

    /// Perturb every conductance with multiplicative Gaussian noise of
    /// relative sigma — models memristor programming stochasticity /
    /// read disturb / drift (the robustness concern the paper's related
    /// work raises against the two-crossbar-copy scheme of [15]).
    pub fn perturb_conductances(&mut self, sigma: f64, rng: &mut Rng) {
        for (gp, gn) in &mut self.params {
            for g in gp.iter_mut().chain(gn.iter_mut()) {
                let f = (1.0 + sigma * rng.gaussian()) as f32;
                *g = (*g * f).clamp(hw::G_MIN, hw::G_MAX);
            }
        }
    }

    /// Classifier accuracy by argmax (or sign for single-output nets).
    /// The argmax uses IEEE total order, so a non-finite output (NaN
    /// from a poisoned conductance or a diverged run) yields a
    /// deterministic — if wrong — prediction instead of a panic (the
    /// same bug class `Engine::classify` fixed; that path additionally
    /// reports the NaN as an error).
    pub fn accuracy(&self, xs: &[Vec<f32>], ys: &[usize]) -> f64 {
        let mut correct = 0;
        for (x, &y) in xs.iter().zip(ys) {
            let out = self.forward(x);
            let pred = if out.len() == 1 {
                usize::from(out[0] > 0.0)
            } else {
                out.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            };
            correct += usize::from(pred == y);
        }
        correct as f64 / xs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;

    fn iris_xt() -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<usize>) {
        let d = datasets::iris(0);
        let xs = d.rows();
        // binary target: setosa vs rest (paper Fig 16 uses 1 output)
        let ys: Vec<usize> = d.y.iter().map(|&y| usize::from(y != 0)).collect();
        let ts: Vec<Vec<f32>> = ys
            .iter()
            .map(|&y| vec![if y == 1 { 0.4 } else { -0.4 }])
            .collect();
        (xs, ts, ys)
    }

    #[test]
    fn chip_net_learns_iris_binary() {
        let (xs, ts, ys) = iris_xt();
        let mut rng = Rng::seeded(3);
        let mut net = Mlp::init(&[4, 10, 1], Constraint::Chip, &mut rng);
        let order: Vec<usize> = (0..xs.len()).collect();
        let first = net.train_epoch(&xs, &ts, 1.0, &order);
        let mut last = first;
        for _ in 0..15 {
            last = net.train_epoch(&xs, &ts, 1.0, &order);
        }
        assert!(last < first * 0.7, "first {first} last {last}");
        assert!(net.accuracy(&xs, &ys) > 0.9);
    }

    #[test]
    fn float_net_learns_iris_3class() {
        let (xs, _, _) = iris_xt();
        let d = datasets::iris(0);
        let ts: Vec<Vec<f32>> = (0..d.len()).map(|i| d.target(i, 3)).collect();
        let mut rng = Rng::seeded(5);
        let mut net = Mlp::init(&[4, 10, 3], Constraint::None, &mut rng);
        let order: Vec<usize> = (0..xs.len()).collect();
        for _ in 0..30 {
            net.train_epoch(&xs, &ts, 0.8, &order);
        }
        assert!(net.accuracy(&xs, &d.y) > 0.9,
                "acc {}", net.accuracy(&xs, &d.y));
    }

    #[test]
    fn unconstrained_at_least_matches_constrained() {
        // Fig 21's premise: constraints cost little but never help much.
        let (xs, ts, ys) = iris_xt();
        let order: Vec<usize> = (0..xs.len()).collect();
        let mut rng = Rng::seeded(7);
        let mut chip = Mlp::init(&[4, 10, 1], Constraint::Chip, &mut rng);
        let mut rng = Rng::seeded(7);
        let mut float = Mlp::init(&[4, 10, 1], Constraint::None, &mut rng);
        for _ in 0..12 {
            chip.train_epoch(&xs, &ts, 1.0, &order);
            float.train_epoch(&xs, &ts, 1.0, &order);
        }
        let (ac, af) = (chip.accuracy(&xs, &ys), float.accuracy(&xs, &ys));
        assert!(af >= ac - 0.05, "float {af} chip {ac}");
    }

    #[test]
    fn forward_on_native_backend_matches_builtin_math() {
        let (xs, _, _) = iris_xt();
        let mut rng = Rng::seeded(13);
        let net = Mlp::init(&[4, 10, 3], Constraint::Chip, &mut rng);
        let backend = crate::runtime::NativeBackend;
        for x in xs.iter().take(20) {
            assert_eq!(net.forward_on(&backend, x).unwrap(), net.forward(x));
        }
    }

    #[test]
    fn accuracy_survives_nan_outputs() {
        // A poisoned conductance drives every output to NaN; pre-fix
        // the argmax was partial_cmp().unwrap() and panicked here.
        let mut rng = Rng::seeded(2);
        let mut net = Mlp::init(&[4, 5, 3], Constraint::None, &mut rng);
        for (gp, gn) in &mut net.params {
            for g in gp.iter_mut().chain(gn.iter_mut()) {
                *g = f32::NAN;
            }
        }
        let xs = vec![vec![0.1f32, -0.2, 0.3, 0.0]; 4];
        let ys = vec![0usize, 1, 2, 0];
        let acc = net.accuracy(&xs, &ys);
        assert!((0.0..=1.0).contains(&acc), "acc {acc}");
        // healthy params still score normally
        let mut rng = Rng::seeded(2);
        let net = Mlp::init(&[4, 5, 3], Constraint::None, &mut rng);
        let acc = net.accuracy(&xs, &ys);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn chip_weights_stay_in_device_range() {
        let (xs, ts, _) = iris_xt();
        let mut rng = Rng::seeded(1);
        let mut net = Mlp::init(&[4, 6, 1], Constraint::Chip, &mut rng);
        let order: Vec<usize> = (0..xs.len()).collect();
        for _ in 0..5 {
            net.train_epoch(&xs, &ts, 5.0, &order);
        }
        for (gp, gn) in &net.params {
            for g in gp.iter().chain(gn) {
                assert!((hw::G_MIN..=hw::G_MAX).contains(g));
            }
        }
    }
}
