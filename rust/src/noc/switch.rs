//! SRAM-based static routing switch (paper Fig 2).
//!
//! Each mesh stop has a 5-port switch (N/E/S/W/Core); an SRAM bit matrix
//! per TDM slot connects input ports to output ports. The scheduler's
//! output is compiled into these images at configuration time by the RISC
//! core; this module models the image itself so the configuration cost
//! (bits written) and the reconfigurability claim are concrete.

/// Switch ports in paper Fig 2's crossbar ordering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Port {
    North,
    East,
    South,
    West,
    Core,
}

pub const PORTS: usize = 5;

/// One slot's 5x5 connection matrix: `conn[inp][out]`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SlotImage {
    conn: [[bool; PORTS]; PORTS],
}

impl SlotImage {
    /// Connect input port -> output port. Returns Err if the output port
    /// is already driven in this slot (electrically illegal).
    pub fn connect(&mut self, inp: Port, out: Port) -> Result<(), String> {
        let o = out as usize;
        for i in 0..PORTS {
            if self.conn[i][o] && i != inp as usize {
                return Err(format!("output {out:?} already driven"));
            }
        }
        self.conn[inp as usize][o] = true;
        Ok(())
    }

    pub fn is_connected(&self, inp: Port, out: Port) -> bool {
        self.conn[inp as usize][out as usize]
    }

    /// SRAM bits in this image (8x8 bit matrix per bus bit in the paper;
    /// logically 5x5 at port granularity).
    pub fn bits(&self) -> usize {
        PORTS * PORTS
    }
}

/// The per-router schedule: one image per TDM slot.
#[derive(Clone, Debug, Default)]
pub struct SwitchConfig {
    pub slots: Vec<SlotImage>,
}

impl SwitchConfig {
    pub fn with_slots(n: usize) -> Self {
        SwitchConfig { slots: vec![SlotImage::default(); n] }
    }

    /// Total SRAM bits the RISC core writes to configure this router.
    pub fn config_bits(&self) -> usize {
        self.slots.iter().map(|s| s.bits()).sum()
    }

    /// A loopback configuration: the core's own output feeds its input in
    /// every slot (multi-layer single-core networks, paper section II).
    pub fn loopback(n_slots: usize) -> Self {
        let mut c = SwitchConfig::with_slots(n_slots);
        for s in &mut c.slots {
            s.connect(Port::Core, Port::Core).expect("empty image");
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_and_query() {
        let mut s = SlotImage::default();
        s.connect(Port::West, Port::Core).unwrap();
        assert!(s.is_connected(Port::West, Port::Core));
        assert!(!s.is_connected(Port::North, Port::Core));
    }

    #[test]
    fn double_driving_an_output_is_rejected() {
        let mut s = SlotImage::default();
        s.connect(Port::West, Port::East).unwrap();
        assert!(s.connect(Port::North, Port::East).is_err());
        // same input again is fine (idempotent)
        assert!(s.connect(Port::West, Port::East).is_ok());
    }

    #[test]
    fn loopback_feeds_core_to_itself() {
        let c = SwitchConfig::loopback(4);
        assert_eq!(c.slots.len(), 4);
        assert!(c.slots.iter().all(|s| s.is_connected(Port::Core, Port::Core)));
        assert_eq!(c.config_bits(), 4 * 25);
    }
}
