//! Statically routed 2-D mesh network-on-chip (paper section II, Fig 2).
//!
//! Feed-forward neural traffic is deterministic, so the paper uses
//! SRAM-configured static switches, time-multiplexed between cores. This
//! module provides: XY routing ([`route`]), the static TDM schedule
//! builder ([`Schedule`]) with per-link occupancy tracking, the switch
//! configuration image ([`switch`]), and link energy/latency accounting.

pub mod schedule;
pub mod switch;

pub use schedule::{Schedule, Transfer};

/// A mesh stop coordinate.
pub type Xy = (usize, usize);

/// A directed link between adjacent mesh stops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Link {
    pub from: Xy,
    pub to: Xy,
}

/// Dimension-ordered (X then Y) route between two mesh stops. Returns the
/// sequence of links; empty when `src == dst` (core loopback through its
/// own switch — how multi-layer single-core networks feed themselves,
/// paper Fig 2).
pub fn route(src: Xy, dst: Xy) -> Vec<Link> {
    let mut links = Vec::new();
    let (mut x, mut y) = src;
    while x != dst.0 {
        let nx = if dst.0 > x { x + 1 } else { x - 1 };
        links.push(Link { from: (x, y), to: (nx, y) });
        x = nx;
    }
    while y != dst.1 {
        let ny = if dst.1 > y { y + 1 } else { y - 1 };
        links.push(Link { from: (x, y), to: (x, ny) });
        y = ny;
    }
    links
}

/// Manhattan hop count of the XY route.
pub fn hops(src: Xy, dst: Xy) -> usize {
    src.0.abs_diff(dst.0) + src.1.abs_diff(dst.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn route_is_x_then_y() {
        let r = route((0, 0), (2, 1));
        assert_eq!(r.len(), 3);
        assert_eq!(r[0], Link { from: (0, 0), to: (1, 0) });
        assert_eq!(r[1], Link { from: (1, 0), to: (2, 0) });
        assert_eq!(r[2], Link { from: (2, 0), to: (2, 1) });
    }

    #[test]
    fn loopback_route_is_empty() {
        assert!(route((3, 3), (3, 3)).is_empty());
    }

    #[test]
    fn route_length_equals_manhattan_distance() {
        forall("route_len", 100, |rng: &mut Rng| {
            let src = (rng.below(12), rng.below(12));
            let dst = (rng.below(12), rng.below(12));
            let r = route(src, dst);
            if r.len() != hops(src, dst) {
                return Err(format!("{src:?}->{dst:?}: {} links", r.len()));
            }
            // links must be contiguous and unit-length
            let mut at = src;
            for l in &r {
                if l.from != at {
                    return Err("discontiguous route".into());
                }
                if hops(l.from, l.to) != 1 {
                    return Err("non-adjacent link".into());
                }
                at = l.to;
            }
            if at != dst {
                return Err("route does not reach dst".into());
            }
            Ok(())
        });
    }
}
