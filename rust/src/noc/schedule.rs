//! Static time-multiplexed schedule for the mesh (paper section II: "The
//! network is statically time multiplexed between cores").
//!
//! The mapper emits a set of [`Transfer`]s per pipeline step; the
//! scheduler assigns each a start slot such that no link carries two
//! transfers in the same slot (wormhole-style pipelining: a transfer of
//! `f` flits occupies link `k` of its route during slots
//! `[t0+k, t0+k+f)`). Greedy earliest-fit is optimal enough for the
//! deterministic traffic here and — critically — deterministic itself,
//! so the SRAM switch images can be programmed once at configuration
//! time.

use std::collections::BTreeMap;

use super::{route, Link, Xy};

/// One logical message between mesh stops.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub src: Xy,
    pub dst: Xy,
    pub bits: u64,
}

/// A scheduled transfer: route plus assigned start slot.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub transfer: Transfer,
    pub links: Vec<Link>,
    pub start_slot: u64,
    pub flits: u64,
}

impl Scheduled {
    /// Slot after which the tail flit has left the last link.
    pub fn finish_slot(&self) -> u64 {
        self.start_slot + self.links.len() as u64 + self.flits
    }
}

/// The static TDM schedule over one pipeline step.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub entries: Vec<Scheduled>,
    /// Busy intervals per link, kept sorted by start slot. BTreeMap,
    /// not HashMap: `validate` iterates it, and which offending link a
    /// failed audit names must not vary run to run (lint rule D1).
    busy: BTreeMap<Link, Vec<(u64, u64)>>,
}

impl Schedule {
    /// Build a schedule for `transfers` on `link_bits`-wide links,
    /// earliest-fit in input order (input order is the mapper's
    /// deterministic traversal, so the whole image is reproducible).
    pub fn build(transfers: &[Transfer], link_bits: usize) -> Schedule {
        let mut s = Schedule::default();
        for t in transfers {
            s.insert(t.clone(), link_bits);
        }
        s
    }

    fn insert(&mut self, t: Transfer, link_bits: usize) {
        let links = route(t.src, t.dst);
        let flits = t.bits.div_ceil(link_bits as u64).max(1);
        if links.is_empty() {
            // Core loopback through its own switch: no mesh link used.
            self.entries.push(Scheduled { transfer: t, links, start_slot: 0, flits });
            return;
        }
        let mut t0 = 0u64;
        'search: loop {
            for (k, l) in links.iter().enumerate() {
                let (s0, s1) = (t0 + k as u64, t0 + k as u64 + flits);
                if let Some(iv) = self.busy.get(l) {
                    for &(b0, b1) in iv {
                        if s0 < b1 && b0 < s1 {
                            // conflict: jump past this busy interval
                            t0 = b1 - k as u64;
                            continue 'search;
                        }
                    }
                }
            }
            break;
        }
        for (k, l) in links.iter().enumerate() {
            let iv = self.busy.entry(*l).or_default();
            iv.push((t0 + k as u64, t0 + k as u64 + flits));
            iv.sort_unstable();
        }
        self.entries.push(Scheduled { transfer: t, links, start_slot: t0, flits });
    }

    /// Total slots until the last transfer completes.
    pub fn makespan_slots(&self) -> u64 {
        self.entries.iter().map(|e| e.finish_slot()).max().unwrap_or(0)
    }

    /// Total bit-hops (the NoC energy integral).
    pub fn bit_hops(&self) -> u64 {
        self.entries
            .iter()
            .map(|e| e.transfer.bits * e.links.len() as u64)
            .sum()
    }

    /// Verify the fundamental TDM invariant: no link is occupied by two
    /// transfers in the same slot. Returns the offending link if any.
    pub fn validate(&self) -> Result<(), Link> {
        for (link, iv) in &self.busy {
            for w in iv.windows(2) {
                if w[0].1 > w[1].0 {
                    return Err(*link);
                }
            }
        }
        Ok(())
    }

    /// NoC energy of the whole step (J).
    pub fn energy_j(&self, energy_per_bit_hop: f64) -> f64 {
        self.bit_hops() as f64 * energy_per_bit_hop
    }

    /// Wall-clock for the step at `cycle_s` per slot.
    pub fn time_s(&self, cycle_s: f64) -> f64 {
        self.makespan_slots() as f64 * cycle_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn disjoint_transfers_start_immediately() {
        let ts = vec![
            Transfer { src: (0, 0), dst: (1, 0), bits: 8 },
            Transfer { src: (5, 5), dst: (6, 5), bits: 8 },
        ];
        let s = Schedule::build(&ts, 8);
        assert!(s.entries.iter().all(|e| e.start_slot == 0));
        assert!(s.validate().is_ok());
    }

    #[test]
    fn conflicting_transfers_serialise() {
        let ts = vec![
            Transfer { src: (0, 0), dst: (2, 0), bits: 16 },
            Transfer { src: (0, 0), dst: (2, 0), bits: 16 },
        ];
        let s = Schedule::build(&ts, 8);
        assert!(s.validate().is_ok());
        assert!(s.entries[1].start_slot >= 2,
                "second start {}", s.entries[1].start_slot);
    }

    #[test]
    fn loopback_consumes_no_links() {
        let s = Schedule::build(
            &[Transfer { src: (1, 1), dst: (1, 1), bits: 300 }], 8);
        assert_eq!(s.bit_hops(), 0);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn schedule_never_double_books_a_link() {
        forall("tdm_invariant", 40, |rng: &mut Rng| {
            let n = rng.range(2, 40);
            let ts: Vec<Transfer> = (0..n)
                .map(|_| Transfer {
                    src: (rng.below(6), rng.below(6)),
                    dst: (rng.below(6), rng.below(6)),
                    bits: rng.range(1, 512) as u64,
                })
                .collect();
            let s = Schedule::build(&ts, 8);
            s.validate().map_err(|l| format!("double-booked {l:?}"))?;
            if s.entries.len() != ts.len() {
                return Err("transfer dropped".into());
            }
            Ok(())
        });
    }

    #[test]
    fn bit_hops_match_manual_count() {
        let ts = vec![Transfer { src: (0, 0), dst: (3, 2), bits: 24 }];
        let s = Schedule::build(&ts, 8);
        assert_eq!(s.bit_hops(), 24 * 5);
        // 3 flits across 5 links, start 0 -> finish at 5 + 3 = 8
        assert_eq!(s.makespan_slots(), 8);
    }
}
