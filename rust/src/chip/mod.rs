//! Multi-tenant chip scheduler: many resident applications served from
//! one simulated 144-core mesh, with modeled reconfiguration.
//!
//! The paper's title word is *reconfigurable*: the mesh is statically
//! time-multiplexed and re-programmed between workloads (sections II,
//! V.B), and the follow-up streaming-multicore paper (arXiv:1606.04609)
//! frames the chip as a shared recognition server. Datacenter
//! accelerators win precisely by co-residency — many models scheduled
//! onto one die (Jouppi et al., arXiv:1704.04760) — so this module
//! turns the single-app serving front ([`crate::serve`]) into a
//! multi-tenant one:
//!
//! 1. **Admission** — every hosted app is placement-checked against
//!    the mesh ([`crate::mapper::place_at`]) and gets a *core offset*
//!    so co-resident placements occupy disjoint mesh stops
//!    ([`plan_residency`]). A set whose combined peak demand exceeds
//!    the chip is rejected up front when
//!    [`ChipConfig::require_resident`] is set; otherwise the overflow
//!    is served via swapping (below).
//! 2. **Per-app ingress** — each app keeps its own bounded request
//!    queue (sized from the 4 kB input buffer for *its* input width)
//!    and its own [`Batcher`], so per-app batching math is exactly the
//!    dedicated [`Server`](crate::serve::Server)'s.
//! 3. **Deficit-round-robin dispatch** — formed batches from every app
//!    multiplex onto **one** shared engine (and its worker pool)
//!    through a DRR picker: each backlogged app earns
//!    [`ChipConfig::quantum`] samples of credit per rotation, so a hot
//!    app cannot starve the others while idle apps cost nothing. The
//!    per-app ready FIFOs between batcher and dispatcher are
//!    depth-bounded, so backpressure reaches all the way back to
//!    `Client::submit` (the in-flight bound is the ingress capacity
//!    plus a couple of batches — never the submission rate).
//! 4. **Modeled reconfiguration** — dispatching a non-resident app
//!    swaps it in: least-recently-dispatched residents are evicted
//!    until it fits, and the switch-image + conductance re-program cost
//!    ([`crate::sim::reconfig_cost`]) is charged into the report. The
//!    cost is *modeled* (accounted, never slept), so functional results
//!    are unaffected.
//!
//! # Determinism contract
//!
//! Per-app results are **bit-identical to a dedicated single-app
//! [`Server`](crate::serve::Server)** serving the same network and
//! parameters. Co-residency changes only *when* an app's batches
//! dispatch, never what they compute: batching math is the shared
//! [`Batcher`]; dispatch runs the same
//! [`Engine::infer`](crate::coordinator::Engine::infer) over the app's
//! own `(net, params)`; and the pool underneath is bit-identical at any
//! worker count ([`crate::coordinator::pool`]). Swaps move mesh
//! residency, not numerics — conductances live in host memory either
//! way. `rust/tests/multiapp_determinism.rs` pins this across apps ×
//! clients × workers, including a schedule that forces swaps.
//!
//! # Example
//!
//! ```
//! use restream::chip::{ChipApp, ChipConfig, ChipScheduler};
//! use restream::config::apps;
//! use restream::coordinator::{init_conductances, Engine};
//!
//! let host = |name: &str| {
//!     let net = apps::network(name).unwrap().clone();
//!     let params = init_conductances(net.layers, 0);
//!     ChipApp { net, params }
//! };
//! let chip = ChipScheduler::start(
//!     Engine::native(),
//!     vec![host("iris_ae"), host("kdd_ae")],
//!     ChipConfig::default(),
//! )
//! .unwrap();
//! let out = chip
//!     .client("iris_ae")
//!     .unwrap()
//!     .call(vec![0.1, -0.2, 0.3, 0.0])
//!     .unwrap();
//! assert_eq!(out.out.len(), 4); // iris_ae reconstruction
//! let report = chip.shutdown();
//! assert_eq!(report.apps.len(), 2);
//! assert_eq!(report.total_requests(), 1);
//! ```

// Rule P1's compiler-side shadow: the request path answers with typed
// errors, never panics. Tests keep their unwraps (the cfg_attr gate).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::dbg_macro))]

mod report;
mod residency;

pub use report::{AppServeReport, MultiServeReport};
pub use residency::{
    footprint, greedy_admission, plan_residency, plan_slots, AppFootprint,
    ResidentSlot,
};

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{apps, Network, SystemConfig};
use crate::coordinator::{stream, Engine};
use crate::runtime::ArrayF32;
use crate::serve::{
    answer_batch, take_batch_inputs, Batcher, Client, Pending, Request,
    ServeStats, Service, StatsAccum,
};
use crate::telemetry::TraceSink;

use residency::Residency;

/// One application hosted by a [`ChipScheduler`]: its network plus the
/// conductance parameters to serve it with.
#[derive(Clone)]
pub struct ChipApp {
    /// The served network (drives mapping, ingress width and batching).
    pub net: Network,
    /// Conductance parameters, as [`Server`](crate::serve::Server)
    /// takes them.
    pub params: Vec<ArrayF32>,
}

/// Tuning knobs of a [`ChipScheduler`].
#[derive(Clone, Debug)]
pub struct ChipConfig {
    /// The chip the residents share (default: the paper's 144-core
    /// 12x12 mesh).
    pub sys: SystemConfig,
    /// Per-app micro-batch limit, as
    /// [`ServeConfig::max_batch`](crate::serve::ServeConfig::max_batch).
    pub max_batch: usize,
    /// Per-app batching window, as
    /// [`ServeConfig::max_wait`](crate::serve::ServeConfig::max_wait).
    pub max_wait: Duration,
    /// Per-app ingress queue depth override. `None` (the default)
    /// sizes each app's queue from the 4 kB input buffer for its input
    /// width ([`stream::buffer_capacity`]).
    pub queue_capacity: Option<usize>,
    /// DRR quantum in samples: the dispatch credit every backlogged app
    /// earns per round-robin rotation (default [`apps::FWD_BATCH`] —
    /// one full hardware tile per turn).
    pub quantum: usize,
    /// When true, [`ChipScheduler::start`] rejects app sets whose
    /// combined peak core demand exceeds the chip ([`plan_residency`])
    /// instead of serving the overflow via swapping.
    pub require_resident: bool,
    /// Request tracer shared by every hosted app, as
    /// [`ServeConfig::trace`](crate::serve::ServeConfig::trace).
    /// `None` (the default) disables tracing.
    pub trace: Option<Arc<crate::telemetry::Tracer>>,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            sys: SystemConfig::default(),
            max_batch: apps::FWD_BATCH,
            max_wait: Duration::from_micros(200),
            queue_capacity: None,
            quantum: apps::FWD_BATCH,
            require_resident: false,
            trace: None,
        }
    }
}

/// A batch formed by one app's [`Batcher`], ready to dispatch.
type ReadyBatch = Vec<(Request, Instant)>;

/// Formed batches one app may have waiting in its ready FIFO before
/// its batcher blocks. This is the backpressure link that keeps the
/// bounded-ingress story true end to end: a full FIFO blocks the
/// app's batcher thread, a blocked batcher stops draining the app's
/// bounded ingress queue, and a full ingress queue blocks
/// `Client::submit` — the DMA input-buffer rule. Per-app in-flight
/// work is therefore bounded by
/// `ingress capacity + (READY_DEPTH + 1) * max_batch` samples, never
/// by the client submission rate.
const READY_DEPTH: usize = 2;

/// Hand-off stage between the per-app batcher threads and the single
/// dispatcher: depth-bounded per-app FIFOs of formed batches plus a
/// count of batchers still running (the dispatcher exits when it hits
/// zero with every FIFO drained).
struct ReadyQueues {
    state: Mutex<ReadyState>,
    cv: Condvar,
}

struct ReadyState {
    queues: Vec<VecDeque<ReadyBatch>>,
    open: usize,
}

impl ReadyQueues {
    fn new(apps: usize) -> ReadyQueues {
        ReadyQueues {
            state: Mutex::new(ReadyState {
                queues: (0..apps).map(|_| VecDeque::new()).collect(),
                open: apps,
            }),
            cv: Condvar::new(),
        }
    }

    /// Queue a formed batch, blocking while the app's FIFO is at
    /// [`READY_DEPTH`] — the dispatcher's pop wakes blocked pushers.
    /// No deadlock is possible: a blocked pusher implies a non-empty
    /// FIFO, so the dispatcher never waits while one exists.
    fn push(&self, app: usize, batch: ReadyBatch) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.queues[app].len() >= READY_DEPTH {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.queues[app].push_back(batch);
        drop(st);
        self.cv.notify_all();
    }

    fn close_one(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.open -= 1;
        drop(st);
        self.cv.notify_all();
    }
}

/// Deficit-round-robin picker over per-app batch FIFOs (Shreedhar &
/// Varghese's DRR, at sample granularity). Each visit to a backlogged
/// app banks `quantum` samples of credit; a batch dispatches once the
/// app's credit covers its size, and an app whose FIFO empties forfeits
/// leftover credit — the classic rule that stops idle flows from
/// hoarding. One hot app therefore gets at most `quantum` samples of
/// service per rotation while others are backlogged.
struct Drr {
    quantum: usize,
    deficit: Vec<usize>,
    cursor: usize,
}

impl Drr {
    fn new(apps: usize, quantum: usize) -> Drr {
        Drr { quantum: quantum.max(1), deficit: vec![0; apps], cursor: 0 }
    }

    /// Pop the next batch to dispatch, or `None` when every FIFO is
    /// empty. Terminates because some backlogged app's credit grows by
    /// `quantum` per rotation until it covers its head batch.
    fn pick<T>(
        &mut self,
        queues: &mut [VecDeque<Vec<T>>],
    ) -> Option<(usize, Vec<T>)> {
        if queues.iter().all(VecDeque::is_empty) {
            return None;
        }
        loop {
            let i = self.cursor;
            self.cursor = (self.cursor + 1) % queues.len();
            if queues[i].is_empty() {
                self.deficit[i] = 0;
                continue;
            }
            self.deficit[i] += self.quantum;
            let need = queues[i].front().map_or(0, Vec::len);
            if self.deficit[i] >= need {
                // lint: allow(P1) — guarded three lines up: this arm
                // is reached only when `queues[i]` is non-empty.
                let batch = queues[i].pop_front().expect("non-empty queue");
                self.deficit[i] -= need;
                if queues[i].is_empty() {
                    self.deficit[i] = 0;
                }
                return Some((i, batch));
            }
        }
    }
}

/// A running multi-tenant scheduler: per-app batcher threads feeding
/// one dispatcher thread that owns the shared [`Engine`]. See the
/// module docs for the pipeline, fairness and determinism contracts,
/// and DESIGN.md "Multi-tenant serving" for the swap lifecycle.
pub struct ChipScheduler {
    clients: Vec<(String, Client)>,
    batchers: Vec<thread::JoinHandle<()>>,
    dispatcher: thread::JoinHandle<MultiServeReport>,
}

impl ChipScheduler {
    /// Spawn the scheduler over `engine` (which it now owns, worker
    /// pool included), hosting every app in `hosted`. Fails when the
    /// app list is empty or has duplicate names, when any app cannot
    /// map onto `cfg.sys` at all, or — with
    /// [`ChipConfig::require_resident`] — when the set's combined peak
    /// core demand exceeds the chip.
    pub fn start(
        engine: Engine,
        hosted: Vec<ChipApp>,
        cfg: ChipConfig,
    ) -> Result<ChipScheduler> {
        if hosted.is_empty() {
            return Err(anyhow!("the chip scheduler needs at least one app"));
        }
        for (i, a) in hosted.iter().enumerate() {
            if hosted[..i].iter().any(|b| b.net.name == a.net.name) {
                return Err(anyhow!(
                    "app {} is hosted twice — each resident needs a \
                     unique name",
                    a.net.name
                ));
            }
        }
        cfg.sys.validate().map_err(anyhow::Error::msg)?;
        let footprints: Vec<AppFootprint> = hosted
            .iter()
            .map(|a| footprint(&a.net, &cfg.sys))
            .collect::<std::result::Result<_, String>>()
            .map_err(anyhow::Error::msg)?;
        if cfg.require_resident {
            plan_slots(&footprints, &cfg.sys).map_err(anyhow::Error::msg)?;
        }
        let ready = Arc::new(ReadyQueues::new(hosted.len()));
        let mut clients = Vec::with_capacity(hosted.len());
        let mut batchers = Vec::with_capacity(hosted.len());
        for (i, app) in hosted.iter().enumerate() {
            let dims = app.net.layers[0];
            let capacity = cfg
                .queue_capacity
                .unwrap_or_else(|| stream::buffer_capacity(dims))
                .max(1);
            let (client, rx) =
                Client::channel_traced(dims, capacity, cfg.trace.clone());
            let batcher = Batcher::new(rx, cfg.max_batch, cfg.max_wait);
            let ready_tx = Arc::clone(&ready);
            let handle = thread::Builder::new()
                .name(format!("restream-chip-batch-{}", app.net.name))
                .spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        ready_tx.push(i, batch);
                    }
                    ready_tx.close_one();
                })
                // lint: allow(P1) — thread spawn fails only on OS
                // resource exhaustion at scheduler start, before any
                // request exists to answer with a typed error.
                .expect("spawning chip batcher thread");
            clients.push((app.net.name.to_string(), client));
            batchers.push(handle);
        }
        let quantum = cfg.quantum;
        let budget = cfg.sys.neural_cores;
        let sinks: Vec<TraceSink> = hosted
            .iter()
            .map(|a| TraceSink::for_app(cfg.trace.clone(), a.net.name))
            .collect();
        let dispatcher = thread::Builder::new()
            .name("restream-chip-dispatch".to_string())
            .spawn(move || {
                dispatch_loop(engine, hosted, footprints, ready, quantum,
                              budget, sinks)
            })
            // lint: allow(P1) — same start-time spawn failure as the
            // batcher threads above; no request path exists yet.
            .expect("spawning chip dispatcher thread");
        Ok(ChipScheduler { clients, batchers, dispatcher })
    }

    /// Names of the hosted apps, in registration order.
    pub fn apps(&self) -> Vec<String> {
        self.clients.iter().map(|(name, _)| name.clone()).collect()
    }

    /// A submission handle for `app` (any number may exist; clones of
    /// one app share that app's bounded ingress queue).
    pub fn client(&self, app: &str) -> Result<Client> {
        self.clients
            .iter()
            .find(|(name, _)| name == app)
            .map(|(_, client)| client.clone())
            .ok_or_else(|| {
                anyhow!("app {app} is not hosted by this scheduler")
            })
    }

    /// Stop accepting requests and return the aggregate
    /// [`MultiServeReport`]. Blocks until every outstanding client
    /// clone (of every app) has been dropped and the final batches have
    /// been answered — the same contract as
    /// [`Server::shutdown`](crate::serve::Server::shutdown).
    pub fn shutdown(self) -> MultiServeReport {
        let ChipScheduler { clients, batchers, dispatcher } = self;
        drop(clients);
        for handle in batchers {
            // lint: allow(P1) — a batcher panic is already a bug; the
            // only honest continuation of shutdown is to propagate it.
            handle.join().expect("chip batcher thread panicked");
        }
        // lint: allow(P1) — propagating a dispatcher panic, as above.
        dispatcher.join().expect("chip dispatcher thread panicked")
    }
}

/// The unified serving surface (see [`crate::serve::Service`]): submit
/// routes through the per-app [`Client`], live stats sum per-app
/// acceptance, shutdown collapses the [`MultiServeReport`] into the
/// interface-level counters.
impl Service for ChipScheduler {
    fn apps(&self) -> Vec<String> {
        ChipScheduler::apps(self)
    }

    fn submit(&self, app: &str, x: Vec<f32>) -> Result<Pending> {
        ChipScheduler::client(self, app)?.submit(x)
    }

    fn stats(&self) -> ServeStats {
        ServeStats {
            apps: self.clients.len(),
            requests: self
                .clients
                .iter()
                .map(|(_, client)| client.submitted())
                .sum(),
            ..ServeStats::default()
        }
    }

    fn shutdown(self: Box<Self>) -> ServeStats {
        ChipScheduler::shutdown(*self).stats()
    }
}

/// The shared dispatcher: DRR-pick ready batches across apps, swap the
/// owning app in when it is not resident (charging the modeled
/// reconfiguration), run the batch on the shared engine and route the
/// replies. Runs until every app's batcher has closed and every FIFO
/// is drained.
fn dispatch_loop(
    engine: Engine,
    hosted: Vec<ChipApp>,
    footprints: Vec<AppFootprint>,
    ready: Arc<ReadyQueues>,
    quantum: usize,
    budget: usize,
    sinks: Vec<TraceSink>,
) -> MultiServeReport {
    let n = hosted.len();
    let mut drr = Drr::new(n, quantum);
    let mut stats: Vec<StatsAccum> =
        (0..n).map(|_| StatsAccum::default()).collect();
    let mut residency =
        Residency::new(budget, footprints.iter().map(|f| f.cores).collect());
    let mut swaps_in = vec![0usize; n];
    let mut reconfig_s = vec![0.0f64; n];
    // Initial residents pay their configuration once up front — the
    // chip must be programmed before the first sample either way.
    for i in 0..n {
        if residency.is_resident(i) {
            reconfig_s[i] += footprints[i].reconfig.total_s();
        }
    }
    let mut swaps = 0usize;
    let mut evictions = 0usize;
    let mut span: Option<(Instant, Instant)> = None;
    loop {
        let picked = {
            let mut st =
                ready.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(p) = drr.pick(&mut st.queues) {
                    break Some(p);
                }
                if st.open == 0 {
                    break None;
                }
                st = ready
                    .cv
                    .wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some((i, mut batch)) = picked else { break };
        // The pop freed FIFO space: wake any batcher blocked on a
        // full ready FIFO (see ReadyQueues::push).
        ready.cv.notify_all();
        // Modeled reconfiguration: a non-resident app swaps in before
        // its batch may run; the charge is accounted, never slept.
        let outcome = residency.ensure(i);
        if outcome.swapped_in {
            swaps_in[i] += 1;
            swaps += 1;
            evictions += outcome.evicted.len();
            reconfig_s[i] += footprints[i].reconfig.total_s();
        }
        let dispatch = Instant::now();
        let xs = take_batch_inputs(&mut batch);
        let result = engine.infer(&hosted[i].net, &hosted[i].params, &xs);
        let done = Instant::now();
        let start = span.map_or(dispatch, |(start, _)| start);
        span = Some((start, done));
        answer_batch(result, batch, dispatch, done, &mut stats[i],
                     &sinks[i]);
    }
    let offsets = residency.offsets();
    let apps: Vec<AppServeReport> = (0..n)
        .map(|i| AppServeReport {
            app: footprints[i].app.clone(),
            cores: footprints[i].cores,
            resident: residency.is_resident(i),
            offset: offsets[i],
            swaps_in: swaps_in[i],
            reconfig_s: reconfig_s[i],
            serve: stats[i].finish(),
        })
        .collect();
    MultiServeReport {
        apps,
        wall_s: span.map_or(0.0, |(start, end)| {
            end.saturating_duration_since(start).as_secs_f64()
        }),
        chip_cores: budget,
        occupancy_pct: 100.0 * residency.peak_used() as f64
            / budget.max(1) as f64,
        swaps,
        evictions,
        reconfig_total_s: reconfig_s.iter().sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::init_conductances;

    fn host(name: &str, seed: u64) -> ChipApp {
        let net = apps::network(name).unwrap().clone();
        let params = init_conductances(net.layers, seed);
        ChipApp { net, params }
    }

    /// Build `n` real ingress requests (the reply receipts are
    /// dropped — only the queueing behaviour is under test).
    fn raw_requests(n: usize) -> Vec<Request> {
        let (client, rx) = Client::channel(2, n.max(1));
        let pendings: Vec<_> = (0..n)
            .map(|_| client.submit(vec![0.0, 0.0]).unwrap())
            .collect();
        drop(client);
        drop(pendings);
        rx.iter().collect()
    }

    #[test]
    fn ready_fifos_apply_backpressure_to_batchers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ready = Arc::new(ReadyQueues::new(1));
        let pushed = Arc::new(AtomicUsize::new(0));
        let ready2 = Arc::clone(&ready);
        let pushed2 = Arc::clone(&pushed);
        let reqs = raw_requests(READY_DEPTH + 1);
        let producer = thread::spawn(move || {
            for req in reqs {
                ready2.push(0, vec![(req, Instant::now())]);
                pushed2.fetch_add(1, Ordering::SeqCst);
            }
        });
        // the producer fills the FIFO, then must block on the extra
        let deadline = Instant::now() + Duration::from_secs(5);
        while pushed.load(Ordering::SeqCst) < READY_DEPTH
            && Instant::now() < deadline
        {
            thread::sleep(Duration::from_millis(5));
        }
        thread::sleep(Duration::from_millis(30));
        assert_eq!(
            pushed.load(Ordering::SeqCst),
            READY_DEPTH,
            "push past READY_DEPTH must block"
        );
        // a dispatcher-style pop frees a slot and wakes the pusher
        {
            let mut st =
                ready.state.lock().unwrap_or_else(|e| e.into_inner());
            st.queues[0].pop_front().expect("FIFO full");
            drop(st);
            ready.cv.notify_all();
        }
        producer.join().unwrap();
        assert_eq!(pushed.load(Ordering::SeqCst), READY_DEPTH + 1);
    }

    #[test]
    fn drr_interleaves_a_hot_app_with_a_light_one() {
        // App 0 has a deep backlog of full 64-sample batches; app 1 has
        // single-sample batches. With quantum 64 every rotation serves
        // both — the hot app cannot starve the light one.
        let mut q: Vec<VecDeque<Vec<u32>>> = vec![
            (0..8).map(|_| vec![0u32; 64]).collect(),
            (0..4).map(|_| vec![1u32; 1]).collect(),
        ];
        let mut drr = Drr::new(2, 64);
        let mut order = Vec::new();
        while let Some((i, _)) = drr.pick(&mut q) {
            order.push(i);
        }
        assert_eq!(order.len(), 12);
        // the first four rotations alternate 0, 1, 0, 1, ...
        assert_eq!(&order[..8], &[0, 1, 0, 1, 0, 1, 0, 1]);
        // afterwards only app 0's backlog remains
        assert_eq!(&order[8..], &[0, 0, 0, 0]);
    }

    #[test]
    fn drr_banks_credit_for_oversized_batches() {
        // One 10-sample batch under a quantum of 4 needs three
        // rotations of banked credit before it dispatches.
        let mut q: Vec<VecDeque<Vec<u32>>> =
            vec![VecDeque::from(vec![vec![0u32; 10]])];
        let mut drr = Drr::new(1, 4);
        let (i, batch) = drr.pick(&mut q).unwrap();
        assert_eq!(i, 0);
        assert_eq!(batch.len(), 10);
        assert!(drr.pick(&mut q).is_none());
    }

    #[test]
    fn round_trips_across_co_resident_apps() {
        let chip = ChipScheduler::start(
            Engine::native(),
            vec![host("iris_ae", 3), host("kdd_ae", 3)],
            ChipConfig::default(),
        )
        .unwrap();
        assert_eq!(chip.apps(), vec!["iris_ae", "kdd_ae"]);
        assert!(chip.client("nope").is_err());
        let iris = chip.client("iris_ae").unwrap();
        let kdd = chip.client("kdd_ae").unwrap();
        assert_eq!(iris.dims(), 4);
        assert_eq!(kdd.dims(), 41);
        for _ in 0..3 {
            let r = iris.call(vec![0.1, -0.2, 0.3, 0.0]).unwrap();
            assert_eq!(r.out.len(), 4);
            let r = kdd.call(vec![0.05; 41]).unwrap();
            assert_eq!(r.out.len(), 41);
        }
        drop(iris);
        drop(kdd);
        let report = chip.shutdown();
        assert_eq!(report.total_requests(), 6);
        assert_eq!(report.total_errors(), 0);
        assert_eq!(report.apps[0].serve.requests, 3);
        assert_eq!(report.apps[1].serve.requests, 3);
        // both fit the 144-core chip side by side: no swaps, and both
        // end resident at disjoint offsets with initial config charged
        assert_eq!(report.swaps, 0);
        assert!(report.apps.iter().all(|a| a.resident));
        let mut offs: Vec<usize> =
            report.apps.iter().map(|a| a.offset.unwrap()).collect();
        offs.sort_unstable();
        assert_eq!(offs, vec![0, 2]);
        assert!(report.reconfig_total_s > 0.0);
        assert!(report.occupancy_pct > 0.0 && report.occupancy_pct < 100.0);
        assert!(report.aggregate_rps() > 0.0);
    }

    #[test]
    fn serves_through_the_service_trait() {
        let svc: Box<dyn Service> = Box::new(
            ChipScheduler::start(
                Engine::native(),
                vec![host("iris_ae", 3), host("kdd_ae", 3)],
                ChipConfig::default(),
            )
            .unwrap(),
        );
        assert_eq!(svc.apps(), vec!["iris_ae", "kdd_ae"]);
        assert!(svc.submit("nope", vec![0.0; 4]).is_err());
        let r = svc.call("iris_ae", vec![0.1, -0.2, 0.3, 0.0]).unwrap();
        assert_eq!(r.out.len(), 4);
        let r = svc.call("kdd_ae", vec![0.05; 41]).unwrap();
        assert_eq!(r.out.len(), 41);
        let live = svc.stats();
        assert_eq!((live.apps, live.requests), (2, 2));
        let done = svc.shutdown();
        assert_eq!(done.apps, 2);
        assert_eq!(done.requests, 2);
        assert_eq!(done.errors, 0);
    }

    #[test]
    fn zero_wait_unit_queues_still_serve_every_tenant() {
        // Tightest per-app configuration: no straggler window and a
        // one-sample ingress queue, so the batcher/ready-FIFO path
        // runs under constant backpressure for both tenants.
        let cfg = ChipConfig {
            max_wait: Duration::ZERO,
            queue_capacity: Some(1),
            ..ChipConfig::default()
        };
        let chip = ChipScheduler::start(
            Engine::native(),
            vec![host("iris_ae", 1), host("kdd_ae", 1)],
            cfg,
        )
        .unwrap();
        let iris = chip.client("iris_ae").unwrap();
        let kdd = chip.client("kdd_ae").unwrap();
        for i in 0..6 {
            let r = iris.call(vec![0.05 * i as f32; 4]).unwrap();
            assert_eq!(r.out.len(), 4);
            let r = kdd.call(vec![0.01; 41]).unwrap();
            assert_eq!(r.out.len(), 41);
        }
        drop(iris);
        drop(kdd);
        let report = chip.shutdown();
        assert_eq!(report.total_requests(), 12);
        assert_eq!(report.total_errors(), 0);
    }

    #[test]
    fn shutdown_answers_requests_still_queued_in_the_ready_fifos() {
        // Queue a burst and shut down immediately: the batchers flush
        // their partial batches on disconnect, the dispatcher drains
        // every ready FIFO before reporting, and each receipt settles
        // with a response — never the typed "shut down before
        // replying" error a silent drop would produce. The generous
        // max_wait guarantees the burst is still queued at shutdown.
        let cfg = ChipConfig {
            max_wait: Duration::from_secs(5),
            ..ChipConfig::default()
        };
        let chip = ChipScheduler::start(
            Engine::native(),
            vec![host("iris_ae", 2), host("kdd_ae", 2)],
            cfg,
        )
        .unwrap();
        let iris = chip.client("iris_ae").unwrap();
        let kdd = chip.client("kdd_ae").unwrap();
        let mut pendings = Vec::new();
        for _ in 0..5 {
            pendings.push(iris.submit(vec![0.1, -0.1, 0.2, 0.0]).unwrap());
            pendings.push(kdd.submit(vec![0.02; 41]).unwrap());
        }
        drop(iris);
        drop(kdd);
        let report = chip.shutdown();
        assert_eq!(report.total_requests(), 10);
        assert_eq!(report.total_errors(), 0);
        for pending in pendings {
            pending.wait().expect("queued request was dropped");
        }
    }

    #[test]
    fn duplicate_and_empty_app_sets_are_rejected() {
        let err = ChipScheduler::start(
            Engine::native(),
            Vec::new(),
            ChipConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
        let err = ChipScheduler::start(
            Engine::native(),
            vec![host("iris_ae", 0), host("iris_ae", 1)],
            ChipConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("hosted twice"), "{err}");
    }

    #[test]
    fn require_resident_rejects_an_overflowing_set() {
        // A 2-core chip cannot co-host two 2-core apps residently...
        let cfg = ChipConfig {
            sys: SystemConfig { neural_cores: 2, ..Default::default() },
            require_resident: true,
            ..ChipConfig::default()
        };
        let err = ChipScheduler::start(
            Engine::native(),
            vec![host("iris_ae", 0), host("kdd_ae", 0)],
            cfg.clone(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("chip has 2"), "{err}");
        // ...but the default (swapping) scheduler hosts the same set.
        let chip = ChipScheduler::start(
            Engine::native(),
            vec![host("iris_ae", 0), host("kdd_ae", 0)],
            ChipConfig { require_resident: false, ..cfg },
        )
        .unwrap();
        let iris = chip.client("iris_ae").unwrap();
        let kdd = chip.client("kdd_ae").unwrap();
        iris.call(vec![0.0; 4]).unwrap();
        kdd.call(vec![0.0; 41]).unwrap();
        iris.call(vec![0.1; 4]).unwrap();
        drop(iris);
        drop(kdd);
        let report = chip.shutdown();
        // serving all three batches forced at least one swap-in, each
        // charged a modeled reconfiguration
        assert!(report.swaps >= 1, "swaps {}", report.swaps);
        assert!(report.evictions >= 1);
        assert!(report.reconfig_total_s > 0.0);
        assert_eq!(report.total_errors(), 0);
    }
}
