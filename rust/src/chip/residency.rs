//! Residency accounting: which applications occupy which cores of the
//! 144-core mesh, and what admitting one costs.
//!
//! An application's **footprint** is the peak simultaneous core demand
//! of its serving configuration (the recognition mapping, exactly as
//! `sim::recognition_cost` maps it) plus its modeled reconfiguration
//! cost ([`crate::sim::reconfig_cost`]). A **resident set** is a group
//! of footprints packed side by side into the mesh's row-major core
//! order: each resident gets a *core offset*, its placement is
//! re-derived at that offset via [`crate::mapper::place_at`], and the
//! resulting mesh stops are checked disjoint — occupancy is explicit,
//! not implied.
//!
//! [`plan_residency`] is the admission gate: it fails fast, with a
//! per-app breakdown, when the set's combined demand exceeds the chip.
//! The scheduler's dynamic swap path (`super::ChipScheduler`) reuses
//! the same footprints with an LRU eviction policy for sets that are
//! *allowed* to overflow.

use crate::config::{Network, SystemConfig};
use crate::mapper::{place_at, StageMap};
use crate::sim::{self, ReconfigCost};

/// Static footprint of one application on the chip: what residency
/// costs in cores and what (re)admission costs in modeled time.
#[derive(Clone, Debug)]
pub struct AppFootprint {
    /// Application name.
    pub app: String,
    /// Peak simultaneous core demand of the serving configuration.
    pub cores: usize,
    /// Modeled cost of (re)configuring the chip for this app — charged
    /// by the scheduler on every swap-in.
    pub reconfig: ReconfigCost,
    /// The serving-configuration stage, kept for placement checks at
    /// admission time.
    stage: StageMap,
}

/// Compute the serving footprint of `net` on `sys`. The mapping is
/// [`sim::serving_map`] — the one home of the "serving runs the
/// deployed forward network" remap rule — built once here and priced
/// in place via [`sim::reconfig_cost_of`] (no re-mapping). Errors when
/// the app cannot map at all (a single layer larger than the core
/// budget).
pub fn footprint(net: &Network, sys: &SystemConfig)
    -> Result<AppFootprint, String> {
    let map = sim::serving_map(net, sys)?;
    let stage = map
        .stages
        .into_iter()
        .next()
        .ok_or_else(|| format!("{}: mapping produced no stages", net.name))?;
    let reconfig = sim::reconfig_cost_of(&stage, sys);
    Ok(AppFootprint {
        app: net.name.to_string(),
        cores: stage.cores_used(),
        reconfig,
        stage,
    })
}

/// Greedy admission in listed order: each app becomes resident — at
/// the next packed offset — if it still fits next to everyone admitted
/// before it; apps that do not fit are skipped (`None`), and later,
/// smaller apps may still be admitted. This is **the** initial
/// admission rule: the scheduler's `Residency` state and the
/// `restream report --occupancy` table both call it, so the report can
/// never drift from what the scheduler actually does.
pub fn greedy_admission(cores: &[usize], budget: usize)
    -> Vec<Option<usize>> {
    let mut slots = Vec::with_capacity(cores.len());
    let mut used = 0usize;
    for &need in cores {
        if used + need <= budget {
            slots.push(Some(used));
            used += need;
        } else {
            slots.push(None);
        }
    }
    slots
}

/// One resident's slot on the mesh: `cores` mesh cores starting at
/// row-major core id `offset`.
#[derive(Clone, Debug)]
pub struct ResidentSlot {
    /// Application name.
    pub app: String,
    /// Peak simultaneous core demand.
    pub cores: usize,
    /// Row-major core id the app's placement starts at.
    pub offset: usize,
}

/// Admission check for a *fully resident* set: compute every app's
/// [`footprint`] and hand them to [`plan_slots`].
pub fn plan_residency(nets: &[&Network], sys: &SystemConfig)
    -> Result<Vec<ResidentSlot>, String> {
    let footprints = nets
        .iter()
        .map(|net| footprint(net, sys))
        .collect::<Result<Vec<_>, String>>()?;
    plan_slots(&footprints, sys)
}

/// [`plan_residency`] over already-computed footprints (the scheduler
/// computes each app's footprint once and reuses it here): place every
/// app side by side on one chip, offsets assigned by
/// [`greedy_admission`] in listed order, and placement-check each at
/// its offset (disjoint mesh stops). Errors — descriptively, with the
/// per-app core breakdown — when the combined peak demand exceeds the
/// chip's core budget.
pub fn plan_slots(footprints: &[AppFootprint], sys: &SystemConfig)
    -> Result<Vec<ResidentSlot>, String> {
    let cores: Vec<usize> = footprints.iter().map(|fp| fp.cores).collect();
    let used: usize = cores.iter().sum();
    if used > sys.neural_cores {
        let detail: Vec<String> = footprints
            .iter()
            .map(|fp| format!("{}={}", fp.app, fp.cores))
            .collect();
        return Err(format!(
            "resident set needs {used} neural cores but the chip has \
             {}: {}; drop an app or serve the overflow via \
             reconfiguration (swapping)",
            sys.neural_cores,
            detail.join(" + ")
        ));
    }
    // Placement-check every slot at its offset: stops must be disjoint
    // across residents (they are by construction — offsets partition
    // the row-major core order — but the check keeps the invariant
    // honest if the mapper's placement rule ever changes).
    let offsets = greedy_admission(&cores, sys.neural_cores);
    let mut slots = Vec::with_capacity(footprints.len());
    let mut taken: std::collections::BTreeSet<(usize, usize)> =
        std::collections::BTreeSet::new();
    for (fp, slot) in footprints.iter().zip(&offsets) {
        // lint: allow(P1) — the guard above returned an error unless
        // the whole set fits, and greedy_admission admits every app
        // whose demand fits; an unfilled slot here is a plain bug in
        // greedy_admission, not a request-path condition.
        let offset = slot.expect("the whole set fits the chip");
        let placement = place_at(&fp.stage, sys, offset);
        // A multi-phase stage legitimately reuses its own stops across
        // phases (the chip reconfigures between them) — dedupe within
        // the app before checking across apps. BTreeSet, not HashSet:
        // the iteration below reports the first conflict, and the
        // error message must name the same stop on every run (lint
        // rule D1).
        let mine: std::collections::BTreeSet<(usize, usize)> =
            placement.coords.iter().flatten().copied().collect();
        for xy in mine {
            if !taken.insert(xy) {
                return Err(format!(
                    "{}: placement at offset {offset} reuses mesh stop \
                     {xy:?}",
                    fp.app
                ));
            }
        }
        slots.push(ResidentSlot {
            app: fp.app.clone(),
            cores: fp.cores,
            offset,
        });
    }
    Ok(slots)
}

/// Dynamic residency state of the running scheduler: who is on the
/// chip now, in least-recently-dispatched order, under a fixed core
/// budget. Offsets re-pack contiguously on every change — the modeled
/// reconfiguration re-places the incoming app anyway, and the paper's
/// chip is fully re-programmed between workloads (section II).
#[derive(Debug)]
pub(crate) struct Residency {
    budget: usize,
    demand: Vec<usize>,
    resident: Vec<bool>,
    /// Resident app indices, least-recently-dispatched first.
    lru: std::collections::VecDeque<usize>,
    used: usize,
    peak_used: usize,
}

/// Outcome of one [`Residency::ensure`] call.
pub(crate) struct SwapOutcome {
    /// True when the app had to be swapped in (was not resident).
    pub(crate) swapped_in: bool,
    /// Apps evicted to make room, in eviction order.
    pub(crate) evicted: Vec<usize>,
}

impl Residency {
    /// Initial admission via [`greedy_admission`] in app order: an app
    /// becomes resident if it still fits next to everyone admitted
    /// before it.
    pub(crate) fn new(budget: usize, demand: Vec<usize>) -> Residency {
        let n = demand.len();
        let admitted = greedy_admission(&demand, budget);
        let mut r = Residency {
            budget,
            demand,
            resident: vec![false; n],
            lru: std::collections::VecDeque::new(),
            used: 0,
            peak_used: 0,
        };
        for (i, slot) in admitted.iter().enumerate() {
            if slot.is_some() {
                r.resident[i] = true;
                r.used += r.demand[i];
                r.lru.push_back(i);
            }
        }
        r.peak_used = r.used;
        r
    }

    pub(crate) fn is_resident(&self, i: usize) -> bool {
        self.resident[i]
    }

    pub(crate) fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Make app `i` resident — evicting least-recently-dispatched
    /// residents until it fits — and mark it most recently dispatched.
    pub(crate) fn ensure(&mut self, i: usize) -> SwapOutcome {
        if self.resident[i] {
            if let Some(pos) = self.lru.iter().position(|&j| j == i) {
                self.lru.remove(pos);
            }
            self.lru.push_back(i);
            return SwapOutcome { swapped_in: false, evicted: Vec::new() };
        }
        let mut evicted = Vec::new();
        while self.used + self.demand[i] > self.budget {
            let victim = self
                .lru
                .pop_front()
                // lint: allow(P1) — an empty LRU with unmet demand
                // means one app alone exceeds the budget, which both
                // entry points reject before a Residency exists; this
                // is an internal invariant, not a request error.
                .expect("app exceeds the chip alone — rejected at start");
            self.resident[victim] = false;
            self.used -= self.demand[victim];
            evicted.push(victim);
        }
        self.resident[i] = true;
        self.used += self.demand[i];
        self.lru.push_back(i);
        self.peak_used = self.peak_used.max(self.used);
        SwapOutcome { swapped_in: true, evicted }
    }

    /// Current offsets: residents packed contiguously in LRU order,
    /// `None` for swapped-out apps.
    pub(crate) fn offsets(&self) -> Vec<Option<usize>> {
        let mut offsets = vec![None; self.demand.len()];
        let mut next = 0usize;
        for &i in &self.lru {
            offsets[i] = Some(next);
            next += self.demand[i];
        }
        offsets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::apps;

    #[test]
    fn footprints_match_the_mapper() {
        let sys = SystemConfig::default();
        let kdd = footprint(apps::network("kdd_ae").unwrap(), &sys).unwrap();
        assert_eq!(kdd.app, "kdd_ae");
        assert_eq!(kdd.cores, 2);
        assert!(kdd.reconfig.total_s() > 0.0);
        // iris_ae maps one core per layer (4->2, 2->4)
        let iris =
            footprint(apps::network("iris_ae").unwrap(), &sys).unwrap();
        assert_eq!(iris.cores, 2);
    }

    #[test]
    fn plan_packs_offsets_in_order() {
        let sys = SystemConfig::default();
        let nets = [
            apps::network("iris_ae").unwrap(),
            apps::network("kdd_ae").unwrap(),
            apps::network("iris_class").unwrap(),
        ];
        let slots = plan_residency(&nets.iter().copied().collect::<Vec<_>>(),
                                   &sys).unwrap();
        assert_eq!(slots.len(), 3);
        assert_eq!(slots[0].offset, 0);
        assert_eq!(slots[1].offset, 2); // after iris_ae's two cores
        assert_eq!(slots[2].offset, 4); // after kdd_ae's two cores
    }

    #[test]
    fn greedy_admission_skips_and_continues() {
        // budget 4, demands [2, 3, 1]: app 1 does not fit after app 0,
        // but app 2 still does — skip-and-continue, offsets packed.
        assert_eq!(
            greedy_admission(&[2, 3, 1], 4),
            vec![Some(0), None, Some(2)]
        );
        assert_eq!(greedy_admission(&[], 4), Vec::<Option<usize>>::new());
        assert_eq!(greedy_admission(&[5], 4), vec![None]);
        assert_eq!(greedy_admission(&[0, 4], 4), vec![Some(0), Some(0)]);
    }

    #[test]
    fn plan_rejects_oversubscription_descriptively() {
        // A 2-core chip cannot co-host iris_ae (2) and kdd_ae (2).
        let sys = SystemConfig { neural_cores: 2, ..Default::default() };
        let nets = [
            apps::network("iris_ae").unwrap(),
            apps::network("kdd_ae").unwrap(),
        ];
        let err = plan_residency(
            &nets.iter().copied().collect::<Vec<_>>(),
            &sys,
        )
        .unwrap_err();
        assert!(err.contains("needs 4 neural cores"), "{err}");
        assert!(err.contains("chip has 2"), "{err}");
        assert!(err.contains("kdd_ae=2"), "{err}");
    }

    #[test]
    fn overlap_error_names_the_smallest_stop_on_every_run() {
        // Two residents forced onto the same offset (by lying about
        // their core demand) collide on every mesh stop; the audit
        // iterates a BTreeSet, so each run must report the *same*,
        // smallest reused stop — with a HashSet the reported stop (and
        // thus the error text) varied run to run.
        let sys = SystemConfig::default();
        let mut a = footprint(apps::network("iris_ae").unwrap(), &sys)
            .unwrap();
        a.cores = 0;
        let mut b = a.clone();
        b.app = "iris_ae_twin".to_string();
        let expected_stop = place_at(&a.stage, &sys, 0)
            .coords
            .iter()
            .flatten()
            .copied()
            .min()
            .unwrap();
        let msgs: Vec<String> = (0..8)
            .map(|_| {
                plan_slots(&[a.clone(), b.clone()], &sys).unwrap_err()
            })
            .collect();
        for m in &msgs {
            assert_eq!(m, &msgs[0]);
            assert!(m.contains("iris_ae_twin"), "{m}");
            assert!(m.contains(&format!("{expected_stop:?}")), "{m}");
        }
    }

    #[test]
    fn residency_swaps_lru_first() {
        // budget 3, demands [1, 1, 2]: apps 0 and 1 start resident.
        let mut r = Residency::new(3, vec![1, 1, 2]);
        assert!(r.is_resident(0) && r.is_resident(1) && !r.is_resident(2));
        assert_eq!(r.peak_used(), 2);
        // app 2 needs 2: evicts the LRU resident (app 0)
        let s = r.ensure(2);
        assert!(s.swapped_in);
        assert_eq!(s.evicted, vec![0]);
        assert!(!r.is_resident(0) && r.is_resident(1) && r.is_resident(2));
        assert_eq!(r.peak_used(), 3);
        // touching app 1 refreshes it, so app 0's return evicts app 2
        let s = r.ensure(1);
        assert!(!s.swapped_in && s.evicted.is_empty());
        let s = r.ensure(0);
        assert!(s.swapped_in);
        assert_eq!(s.evicted, vec![2]);
        // offsets pack residents contiguously (LRU order: 1 then 0)
        let offsets = r.offsets();
        assert_eq!(offsets[1], Some(0));
        assert_eq!(offsets[0], Some(1));
        assert_eq!(offsets[2], None);
    }
}
