//! Multi-tenant serving metrics: per-app serve statistics plus the
//! chip-level residency/swap accounting, returned by
//! [`ChipScheduler::shutdown`](super::ChipScheduler::shutdown) and
//! printed by `restream serve --apps` / the `perf_multiapp` bench.

use crate::serve::ServeReport;

/// One hosted application's share of a scheduler lifetime.
#[derive(Clone, Debug)]
pub struct AppServeReport {
    /// Application name.
    pub app: String,
    /// Peak simultaneous core demand of the app's serving config.
    pub cores: usize,
    /// Whether the app was resident when the scheduler shut down.
    pub resident: bool,
    /// Row-major core offset of the app's placement at shutdown
    /// (`None` while swapped out).
    pub offset: Option<usize>,
    /// Times the app was swapped in after start (0 = never evicted or
    /// initially resident and never displaced).
    pub swaps_in: usize,
    /// Modeled reconfiguration time charged to this app (s): initial
    /// configuration plus every swap-in.
    pub reconfig_s: f64,
    /// The app's own latency/throughput statistics — the same shape a
    /// dedicated single-app [`Server`](crate::serve::Server) returns.
    pub serve: ServeReport,
}

/// Aggregate statistics of one [`ChipScheduler`](super::ChipScheduler)
/// lifetime.
#[derive(Clone, Debug, Default)]
pub struct MultiServeReport {
    /// Per-app breakdown, in registration order.
    pub apps: Vec<AppServeReport>,
    /// First dispatch -> last completion across every app (s).
    pub wall_s: f64,
    /// The chip's neural-core budget the residents shared.
    pub chip_cores: usize,
    /// Peak resident core demand as a percentage of the budget.
    pub occupancy_pct: f64,
    /// Swap-ins performed after start (each charged a reconfiguration).
    pub swaps: usize,
    /// Residents evicted to make room for those swap-ins.
    pub evictions: usize,
    /// Total modeled reconfiguration time charged (s).
    pub reconfig_total_s: f64,
}

impl MultiServeReport {
    /// Requests answered across every app (successes plus errors).
    pub fn total_requests(&self) -> usize {
        self.apps.iter().map(|a| a.serve.requests).sum()
    }

    /// Batches dispatched across every app.
    pub fn total_batches(&self) -> usize {
        self.apps.iter().map(|a| a.serve.batches).sum()
    }

    /// Requests answered with an error across every app.
    pub fn total_errors(&self) -> usize {
        self.apps.iter().map(|a| a.serve.errors).sum()
    }

    /// Aggregate throughput in requests per second over [`Self::wall_s`]
    /// (0 before any request).
    pub fn aggregate_rps(&self) -> f64 {
        let requests = self.total_requests();
        if requests == 0 {
            0.0
        } else {
            requests as f64 / self.wall_s.max(1e-12)
        }
    }

    /// Collapse into the interface-level
    /// [`ServeStats`](crate::serve::ServeStats) counters.
    pub fn stats(&self) -> crate::serve::ServeStats {
        crate::serve::ServeStats {
            apps: self.apps.len(),
            requests: self.total_requests(),
            batches: self.total_batches(),
            errors: self.total_errors(),
            wall_s: self.wall_s,
        }
    }

    /// Serialise under the shared report schema
    /// ([`crate::telemetry::REPORT_SCHEMA`], kind `"multi_serve"`);
    /// every per-app entry embeds its full [`ServeReport`] object.
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        let apps: Vec<Json> = self
            .apps
            .iter()
            .map(|a| {
                Json::obj()
                    .with("app", Json::Str(a.app.clone()))
                    .with("cores", Json::Int(a.cores as i64))
                    .with("resident", Json::Bool(a.resident))
                    .with(
                        "offset",
                        match a.offset {
                            Some(o) => Json::Int(o as i64),
                            None => Json::Null,
                        },
                    )
                    .with("swaps_in", Json::Int(a.swaps_in as i64))
                    .with("reconfig_s", Json::Num(a.reconfig_s))
                    .with("serve", a.serve.to_json())
            })
            .collect();
        Json::obj()
            .with(
                "schema",
                Json::Str(crate::telemetry::REPORT_SCHEMA.to_string()),
            )
            .with("kind", Json::Str("multi_serve".to_string()))
            .with("wall_s", Json::Num(self.wall_s))
            .with("chip_cores", Json::Int(self.chip_cores as i64))
            .with("occupancy_pct", Json::Num(self.occupancy_pct))
            .with("swaps", Json::Int(self.swaps as i64))
            .with("evictions", Json::Int(self.evictions as i64))
            .with("reconfig_total_s", Json::Num(self.reconfig_total_s))
            .with("aggregate_rps", Json::Num(self.aggregate_rps()))
            .with("apps", Json::Arr(apps))
    }

    /// Human-readable multi-line summary (what `restream serve --apps`
    /// prints after the request streams end).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "multi-tenant chip: {} apps on {} cores, peak occupancy \
             {:.1}%, {} swaps ({} evictions), reconfig charged {:.1} us\n",
            self.apps.len(),
            self.chip_cores,
            self.occupancy_pct,
            self.swaps,
            self.evictions,
            self.reconfig_total_s * 1e6,
        );
        for a in &self.apps {
            let place = match a.offset {
                Some(o) => format!("@{o:>3}"),
                None => "out ".to_string(),
            };
            s.push_str(&format!(
                "  {:<14} {:>3} cores {place}  {:>6} req / {:>5} batches \
                 ({} err)  p50 {:>8.1} us  p99 {:>8.1} us  \
                 {} swap-ins, reconfig {:.1} us\n",
                a.app,
                a.cores,
                a.serve.requests,
                a.serve.batches,
                a.serve.errors,
                a.serve.total.p50_us,
                a.serve.total.p99_us,
                a.swaps_in,
                a.reconfig_s * 1e6,
            ));
        }
        s.push_str(&format!(
            "aggregate: {} requests in {} batches over {:.3}s -> \
             {:.0} req/s\n",
            self.total_requests(),
            self.total_batches(),
            self.wall_s,
            self.aggregate_rps(),
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_sum_over_apps() {
        let app = |name: &str, requests: usize| AppServeReport {
            app: name.to_string(),
            cores: 2,
            resident: true,
            offset: Some(0),
            swaps_in: 1,
            reconfig_s: 1e-6,
            serve: ServeReport {
                requests,
                batches: requests / 2,
                errors: 0,
                wall_s: 1.0,
                ..Default::default()
            },
        };
        let r = MultiServeReport {
            apps: vec![app("a", 10), app("b", 30)],
            wall_s: 2.0,
            chip_cores: 144,
            occupancy_pct: 2.8,
            swaps: 2,
            evictions: 1,
            reconfig_total_s: 2e-6,
        };
        assert_eq!(r.total_requests(), 40);
        assert_eq!(r.total_batches(), 20);
        assert_eq!(r.total_errors(), 0);
        assert_eq!(r.aggregate_rps(), 20.0);
        let flat = r.stats();
        assert_eq!(flat.apps, 2);
        assert_eq!(flat.requests, 40);
        assert_eq!(flat.wall_s, 2.0);
        let s = r.summary();
        assert!(s.contains("2 apps"), "{s}");
        assert!(s.contains("40 requests"), "{s}");
        // the empty report guards its ratios
        let empty = MultiServeReport::default();
        assert_eq!(empty.aggregate_rps(), 0.0);

        // and the report round-trips through the shared schema
        use crate::telemetry::json;
        let text = r.to_json().to_string();
        let doc = json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("kind").and_then(json::Json::as_str),
            Some("multi_serve")
        );
        let apps = doc.get("apps").expect("apps").items();
        assert_eq!(apps.len(), 2);
        assert_eq!(
            apps[1]
                .get("serve")
                .and_then(|s| s.get("requests"))
                .and_then(json::Json::as_i64),
            Some(30)
        );
    }
}
