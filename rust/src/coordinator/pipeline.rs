//! Layer-pipelined streaming execution: the second parallelism axis.
//!
//! The data-parallel pool ([`super::pool`]) replicates the whole
//! network and splits the *batch*; this module instead splits the
//! *network* — layer `l` (or a group of layers when one underfills a
//! stage) runs as a dedicated pipeline stage, and samples stream
//! through the stages like parts down an assembly line. This is the
//! execution shape of the follow-up streaming-multicore paper
//! (arXiv:1606.04609): each core group holds its layer's weights
//! resident and works on a different chunk of the sample stream at the
//! same time. `mapper::plan_pipeline` gives every stage its core group
//! on the mesh, and `sim::pipeline_cost` prices the stage-boundary
//! activations crossing the NoC.
//!
//! # Backpressure
//!
//! Stages are connected by **bounded** `sync_channel`s sized from the
//! chip's 4 kB input buffer ([`stream::buffer_capacity`] for the
//! boundary's activation width, in whole chunks) — the same sizing the
//! serving queue uses. A slow stage therefore stalls its producer
//! (blocking send) instead of buffering unboundedly, exactly like the
//! DMA backpressure on the modeled input buffer; the stall shows up as
//! [`StageReport::stall_s`].
//!
//! # Determinism contract
//!
//! Pipelined results are **bit-identical** to the sequential and
//! data-parallel paths, by construction:
//!
//! * chunk boundaries are fixed by `(n_items, tile)` — the identical
//!   tile loop the sequential `forward_range` runs, padding included —
//!   and stage boundaries by `(n_layers, stages)`
//!   ([`mapper::stage_layer_bounds`]), never by timing;
//! * inter-stage queues are FIFO with one producer and one consumer,
//!   so chunks pass every stage in input order;
//! * each stage applies the same input clip / bias append / crossbar
//!   forward ([`Backend::forward`]) the fused batched forward applies,
//!   layer by layer, and the forward math is row-independent, so a
//!   chunk's real rows never see its padding rows.
//!
//! Threads only decide *when* a stage runs a chunk, never *what* it
//! computes. `tests/pipeline_determinism.rs` pins this across every
//! registered app, worker count and stage count through
//! [`testing::ExecModeHarness`](crate::testing::ExecModeHarness).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use anyhow::{anyhow, ensure, Result};

use super::pool::ShardPlan;
use super::stream;
use crate::config::hwspec as hw;
use crate::mapper;
use crate::metrics::Stopwatch;
use crate::runtime::{clip_input, with_bias, ArrayF32, Backend, FwdMode};

/// How the engine executes a batched forward pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Replicate the network, split the batch into contiguous shards
    /// over the worker pool (the PR 2 path; the default).
    #[default]
    DataParallel,
    /// Split the network into layer stages and stream sample chunks
    /// through them over bounded queues.
    Pipelined,
    /// Both axes: one pipeline replica per worker, each streaming its
    /// contiguous shard of the batch.
    Hybrid,
}

impl std::str::FromStr for ExecMode {
    type Err = String;

    fn from_str(s: &str) -> Result<ExecMode, String> {
        match s {
            "parallel" | "data-parallel" | "dp" => Ok(ExecMode::DataParallel),
            "pipeline" | "pipelined" => Ok(ExecMode::Pipelined),
            "hybrid" => Ok(ExecMode::Hybrid),
            other => Err(format!(
                "unknown exec mode '{other}' (parallel|pipeline|hybrid)"
            )),
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecMode::DataParallel => "data-parallel",
            ExecMode::Pipelined => "pipeline",
            ExecMode::Hybrid => "hybrid",
        })
    }
}

/// Occupancy/stall accounting of one pipeline stage (summed over
/// replicas under [`ExecMode::Hybrid`]).
#[derive(Clone, Debug, Default)]
pub struct StageReport {
    /// Stage index in stream order.
    pub stage: usize,
    /// Network layer range `[lo, hi)` the stage owns.
    pub layers: (usize, usize),
    /// Chunks the stage processed.
    pub chunks: usize,
    /// Time spent computing (s).
    pub busy_s: f64,
    /// Time blocked sending into a full downstream queue (s) — the
    /// backpressure stall.
    pub stall_s: f64,
    /// Time blocked waiting for an upstream chunk (s).
    pub idle_s: f64,
}

impl StageReport {
    /// Fraction of the stage's active time spent computing (0 when the
    /// stage never ran).
    pub fn occupancy(&self) -> f64 {
        let total = self.busy_s + self.stall_s + self.idle_s;
        if total <= 0.0 {
            0.0
        } else {
            self.busy_s / total
        }
    }
}

/// Per-stage stats of the most recent pipelined forward — the
/// pipeline sibling of [`ExecReport`](super::ExecReport), surfaced
/// through [`Engine::last_pipeline_report`](super::Engine::last_pipeline_report).
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    /// Operation label, e.g. `forward_batch/mnist_class_fwd_b64`.
    pub op: String,
    /// Per-stage occupancy/stall accounting, in stream order.
    pub stages: Vec<StageReport>,
    /// Pipeline replicas that ran (1 for [`ExecMode::Pipelined`], the
    /// shard count for [`ExecMode::Hybrid`]).
    pub replicas: usize,
    /// End-to-end wall-clock of the pipelined phase (s).
    pub wall_s: f64,
    /// Samples streamed through.
    pub samples: usize,
}

impl PipelineReport {
    /// Samples per second over [`Self::wall_s`] (0 when unknown).
    pub fn throughput(&self) -> f64 {
        if self.samples == 0 || self.wall_s <= 0.0 {
            0.0
        } else {
            self.samples as f64 / self.wall_s
        }
    }

    /// Serialise under the shared report schema
    /// ([`crate::telemetry::REPORT_SCHEMA`], kind `"pipeline"`).
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|st| {
                Json::obj()
                    .with("stage", Json::Int(st.stage as i64))
                    .with("layer_lo", Json::Int(st.layers.0 as i64))
                    .with("layer_hi", Json::Int(st.layers.1 as i64))
                    .with("chunks", Json::Int(st.chunks as i64))
                    .with("busy_s", Json::Num(st.busy_s))
                    .with("stall_s", Json::Num(st.stall_s))
                    .with("idle_s", Json::Num(st.idle_s))
                    .with("occupancy", Json::Num(st.occupancy()))
            })
            .collect();
        Json::obj()
            .with(
                "schema",
                Json::Str(crate::telemetry::REPORT_SCHEMA.to_string()),
            )
            .with("kind", Json::Str("pipeline".to_string()))
            .with("op", Json::Str(self.op.clone()))
            .with("replicas", Json::Int(self.replicas as i64))
            .with("samples", Json::Int(self.samples as i64))
            .with("wall_s", Json::Num(self.wall_s))
            .with("throughput_sps", Json::Num(self.throughput()))
            .with("stages", Json::Arr(stages))
    }

    /// Multi-line human-readable summary (one line per stage).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "pipeline {}: {} stage(s) x {} replica(s), {} samples in \
             {:.3}s ({:.0} samples/s)",
            self.op,
            self.stages.len(),
            self.replicas,
            self.samples,
            self.wall_s,
            self.throughput(),
        );
        for st in &self.stages {
            s.push_str(&format!(
                "\n  stage {} (layers {}..{}): {} chunk(s), \
                 busy {:.2}ms, stall {:.2}ms, idle {:.2}ms \
                 ({:.0}% occupied)",
                st.stage,
                st.layers.0,
                st.layers.1,
                st.chunks,
                st.busy_s * 1e3,
                st.stall_s * 1e3,
                st.idle_s * 1e3,
                st.occupancy() * 100.0,
            ));
        }
        s
    }
}

/// One sample chunk travelling down the pipeline: the activations of
/// `rows` real samples (the rest of the tile is padding), plus the
/// bottleneck code once the owning stage has captured it.
struct ChunkMsg {
    rows: usize,
    h: ArrayF32,
    code: Option<ArrayF32>,
}

/// Where a stage's chunks come from: the first stage builds them from
/// the input slice, every later stage receives them from upstream.
enum StageFeed<'a> {
    Source { xs: &'a [Vec<f32>], dims: usize, tile: usize },
    Channel(Receiver<ChunkMsg>),
}

/// Busy/stall/idle accumulators of one stage run.
#[derive(Default)]
struct StageAccum {
    chunks: usize,
    busy_s: f64,
    stall_s: f64,
    idle_s: f64,
}

/// One stage's loop: acquire a chunk (build or receive), run the owned
/// layers over it, pass it on (or, at the final stage, strip padding
/// into output rows). Returns the timing accumulators plus the final
/// stage's collected rows (empty elsewhere). A failed send means the
/// downstream stage stopped (its own error will surface) — clean stop.
#[allow(clippy::too_many_arguments)]
fn run_stage(
    backend: &dyn Backend,
    params: &[ArrayF32],
    layers: (usize, usize),
    mode: FwdMode,
    code_idx: usize,
    mut feed: StageFeed<'_>,
    next: Option<SyncSender<ChunkMsg>>,
    collect: Option<usize>,
) -> Result<(StageAccum, Vec<Vec<f32>>)> {
    let mut acc = StageAccum::default();
    let mut out = Vec::new();
    let mut pos = 0usize;
    loop {
        // Acquire the next chunk. Building one from the source slice is
        // compute (busy); waiting on the upstream queue is idle.
        let mut msg = match &mut feed {
            StageFeed::Source { xs, dims, tile } => {
                if pos >= xs.len() {
                    break;
                }
                let t = Stopwatch::start();
                let chunk = &xs[pos..(pos + *tile).min(xs.len())];
                pos += chunk.len();
                // The identical tile the sequential loop builds
                // (`forward_range`): zero-padded to a full tile, input
                // clip applied once, up front.
                let mut data = Vec::with_capacity(*tile * *dims);
                for x in chunk {
                    data.extend_from_slice(x);
                }
                data.resize(*tile * *dims, 0.0);
                let x_arr = ArrayF32::matrix(*tile, *dims, data)
                    .map_err(|e| anyhow!(e))?;
                acc.busy_s += t.elapsed_s();
                ChunkMsg {
                    rows: chunk.len(),
                    h: clip_input(&x_arr),
                    code: None,
                }
            }
            StageFeed::Channel(rx) => {
                let t = Stopwatch::start();
                match rx.recv() {
                    Ok(msg) => {
                        acc.idle_s += t.elapsed_s();
                        msg
                    }
                    Err(_) => break, // upstream done (or failed)
                }
            }
        };
        // Run the owned layers — the same bias append + crossbar
        // forward the fused `forward_batch` composes.
        let t = Stopwatch::start();
        for l in layers.0..layers.1 {
            let (gp, gn) = (&params[2 * l], &params[2 * l + 1]);
            ensure!(
                gp.shape[0] == msg.h.shape[1] + 1,
                "layer {l}: crossbar has {} rows but gets {} inputs + bias",
                gp.shape[0],
                msg.h.shape[1]
            );
            let a = with_bias(&msg.h);
            let (y, _) = backend.forward(&a, gp, gn, hw::OUT_BITS)?;
            msg.h = y;
            if mode == FwdMode::ReconAndCode && l == code_idx {
                msg.code = Some(msg.h.clone());
            }
        }
        acc.busy_s += t.elapsed_s();
        acc.chunks += 1;
        match &next {
            Some(tx) => {
                let t = Stopwatch::start();
                if tx.send(msg).is_err() {
                    break;
                }
                acc.stall_s += t.elapsed_s();
            }
            None => {
                let output_idx =
                    collect.expect("final stage collects an output");
                let y = if output_idx == 0 {
                    msg.h
                } else {
                    msg.code.ok_or_else(|| {
                        anyhow!("missing output {output_idx}")
                    })?
                };
                for i in 0..msg.rows {
                    out.push(y.row_slice(i).to_vec());
                }
            }
        }
    }
    Ok((acc, out))
}

/// Stream `xs` through a `stages`-deep layer pipeline. Bit-identical
/// to the sequential tile loop (see the module docs); `tile` must be
/// the same tile the data-parallel plan uses
/// ([`apps::FWD_BATCH`](crate::config::apps::FWD_BATCH) in practice)
/// for the chunk boundaries to line up.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_pipelined(
    backend: &dyn Backend,
    op: String,
    mode: FwdMode,
    params: &[ArrayF32],
    xs: &[Vec<f32>],
    dims: usize,
    output_idx: usize,
    stages: usize,
    tile: usize,
) -> Result<(Vec<Vec<f32>>, PipelineReport)> {
    ensure!(
        !params.is_empty() && params.len() % 2 == 0,
        "crossbar params come in (gp, gn) pairs, got {}",
        params.len()
    );
    ensure!(
        output_idx == 0 || (mode == FwdMode::ReconAndCode && output_idx == 1),
        "missing output {output_idx}"
    );
    ensure!(tile > 0, "tile must be positive");
    let n_layers = params.len() / 2;
    let stages = stages.clamp(1, n_layers);
    let code_idx =
        if n_layers > 1 { n_layers / 2 - 1 } else { n_layers - 1 };
    let bounds: Vec<(usize, usize)> = (0..stages)
        .map(|s| mapper::stage_layer_bounds(n_layers, stages, s))
        .collect();
    let t0 = Stopwatch::start();
    if xs.is_empty() {
        return Ok((
            Vec::new(),
            PipelineReport { op, replicas: 1, ..PipelineReport::default() },
        ));
    }
    // Bounded inter-stage queues: the 4 kB input-buffer sizing for the
    // boundary's activation width, in whole chunks — a full queue
    // blocks the producer's send (backpressure).
    let mut feeds: Vec<StageFeed<'_>> = Vec::with_capacity(stages);
    let mut nexts: Vec<Option<SyncSender<ChunkMsg>>> =
        Vec::with_capacity(stages);
    feeds.push(StageFeed::Source { xs, dims, tile });
    for s in 0..stages - 1 {
        let boundary_width = params[2 * (bounds[s].1 - 1)].shape[1];
        let cap =
            stream::buffer_capacity(boundary_width).div_ceil(tile).max(1);
        let (tx, rx) = sync_channel(cap);
        nexts.push(Some(tx));
        feeds.push(StageFeed::Channel(rx));
    }
    nexts.push(None);
    let results: Vec<Result<(StageAccum, Vec<Vec<f32>>)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = feeds
                .into_iter()
                .zip(nexts)
                .enumerate()
                .map(|(s, (feed, tx))| {
                    let layers = bounds[s];
                    let collect =
                        (s + 1 == stages).then_some(output_idx);
                    scope.spawn(move || {
                        run_stage(
                            backend, params, layers, mode, code_idx, feed,
                            tx, collect,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pipeline stage thread panicked"))
                .collect()
        });
    let mut out = Vec::new();
    let mut stage_reports = Vec::with_capacity(stages);
    for (s, result) in results.into_iter().enumerate() {
        let (acc, rows) = result?;
        stage_reports.push(StageReport {
            stage: s,
            layers: bounds[s],
            chunks: acc.chunks,
            busy_s: acc.busy_s,
            stall_s: acc.stall_s,
            idle_s: acc.idle_s,
        });
        if s + 1 == stages {
            out = rows;
        }
    }
    ensure!(
        out.len() == xs.len(),
        "pipeline returned {} rows for {} samples",
        out.len(),
        xs.len()
    );
    Ok((
        out,
        PipelineReport {
            op,
            stages: stage_reports,
            replicas: 1,
            wall_s: t0.elapsed_s(),
            samples: xs.len(),
        },
    ))
}

/// Hybrid execution: one pipeline replica per worker, each streaming a
/// contiguous tile-aligned shard of `xs` ([`ShardPlan::contiguous`] —
/// the data-parallel shard rule, so every shard's chunks are exactly
/// the chunks the sequential loop would build over that range).
/// Replica outputs concatenate in shard order; stage timings sum
/// across replicas.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_hybrid(
    backend: &dyn Backend,
    op: String,
    mode: FwdMode,
    params: &[ArrayF32],
    xs: &[Vec<f32>],
    dims: usize,
    output_idx: usize,
    stages: usize,
    tile: usize,
    replicas: usize,
) -> Result<(Vec<Vec<f32>>, PipelineReport)> {
    let plan = ShardPlan::contiguous(xs.len(), tile, replicas.max(1));
    if plan.shards() <= 1 {
        return forward_pipelined(
            backend, op, mode, params, xs, dims, output_idx, stages, tile,
        );
    }
    let t0 = Stopwatch::start();
    let results: Vec<Result<(Vec<Vec<f32>>, PipelineReport)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = plan
                .bounds
                .iter()
                .map(|&(lo, hi)| {
                    let op = op.clone();
                    scope.spawn(move || {
                        forward_pipelined(
                            backend,
                            op,
                            mode,
                            params,
                            &xs[lo..hi],
                            dims,
                            output_idx,
                            stages,
                            tile,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pipeline replica thread panicked"))
                .collect()
        });
    let mut out = Vec::with_capacity(xs.len());
    let mut stage_reports: Vec<StageReport> = Vec::new();
    let mut replica_count = 0usize;
    for result in results {
        let (rows, report) = result?;
        out.extend(rows);
        replica_count += 1;
        for st in report.stages {
            match stage_reports.iter_mut().find(|r| r.stage == st.stage) {
                Some(total) => {
                    total.chunks += st.chunks;
                    total.busy_s += st.busy_s;
                    total.stall_s += st.stall_s;
                    total.idle_s += st.idle_s;
                }
                None => stage_reports.push(st),
            }
        }
    }
    Ok((
        out,
        PipelineReport {
            op,
            stages: stage_reports,
            replicas: replica_count,
            wall_s: t0.elapsed_s(),
            samples: xs.len(),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::apps;
    use crate::coordinator::{init_conductances, Engine};
    use crate::runtime::NativeBackend;
    use crate::testing::Rng;

    fn samples(n: usize, dims: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seeded(seed);
        (0..n).map(|_| rng.vec_uniform(dims, -0.7, 0.7)).collect()
    }

    #[test]
    fn exec_mode_parses_and_displays() {
        for (txt, mode) in [
            ("parallel", ExecMode::DataParallel),
            ("data-parallel", ExecMode::DataParallel),
            ("dp", ExecMode::DataParallel),
            ("pipeline", ExecMode::Pipelined),
            ("pipelined", ExecMode::Pipelined),
            ("hybrid", ExecMode::Hybrid),
        ] {
            assert_eq!(txt.parse::<ExecMode>().unwrap(), mode);
        }
        assert_eq!(ExecMode::default(), ExecMode::DataParallel);
        let err = "warp".parse::<ExecMode>().unwrap_err();
        assert!(err.contains("unknown exec mode 'warp'"), "{err}");
        assert_eq!(ExecMode::Pipelined.to_string(), "pipeline");
    }

    #[test]
    fn pipeline_report_round_trips_through_json() {
        use crate::telemetry::json;
        let r = PipelineReport {
            op: "forward_batch/test".to_string(),
            stages: vec![StageReport {
                stage: 0,
                layers: (0, 2),
                chunks: 3,
                busy_s: 0.06,
                stall_s: 0.02,
                idle_s: 0.02,
            }],
            replicas: 1,
            wall_s: 0.1,
            samples: 192,
        };
        let text = r.to_json().to_string();
        let doc = json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("kind").and_then(json::Json::as_str),
            Some("pipeline")
        );
        let stages = doc.get("stages").expect("stages").items();
        assert_eq!(
            stages[0].get("occupancy").and_then(json::Json::as_f64),
            Some(0.6)
        );
        assert_eq!(
            doc.get("throughput_sps").and_then(json::Json::as_f64),
            Some(1920.0)
        );
    }

    #[test]
    fn pipelined_forward_matches_the_sequential_engine() {
        // Chunked + staged streaming must reproduce the fused batched
        // forward bit for bit, at every stage depth, with a ragged
        // tail tile in play (70 = 64 + 6).
        let net = apps::network("mnist_class").unwrap();
        let params = init_conductances(net.layers, 5);
        let xs = samples(70, net.layers[0], 40);
        let engine = Engine::native();
        let want = engine.infer(net, &params, &xs).unwrap();
        let n_layers = net.layers.len() - 1;
        for stages in 1..=n_layers + 1 {
            let (got, report) = forward_pipelined(
                &NativeBackend,
                "test".to_string(),
                FwdMode::Final,
                &params,
                &xs,
                net.layers[0],
                0,
                stages,
                apps::FWD_BATCH,
            )
            .unwrap();
            assert_eq!(got, want, "stages={stages}");
            assert_eq!(report.samples, 70);
            assert_eq!(report.stages.len(), stages.min(n_layers));
            assert!(report
                .stages
                .iter()
                .all(|s| s.chunks == 2), "every stage sees every chunk");
        }
    }

    #[test]
    fn code_capture_rides_the_pipeline() {
        // The AE bottleneck is captured mid-pipeline and must travel to
        // the final stage intact, for both outputs.
        let net = apps::network("kdd_ae").unwrap();
        let params = init_conductances(net.layers, 9);
        let xs = samples(10, net.layers[0], 41);
        let engine = Engine::native();
        for output_idx in [0usize, 1] {
            let want = if output_idx == 0 {
                engine.reconstruct(net, &params, &xs).unwrap()
            } else {
                engine.encode(net, &params, &xs).unwrap()
            };
            let (got, _) = forward_pipelined(
                &NativeBackend,
                "test".to_string(),
                FwdMode::ReconAndCode,
                &params,
                &xs,
                net.layers[0],
                output_idx,
                2,
                apps::FWD_BATCH,
            )
            .unwrap();
            assert_eq!(got, want, "output {output_idx}");
        }
    }

    #[test]
    fn hybrid_replicas_concatenate_in_shard_order() {
        let net = apps::network("iris_class").unwrap();
        let params = init_conductances(net.layers, 2);
        let xs = samples(200, net.layers[0], 17);
        let engine = Engine::native();
        let want = engine.infer(net, &params, &xs).unwrap();
        let (got, report) = forward_hybrid(
            &NativeBackend,
            "test".to_string(),
            FwdMode::Final,
            &params,
            &xs,
            net.layers[0],
            0,
            2,
            apps::FWD_BATCH,
            3,
        )
        .unwrap();
        assert_eq!(got, want);
        assert_eq!(report.replicas, 3);
        assert_eq!(report.samples, 200);
    }

    #[test]
    fn degenerate_inputs_are_handled() {
        let net = apps::network("iris_ae").unwrap();
        let params = init_conductances(net.layers, 1);
        // empty stream: no rows, no stages run
        let (out, report) = forward_pipelined(
            &NativeBackend,
            "empty".to_string(),
            FwdMode::ReconAndCode,
            &params,
            &[],
            4,
            0,
            2,
            apps::FWD_BATCH,
        )
        .unwrap();
        assert!(out.is_empty());
        assert_eq!(report.samples, 0);
        // an odd parameter list cannot form (gp, gn) pairs
        let mut odd = params.clone();
        odd.pop();
        let err = forward_pipelined(
            &NativeBackend,
            "odd".to_string(),
            FwdMode::Final,
            &odd,
            &samples(3, 4, 7),
            4,
            0,
            1,
            apps::FWD_BATCH,
        )
        .unwrap_err();
        assert!(err.to_string().contains("(gp, gn) pairs"), "{err}");
        // a Final-mode pipeline has no second output to collect
        let err = forward_pipelined(
            &NativeBackend,
            "noout".to_string(),
            FwdMode::Final,
            &params,
            &samples(3, 4, 7),
            4,
            1,
            1,
            apps::FWD_BATCH,
        )
        .unwrap_err();
        assert!(err.to_string().contains("missing output 1"), "{err}");
    }
}
