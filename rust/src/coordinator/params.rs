//! Parameter (conductance-pair) initialisation and host-side encode —
//! the Rust twin of `python/compile/model.init_params`.

use crate::config::hwspec as hw;
use crate::crossbar::ideal;
use crate::runtime::ArrayF32;
use crate::testing::Rng;

/// Initialise differential conductance pairs for a layer list: both
/// conductances near the low end (the paper's "high random resistances")
/// with a small random weight in the pair difference. Layout matches the
/// train artifacts: `[gp0, gn0, gp1, gn1, ...]`, each `(n_in+1) x n_out`.
pub fn init_conductances(layers: &[usize], seed: u64) -> Vec<ArrayF32> {
    let mut rng = Rng::seeded(seed ^ 0x1217);
    let base = hw::G_MIN + 0.12;
    let mut out = Vec::new();
    for w in layers.windows(2) {
        let (n_in, n_out) = (w[0], w[1]);
        let rows = n_in + 1;
        let scale = 1.0 / (n_in as f32).sqrt();
        let mut gp = vec![0.0f32; rows * n_out];
        let mut gn = vec![0.0f32; rows * n_out];
        for i in 0..rows * n_out {
            let wv = rng.uniform_f32(-scale, scale);
            gp[i] = (base + 0.5 * wv).clamp(hw::G_MIN, hw::G_MAX);
            gn[i] = (base - 0.5 * wv).clamp(hw::G_MIN, hw::G_MAX);
        }
        out.push(ArrayF32 { shape: vec![rows, n_out], data: gp });
        out.push(ArrayF32 { shape: vec![rows, n_out], data: gn });
    }
    out
}

/// Encode one sample through a single trained crossbar layer using the
/// ideal-crossbar math (bit-compatible with the L1 kernels) — used by
/// the DR pipeline between stages.
pub fn encode_layer(x: &[f32], gp: &ArrayF32, gn: &ArrayF32) -> Vec<f32> {
    let rows = gp.shape[0];
    let n_out = gp.shape[1];
    debug_assert_eq!(rows, x.len() + 1);
    let mut a: Vec<f32> = x
        .iter()
        .map(|v| v.clamp(-hw::V_RAIL, hw::V_RAIL))
        .collect();
    a.push(hw::V_RAIL);
    let (y, _) = ideal::fwd(&a, &gp.data, &gn.data, 1, rows, n_out,
                            hw::OUT_BITS);
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_artifacts() {
        let ps = init_conductances(&[41, 15, 41], 0);
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0].shape, vec![42, 15]);
        assert_eq!(ps[1].shape, vec![42, 15]);
        assert_eq!(ps[2].shape, vec![16, 41]);
    }

    #[test]
    fn conductances_in_device_range_and_seeded() {
        let a = init_conductances(&[10, 5], 7);
        let b = init_conductances(&[10, 5], 7);
        let c = init_conductances(&[10, 5], 8);
        assert_eq!(a[0].data, b[0].data);
        assert_ne!(a[0].data, c[0].data);
        for g in &a[0].data {
            assert!((hw::G_MIN..=hw::G_MAX).contains(g));
        }
    }

    #[test]
    fn encode_layer_output_is_quantised_and_sized() {
        let ps = init_conductances(&[4, 2], 1);
        let y = encode_layer(&[0.1, -0.2, 0.3, 0.0], &ps[0], &ps[1]);
        assert_eq!(y.len(), 2);
        let levels = (1 << hw::OUT_BITS) - 1;
        for v in y {
            let code = (v + hw::V_RAIL) * levels as f32;
            assert!((code - code.round()).abs() < 1e-4);
        }
    }
}
