//! Bounded streaming front: a producer thread plays the DMA engine,
//! pushing samples into a bounded channel sized like the chip's 4 kB
//! input buffer; the consumer (the training loop) drains it. When the
//! consumer falls behind, the producer blocks — the same backpressure
//! the real DMA sees when the input buffer fills.
//!
//! [`buffer_capacity`] is the shared sizing rule: the training stream
//! here and the serving request queue ([`crate::serve`]) both bound
//! their channels to what the hardware input buffer actually holds.

use std::sync::mpsc::{sync_channel, SyncSender};
use std::thread;

use anyhow::Result;

use crate::config::SystemConfig;

/// Bounded-queue capacity in *samples* for a given sample width,
/// sized like the chip's 4 kB input buffer
/// ([`SystemConfig::input_buffer_bytes`]):
///
/// ```text
/// capacity = max(1, input_buffer_bytes / (sample_dims * size_of::<f32>()))
/// ```
///
/// Samples cross the DMA front as f32 words, so a 784-dim MNIST sample
/// occupies 3 136 bytes and barely one fits the buffer, while a 4-dim
/// Iris sample fits 256 times. (An earlier revision divided by the
/// dimension count alone, modeling a DMA queue 4× deeper than the
/// hardware buffer.) A sample wider than the whole buffer still gets
/// one slot — the DMA streams it through in fragments.
///
/// Both the training stream ([`run`]) and the serving front end
/// ([`crate::serve`]) bound their queues with this capacity.
///
/// ```
/// use restream::coordinator::stream::buffer_capacity;
/// assert_eq!(buffer_capacity(784), 1); // 4096 / (784 * 4) = 1.30…
/// assert_eq!(buffer_capacity(4), 256); // 4096 / (4 * 4)
/// ```
pub fn buffer_capacity(sample_dims: usize) -> usize {
    let sys = SystemConfig::default();
    let sample_bytes = sample_dims.max(1) * std::mem::size_of::<f32>();
    (sys.input_buffer_bytes / sample_bytes).max(1)
}

/// Stream `xs` in `order` through a bounded queue into `consume(i, x)`.
/// The producer runs on its own thread; any consumer error stops the
/// stream and is returned.
pub fn run(
    xs: &[Vec<f32>],
    order: &[usize],
    mut consume: impl FnMut(usize, &[f32]) -> Result<()>,
) -> Result<()> {
    let cap = buffer_capacity(xs.first().map_or(1, Vec::len));
    let (tx, rx): (SyncSender<(usize, Vec<f32>)>, _) = sync_channel(cap);
    // The producer owns copies (the DMA reads DRAM, not our heap).
    let items: Vec<(usize, Vec<f32>)> =
        order.iter().map(|&i| (i, xs[i].clone())).collect();
    let producer = thread::spawn(move || {
        for it in items {
            if tx.send(it).is_err() {
                break; // consumer hung up (error path)
            }
        }
    });
    let mut result = Ok(());
    for (i, x) in rx.iter() {
        if let Err(e) = consume(i, &x) {
            result = Err(e);
            break;
        }
    }
    drop(rx);
    let _ = producer.join();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_everything_in_order() {
        let xs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32]).collect();
        let order: Vec<usize> = (0..100).rev().collect();
        let mut seen = Vec::new();
        run(&xs, &order, |i, x| {
            assert_eq!(x[0] as usize, i);
            seen.push(i);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, order);
    }

    #[test]
    fn consumer_error_stops_stream() {
        let xs: Vec<Vec<f32>> = (0..1000).map(|i| vec![i as f32]).collect();
        let order: Vec<usize> = (0..1000).collect();
        let mut n = 0;
        let res = run(&xs, &order, |i, _| {
            n += 1;
            if i == 5 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(res.is_err());
        assert_eq!(n, 6);
    }

    #[test]
    fn capacity_tracks_input_buffer() {
        // 4 kB buffer of f32 words: 784 dims -> 3136 B -> 1 slot;
        // 4 dims -> 16 B -> 256 slots; degenerate 0 dims clamps to the
        // 1-word sample (1024 slots); oversized samples keep 1 slot.
        assert_eq!(buffer_capacity(784), 1);
        assert_eq!(buffer_capacity(4), 256);
        assert_eq!(buffer_capacity(0), 1024);
        assert_eq!(buffer_capacity(5000), 1);
    }

    #[test]
    fn capacity_pinned_for_registered_apps() {
        use crate::config::apps;
        // input_buffer_bytes / (dims * 4), floored, min 1 — pinned per
        // registered app so the modeled DMA depth cannot silently
        // drift from the 4 kB hardware buffer again.
        let expect = [
            ("iris_class", 256), // 4 dims
            ("iris_ae", 256),
            ("kdd_ae", 24),     // 41 dims -> 4096/164
            ("mnist_class", 1), // 784 dims -> 3136 B/sample
            ("mnist_dr", 1),
            ("isolet_class", 1), // 617 dims -> 2468 B/sample
            ("isolet_dr", 1),
        ];
        for (name, capacity) in expect {
            let net = apps::network(name).unwrap();
            assert_eq!(
                buffer_capacity(net.layers[0]),
                capacity,
                "{name} ({} dims)",
                net.layers[0]
            );
        }
        for app in apps::KMEANS_APPS {
            // 20 reduced dims -> 80 B/sample -> 51 slots
            assert_eq!(buffer_capacity(app.dims), 51, "{}", app.name);
        }
    }

    #[test]
    fn slow_consumer_still_gets_all_samples() {
        let xs: Vec<Vec<f32>> = (0..50).map(|i| vec![i as f32; 2048]).collect();
        let order: Vec<usize> = (0..50).collect();
        let mut n = 0;
        run(&xs, &order, |_, _| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 50);
    }
}
