//! Bounded streaming front: a producer thread plays the DMA engine,
//! pushing samples into a bounded channel sized like the chip's 4 kB
//! input buffer; the consumer (the training loop) drains it. When the
//! consumer falls behind, the producer blocks — the same backpressure
//! the real DMA sees when the input buffer fills.
//!
//! [`buffer_capacity`] is the shared sizing rule: the training stream
//! here and the serving request queue ([`crate::serve`]) both bound
//! their channels to what the hardware input buffer actually holds.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::thread;

use anyhow::Result;

use crate::config::SystemConfig;

/// Bounded-queue capacity in *samples* for a given sample width,
/// sized like the chip's 4 kB input buffer
/// ([`SystemConfig::input_buffer_bytes`]):
///
/// ```text
/// capacity = max(1, input_buffer_bytes / (sample_dims * size_of::<f32>()))
/// ```
///
/// Samples cross the DMA front as f32 words, so a 784-dim MNIST sample
/// occupies 3 136 bytes and barely one fits the buffer, while a 4-dim
/// Iris sample fits 256 times. (An earlier revision divided by the
/// dimension count alone, modeling a DMA queue 4× deeper than the
/// hardware buffer.) A sample wider than the whole buffer still gets
/// one slot — the DMA streams it through in fragments.
///
/// Both the training stream ([`run`]) and the serving front end
/// ([`crate::serve`]) bound their queues with this capacity.
///
/// ```
/// use restream::coordinator::stream::buffer_capacity;
/// assert_eq!(buffer_capacity(784), 1); // 4096 / (784 * 4) = 1.30…
/// assert_eq!(buffer_capacity(4), 256); // 4096 / (4 * 4)
/// ```
pub fn buffer_capacity(sample_dims: usize) -> usize {
    let sys = SystemConfig::default();
    let sample_bytes = sample_dims.max(1) * std::mem::size_of::<f32>();
    (sys.input_buffer_bytes / sample_bytes).max(1)
}

/// Observability probe for [`run_probed`]: counts the sample copies
/// alive between the producer cloning them out of the dataset and the
/// consumer finishing with them. The bounded-memory story of the
/// 4 kB-buffer stream is exactly that this stays at
/// `buffer_capacity + 2` (the queued samples, plus one in the
/// producer's hands mid-send, plus one in the consumer's hands) — never
/// the dataset size. `stream.rs`'s regression tests pin that bound.
#[derive(Debug, Default)]
pub struct StreamProbe {
    in_flight: AtomicUsize,
    peak: AtomicUsize,
}

impl StreamProbe {
    /// A fresh probe (all counters zero).
    pub fn new() -> StreamProbe {
        StreamProbe::default()
    }

    fn cloned(&self) {
        let now = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    fn consumed(&self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }

    /// Most sample copies ever alive at once during the run.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

/// Stream `xs` in `order` through a bounded queue into `consume(i, x)`.
/// The producer runs on its own thread; any consumer error stops the
/// stream and is returned.
///
/// The producer copies **lazily, one sample at a time** as it sends
/// (the DMA reads DRAM per sample; an earlier revision cloned the whole
/// epoch's worth up front, so the "bounded 4 kB buffer" memory story
/// only held for the channel, not the producer). Peak live copies are
/// bounded by the channel capacity plus two regardless of dataset size
/// — observable through [`run_probed`].
pub fn run(
    xs: &[Vec<f32>],
    order: &[usize],
    consume: impl FnMut(usize, &[f32]) -> Result<()>,
) -> Result<()> {
    run_probed(xs, order, consume, None)
}

/// [`run`] with an optional [`StreamProbe`] counting live sample
/// copies — the regression hook for the bounded-memory contract.
pub fn run_probed(
    xs: &[Vec<f32>],
    order: &[usize],
    mut consume: impl FnMut(usize, &[f32]) -> Result<()>,
    probe: Option<&StreamProbe>,
) -> Result<()> {
    let cap = buffer_capacity(xs.first().map_or(1, Vec::len));
    let (tx, rx) = sync_channel::<(usize, Vec<f32>)>(cap);
    thread::scope(|scope| {
        let producer = scope.spawn(move || {
            for &i in order {
                // One copy per sample; the bounded send blocks while
                // the channel is full (the DMA's input-buffer
                // backpressure), so at most one copy waits here.
                let x = xs[i].clone();
                if let Some(p) = probe {
                    p.cloned();
                }
                if tx.send((i, x)).is_err() {
                    break; // consumer hung up (error path)
                }
            }
        });
        let mut result = Ok(());
        for (i, x) in rx.iter() {
            let consumed = consume(i, &x);
            drop(x);
            if let Some(p) = probe {
                p.consumed();
            }
            if let Err(e) = consumed {
                result = Err(e);
                break;
            }
        }
        drop(rx);
        let _ = producer.join();
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_everything_in_order() {
        let xs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32]).collect();
        let order: Vec<usize> = (0..100).rev().collect();
        let mut seen = Vec::new();
        run(&xs, &order, |i, x| {
            assert_eq!(x[0] as usize, i);
            seen.push(i);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, order);
    }

    #[test]
    fn consumer_error_stops_stream() {
        let xs: Vec<Vec<f32>> = (0..1000).map(|i| vec![i as f32]).collect();
        let order: Vec<usize> = (0..1000).collect();
        let mut n = 0;
        let res = run(&xs, &order, |i, _| {
            n += 1;
            if i == 5 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(res.is_err());
        assert_eq!(n, 6);
    }

    #[test]
    fn capacity_tracks_input_buffer() {
        // 4 kB buffer of f32 words: 784 dims -> 3136 B -> 1 slot;
        // 4 dims -> 16 B -> 256 slots; degenerate 0 dims clamps to the
        // 1-word sample (1024 slots); oversized samples keep 1 slot.
        assert_eq!(buffer_capacity(784), 1);
        assert_eq!(buffer_capacity(4), 256);
        assert_eq!(buffer_capacity(0), 1024);
        assert_eq!(buffer_capacity(5000), 1);
    }

    #[test]
    fn capacity_pinned_for_registered_apps() {
        use crate::config::apps;
        // input_buffer_bytes / (dims * 4), floored, min 1 — pinned per
        // registered app so the modeled DMA depth cannot silently
        // drift from the 4 kB hardware buffer again.
        let expect = [
            ("iris_class", 256), // 4 dims
            ("iris_ae", 256),
            ("kdd_ae", 24),     // 41 dims -> 4096/164
            ("mnist_class", 1), // 784 dims -> 3136 B/sample
            ("mnist_dr", 1),
            ("isolet_class", 1), // 617 dims -> 2468 B/sample
            ("isolet_dr", 1),
        ];
        for (name, capacity) in expect {
            let net = apps::network(name).unwrap();
            assert_eq!(
                buffer_capacity(net.layers[0]),
                capacity,
                "{name} ({} dims)",
                net.layers[0]
            );
        }
        for app in apps::KMEANS_APPS {
            // 20 reduced dims -> 80 B/sample -> 51 slots
            assert_eq!(buffer_capacity(app.dims), 51, "{}", app.name);
        }
    }

    #[test]
    fn producer_copies_stay_bounded_by_the_buffer() {
        // 2048-dim samples -> 8 kB each -> a 1-slot channel. Cloning
        // the whole epoch up front (the pre-fix behaviour) would put
        // all 50 copies in flight at once; the lazy producer keeps at
        // most capacity + 2 alive (queued + one mid-send + one being
        // consumed), independent of dataset size.
        let xs: Vec<Vec<f32>> =
            (0..50).map(|i| vec![i as f32; 2048]).collect();
        let order: Vec<usize> = (0..50).collect();
        let cap = buffer_capacity(2048);
        assert_eq!(cap, 1);
        let probe = StreamProbe::new();
        let mut n = 0;
        run_probed(
            &xs,
            &order,
            |i, x| {
                assert_eq!(x[0] as usize, i);
                n += 1;
                Ok(())
            },
            Some(&probe),
        )
        .unwrap();
        assert_eq!(n, 50);
        assert!(probe.peak() >= 1);
        assert!(
            probe.peak() <= cap + 2,
            "peak {} live copies > bound {}",
            probe.peak(),
            cap + 2
        );
    }

    #[test]
    fn zero_dim_samples_clamp_to_one_word() {
        // Degenerate 0-dim samples price as one f32 word: the 4 kB
        // buffer holds 1024 of them, and the stream still delivers.
        assert_eq!(buffer_capacity(0), 1024);
        let xs: Vec<Vec<f32>> = vec![Vec::new(); 5];
        let order: Vec<usize> = (0..5).collect();
        let mut n = 0;
        run(&xs, &order, |_, x| {
            assert!(x.is_empty());
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 5);
    }

    #[test]
    fn slow_consumer_still_gets_all_samples() {
        let xs: Vec<Vec<f32>> = (0..50).map(|i| vec![i as f32; 2048]).collect();
        let order: Vec<usize> = (0..50).collect();
        let mut n = 0;
        run(&xs, &order, |_, _| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 50);
    }
}
