//! Bounded streaming front: a producer thread plays the DMA engine,
//! pushing samples into a bounded channel sized like the chip's 4 kB
//! input buffer; the consumer (the training loop) drains it. When the
//! consumer falls behind, the producer blocks — the same backpressure
//! the real DMA sees when the input buffer fills.

use std::sync::mpsc::{sync_channel, SyncSender};
use std::thread;

use anyhow::Result;

use crate::config::SystemConfig;

/// Channel capacity for a given sample width, matching the input buffer.
pub fn buffer_capacity(sample_dims: usize) -> usize {
    let sys = SystemConfig::default();
    (sys.input_buffer_bytes / sample_dims.max(1)).max(1)
}

/// Stream `xs` in `order` through a bounded queue into `consume(i, x)`.
/// The producer runs on its own thread; any consumer error stops the
/// stream and is returned.
pub fn run(
    xs: &[Vec<f32>],
    order: &[usize],
    mut consume: impl FnMut(usize, &[f32]) -> Result<()>,
) -> Result<()> {
    let cap = buffer_capacity(xs.first().map_or(1, Vec::len));
    let (tx, rx): (SyncSender<(usize, Vec<f32>)>, _) = sync_channel(cap);
    // The producer owns copies (the DMA reads DRAM, not our heap).
    let items: Vec<(usize, Vec<f32>)> =
        order.iter().map(|&i| (i, xs[i].clone())).collect();
    let producer = thread::spawn(move || {
        for it in items {
            if tx.send(it).is_err() {
                break; // consumer hung up (error path)
            }
        }
    });
    let mut result = Ok(());
    for (i, x) in rx.iter() {
        if let Err(e) = consume(i, &x) {
            result = Err(e);
            break;
        }
    }
    drop(rx);
    let _ = producer.join();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivers_everything_in_order() {
        let xs: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32]).collect();
        let order: Vec<usize> = (0..100).rev().collect();
        let mut seen = Vec::new();
        run(&xs, &order, |i, x| {
            assert_eq!(x[0] as usize, i);
            seen.push(i);
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, order);
    }

    #[test]
    fn consumer_error_stops_stream() {
        let xs: Vec<Vec<f32>> = (0..1000).map(|i| vec![i as f32]).collect();
        let order: Vec<usize> = (0..1000).collect();
        let mut n = 0;
        let res = run(&xs, &order, |i, _| {
            n += 1;
            if i == 5 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(res.is_err());
        assert_eq!(n, 6);
    }

    #[test]
    fn capacity_tracks_input_buffer() {
        // 4 kB buffer, 784-float samples -> 5 slots; 4-float -> 1024.
        assert_eq!(buffer_capacity(784), 5);
        assert_eq!(buffer_capacity(4), 1024);
        assert_eq!(buffer_capacity(0), 4096);
    }

    #[test]
    fn slow_consumer_still_gets_all_samples() {
        let xs: Vec<Vec<f32>> = (0..50).map(|i| vec![i as f32; 2048]).collect();
        let order: Vec<usize> = (0..50).collect();
        let mut n = 0;
        run(&xs, &order, |_, _| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 50);
    }
}
