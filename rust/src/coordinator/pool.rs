//! Sharded execution: a fixed worker pool that is the software twin of
//! the chip's core mesh.
//!
//! The paper's architecture is *multicore* — a mapped network occupies
//! many mesh cores at once and samples stream through them in parallel.
//! This module gives the simulator the same execution shape: the
//! [`Engine`](super::Engine)'s batched operations (`infer`, `kmeans`,
//! `anomaly_scores`) and the mini-batch training gradient phase
//! (`train_with`, `Backend::grad_batch` per shard) split their input
//! batches into contiguous, tile-aligned shards ([`ShardPlan`]) and run
//! the shards on a fixed pool of `std::thread` workers
//! ([`WorkerPool`]).
//!
//! # Determinism contract
//!
//! Parallel results are **bit-identical** to the sequential path at any
//! worker count, guaranteed by construction:
//!
//! * shard boundaries are **fixed** by `(n_items, tile, shard count)` —
//!   never by the worker count — and always tile-aligned, so every
//!   shard performs exactly the backend calls the sequential loop
//!   would (same tiles, same padding);
//! * each shard returns its *partial* results (per-tile outputs and
//!   accumulator registers) and the caller folds them **left-to-right
//!   in shard order** on one thread, reproducing the sequential
//!   floating-point reduction order exactly.
//!
//! Workers therefore only decide *when* a shard runs, never *what* it
//! computes or in which order partials combine.
//!
//! The default shard count of a batched forward comes from the
//! `mapper`'s core placement ([`crate::mapper::shard_hint`]): an app
//! that occupies N mesh cores is sharded N ways, so the pool
//! parallelises the way the chip does. K-means epochs and the training
//! gradient phase shard one tile per job instead (the clustering
//! core's batch-sized streaming passes; `apps::GRAD_TILE` samples per
//! gradient shard). The pool size comes from `--workers N` on the CLI
//! or the `RESTREAM_WORKERS` environment variable
//! ([`default_workers`]).
//!
//! Jobs must not submit nested jobs to the same pool (the workers a
//! nested submission would need may all be blocked on it); the engine's
//! operations never do.
//!
//! # Worker-failure recovery
//!
//! A worker thread that dies mid-operation (simulated by
//! [`WorkerPool::inject_failure`], available under
//! `cfg(any(test, feature = "faultinject"))`) takes its current shard
//! down with it. The pool detects the death through the ack channel —
//! every submitted job sends exactly one ack, `Done` after computing or
//! `Died(shard)` when the failure fires — and **resubmits the dead
//! worker's shard** to the surviving workers. The recovered shard runs
//! the identical closure over the identical bounds, and the caller's
//! left-to-right fold consumes slots in shard order regardless of which
//! worker filled them, so a run with a killed worker produces results
//! **bit-identical** to an undisturbed run (`tests/fault_recovery.rs`
//! pins this through full training runs). Reassigned shard indices are
//! reported through [`WorkerPool::recovered_last_run`] and flow into
//! [`ExecReport::recovered_shards`]. Recovery needs a surviving worker,
//! so fault injection requires a pool of at least two threads.
//!
//! The serving layer ([`crate::serve`]) sits directly on these sharded
//! operations: every micro-batch it coalesces dispatches through
//! [`Engine::infer`](super::Engine::infer), so serving inherits this
//! determinism contract wholesale — which is what makes a request's
//! result independent of the batch it lands in.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Worker-pool size from `$RESTREAM_WORKERS` (default: 1, sequential).
/// Unparseable or zero values fall back to 1.
pub fn default_workers() -> usize {
    // lint: allow(D2) — $RESTREAM_WORKERS is an explicit config knob
    // read only by this entry-point helper (library construction via
    // `Engine::new` never reads the environment); the worker count it
    // picks cannot change results — bit-identity at any pool size is
    // the PR 2 contract, pinned by tests/parallel_determinism.rs.
    std::env::var("RESTREAM_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map_or(1, |n| n.max(1))
}

/// Fixed, tile-aligned split of a batch into contiguous shards.
///
/// Boundaries depend only on `(n_items, tile, shards)` — see the
/// module-level determinism contract.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Tile (backend batch) size the shards are aligned to.
    pub tile: usize,
    /// Item-index range `[lo, hi)` of each shard, ascending and
    /// contiguous; every `lo` is a tile multiple.
    pub bounds: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Split `n_items` into at most `shards` contiguous shards of whole
    /// `tile`-sized groups (the last tile may be short). Tiles are
    /// distributed as evenly as possible, earlier shards taking the
    /// remainder — the same segmentation rule the mapper uses for row
    /// splits.
    pub fn contiguous(n_items: usize, tile: usize, shards: usize) -> ShardPlan {
        assert!(tile > 0, "tile must be positive");
        let tiles = n_items.div_ceil(tile);
        if tiles == 0 {
            return ShardPlan { tile, bounds: Vec::new() };
        }
        let shards = shards.clamp(1, tiles);
        let base = tiles / shards;
        let extra = tiles % shards;
        let mut bounds = Vec::with_capacity(shards);
        let mut tile_lo = 0usize;
        for s in 0..shards {
            let tile_hi = tile_lo + base + usize::from(s < extra);
            let lo = tile_lo * tile;
            let hi = (tile_hi * tile).min(n_items);
            bounds.push((lo, hi));
            tile_lo = tile_hi;
        }
        ShardPlan { tile, bounds }
    }

    /// Number of shards in the plan.
    pub fn shards(&self) -> usize {
        self.bounds.len()
    }
}

/// Wall-clock of one shard of a sharded operation.
#[derive(Clone, Debug)]
pub struct ShardTiming {
    /// Shard index (= reduction position).
    pub shard: usize,
    /// Item-index range `[lo, hi)` the shard covered.
    pub range: (usize, usize),
    /// Time the shard spent executing on its worker (s).
    pub wall_s: f64,
}

/// Per-shard execution stats of the most recent sharded operation —
/// the data-parallel sibling of [`TrainReport`](super::TrainReport),
/// surfaced through [`Engine::last_parallel_report`](super::Engine::last_parallel_report).
#[derive(Clone, Debug, Default)]
pub struct ExecReport {
    /// Operation label, e.g. `forward_batch/mnist_class_fwd_b64`.
    pub op: String,
    /// Worker-pool size the operation ran with.
    pub workers: usize,
    /// End-to-end wall-clock of the sharded phase (s).
    pub wall_s: f64,
    /// Per-shard timings, in shard (= reduction) order.
    pub shards: Vec<ShardTiming>,
    /// Shards that were reassigned to surviving workers after a worker
    /// death this run (empty in healthy operation).
    pub recovered_shards: Vec<usize>,
}

impl ExecReport {
    /// Sum of per-shard busy time (s) — compare with `wall_s` to read
    /// the effective parallelism.
    pub fn busy_s(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.wall_s)
            .fold(0.0f64, |acc, w| acc + w)
    }

    /// Serialise under the shared report schema
    /// ([`crate::telemetry::REPORT_SCHEMA`], kind `"exec"`).
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                Json::obj()
                    .with("shard", Json::Int(s.shard as i64))
                    .with("lo", Json::Int(s.range.0 as i64))
                    .with("hi", Json::Int(s.range.1 as i64))
                    .with("wall_s", Json::Num(s.wall_s))
            })
            .collect();
        Json::obj()
            .with(
                "schema",
                Json::Str(crate::telemetry::REPORT_SCHEMA.to_string()),
            )
            .with("kind", Json::Str("exec".to_string()))
            .with("op", Json::Str(self.op.clone()))
            .with("workers", Json::Int(self.workers as i64))
            .with("wall_s", Json::Num(self.wall_s))
            .with("busy_s", Json::Num(self.busy_s()))
            .with(
                "recovered_shards",
                Json::Arr(
                    self.recovered_shards
                        .iter()
                        .map(|&s| Json::Int(s as i64))
                        .collect(),
                ),
            )
            .with("shards", Json::Arr(shards))
    }
}

/// What a job tells its worker thread after running: keep serving the
/// queue, or exit (the thread *is* the simulated hardware failure).
enum JobOutcome {
    Continue,
    Exit,
}

/// One ack per submitted job back to the coordinator thread.
enum Ack {
    /// The job computed and stored its result.
    Done,
    /// The worker died before computing shard `.0`; the coordinator
    /// must reassign it.
    Died(usize),
}

type Job = Box<dyn FnOnce() -> JobOutcome + Send + 'static>;

/// A fixed pool of worker threads executing indexed jobs.
///
/// `WorkerPool::new(1)` spawns no threads: jobs run inline on the
/// caller, which *is* the sequential path (and what the 1-worker bench
/// configuration measures). Larger pools keep their threads parked on
/// a shared queue between operations.
pub struct WorkerPool {
    workers: usize,
    /// Job queue into the workers; `None` for the inline (1-worker)
    /// pool. The mutex makes the pool `Sync` without relying on
    /// `mpsc::Sender`'s `Sync`-ness (stabilised later than our MSRV).
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    handles: Vec<thread::JoinHandle<()>>,
    /// One-shot fault plan: the shard index whose worker the next run
    /// kills ([`WorkerPool::inject_failure`]). Armed only by the fault
    /// hook; always `None` in production.
    fault: Mutex<Option<usize>>,
    /// Shard indices reassigned during the most recent run.
    recovered: Mutex<Vec<usize>>,
    /// Worker threads that have exited on a simulated failure.
    lost: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Build a pool of `workers` threads (0 is treated as 1; 1 means
    /// inline execution, no threads).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let lost = Arc::new(AtomicUsize::new(0));
        if workers == 1 {
            return WorkerPool {
                workers: 1,
                tx: None,
                handles: Vec::new(),
                fault: Mutex::new(None),
                recovered: Mutex::new(Vec::new()),
                lost,
            };
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&rx);
            let lost = Arc::clone(&lost);
            let handle = thread::Builder::new()
                .name(format!("restream-shard-{w}"))
                .spawn(move || loop {
                    // Hold the receiver lock while blocked on recv:
                    // idle workers queue on the mutex, and the channel
                    // closing (pool drop) ends the loop.
                    let job =
                        rx.lock().unwrap_or_else(|e| e.into_inner()).recv();
                    match job {
                        Ok(job) => {
                            if let JobOutcome::Exit = job() {
                                // simulated hardware failure: this
                                // worker leaves the pool for good
                                lost.fetch_add(1, Ordering::SeqCst);
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                })
                .expect("spawning pool worker thread");
            handles.push(handle);
        }
        WorkerPool {
            workers,
            tx: Some(Mutex::new(tx)),
            handles,
            fault: Mutex::new(None),
            recovered: Mutex::new(Vec::new()),
            lost,
        }
    }

    /// Pool size (1 = inline sequential execution).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Arm the one-shot fault plan: during the **next** [`WorkerPool::run`],
    /// the worker that picks up shard `shard` dies before computing it
    /// (its thread exits — the software analogue of a mesh core going
    /// dark), and the pool must recover by reassigning the shard.
    /// Requires a threaded pool (≥ 2 workers): recovery needs a
    /// survivor. A `shard` beyond the next run's job count disarms
    /// harmlessly.
    #[cfg(any(test, feature = "faultinject"))]
    pub fn inject_failure(&self, shard: usize) {
        assert!(
            self.workers >= 2,
            "inject_failure needs a threaded pool (>= 2 workers): a \
             1-worker pool runs shards inline on the caller, and a dead \
             sole worker has no survivor to recover on"
        );
        *self.fault.lock().unwrap_or_else(|e| e.into_inner()) = Some(shard);
    }

    /// Shard indices that were reassigned to surviving workers during
    /// the most recent [`WorkerPool::run`] (empty in healthy operation).
    pub fn recovered_last_run(&self) -> Vec<usize> {
        self.recovered
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Number of worker threads that have died on a simulated failure
    /// over the pool's lifetime.
    pub fn lost_workers(&self) -> usize {
        self.lost.load(Ordering::SeqCst)
    }

    /// Run `jobs` indexed jobs, returning their results **in job
    /// order** (job order, not completion order, so callers' fold is
    /// deterministic). Blocks until every job has finished; if any job
    /// panicked, panics after all of them are done.
    ///
    /// If the one-shot fault plan is armed
    /// ([`WorkerPool::inject_failure`]), the victim shard's worker dies
    /// before computing and the shard is resubmitted to the survivors —
    /// its slot is filled by the reassigned execution, so the returned
    /// vector (and any fold over it) is indistinguishable from a
    /// healthy run.
    pub fn run<T, F>(&self, jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.recovered
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clear();
        if jobs == 0 {
            return Vec::new();
        }
        // Take the fault plan exactly once per run: a resubmitted shard
        // must not be re-killed, or recovery could never terminate.
        let armed = self
            .fault
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .filter(|&s| s < jobs);
        let Some(tx) = &self.tx else {
            return (0..jobs).map(&f).collect();
        };
        if jobs == 1 && armed.is_none() {
            return vec![f(0)];
        }
        let slots: Vec<Mutex<Option<T>>> =
            (0..jobs).map(|_| Mutex::new(None)).collect();
        let panicked = AtomicBool::new(false);
        let (done_tx, done_rx) = mpsc::channel::<Ack>();
        let run_one = |i: usize| {
            match panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                Ok(v) => {
                    *slots[i].lock().unwrap_or_else(|e| e.into_inner()) =
                        Some(v);
                }
                Err(_) => panicked.store(true, Ordering::SeqCst),
            }
        };
        let run_ref: &(dyn Fn(usize) + Sync) = &run_one;
        // SAFETY: the transmute erases only the *lifetime* of the
        // `&(dyn Fn(usize) + Sync)` reference — pointee type, `Sync`
        // bound, and vtable are unchanged — so the sole obligation is
        // that no worker thread can still hold the reference once this
        // stack frame (which owns `run_one`, `f`, and the locals they
        // borrow) is left. That holds because the frame cannot be left
        // before every submitted job has executed: every job —
        // including reassigned ones — sends exactly one ack on
        // `done_tx` (`Done` after running its catch_unwind-wrapped
        // payload, `Died` without running it), and the loop below
        // blocks until it has collected `jobs` `Done` acks,
        // resubmitting on every `Died`. After the last ack, no queued
        // job referencing `run_static` remains. (Lint rule C2 pins
        // this annotation to the unsafe block.)
        let run_static = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(run_ref)
        };
        {
            let tx = tx.lock().unwrap_or_else(|e| e.into_inner());
            for i in 0..jobs {
                let done = done_tx.clone();
                let kill = armed == Some(i);
                let job: Job = Box::new(move || {
                    if kill {
                        // die *before* computing: the shard result is
                        // lost with the worker, exactly as a real crash
                        // would lose it
                        let _ = done.send(Ack::Died(i));
                        return JobOutcome::Exit;
                    }
                    run_static(i);
                    let _ = done.send(Ack::Done);
                    JobOutcome::Continue
                });
                tx.send(job).expect("worker pool hung up");
            }
        }
        let mut finished = 0usize;
        let mut recovered: Vec<usize> = Vec::new();
        while finished < jobs {
            match done_rx.recv().expect("a worker dropped a job") {
                Ack::Done => finished += 1,
                Ack::Died(i) => {
                    // reassign the dead worker's shard to the survivors
                    recovered.push(i);
                    let done = done_tx.clone();
                    let job: Job = Box::new(move || {
                        run_static(i);
                        let _ = done.send(Ack::Done);
                        JobOutcome::Continue
                    });
                    tx.lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .send(job)
                        .expect("worker pool hung up");
                }
            }
        }
        *self.recovered.lock().unwrap_or_else(|e| e.into_inner()) =
            recovered;
        if panicked.load(Ordering::SeqCst) {
            panic!("a worker shard panicked (original panic on stderr)");
        }
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|e| e.into_inner())
                    .expect("missing shard result")
            })
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Close the queue so parked workers see a channel error and
        // exit, then reap them.
        self.tx.take();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn shard_plan_is_tile_aligned_and_covers() {
        forall("shard_plan_cover", 120, |rng| {
            let n = rng.range(0, 1500);
            let tile = rng.range(1, 90);
            let shards = rng.range(1, 12);
            let plan = ShardPlan::contiguous(n, tile, shards);
            if n == 0 {
                if plan.shards() != 0 {
                    return Err("empty input must have no shards".into());
                }
                return Ok(());
            }
            if plan.shards() > shards {
                return Err(format!(
                    "{} shards > requested {shards}",
                    plan.shards()
                ));
            }
            let mut expect_lo = 0usize;
            for &(lo, hi) in &plan.bounds {
                if lo != expect_lo {
                    return Err(format!("gap: {lo} != {expect_lo}"));
                }
                if lo % tile != 0 {
                    return Err(format!("{lo} not aligned to tile {tile}"));
                }
                if hi <= lo {
                    return Err(format!("empty shard [{lo}, {hi})"));
                }
                expect_lo = hi;
            }
            if expect_lo != n {
                return Err(format!("coverage ends at {expect_lo} != {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn exec_report_round_trips_through_json() {
        use crate::telemetry::json;
        let r = ExecReport {
            op: "forward_batch/test".to_string(),
            workers: 4,
            wall_s: 0.25,
            shards: vec![
                ShardTiming { shard: 0, range: (0, 64), wall_s: 0.1 },
                ShardTiming { shard: 1, range: (64, 128), wall_s: 0.2 },
            ],
            recovered_shards: vec![1],
        };
        let text = r.to_json().to_string();
        let doc = json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("kind").and_then(json::Json::as_str),
            Some("exec")
        );
        assert_eq!(
            doc.get("busy_s").and_then(json::Json::as_f64),
            Some(r.busy_s())
        );
        let shards = doc.get("shards").expect("shards").items();
        assert_eq!(shards.len(), 2);
        assert_eq!(
            shards[1].get("hi").and_then(json::Json::as_i64),
            Some(128)
        );
    }

    #[test]
    fn shard_plan_of_zero_items_is_empty() {
        // The multi-tenant scheduler leans on this edge: an app whose
        // queue drains to nothing must plan zero shards (no worker-pool
        // jobs), at any tile/shard parameterisation.
        for (tile, shards) in [(1, 1), (64, 4), (8, 144)] {
            let plan = ShardPlan::contiguous(0, tile, shards);
            assert_eq!(plan.shards(), 0, "tile {tile}, shards {shards}");
            assert!(plan.bounds.is_empty());
            assert_eq!(plan.tile, tile);
        }
    }

    #[test]
    fn shard_plan_matches_hand_example() {
        // 130 items in 64-item tiles = 3 tiles; 5 requested shards clamp
        // to 3, one tile each.
        let plan = ShardPlan::contiguous(130, 64, 5);
        assert_eq!(plan.bounds, vec![(0, 64), (64, 128), (128, 130)]);
        // 2 shards over 3 tiles: the first takes the extra tile.
        let plan = ShardPlan::contiguous(130, 64, 2);
        assert_eq!(plan.bounds, vec![(0, 128), (128, 130)]);
    }

    #[test]
    fn shard_plan_ignores_worker_count_by_construction() {
        // The plan type has no worker parameter at all; pin the fact
        // that two identically-parameterised plans agree so a future
        // refactor cannot quietly couple boundaries to the pool.
        let a = ShardPlan::contiguous(1000, 64, 7);
        let b = ShardPlan::contiguous(1000, 64, 7);
        assert_eq!(a.bounds, b.bounds);
    }

    #[test]
    fn pool_results_are_in_job_order() {
        for workers in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(workers);
            let out = pool.run(37, |i| i * i);
            let expect: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(out, expect, "at {workers} workers");
            // pools are reusable across operations
            let out = pool.run(3, |i| i + 1);
            assert_eq!(out, vec![1, 2, 3]);
        }
    }

    #[test]
    fn pool_runs_jobs_concurrently() {
        // Two jobs rendezvous on a barrier: completion is only possible
        // if they run on two workers at once.
        let pool = WorkerPool::new(2);
        let barrier = std::sync::Barrier::new(2);
        let out = pool.run(2, |i| {
            barrier.wait();
            i
        });
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn zero_jobs_and_zero_workers_are_safe() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let out: Vec<usize> = pool.run(0, |i| i);
        assert!(out.is_empty());
        let out: Vec<usize> = WorkerPool::new(3).run(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "worker shard panicked")]
    fn pool_propagates_job_panics() {
        let pool = WorkerPool::new(3);
        pool.run(5, |i| {
            if i == 3 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn injected_failure_recovers_with_identical_results() {
        for workers in [2usize, 4] {
            let pool = WorkerPool::new(workers);
            let healthy = pool.run(9, |i| i * 10);
            assert!(pool.recovered_last_run().is_empty());
            pool.inject_failure(3);
            let recovered = pool.run(9, |i| i * 10);
            assert_eq!(
                recovered, healthy,
                "at {workers} workers: results must not depend on the \
                 failure"
            );
            assert_eq!(pool.recovered_last_run(), vec![3]);
            assert_eq!(pool.lost_workers(), 1);
            // the plan is one-shot: the next run is healthy again,
            // and the report resets
            let again = pool.run(9, |i| i * 10);
            assert_eq!(again, healthy);
            assert!(pool.recovered_last_run().is_empty());
            assert_eq!(pool.lost_workers(), 1);
        }
    }

    #[test]
    fn failure_on_a_single_job_run_still_recovers() {
        // jobs == 1 normally takes the inline shortcut; an armed fault
        // must route through the pool so the death/reassignment cycle
        // actually executes.
        let pool = WorkerPool::new(2);
        pool.inject_failure(0);
        assert_eq!(pool.run(1, |i| i + 41), vec![41]);
        assert_eq!(pool.recovered_last_run(), vec![0]);
    }

    #[test]
    fn out_of_range_fault_plan_disarms() {
        let pool = WorkerPool::new(2);
        pool.inject_failure(99);
        assert_eq!(pool.run(4, |i| i), vec![0, 1, 2, 3]);
        assert!(pool.recovered_last_run().is_empty());
        assert_eq!(pool.lost_workers(), 0);
        // and the stale plan does not linger into later runs
        assert_eq!(pool.run(200, |i| i).len(), 200);
        assert!(pool.recovered_last_run().is_empty());
    }

    #[test]
    #[should_panic(expected = "threaded pool")]
    fn inject_failure_rejects_inline_pools() {
        WorkerPool::new(1).inject_failure(0);
    }

    #[test]
    fn default_workers_parses_env() {
        crate::testing::with_env(
            &[("RESTREAM_WORKERS", Some("6"))],
            || assert_eq!(default_workers(), 6),
        );
        crate::testing::with_env(
            &[("RESTREAM_WORKERS", Some("0"))],
            || assert_eq!(default_workers(), 1),
        );
        crate::testing::with_env(
            &[("RESTREAM_WORKERS", Some("a lot"))],
            || assert_eq!(default_workers(), 1),
        );
        crate::testing::with_env(&[("RESTREAM_WORKERS", None)], || {
            assert_eq!(default_workers(), 1)
        });
    }
}
