//! Streaming training/inference coordinator — the chip's steady-state
//! control loop, in Rust, with Python nowhere on the path.
//!
//! The [`Engine`] owns a pluggable [`Backend`] and drives the
//! per-sample stochastic-BP loop (training), the batched recognition
//! loop, the layerwise DR pipeline, the clustering epochs and the
//! anomaly scorer. Samples arrive through the bounded double-buffered
//! stream of [`crate::coordinator::stream`] — the software twin of the
//! DMA + 4 kB input buffer front (backpressure included).
//!
//! The backend is chosen at construction: [`Engine::native`] composes
//! the reference kernels in-process (the default — no artifacts
//! needed), while the `pjrt` cargo feature adds the artifact-executing
//! PJRT backend ([`Engine::named`]`("pjrt")`). Both implement the same
//! per-sample semantics, so loss curves and trained conductances are
//! interchangeable.
//!
//! Hot-loop design: a PJRT execution round-trips every conductance
//! matrix through host literals, so the coordinator prefers the
//! chunked train operation (`Backend::train_chunk`, the
//! `..._trainchunk_cK` artifacts) which scans K samples of stochastic
//! BP per call, amortising that crossing K-fold; the native backend
//! keeps the same chunked loop to batch its per-step dispatch — the
//! software analogue of the paper's "processing happens at the physical
//! location of the data" (see EXPERIMENTS.md section Perf).
//!
//! Batched operations (`infer`/`encode`/`reconstruct`, `kmeans`,
//! `anomaly_scores`) execute data-parallel across the engine's
//! [`WorkerPool`], sharded the way the `mapper` spreads the app over
//! the chip's core mesh; results are bit-identical to the sequential
//! path at any worker count (see [`pool`] for the determinism
//! contract).
//!
//! Training parallelises by *mini-batch* ([`Engine::train_with`],
//! `batch > 1`): each mini-batch splits into fixed
//! [`apps::GRAD_TILE`]-aligned shards whose gradient sums
//! ([`Backend::grad_batch`]) compute concurrently on the pool, reduce
//! left-to-right on one thread, and fire a single weight update
//! ([`Backend::apply_grads`]) — so trained conductances and loss
//! curves are bit-identical at any worker count for a fixed batch
//! size. `batch == 1` takes the untouched per-sample stochastic-BP
//! path (the paper's section III.E semantics, a serial dependence
//! chain by definition), which [`Engine::train`] always uses.
//!
//! Callers holding *independent single-sample requests* rather than
//! pre-formed batches go through the serving front end
//! ([`crate::serve`]), which micro-batches them into tile-aligned
//! [`Engine::infer`] calls over the same pool.

pub mod params;
pub mod pipeline;
pub mod pool;
pub mod stream;

pub use params::init_conductances;
pub use pipeline::{ExecMode, PipelineReport, StageReport};
pub use pool::{
    default_workers, ExecReport, ShardPlan, ShardTiming, WorkerPool,
};

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::checkpoint::{self, CheckpointError, TrainState};
use crate::config::{apps, AppKind, Network, SystemConfig};
use crate::mapper;
use crate::metrics::Stopwatch;
use crate::runtime::{ArrayF32, Backend, FwdMode, KmeansStep, NativeBackend};
use crate::testing::Rng;

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Mean per-sample loss per epoch.
    pub loss_curve: Vec<f32>,
    pub epochs: usize,
    pub samples_seen: usize,
    /// Host wall-clock of the run (for the perf harness, not the chip
    /// timing model — that is `crate::sim`).
    pub wall_s: f64,
    /// Mini-batch size the run used (1 = the paper's per-sample
    /// stochastic BP; [`Engine::train`] always reports 1).
    pub batch: usize,
    /// Worker-pool size the gradient phase sharded over.
    pub workers: usize,
    /// Wall-clock of the sharded gradient phase summed over every
    /// mini-batch (s; 0 on the sequential path).
    pub grad_wall_s: f64,
    /// Wall-clock of the per-mini-batch weight updates (s; 0 on the
    /// sequential path — its updates are fused into the backend step).
    pub apply_wall_s: f64,
    /// Per-shard busy time accumulated across every mini-batch of the
    /// run, indexed by shard (= reduction) position; empty on the
    /// sequential path. The training twin of [`ExecReport::busy_s`].
    pub shard_busy_s: Vec<f64>,
    /// Gradient shards that had to be reassigned to surviving workers
    /// after a worker death, summed over the run (0 in healthy
    /// operation — see the [`pool`] worker-failure recovery contract).
    pub recovered_shards: usize,
}

impl TrainReport {
    /// Serialise under the shared report schema
    /// ([`crate::telemetry::REPORT_SCHEMA`], kind `"train"`). The full
    /// loss curve and per-shard busy times ride along, so a parsed
    /// report carries everything `summary` prints.
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        Json::obj()
            .with(
                "schema",
                Json::Str(crate::telemetry::REPORT_SCHEMA.to_string()),
            )
            .with("kind", Json::Str("train".to_string()))
            .with("epochs", Json::Int(self.epochs as i64))
            .with("samples_seen", Json::Int(self.samples_seen as i64))
            .with("batch", Json::Int(self.batch as i64))
            .with("workers", Json::Int(self.workers as i64))
            .with("wall_s", Json::Num(self.wall_s))
            .with("grad_wall_s", Json::Num(self.grad_wall_s))
            .with("apply_wall_s", Json::Num(self.apply_wall_s))
            .with(
                "recovered_shards",
                Json::Int(self.recovered_shards as i64),
            )
            .with(
                "loss_curve",
                Json::Arr(
                    self.loss_curve
                        .iter()
                        .map(|&l| Json::Num(f64::from(l)))
                        .collect(),
                ),
            )
            .with(
                "shard_busy_s",
                Json::Arr(
                    self.shard_busy_s
                        .iter()
                        .map(|&s| Json::Num(s))
                        .collect(),
                ),
            )
    }
}

/// Position of a training run at an epoch boundary: everything the
/// epoch loops carry from one epoch to the next. Persisted inside a
/// [`TrainState`] checkpoint and restored by the `*_checkpointed`
/// entry points, which is what makes a resumed run **bit-identical**
/// to an uninterrupted one — the restored cursor replays the exact RNG
/// stream position and sample order the interrupted run would have
/// continued with.
#[derive(Clone, Debug)]
pub struct TrainCursor {
    /// DR pipeline stage (0 for single-stage apps).
    pub stage: usize,
    /// Completed epochs within the current stage.
    pub epochs_done: usize,
    /// Samples consumed so far (current stage).
    pub samples_seen: usize,
    /// Mean per-sample loss of each completed epoch (current stage).
    pub loss_curve: Vec<f32>,
    /// The epoch shuffler, parked exactly where the last completed
    /// epoch left it.
    pub rng: Rng,
    /// Current sample-order permutation (shuffled in place at the top
    /// of every epoch).
    pub order: Vec<usize>,
}

impl TrainCursor {
    /// Cursor at the very start of training: identity order, the
    /// seed's canonical shuffler stream (`seed ^ 0x0BDE`, shared by
    /// the sequential and mini-batch paths).
    pub fn fresh(n_samples: usize, seed: u64) -> TrainCursor {
        TrainCursor {
            stage: 0,
            epochs_done: 0,
            samples_seen: 0,
            loss_curve: Vec::new(),
            rng: Rng::seeded(seed ^ 0x0BDE),
            order: (0..n_samples).collect(),
        }
    }

    /// Cursor at the position a checkpoint recorded.
    pub fn from_state(state: &TrainState) -> TrainCursor {
        TrainCursor {
            stage: state.stage,
            epochs_done: state.epochs_done,
            samples_seen: state.samples_seen,
            loss_curve: state.loss_curve.clone(),
            rng: Rng::from_state(state.rng),
            order: state.order.clone(),
        }
    }
}

/// Per-epoch callback of the training loop, invoked after every
/// completed epoch with the updated cursor and the current parameters.
/// Returning `Ok(false)` halts training gracefully at this epoch
/// boundary (the checkpointed entry points use this to honour
/// [`CheckpointOpts::stop_after`] — and tests use it to simulate a
/// preemption at an exact epoch).
pub type EpochHook<'a> =
    dyn FnMut(&TrainCursor, &[ArrayF32]) -> Result<bool> + 'a;

/// Checkpoint policy of a `*_checkpointed` training run.
#[derive(Clone, Debug)]
pub struct CheckpointOpts {
    /// Directory the checkpoints commit under (created on demand).
    pub dir: PathBuf,
    /// Save every N completed epochs (0 is treated as 1). A checkpoint
    /// is additionally always written at the final epoch and at a
    /// graceful halt, so no completed work is ever lost.
    pub every: usize,
    /// Resume from the most recent complete checkpoint under `dir`
    /// when one exists (fresh start otherwise). The checkpoint must
    /// match the requested app, hardware fingerprint and
    /// hyper-parameters — mismatches are typed errors, and the engine
    /// performs no training before they surface.
    pub resume: bool,
    /// Halt gracefully after this many epochs have run *in this call*
    /// (counted across DR stages). The preemption knob: tests use it
    /// to cut a run at an exact epoch and resume it later.
    pub stop_after: Option<usize>,
}

impl CheckpointOpts {
    /// Checkpoint into `dir` every epoch, no resume, no early halt.
    pub fn new(dir: impl Into<PathBuf>) -> CheckpointOpts {
        CheckpointOpts {
            dir: dir.into(),
            every: 1,
            resume: false,
            stop_after: None,
        }
    }
}

/// Options of [`Engine::fit`], the single training entry point. Built
/// with a small builder chain; the default is the paper's per-sample
/// stochastic BP with no checkpoints:
///
/// ```
/// use restream::coordinator::{CheckpointOpts, TrainOptions};
///
/// // per-sample BP (the default)
/// let plain = TrainOptions::new();
/// assert_eq!(plain.batch, 0);
///
/// // mini-batch 16, checkpointed every 2 epochs, DR pipeline
/// let full = TrainOptions::new()
///     .batch(16)
///     .checkpoint(CheckpointOpts { every: 2, ..CheckpointOpts::new("/tmp/ck") })
///     .dr();
/// assert!(full.dr && full.checkpoint.is_some());
/// ```
#[derive(Clone, Debug, Default)]
pub struct TrainOptions {
    /// Mini-batch size per weight update; `0` or `1` runs the paper's
    /// per-sample stochastic BP (the exact sequential path, bit for
    /// bit — see [`Engine::fit`]).
    pub batch: usize,
    /// Checkpoint policy; `None` (the default) trains without
    /// checkpoints.
    pub checkpoint: Option<CheckpointOpts>,
    /// Train as the layerwise DR pipeline (paper section II): each AE
    /// stage trains on the previous stage's encoding, `epochs` counts
    /// per stage, and the supervised `targets` argument is ignored
    /// (the pipeline is unsupervised).
    pub dr: bool,
    /// Execution mode of the DR pipeline's inter-stage re-encode
    /// passes; `None` (the default) inherits the engine's
    /// [`Engine::exec`] mode. Ignored by non-DR runs — their training
    /// loop has no batched forward. Results are bit-identical under
    /// every mode (`tests/pipeline_determinism.rs`).
    pub exec: Option<ExecMode>,
}

impl TrainOptions {
    /// Per-sample BP, no checkpoints, single-stage — the default.
    pub fn new() -> TrainOptions {
        TrainOptions::default()
    }

    /// Set the mini-batch size (see [`TrainOptions::batch`]).
    pub fn batch(mut self, batch: usize) -> TrainOptions {
        self.batch = batch;
        self
    }

    /// Train under `opts`' checkpoint policy.
    pub fn checkpoint(mut self, opts: CheckpointOpts) -> TrainOptions {
        self.checkpoint = Some(opts);
        self
    }

    /// Train as the layerwise DR pipeline (see [`TrainOptions::dr`]).
    pub fn dr(mut self) -> TrainOptions {
        self.dr = true;
        self
    }

    /// Run the DR re-encode passes under `exec` (see
    /// [`TrainOptions::exec`]).
    pub fn exec(mut self, exec: ExecMode) -> TrainOptions {
        self.exec = Some(exec);
        self
    }
}

/// What [`Engine::fit`] returns: the trained parameters plus one
/// [`TrainReport`] per trained stage — a single report for classifier
/// and plain-AE runs, one per entered AE stage for DR pipeline runs
/// (so a resumed pipeline that skipped completed stages reports only
/// the stages this call ran).
#[derive(Clone, Debug)]
pub struct TrainRun {
    /// Trained conductance parameters. For DR runs: the encoder-stack
    /// params, matching the `{app}_fwd_b64` artifact layout.
    pub params: Vec<ArrayF32>,
    /// Per-stage training reports, in stage order.
    pub reports: Vec<TrainReport>,
}

impl TrainRun {
    /// The last stage's report (`None` when a resumed/halted pipeline
    /// ran no stage in this call).
    pub fn last_report(&self) -> Option<&TrainReport> {
        self.reports.last()
    }
}

/// Package the current training position as a persistable [`TrainState`].
fn snapshot(
    net: &Network,
    seed: u64,
    lr: f32,
    batch: usize,
    cursor: &TrainCursor,
    encoder: &[ArrayF32],
    params: &[ArrayF32],
) -> TrainState {
    let mut s = TrainState::fresh(net, seed, lr, batch);
    s.stage = cursor.stage;
    s.epochs_done = cursor.epochs_done;
    s.samples_seen = cursor.samples_seen;
    s.n_samples = cursor.order.len();
    s.rng = cursor.rng.state();
    s.order = cursor.order.clone();
    s.loss_curve = cursor.loss_curve.clone();
    s.encoder = encoder.to_vec();
    s.params = params.to_vec();
    s
}

/// Check a loaded checkpoint against the run it is asked to resume:
/// identity ([`TrainState::verify_matches`]) plus every hyper-parameter
/// that feeds the deterministic replay. All failures are typed and
/// fire before any training state is touched.
fn validate_resume(
    state: &TrainState,
    net: &Network,
    n_samples: usize,
    seed: u64,
    lr: f32,
    batch: usize,
) -> Result<(), CheckpointError> {
    state.verify_matches(net)?;
    let mismatch =
        |detail: String| CheckpointError::StateMismatch { detail };
    if state.seed != seed {
        return Err(mismatch(format!(
            "checkpoint was trained with seed {}, this run asks for {seed}",
            state.seed
        )));
    }
    if state.lr.to_bits() != lr.to_bits() {
        return Err(mismatch(format!(
            "checkpoint was trained at lr {}, this run asks for {lr}",
            state.lr
        )));
    }
    if state.batch != batch.max(1) {
        return Err(mismatch(format!(
            "checkpoint was trained at batch {}, this run asks for {batch}",
            state.batch
        )));
    }
    if state.n_samples != n_samples {
        return Err(mismatch(format!(
            "checkpoint covers {} samples, this dataset has {n_samples}",
            state.n_samples
        )));
    }
    Ok(())
}

/// The streaming coordinator.
pub struct Engine {
    backend: Box<dyn Backend>,
    /// Fixed worker pool the batched operations shard over.
    pool: WorkerPool,
    /// How batched forwards execute (see [`ExecMode`]); training's
    /// gradient phase always shards data-parallel, but the DR
    /// pipeline's inter-stage re-encodes follow this mode.
    exec: ExecMode,
    /// Stage count for the pipelined exec modes; `None` = one stage
    /// per layer (clamped to `1..=n_layers` per app at run time).
    pipeline_stages: Option<usize>,
    /// Per-shard stats of the most recent sharded operation.
    last_report: Mutex<Option<ExecReport>>,
    /// Per-stage stats of the most recent pipelined forward.
    last_pipeline: Mutex<Option<PipelineReport>>,
    /// Memoised `mapper::shard_hint` per app name (the hint is a
    /// deterministic function of the network and the default chip).
    shard_hints: Mutex<std::collections::BTreeMap<String, usize>>,
}

impl Engine {
    /// Build over any compute backend. Sequential by default (one
    /// worker); scale out with [`Engine::with_workers`]. The
    /// `$RESTREAM_WORKERS` environment variable is honoured by
    /// [`Engine::open_default`] and the CLI, not here, so library
    /// construction never reads the environment.
    pub fn new(backend: Box<dyn Backend>) -> Self {
        Engine {
            backend,
            pool: WorkerPool::new(1),
            exec: ExecMode::DataParallel,
            pipeline_stages: None,
            last_report: Mutex::new(None),
            last_pipeline: Mutex::new(None),
            shard_hints: Mutex::new(std::collections::BTreeMap::new()),
        }
    }

    /// Replace the worker pool with one of `workers` threads (0 is
    /// treated as 1; 1 executes shards inline — the sequential path).
    /// No-op when the pool already has that size.
    pub fn with_workers(mut self, workers: usize) -> Self {
        let workers = workers.max(1);
        if workers != self.pool.workers() {
            self.pool = WorkerPool::new(workers);
        }
        self
    }

    /// Size of the worker pool the batched operations shard over.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Select how batched forwards execute (see [`ExecMode`]). The
    /// data-parallel default keeps the PR 2 sharded path; the
    /// pipelined modes stream through layer stages — bit-identical
    /// results either way (`tests/pipeline_determinism.rs`).
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Fix the stage count of the pipelined exec modes (`0` restores
    /// the default: one stage per layer). Clamped to `1..=n_layers`
    /// per app at run time.
    pub fn with_pipeline_stages(mut self, stages: usize) -> Self {
        self.pipeline_stages = (stages > 0).then_some(stages);
        self
    }

    /// The execution mode batched forwards use.
    pub fn exec(&self) -> ExecMode {
        self.exec
    }

    /// Per-shard timing of the most recent sharded operation
    /// ([`ExecReport`] — the data-parallel sibling of [`TrainReport`]),
    /// or `None` before the first one.
    pub fn last_parallel_report(&self) -> Option<ExecReport> {
        self.last_report
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    fn record(&self, report: ExecReport) {
        *self.last_report.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(report);
    }

    /// Per-stage occupancy/stall stats of the most recent pipelined
    /// forward ([`PipelineReport`] — the pipeline sibling of
    /// [`Engine::last_parallel_report`]), or `None` before the first
    /// one (the data-parallel mode never writes it).
    pub fn last_pipeline_report(&self) -> Option<PipelineReport> {
        self.last_pipeline
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    fn record_pipeline(&self, report: PipelineReport) {
        *self.last_pipeline.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(report);
    }

    /// Default shard plan of a batched network operation: tiles of
    /// [`apps::FWD_BATCH`] samples, split into as many contiguous
    /// shards as the app's mapping occupies mesh cores
    /// ([`mapper::shard_hint`]) — the pool parallelises the way the
    /// chip does. The hint is memoised per app name, so repeated
    /// batched calls skip the mapping work.
    fn shard_plan(&self, net: &Network, n_items: usize) -> ShardPlan {
        let hint = *self
            .shard_hints
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(net.name.to_string())
            .or_insert_with(|| {
                mapper::shard_hint(net, &SystemConfig::default())
            });
        ShardPlan::contiguous(n_items, apps::FWD_BATCH, hint)
    }

    /// Run one shard job per plan entry on the worker pool, timing each
    /// shard and recording the [`ExecReport`], and return the per-shard
    /// outputs **in shard order** (the caller's left-to-right reduction
    /// order) along with that report (which the training loop folds
    /// into its [`TrainReport`]). Shared by every plan-based sharded
    /// operation so the stats bookkeeping cannot drift between them.
    fn run_sharded<T: Send>(
        &self,
        op: String,
        plan: &ShardPlan,
        f: impl Fn(usize, (usize, usize)) -> T + Sync,
    ) -> (Vec<T>, ExecReport) {
        let t0 = Stopwatch::start();
        let timed = self.pool.run(plan.shards(), |s| {
            let t = Stopwatch::start();
            let out = f(s, plan.bounds[s]);
            (out, t.elapsed_s())
        });
        let mut shards = Vec::with_capacity(plan.shards());
        let mut outs = Vec::with_capacity(plan.shards());
        for (s, (out, wall_s)) in timed.into_iter().enumerate() {
            shards.push(ShardTiming {
                shard: s,
                range: plan.bounds[s],
                wall_s,
            });
            outs.push(out);
        }
        let report = ExecReport {
            op,
            workers: self.pool.workers(),
            wall_s: t0.elapsed_s(),
            shards,
            recovered_shards: self.pool.recovered_last_run(),
        };
        self.record(report.clone());
        (outs, report)
    }

    /// The default engine: the in-process native backend.
    pub fn native() -> Self {
        Engine::new(Box::new(NativeBackend))
    }

    /// Build over a backend by name: `"native"`, or `"pjrt"` when the
    /// crate is compiled with the `pjrt` feature.
    pub fn named(name: &str) -> Result<Self> {
        match name {
            "native" => Ok(Engine::native()),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(Engine::new(Box::new(
                crate::runtime::PjrtBackend::open_default()?,
            ))),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => Err(anyhow!(
                "backend 'pjrt' needs the `pjrt` cargo feature \
                 (cargo build --features pjrt)"
            )),
            other => Err(anyhow!("unknown backend '{other}' (native|pjrt)")),
        }
    }

    /// Backend from `$RESTREAM_BACKEND` (default: `native`) and
    /// worker-pool size from `$RESTREAM_WORKERS` (default: 1).
    pub fn open_default() -> Result<Self> {
        // lint: allow(D2) — $RESTREAM_BACKEND is an explicit config
        // knob read once at construction; it selects which backend
        // runs, never what it computes (tests/backend_parity.rs pins
        // the backends bit-identical).
        let name = std::env::var("RESTREAM_BACKEND")
            .unwrap_or_else(|_| "native".to_string());
        Ok(Self::named(&name)?.with_workers(default_workers()))
    }

    /// The compute backend in use.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Train under one [`TrainOptions`] policy — **the** training
    /// entry point, collapsing what used to be five (`train`,
    /// `train_with`, `train_checkpointed`, `train_dr`,
    /// `train_dr_checkpointed`, all kept as thin deprecated wrappers).
    ///
    /// * `targets(i)` supplies the supervised target row for sample
    ///   `i`; ignored when [`TrainOptions::dr`] is set (the DR
    ///   pipeline is unsupervised — pass `|_| Vec::new()`).
    /// * `epochs` counts whole-dataset passes; under `dr` it counts
    ///   **per stage**.
    /// * [`TrainOptions::batch`] selects per-sample BP (`<= 1`, the
    ///   exact sequential path of the paper) or mini-batch gradient
    ///   accumulation sharded over the worker pool — bit-identical at
    ///   any worker count either way
    ///   (`tests/train_determinism.rs`).
    /// * [`TrainOptions::checkpoint`] trains under a checkpoint
    ///   policy; resumed runs are bit-identical to uninterrupted ones
    ///   (`tests/checkpoint_determinism.rs`).
    ///
    /// The wrappers delegate to the same two internal bodies as `fit`,
    /// so old and new API cannot drift.
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
        opts: &TrainOptions,
    ) -> Result<TrainRun> {
        let batch = opts.batch.max(1);
        let ckpt = opts.checkpoint.as_ref();
        if opts.dr {
            let exec = opts.exec.unwrap_or(self.exec);
            let (params, reports) = self.train_dr_impl(
                net, xs, epochs, lr, seed, batch, exec, ckpt,
            )?;
            Ok(TrainRun { params, reports })
        } else {
            let (params, report) = self.train_impl(
                net, xs, &targets, epochs, lr, seed, batch, ckpt,
            )?;
            Ok(TrainRun { params, reports: vec![report] })
        }
    }

    /// Train a classifier or plain AE with per-sample stochastic BP.
    /// `targets(i)` supplies the target row for sample `i`. Equivalent
    /// to [`Engine::fit`] with default [`TrainOptions`].
    #[deprecated(note = "use Engine::fit with TrainOptions")]
    pub fn train(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        self.train_impl(
            net, xs, &targets, epochs, lr, seed, apps::TRAIN_BATCH, None,
        )
    }

    /// Train with mini-batch gradient accumulation of `batch` samples
    /// per weight update, the gradient phase sharded data-parallel over
    /// the worker pool.
    ///
    /// * `batch <= 1` runs the paper's per-sample stochastic BP — the
    ///   exact sequential path of [`Engine::train`], bit for bit.
    /// * `batch > 1` accumulates `Backend::grad_batch` sums over fixed
    ///   [`apps::GRAD_TILE`]-aligned shards and applies one update per
    ///   mini-batch. Epoch sample order is a function of `seed` alone,
    ///   shard boundaries of the mini-batch size alone, and shard
    ///   partials reduce left-to-right on one thread — so trained
    ///   params and loss curves are **bit-identical at any worker
    ///   count** (`tests/train_determinism.rs` pins both properties).
    ///
    /// The native backend accepts any `batch`/dataset combination
    /// (short tail shards and tail mini-batches just carry fewer
    /// rows). A backend with a fixed-shape gradient artifact (PJRT —
    /// `Backend::grad_tile` reports a nonzero tile) additionally
    /// requires `batch` to be a multiple of the tile and the dataset
    /// size a multiple of `batch`; violations — and an unloadable
    /// gradient artifact — fail fast **before** the first epoch.
    #[deprecated(note = "use Engine::fit with TrainOptions::new().batch(n)")]
    pub fn train_with(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
        batch: usize,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        self.train_impl(net, xs, &targets, epochs, lr, seed, batch, None)
    }

    /// [`Engine::train_with`] under a checkpoint policy: snapshots of
    /// the full training state commit atomically under `opts.dir` every
    /// [`CheckpointOpts::every`] epochs (and at the final or halt
    /// epoch), and `opts.resume` restarts from the most recent complete
    /// checkpoint instead of epoch 0. Because the restored cursor
    /// replays the exact RNG stream position and sample order, the
    /// resumed run's final conductances and loss curve are
    /// **bit-identical** to the uninterrupted run's — for every
    /// registered app, at any worker count and batch size
    /// (`tests/checkpoint_determinism.rs` pins all of it). The returned
    /// report spans the whole training history (resumed epochs
    /// included), exactly as the uninterrupted run would report it.
    #[allow(clippy::too_many_arguments)]
    #[deprecated(
        note = "use Engine::fit with TrainOptions::new().checkpoint(opts)"
    )]
    pub fn train_checkpointed(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
        batch: usize,
        opts: &CheckpointOpts,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        self.train_impl(
            net, xs, &targets, epochs, lr, seed, batch, Some(opts),
        )
    }

    /// Shared body of [`Engine::train_with`] /
    /// [`Engine::train_checkpointed`]: one code path, so the
    /// checkpointed variant cannot drift from the plain one.
    #[allow(clippy::too_many_arguments)]
    fn train_impl(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: &impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
        batch: usize,
        opts: Option<&CheckpointOpts>,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        let batch = batch.max(1);
        let resumed = match opts {
            Some(o) if o.resume => {
                let state =
                    self.load_resume(net, xs.len(), seed, lr, batch,
                                     &o.dir)?;
                if let Some(s) = &state {
                    if s.stage != 0 {
                        return Err(CheckpointError::StateMismatch {
                            detail: format!(
                                "checkpoint sits in DR stage {}, but {} \
                                 trains in a single stage",
                                s.stage, net.name
                            ),
                        }
                        .into());
                    }
                }
                state
            }
            _ => None,
        };
        let (mut cursor, params) = match resumed {
            Some(state) => {
                let cursor = TrainCursor::from_state(&state);
                (cursor, state.params)
            }
            None => (
                TrainCursor::fresh(xs.len(), seed),
                init_conductances(net.layers, seed),
            ),
        };
        let graph = net.train_artifact();
        let chunk_graph =
            format!("{}_trainchunk_c{}", net.name, apps::TRAIN_CHUNK);
        let grad_graph = net.grad_artifact();
        let mut ran = 0usize;
        let mut hook: Box<EpochHook<'_>> = match opts {
            Some(o) => {
                let dir = o.dir.clone();
                let every = o.every.max(1);
                let stop_after = o.stop_after;
                Box::new(
                    move |cursor: &TrainCursor,
                          params: &[ArrayF32]|
                          -> Result<bool> {
                        ran += 1;
                        let halting =
                            stop_after.is_some_and(|n| ran >= n);
                        let done = cursor.epochs_done >= epochs;
                        if halting
                            || done
                            || cursor.epochs_done % every == 0
                        {
                            let state = snapshot(
                                net, seed, lr, batch, cursor, &[], params,
                            );
                            checkpoint::save(&dir, &state)?;
                        }
                        Ok(!halting)
                    },
                )
            }
            None => Box::new(|_, _| Ok(true)),
        };
        self.train_loop(
            &graph, &chunk_graph, &grad_graph, params, xs, targets,
            epochs, lr, batch, &mut cursor, &mut hook,
        )
    }

    /// Load-and-validate the resume source: the most recent complete
    /// checkpoint under `dir`, or `None` for a fresh start when the
    /// directory holds none yet.
    fn load_resume(
        &self,
        net: &Network,
        n_samples: usize,
        seed: u64,
        lr: f32,
        batch: usize,
        dir: &Path,
    ) -> Result<Option<TrainState>> {
        let Some(path) = checkpoint::latest(dir)? else {
            return Ok(None);
        };
        let state = checkpoint::load(&path)?;
        validate_resume(&state, net, n_samples, seed, lr, batch)?;
        Ok(Some(state))
    }

    /// Write `state` as an atomically committed checkpoint under `dir`;
    /// returns the checkpoint's final path. Thin engine-level wrapper
    /// over [`checkpoint::save`] — the `*_checkpointed` entry points
    /// call it per epoch, and the CLI uses it for the final snapshot.
    pub fn save_checkpoint(
        &self,
        dir: &Path,
        state: &TrainState,
    ) -> Result<PathBuf, CheckpointError> {
        checkpoint::save(dir, state)
    }

    /// Load (and integrity-check) the most recent complete checkpoint
    /// under `dir`. Every failure — missing directory, truncated file,
    /// checksum mismatch, foreign app or build — is a typed
    /// [`CheckpointError`], and the engine itself is never mutated:
    /// restoring happens only by handing the returned state to a
    /// `*_checkpointed` entry point, so a failed load leaves the engine
    /// exactly as it was.
    pub fn resume_from(
        &self,
        dir: &Path,
    ) -> Result<TrainState, CheckpointError> {
        let path = checkpoint::latest(dir)?.ok_or_else(|| {
            CheckpointError::Missing { path: dir.to_path_buf() }
        })?;
        checkpoint::load(&path)
    }

    /// Arm a one-shot simulated worker failure on the engine's pool:
    /// during the next sharded operation, the worker picking up shard
    /// `shard` dies and the pool recovers by reassigning the shard (see
    /// [`WorkerPool::inject_failure`]). Test-only surface.
    #[cfg(any(test, feature = "faultinject"))]
    pub fn inject_worker_failure(&self, shard: usize) {
        self.pool.inject_failure(shard);
    }

    /// The generic training loop: dispatches between the sequential
    /// per-sample path (`batch <= 1`, untouched stochastic-BP
    /// semantics) and the data-parallel mini-batch path. `cursor`
    /// carries the epoch position (possibly restored from a
    /// checkpoint); the loop trains until `cursor.epochs_done` reaches
    /// `epochs` or `hook` requests a halt. The returned report spans
    /// the cursor's whole history, not just the epochs this call ran.
    #[allow(clippy::too_many_arguments)]
    fn train_loop(
        &self,
        graph: &str,
        chunk_graph: &str,
        grad_graph: &str,
        params: Vec<ArrayF32>,
        xs: &[Vec<f32>],
        targets: &impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        batch: usize,
        cursor: &mut TrainCursor,
        hook: &mut EpochHook<'_>,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        let start = Stopwatch::start();
        let batch = batch.max(1);
        if cursor.order.len() != xs.len() {
            return Err(anyhow!(
                "training cursor covers {} samples, dataset has {}",
                cursor.order.len(),
                xs.len()
            ));
        }
        let mut report = TrainReport {
            batch,
            workers: self.pool.workers(),
            ..TrainReport::default()
        };
        let params = if batch == 1 {
            self.train_epochs_sequential(
                graph, chunk_graph, params, xs, targets, epochs, lr,
                cursor, hook,
            )?
        } else {
            self.train_epochs_minibatch(
                grad_graph, params, xs, targets, epochs, lr, batch,
                cursor, &mut report, hook,
            )?
        };
        report.epochs = cursor.epochs_done;
        report.samples_seen = cursor.samples_seen;
        report.loss_curve = cursor.loss_curve.clone();
        report.wall_s = start.elapsed_s();
        Ok((params, report))
    }

    /// The sequential per-sample epochs (the paper's stochastic BP).
    ///
    /// Per-sample semantics are `Backend::train_step` (`params…, x, t,
    /// lr -> params…, loss`); when the backend offers a chunked variant
    /// (`Backend::chunk_size > 1`), full chunks of K samples go through
    /// `Backend::train_chunk` (same per-sample math, one call) and only
    /// the epoch tail falls back to single steps — for the PJRT backend
    /// this amortises the host/device boundary K-fold (EXPERIMENTS.md
    /// §Perf), for the native backend it batches dispatch.
    ///
    /// Epochs run from `cursor.epochs_done` up to `epochs`; the cursor
    /// advances at every epoch boundary and `hook` can halt the loop
    /// there (chunk buffers always drain within an epoch, so an epoch
    /// boundary is a clean checkpoint cut).
    #[allow(clippy::too_many_arguments)]
    fn train_epochs_sequential(
        &self,
        graph: &str,
        chunk_graph: &str,
        mut params: Vec<ArrayF32>,
        xs: &[Vec<f32>],
        targets: &impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        cursor: &mut TrainCursor,
        hook: &mut EpochHook<'_>,
    ) -> Result<Vec<ArrayF32>> {
        let chunk_k = self.backend.chunk_size(chunk_graph);
        let dims = xs.first().map_or(0, Vec::len);
        let t_dim = if xs.is_empty() { 0 } else { targets(0).len() };
        while cursor.epochs_done < epochs {
            cursor.rng.shuffle(&mut cursor.order);
            let mut epoch_loss = 0.0f32;
            let mut pulled = 0usize;
            // chunk accumulation buffers (flushed at chunk_k samples)
            let mut buf_i: Vec<usize> = Vec::with_capacity(chunk_k);
            let mut buf_x: Vec<f32> = Vec::with_capacity(chunk_k * dims);
            stream::run(xs, &cursor.order, |i, x| {
                pulled += 1;
                if chunk_k > 1 {
                    buf_i.push(i);
                    buf_x.extend_from_slice(x);
                    if buf_i.len() == chunk_k {
                        let mut ts = Vec::with_capacity(chunk_k * t_dim);
                        for &j in &buf_i {
                            ts.extend(targets(j));
                        }
                        let xs_arr = ArrayF32::matrix(
                            chunk_k,
                            dims,
                            std::mem::take(&mut buf_x),
                        )
                        .map_err(anyhow::Error::msg)?;
                        let ts_arr = ArrayF32::matrix(chunk_k, t_dim, ts)
                            .map_err(anyhow::Error::msg)?;
                        let (next, losses) = self.backend.train_chunk(
                            chunk_graph,
                            std::mem::take(&mut params),
                            &xs_arr,
                            &ts_arr,
                            lr,
                        )?;
                        params = next;
                        epoch_loss += losses
                            .iter()
                            .fold(0.0f32, |acc, l| acc + l);
                        buf_i.clear();
                    }
                    Ok(())
                } else {
                    let (next, loss) = self.backend.train_step(
                        graph,
                        std::mem::take(&mut params),
                        &ArrayF32::row(x.to_vec()),
                        &ArrayF32::row(targets(i)),
                        lr,
                    )?;
                    params = next;
                    epoch_loss += loss;
                    Ok(())
                }
            })?;
            // epoch tail: fewer than chunk_k samples left over
            for &i in &buf_i {
                let (next, loss) = self.backend.train_step(
                    graph,
                    std::mem::take(&mut params),
                    &ArrayF32::row(xs[i].clone()),
                    &ArrayF32::row(targets(i)),
                    lr,
                )?;
                params = next;
                epoch_loss += loss;
            }
            cursor.samples_seen += pulled;
            cursor.loss_curve.push(epoch_loss / pulled.max(1) as f32);
            cursor.epochs_done += 1;
            if !hook(cursor, &params)? {
                break;
            }
        }
        Ok(params)
    }

    /// The data-parallel mini-batch epochs: samples stream through the
    /// bounded input buffer into mini-batch accumulation buffers
    /// (mirroring the chunk path), and every full — or tail-short —
    /// mini-batch runs one sharded gradient step.
    #[allow(clippy::too_many_arguments)]
    fn train_epochs_minibatch(
        &self,
        grad_graph: &str,
        mut params: Vec<ArrayF32>,
        xs: &[Vec<f32>],
        targets: &impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        batch: usize,
        cursor: &mut TrainCursor,
        report: &mut TrainReport,
        hook: &mut EpochHook<'_>,
    ) -> Result<Vec<ArrayF32>> {
        let dims = xs.first().map_or(0, Vec::len);
        let t_dim = if xs.is_empty() { 0 } else { targets(0).len() };
        // Fail fast on backends with a fixed-shape gradient artifact
        // (PJRT): every shard must carry exactly `tile` samples, which
        // requires batch % tile == 0 (no short shard inside a
        // mini-batch) and n % batch == 0 (no short tail mini-batch).
        // Checking up front means no epoch runs — and no weight
        // updates apply — before the configuration error surfaces. A
        // grad_tile error (unloadable gradient artifact) propagates
        // here for the same reason.
        let tile = self.backend.grad_tile(grad_graph)?;
        if tile > 0 {
            if tile != apps::GRAD_TILE {
                // No --batch value can ever satisfy this: the
                // coordinator always shards at GRAD_TILE samples.
                return Err(anyhow!(
                    "backend '{}' lowered {grad_graph} at a \
                     {tile}-sample gradient tile, but this build \
                     shards mini-batches at {}-sample tiles \
                     (apps::GRAD_TILE) — regenerate the artifacts \
                     (make artifacts) so the two agree",
                    self.backend.name(),
                    apps::GRAD_TILE
                ));
            }
            if batch % tile != 0 || xs.len() % batch != 0 {
                return Err(anyhow!(
                    "backend '{}' executes fixed {tile}-sample gradient \
                     tiles ({grad_graph}): mini-batch training needs \
                     --batch (= {batch}) to be a multiple of {tile} and \
                     the dataset size (= {}) a multiple of --batch; \
                     adjust --batch/--samples or use --batch 1",
                    self.backend.name(),
                    xs.len()
                ));
            }
        }
        // Same generator stream as the sequential path (the cursor's
        // rng is seeded `seed ^ 0x0BDE` by `TrainCursor::fresh`): the
        // epoch sample order is a function of the seed stream alone —
        // never of the batch size or the worker count.
        while cursor.epochs_done < epochs {
            cursor.rng.shuffle(&mut cursor.order);
            let mut epoch_loss = 0.0f32;
            let mut pulled = 0usize;
            let mut buf_i: Vec<usize> = Vec::with_capacity(batch);
            let mut buf_x: Vec<f32> = Vec::with_capacity(batch * dims);
            stream::run(xs, &cursor.order, |i, x| {
                pulled += 1;
                buf_i.push(i);
                buf_x.extend_from_slice(x);
                if buf_i.len() == batch {
                    epoch_loss += self.minibatch_step(
                        grad_graph, &mut params, &buf_i, &mut buf_x,
                        targets, dims, t_dim, lr, report,
                    )?;
                    buf_i.clear();
                }
                Ok(())
            })?;
            if !buf_i.is_empty() {
                // epoch tail: one short mini-batch, same math
                epoch_loss += self.minibatch_step(
                    grad_graph, &mut params, &buf_i, &mut buf_x, targets,
                    dims, t_dim, lr, report,
                )?;
            }
            cursor.samples_seen += pulled;
            cursor.loss_curve.push(epoch_loss / pulled.max(1) as f32);
            cursor.epochs_done += 1;
            if !hook(cursor, &params)? {
                break;
            }
        }
        Ok(params)
    }

    /// One data-parallel mini-batch step: split the buffered samples
    /// into fixed [`apps::GRAD_TILE`]-aligned shards (one tile per
    /// shard — the clustering core's batch-sized-pass precedent, so
    /// boundaries depend only on the mini-batch size), compute
    /// per-shard gradient sums concurrently on the worker pool, fold
    /// the accumulators left-to-right in shard order on this thread,
    /// and fire a single weight update. Returns the summed pre-update
    /// sample losses of the mini-batch.
    fn minibatch_step(
        &self,
        grad_graph: &str,
        params: &mut Vec<ArrayF32>,
        buf_i: &[usize],
        buf_x: &mut Vec<f32>,
        targets: &impl Fn(usize) -> Vec<f32>,
        dims: usize,
        t_dim: usize,
        lr: f32,
        report: &mut TrainReport,
    ) -> Result<f32> {
        let b = buf_i.len();
        let xs_arr = ArrayF32::matrix(b, dims, std::mem::take(buf_x))
            .map_err(anyhow::Error::msg)?;
        // the take left a zero-capacity Vec behind; re-reserve so the
        // next mini-batch fills without doubling reallocations
        buf_x.reserve(b * dims);
        let mut ts = Vec::with_capacity(b * t_dim);
        for &j in buf_i {
            ts.extend(targets(j));
        }
        let ts_arr =
            ArrayF32::matrix(b, t_dim, ts).map_err(anyhow::Error::msg)?;
        let plan = ShardPlan::contiguous(
            b,
            apps::GRAD_TILE,
            b.div_ceil(apps::GRAD_TILE),
        );
        let backend = self.backend.as_ref();
        let cur: &[ArrayF32] = params;
        let (shard_outs, exec) = self.run_sharded(
            format!("grad_batch/{grad_graph}"),
            &plan,
            |_, (lo, hi)| -> Result<crate::runtime::GradBatch> {
                let xs_s = ArrayF32::matrix(
                    hi - lo,
                    dims,
                    xs_arr.data[lo * dims..hi * dims].to_vec(),
                )
                .map_err(anyhow::Error::msg)?;
                let ts_s = ArrayF32::matrix(
                    hi - lo,
                    t_dim,
                    ts_arr.data[lo * t_dim..hi * t_dim].to_vec(),
                )
                .map_err(anyhow::Error::msg)?;
                backend.grad_batch(grad_graph, cur, &xs_s, &ts_s)
            },
        );
        // Left-to-right fold in shard order on this thread: gradient
        // accumulators sum elementwise, losses sum in sample order —
        // the fixed reduction the determinism contract requires.
        let mut total: Vec<ArrayF32> = Vec::new();
        let mut loss_sum = 0.0f32;
        for gb in shard_outs {
            let gb = gb?;
            loss_sum += gb.losses.iter().fold(0.0f32, |acc, l| acc + l);
            if total.is_empty() {
                total = gb.grads;
            } else {
                for (acc, g) in total.iter_mut().zip(&gb.grads) {
                    for (a, v) in acc.data.iter_mut().zip(&g.data) {
                        *a += v;
                    }
                }
            }
        }
        if total.is_empty() {
            return Err(anyhow!("empty mini-batch"));
        }
        let t0 = Stopwatch::start();
        *params = backend.apply_grads(
            grad_graph,
            std::mem::take(params),
            &total,
            lr,
        )?;
        report.apply_wall_s += t0.elapsed_s();
        report.grad_wall_s += exec.wall_s;
        report.recovered_shards += exec.recovered_shards.len();
        for s in &exec.shards {
            if report.shard_busy_s.len() <= s.shard {
                report.shard_busy_s.resize(s.shard + 1, 0.0);
            }
            report.shard_busy_s[s.shard] += s.wall_s;
        }
        Ok(loss_sum)
    }

    /// Layerwise DR pipeline (paper section II): train each AE stage on
    /// the current representation, then re-encode the dataset with the
    /// trained encoder and move on. Returns the encoder-stack params
    /// (matching the `{app}_fwd_b64` artifact layout) plus stage reports.
    /// `batch` selects each stage's mini-batch size exactly as in
    /// [`Engine::fit`] (1 = the sequential per-sample path).
    #[deprecated(note = "use Engine::fit with TrainOptions::new().dr()")]
    pub fn train_dr(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        epochs_per_stage: usize,
        lr: f32,
        seed: u64,
        batch: usize,
    ) -> Result<(Vec<ArrayF32>, Vec<TrainReport>)> {
        self.train_dr_impl(
            net, xs, epochs_per_stage, lr, seed, batch, self.exec, None,
        )
    }

    /// [`Engine::train_dr`] under a checkpoint policy — the DR sibling
    /// of [`Engine::train_checkpointed`]. A checkpoint records the
    /// pipeline stage, the completed stages' encoder conductances and
    /// the in-flight stage's full cursor; resuming re-encodes the
    /// dataset through the stored encoder stack (the exact
    /// `params::encode_layer` math the uninterrupted pipeline ran) and
    /// continues the interrupted stage mid-flight, so the final encoder
    /// stack is **bit-identical** to an uninterrupted run. On a
    /// graceful halt ([`CheckpointOpts::stop_after`]) the returned
    /// encoder stack covers completed stages only; stage reports cover
    /// the stages this call entered.
    #[allow(clippy::too_many_arguments)]
    #[deprecated(
        note = "use Engine::fit with TrainOptions::new().dr().checkpoint(opts)"
    )]
    pub fn train_dr_checkpointed(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        epochs_per_stage: usize,
        lr: f32,
        seed: u64,
        batch: usize,
        opts: &CheckpointOpts,
    ) -> Result<(Vec<ArrayF32>, Vec<TrainReport>)> {
        self.train_dr_impl(
            net, xs, epochs_per_stage, lr, seed, batch, self.exec,
            Some(opts),
        )
    }

    /// Shared body of [`Engine::train_dr`] /
    /// [`Engine::train_dr_checkpointed`].
    #[allow(clippy::too_many_arguments)]
    fn train_dr_impl(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        epochs_per_stage: usize,
        lr: f32,
        seed: u64,
        batch: usize,
        exec: ExecMode,
        opts: Option<&CheckpointOpts>,
    ) -> Result<(Vec<ArrayF32>, Vec<TrainReport>)> {
        if net.kind != AppKind::DimReduction {
            return Err(anyhow!("{} is not a DR app", net.name));
        }
        let stages = net.dr_stages().len();
        let resumed = match opts {
            Some(o) if o.resume => {
                let state =
                    self.load_resume(net, xs.len(), seed, lr,
                                     batch.max(1), &o.dir)?;
                if let Some(s) = &state {
                    if s.stage >= stages {
                        return Err(CheckpointError::StateMismatch {
                            detail: format!(
                                "checkpoint sits in stage {} but {} has \
                                 only {stages} stages",
                                s.stage, net.name
                            ),
                        }
                        .into());
                    }
                    if s.encoder.len() != 2 * s.stage {
                        return Err(CheckpointError::StateMismatch {
                            detail: format!(
                                "checkpoint carries {} encoder arrays \
                                 for stage {} (want {})",
                                s.encoder.len(),
                                s.stage,
                                2 * s.stage
                            ),
                        }
                        .into());
                    }
                }
                state
            }
            _ => None,
        };
        let start_stage = resumed.as_ref().map_or(0, |s| s.stage);
        let mut encoder_params: Vec<ArrayF32> = resumed
            .as_ref()
            .map_or_else(Vec::new, |s| s.encoder.clone());
        // Rebuild the in-flight representation by re-encoding the raw
        // dataset through the stored encoder stack — deterministic
        // ideal-crossbar math, identical to the re-encodes the
        // uninterrupted pipeline performed stage by stage.
        let mut current: Vec<Vec<f32>> = xs.to_vec();
        for pair in encoder_params.chunks(2) {
            current = self.reencode(
                exec,
                &format!("dr_reencode/{}", net.name),
                &current,
                &pair[0],
                &pair[1],
            )?;
        }
        let mut restored =
            resumed.map(|s| (TrainCursor::from_state(&s), s.params));
        let mut reports = Vec::new();
        let mut ran = 0usize;
        for (s, (n_in, n_hid)) in net.dr_stages().iter().enumerate() {
            if s < start_stage {
                continue;
            }
            let graph = net.stage_artifact(s);
            let chunk_graph = format!(
                "{}_stage{}_trainchunk_c{}",
                net.name,
                s,
                apps::TRAIN_CHUNK
            );
            let grad_graph = net.stage_grad_artifact(s);
            let (mut cursor, stage_params) = match restored.take() {
                Some(r) => r,
                None => {
                    let mut c =
                        TrainCursor::fresh(current.len(), seed + s as u64);
                    c.stage = s;
                    (
                        c,
                        init_conductances(
                            &[*n_in, *n_hid, *n_in],
                            seed + s as u64,
                        ),
                    )
                }
            };
            let targets = {
                let cur = current.clone();
                move |i: usize| cur[i].clone()
            };
            let mut hook: Box<EpochHook<'_>> = match opts {
                Some(o) => {
                    let dir = o.dir.clone();
                    let every = o.every.max(1);
                    let stop_after = o.stop_after;
                    let ran = &mut ran;
                    let encoder = encoder_params.clone();
                    Box::new(
                        move |cursor: &TrainCursor,
                              params: &[ArrayF32]|
                              -> Result<bool> {
                            *ran += 1;
                            let halting =
                                stop_after.is_some_and(|n| *ran >= n);
                            let done =
                                cursor.epochs_done >= epochs_per_stage;
                            if halting
                                || done
                                || cursor.epochs_done % every == 0
                            {
                                let state = snapshot(
                                    net, seed, lr, batch, cursor,
                                    &encoder, params,
                                );
                                checkpoint::save(&dir, &state)?;
                            }
                            Ok(!halting)
                        },
                    )
                }
                None => Box::new(|_, _| Ok(true)),
            };
            let (trained, report) = self.train_loop(
                &graph,
                &chunk_graph,
                &grad_graph,
                stage_params,
                &current,
                &targets,
                epochs_per_stage,
                lr,
                batch,
                &mut cursor,
                &mut hook,
            )?;
            drop(hook);
            reports.push(report);
            if cursor.epochs_done < epochs_per_stage {
                // graceful halt mid-stage: the checkpoint written at
                // the halt epoch carries the resume point; the
                // incomplete stage contributes no encoder
                break;
            }
            // keep the encoder half; re-encode through it (bit-compatible
            // ideal-crossbar math) for the next stage
            let (gp, gn) = (&trained[0], &trained[1]);
            current = self.reencode(
                exec,
                &format!("dr_reencode/{}_stage{s}", net.name),
                &current,
                gp,
                gn,
            )?;
            encoder_params.extend_from_slice(&trained[..2]);
        }
        Ok((encoder_params, reports))
    }

    /// One DR inter-stage re-encode pass: every sample through a
    /// trained encoder layer. The pipelined exec modes stream it
    /// through a single-stage pipeline — bit-identical to the
    /// per-sample [`params::encode_layer`] math (the forward is
    /// row-independent; pinned by `tests/pipeline_determinism.rs`).
    fn reencode(
        &self,
        exec: ExecMode,
        op: &str,
        xs: &[Vec<f32>],
        gp: &ArrayF32,
        gn: &ArrayF32,
    ) -> Result<Vec<Vec<f32>>> {
        if exec == ExecMode::DataParallel {
            return Ok(xs
                .iter()
                .map(|x| params::encode_layer(x, gp, gn))
                .collect());
        }
        let pair = [gp.clone(), gn.clone()];
        let dims = xs.first().map_or(0, Vec::len);
        let (out, report) = if exec == ExecMode::Pipelined {
            pipeline::forward_pipelined(
                self.backend.as_ref(),
                op.to_string(),
                FwdMode::Final,
                &pair,
                xs,
                dims,
                0,
                1,
                apps::FWD_BATCH,
            )?
        } else {
            pipeline::forward_hybrid(
                self.backend.as_ref(),
                op.to_string(),
                FwdMode::Final,
                &pair,
                xs,
                dims,
                0,
                1,
                apps::FWD_BATCH,
                self.workers(),
            )?
        };
        self.record_pipeline(report);
        Ok(out)
    }

    /// Batched recognition through the net's forward graph, sharded
    /// across the worker pool. Returns one output row per input sample
    /// (padding stripped), bit-identical at any worker count.
    pub fn infer(&self, net: &Network, params: &[ArrayF32],
                 xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mode = FwdMode::for_kind(net.kind);
        self.batched_forward(net, mode, params, xs, 0)
    }

    /// Batched AE forward returning reconstruction rows (output 0).
    pub fn reconstruct(&self, net: &Network, params: &[ArrayF32],
                       xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(net, params, xs)
    }

    /// Batched encode to the bottleneck representation. Plain AEs return
    /// (reconstruction, code) — the code is output 1; DR apps' forward
    /// graph *is* the encoder stack, so the code is output 0.
    pub fn encode(&self, net: &Network, params: &[ArrayF32],
                  xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mode = FwdMode::for_kind(net.kind);
        // for AEs the code is output 1; a DR forward graph *is* the
        // encoder stack, so its code is output 0
        let idx = usize::from(mode == FwdMode::ReconAndCode);
        self.batched_forward(net, mode, params, xs, idx)
    }

    /// Batched forward, dispatched on the engine's [`ExecMode`].
    ///
    /// Data-parallel (the default): contiguous tile-aligned shards run
    /// on the worker pool, each executing the same tile loop the
    /// sequential engine ran ([`forward_range`]); shard outputs
    /// concatenate left-to-right. Pipelined/hybrid: the same tile
    /// chunks stream through layer stages
    /// ([`pipeline::forward_pipelined`]). All paths are bit-identical
    /// to the sequential loop at any worker/stage count.
    fn batched_forward(
        &self,
        net: &Network,
        mode: FwdMode,
        params: &[ArrayF32],
        xs: &[Vec<f32>],
        output_idx: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let graph = net.fwd_artifact();
        // One global row width for every shard (as the sequential loop
        // had), so ragged inputs cannot make shards disagree.
        let dims = xs.first().map_or(0, Vec::len);
        let backend = self.backend.as_ref();
        let op = format!("forward_batch/{graph}");
        if self.exec != ExecMode::DataParallel {
            let stages =
                self.pipeline_stages.unwrap_or(params.len() / 2).max(1);
            let (out, report) = if self.exec == ExecMode::Pipelined {
                pipeline::forward_pipelined(
                    backend, op, mode, params, xs, dims, output_idx,
                    stages, apps::FWD_BATCH,
                )?
            } else {
                pipeline::forward_hybrid(
                    backend, op, mode, params, xs, dims, output_idx,
                    stages, apps::FWD_BATCH, self.workers(),
                )?
            };
            self.record_pipeline(report);
            return Ok(out);
        }
        let plan = self.shard_plan(net, xs.len());
        let (shard_outs, _) = self.run_sharded(op, &plan, |_, (lo, hi)| {
            forward_range(
                backend,
                &graph,
                mode,
                params,
                &xs[lo..hi],
                dims,
                output_idx,
                plan.tile,
            )
        });
        let mut out = Vec::with_capacity(xs.len());
        for rows in shard_outs {
            out.extend(rows?);
        }
        Ok(out)
    }

    /// Classifier predictions by argmax (sign for single-output nets).
    /// A non-finite network output (NaN from a poisoned conductance or
    /// a diverged backend) is reported as an error, never a panic.
    pub fn classify(&self, net: &Network, params: &[ArrayF32],
                    xs: &[Vec<f32>]) -> Result<Vec<usize>> {
        let outs = self.infer(net, params, xs)?;
        let mut preds = Vec::with_capacity(outs.len());
        for (i, o) in outs.iter().enumerate() {
            if o.iter().any(|v| !v.is_finite()) {
                return Err(anyhow!(
                    "classify: non-finite output for sample {i} of {} \
                     (backend '{}')",
                    net.name,
                    self.backend.name()
                ));
            }
            preds.push(if o.len() == 1 {
                usize::from(o[0] > 0.0)
            } else {
                o.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            });
        }
        Ok(preds)
    }

    /// k-means through the clustering-core graph: batched assignment,
    /// centre accumulation in the backend, division at epoch end in the
    /// coordinator (as the core's registers do). Returns (centres,
    /// assignments).
    ///
    /// The per-epoch assignment + accumulation phase is sharded over
    /// the worker pool at tile granularity (the clustering core's
    /// batch-sized streaming passes); each tile returns its raw
    /// accumulator registers and the caller folds them left-to-right
    /// in tile order, so centres and assignments are bit-identical to
    /// the sequential path at any worker count.
    pub fn kmeans(
        &self,
        app: &apps::App,
        xs: &[Vec<f32>],
        epochs: usize,
        seed: u64,
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let graph = app.step_artifact();
        let (d, k) = (app.dims, app.clusters);
        let mut rng = Rng::seeded(seed ^ 0x63A5);
        // seed centres from k distinct samples
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        rng.shuffle(&mut idx);
        let mut centres: Vec<f32> = idx
            .iter()
            .take(k)
            .flat_map(|&i| xs[i].clone())
            .collect();
        let batch = apps::FWD_BATCH;
        // One tile per shard: the clustering core's batch-sized
        // streaming passes are the unit of parallel work.
        let plan = ShardPlan::contiguous(
            xs.len(),
            batch,
            xs.len().div_ceil(batch),
        );
        let mut assign = vec![0usize; xs.len()];
        let backend = self.backend.as_ref();
        for _ in 0..epochs {
            let mut acc = vec![0.0f32; k * d];
            let mut counts = vec![0.0f32; k];
            let centres_arr = ArrayF32::matrix(k, d, centres.clone())
                .map_err(|e| anyhow!(e))?;
            let graph_ref = &graph;
            let centres_ref = &centres_arr;
            let (tiles, _) = self.run_sharded(
                format!("kmeans/{}", app.name),
                &plan,
                |_, (lo, hi)| {
                    kmeans_tile(
                        backend, graph_ref, centres_ref, &xs[lo..hi],
                        batch, d,
                    )
                },
            );
            // Left-to-right fold in tile order — line-for-line the
            // sequence of additions and padding corrections the
            // sequential loop performed.
            for (ci, step) in tiles.into_iter().enumerate() {
                let step = step?;
                let (lo, hi) = plan.bounds[ci];
                let chunk_len = hi - lo;
                let pad_rows = batch - chunk_len;
                for i in 0..chunk_len {
                    assign[lo + i] = step.assign[i];
                }
                for v in 0..k * d {
                    acc[v] += step.acc[v];
                }
                for c in 0..k {
                    counts[c] += step.counts[c];
                }
                if pad_rows > 0 {
                    // remove the padded duplicates' contribution
                    let last = &xs[lo + chunk_len - 1];
                    let c0 = step.assign[batch - 1];
                    counts[c0] -= pad_rows as f32;
                    for dd in 0..d {
                        acc[c0 * d + dd] -= pad_rows as f32 * last[dd];
                    }
                }
            }
            for c in 0..k {
                if counts[c] > 0.5 {
                    for dd in 0..d {
                        centres[c * d + dd] = acc[c * d + dd] / counts[c];
                    }
                }
            }
        }
        let centres_rows =
            centres.chunks(d).map(|c| c.to_vec()).collect();
        Ok((centres_rows, assign))
    }

    /// Anomaly scores: Manhattan distance between each input and its AE
    /// reconstruction (paper Figs 18–19). The reconstruction runs
    /// sharded (see [`Engine::infer`]); the per-sample scoring is then
    /// sharded over the same plan. Per-sample scores are independent,
    /// so the concatenation is bit-identical at any worker count.
    pub fn anomaly_scores(&self, net: &Network, params: &[ArrayF32],
                          xs: &[Vec<f32>]) -> Result<Vec<f64>> {
        let recon = self.reconstruct(net, params, xs)?;
        let plan = self.shard_plan(net, xs.len());
        let recon_ref = &recon;
        let (parts, _) = self.run_sharded(
            format!("anomaly_scores/{}", net.name),
            &plan,
            |_, (lo, hi)| -> Vec<f64> {
                xs[lo..hi]
                    .iter()
                    .zip(&recon_ref[lo..hi])
                    .map(|(x, r)| {
                        x.iter()
                            .zip(r)
                            .map(|(a, b)| {
                                let ac = a.clamp(-0.5, 0.5);
                                (ac - b).abs() as f64
                            })
                            .fold(0.0f64, |acc, d| acc + d)
                    })
                    .collect()
            },
        );
        let mut out = Vec::with_capacity(xs.len());
        for scores in parts {
            out.extend(scores);
        }
        Ok(out)
    }
}

/// Sequential tile loop over one shard of a batched forward — exactly
/// the loop the single-threaded engine ran, applied to a tile-aligned
/// slice, so per-tile padding and backend calls match the sequential
/// path call-for-call. `dims` is the global row width (computed once
/// from the whole batch, never per shard).
fn forward_range(
    backend: &dyn Backend,
    graph: &str,
    mode: FwdMode,
    params: &[ArrayF32],
    xs: &[Vec<f32>],
    dims: usize,
    output_idx: usize,
    tile: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(xs.len());
    for chunk in xs.chunks(tile) {
        let mut data = Vec::with_capacity(tile * dims);
        for x in chunk {
            data.extend_from_slice(x);
        }
        data.resize(tile * dims, 0.0); // pad the tail tile
        let x_arr =
            ArrayF32::matrix(tile, dims, data).map_err(|e| anyhow!(e))?;
        let outs = backend.forward_batch(graph, mode, params, &x_arr)?;
        let y = outs
            .get(output_idx)
            .ok_or_else(|| anyhow!("missing output {output_idx}"))?;
        for i in 0..chunk.len() {
            out.push(y.row_slice(i).to_vec());
        }
    }
    Ok(out)
}

/// One clustering-core tile: pad the chunk to the tile size with copies
/// of its last real row (so padding joins that row's cluster — the
/// caller subtracts the duplicates during the ordered reduction) and
/// run the backend's batched k-means step.
fn kmeans_tile(
    backend: &dyn Backend,
    graph: &str,
    centres: &ArrayF32,
    chunk: &[Vec<f32>],
    tile: usize,
    dims: usize,
) -> Result<KmeansStep> {
    let mut data = Vec::with_capacity(tile * dims);
    for x in chunk {
        data.extend_from_slice(x);
    }
    let last = &chunk[chunk.len() - 1];
    for _ in 0..tile - chunk.len() {
        data.extend_from_slice(last);
    }
    let x_arr = ArrayF32::matrix(tile, dims, data).map_err(|e| anyhow!(e))?;
    backend.kmeans_batch(graph, &x_arr, centres)
}

// These unit tests deliberately keep exercising the deprecated
// train/train_with wrappers: they pin that the thin wrappers still
// reach the shared internal bodies (`Engine::fit` equivalence is
// pinned in tests/integration_train.rs).
#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    #[test]
    fn named_backends_resolve() {
        assert_eq!(Engine::native().backend().name(), "native");
        assert_eq!(Engine::named("native").unwrap().backend().name(),
                   "native");
        assert!(Engine::named("frobnicate").is_err());
        #[cfg(not(feature = "pjrt"))]
        {
            let err = Engine::named("pjrt").unwrap_err();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }

    #[test]
    fn train_report_round_trips_through_json() {
        use crate::telemetry::json;
        let r = TrainReport {
            loss_curve: vec![0.5, 0.25],
            epochs: 2,
            samples_seen: 300,
            wall_s: 1.5,
            batch: 32,
            workers: 4,
            grad_wall_s: 1.0,
            apply_wall_s: 0.2,
            shard_busy_s: vec![0.5, 0.5],
            recovered_shards: 0,
        };
        let text = r.to_json().to_string();
        let doc = json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("kind").and_then(json::Json::as_str),
            Some("train")
        );
        assert_eq!(
            doc.get("epochs").and_then(json::Json::as_i64),
            Some(2)
        );
        assert_eq!(doc.get("loss_curve").expect("curve").items().len(), 2);
    }

    #[test]
    fn default_backend_is_native() {
        // Scoped env override: the assertion runs whether or not the
        // ambient test environment pre-set RESTREAM_BACKEND.
        crate::testing::with_env(&[("RESTREAM_BACKEND", None)], || {
            assert_eq!(Engine::open_default().unwrap().backend().name(),
                       "native");
        });
        crate::testing::with_env(
            &[("RESTREAM_BACKEND", Some("native"))],
            || {
                assert_eq!(
                    Engine::open_default().unwrap().backend().name(),
                    "native"
                );
            },
        );
        crate::testing::with_env(
            &[("RESTREAM_BACKEND", Some("frobnicate"))],
            || assert!(Engine::open_default().is_err()),
        );
    }

    #[test]
    fn worker_count_from_env_and_builder() {
        // Engine::new/native never read the environment (so plain
        // library construction cannot race env-mutating tests); the
        // env knob applies through open_default and the CLI.
        crate::testing::with_env(
            &[
                ("RESTREAM_WORKERS", Some("3")),
                ("RESTREAM_BACKEND", None),
            ],
            || {
                assert_eq!(Engine::native().workers(), 1);
                assert_eq!(Engine::open_default().unwrap().workers(), 3);
            },
        );
        crate::testing::with_env(
            &[("RESTREAM_WORKERS", None), ("RESTREAM_BACKEND", None)],
            || assert_eq!(Engine::open_default().unwrap().workers(), 1),
        );
        assert_eq!(Engine::native().with_workers(5).workers(), 5);
        assert_eq!(Engine::native().with_workers(0).workers(), 1);
    }

    #[test]
    fn classify_reports_nan_instead_of_panicking() {
        // A poisoned conductance propagates NaN through the quantisers
        // to the argmax; pre-fix this was a partial_cmp().unwrap()
        // panic, now it must surface as an error.
        let net = Network {
            name: "nan_probe",
            layers: &[4, 3, 3],
            kind: AppKind::Classifier,
            classes: 3,
        };
        let mut params = init_conductances(net.layers, 0);
        for v in params[0].data.iter_mut() {
            *v = f32::NAN;
        }
        let e = Engine::native();
        let xs = vec![vec![0.1f32, -0.2, 0.3, 0.0]; 3];
        let err = e.classify(&net, &params, &xs).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        // healthy params still classify fine
        let good = init_conductances(net.layers, 0);
        assert_eq!(e.classify(&net, &good, &xs).unwrap().len(), 3);
    }

    #[test]
    fn fixed_tile_backend_rejects_ragged_batches_before_training() {
        // A backend with a fixed-shape gradient artifact (the PJRT
        // path) must fail fast on mini-batch/dataset combinations that
        // would produce a ragged shard — before any update applies.
        struct FixedTile(usize);
        impl crate::runtime::Backend for FixedTile {
            fn name(&self) -> &'static str {
                "fixed-tile"
            }
            fn grad_tile(&self, grad_graph: &str) -> Result<usize> {
                if self.0 == 0 {
                    return Err(anyhow!("artifact {grad_graph} missing"));
                }
                Ok(self.0)
            }
        }
        let net = apps::network("iris_ae").unwrap();
        let mk = || Engine::new(Box::new(FixedTile(apps::GRAD_TILE)));
        let mut rng = Rng::seeded(1);
        let xs: Vec<Vec<f32>> =
            (0..32).map(|_| rng.vec_uniform(4, -0.5, 0.5)).collect();
        // batch not a multiple of the tile: short shard inside a batch
        let xs_t = xs.clone();
        let err = mk()
            .train_with(net, &xs, move |i| xs_t[i].clone(), 1, 0.5, 0, 12)
            .unwrap_err();
        assert!(err.to_string().contains("fixed 8-sample"), "{err}");
        // dataset not a multiple of the batch: short tail mini-batch
        let xs27 = &xs[..27];
        let xs_t: Vec<Vec<f32>> = xs27.to_vec();
        let err = mk()
            .train_with(net, xs27, move |i| xs_t[i].clone(), 1, 0.5, 0, 8)
            .unwrap_err();
        assert!(err.to_string().contains("multiple of --batch"), "{err}");
        // aligned configuration passes the check (and the mock's
        // default native grad math trains fine)
        let xs_t = xs.clone();
        assert!(mk()
            .train_with(net, &xs, move |i| xs_t[i].clone(), 1, 0.5, 0, 8)
            .is_ok());
        // a tile that can never match the coordinator's GRAD_TILE
        // shards gets the regenerate-artifacts message, not --batch
        // advice
        let xs_t = xs.clone();
        let err = Engine::new(Box::new(FixedTile(16)))
            .train_with(net, &xs, move |i| xs_t[i].clone(), 1, 0.5, 0, 16)
            .unwrap_err();
        assert!(err.to_string().contains("regenerate"), "{err}");
        // an unloadable gradient artifact surfaces before epoch 1 too
        let xs_t = xs.clone();
        let err = Engine::new(Box::new(FixedTile(0)))
            .train_with(net, &xs, move |i| xs_t[i].clone(), 1, 0.5, 0, 8)
            .unwrap_err();
        assert!(err.to_string().contains("missing"), "{err}");
        // the native backend has no tile constraint: ragged is fine
        let xs_t = xs.clone();
        assert!(Engine::native()
            .train_with(net, &xs, move |i| xs_t[i].clone(), 1, 0.5, 0, 12)
            .is_ok());
    }

    #[test]
    fn minibatch_training_runs_and_reports() {
        let net = apps::network("iris_ae").unwrap(); // 4-2-4, cheap
        let mut rng = Rng::seeded(3);
        let xs: Vec<Vec<f32>> =
            (0..37).map(|_| rng.vec_uniform(4, -0.5, 0.5)).collect();
        let e = Engine::native().with_workers(2);
        let xs_t = xs.clone();
        let (params, rep) = e
            .train_with(net, &xs, move |i| xs_t[i].clone(), 2, 0.5, 1, 16)
            .unwrap();
        assert_eq!(rep.batch, 16);
        assert_eq!(rep.workers, 2);
        assert_eq!(rep.epochs, 2);
        assert_eq!(rep.samples_seen, 74);
        assert_eq!(rep.loss_curve.len(), 2);
        // 16-sample mini-batches split into two 8-sample shards
        assert_eq!(rep.shard_busy_s.len(), 2);
        assert!(rep.grad_wall_s >= 0.0 && rep.apply_wall_s >= 0.0);
        assert_eq!(params.len(), 4);
        // the engine's last sharded op is the gradient phase
        let pr = e.last_parallel_report().unwrap();
        assert!(pr.op.starts_with("grad_batch/"), "{}", pr.op);
        // sequential runs report batch 1 and no shard timings
        let xs_t = xs.clone();
        let (_, rep1) = e
            .train(net, &xs, move |i| xs_t[i].clone(), 1, 0.5, 1)
            .unwrap();
        assert_eq!(rep1.batch, 1);
        assert!(rep1.shard_busy_s.is_empty());
        assert_eq!(rep1.grad_wall_s, 0.0);
    }

    #[test]
    fn sharded_ops_record_parallel_reports() {
        let net = apps::network("iris_ae").unwrap();
        let params = init_conductances(net.layers, 1);
        let mut rng = Rng::seeded(5);
        let xs: Vec<Vec<f32>> =
            (0..130).map(|_| rng.vec_uniform(4, -0.5, 0.5)).collect();
        let e = Engine::native().with_workers(2);
        assert!(e.last_parallel_report().is_none());
        e.infer(net, &params, &xs).unwrap();
        let rep = e.last_parallel_report().unwrap();
        assert!(rep.op.starts_with("forward_batch/"), "{}", rep.op);
        assert_eq!(rep.workers, 2);
        assert!(!rep.shards.is_empty());
        // shards cover the batch contiguously in reduction order
        let mut lo = 0;
        for s in &rep.shards {
            assert_eq!(s.range.0, lo);
            lo = s.range.1;
        }
        assert_eq!(lo, xs.len());
        assert!(rep.busy_s() >= 0.0 && rep.wall_s >= 0.0);
        e.anomaly_scores(net, &params, &xs).unwrap();
        let rep = e.last_parallel_report().unwrap();
        assert!(rep.op.starts_with("anomaly_scores/"), "{}", rep.op);
    }
}
