//! Streaming training/inference coordinator — the chip's steady-state
//! control loop, in Rust, with Python nowhere on the path.
//!
//! The [`Engine`] owns the PJRT [`Runtime`] and drives the per-sample
//! stochastic-BP loop (training), the batched recognition loop, the
//! layerwise DR pipeline, the clustering epochs and the anomaly scorer.
//! Samples arrive through the bounded double-buffered stream of
//! [`crate::coordinator::stream`] — the software twin of the DMA + 4 kB
//! input buffer front (backpressure included).
//!
//! Hot-loop design: the PJRT wrapper cannot untuple device buffers, so
//! weights round-trip through host literals per execution; the chunked
//! `..._trainchunk_cK` artifacts scan K samples of stochastic BP inside
//! one XLA program, amortising that crossing K-fold — the software
//! analogue of the paper's "processing happens at the physical location
//! of the data" (see EXPERIMENTS.md section Perf).

pub mod params;
pub mod stream;

pub use params::init_conductances;

use anyhow::{anyhow, Result};

use crate::config::{apps, AppKind, Network};
use crate::runtime::{ArrayF32, Executable, Runtime};
use crate::testing::Rng;

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Mean per-sample loss per epoch.
    pub loss_curve: Vec<f32>,
    pub epochs: usize,
    pub samples_seen: usize,
    /// Host wall-clock of the run (for the perf harness, not the chip
    /// timing model — that is `crate::sim`).
    pub wall_s: f64,
}

/// The streaming coordinator.
pub struct Engine {
    pub rt: Runtime,
}

impl Engine {
    pub fn new(rt: Runtime) -> Self {
        Engine { rt }
    }

    pub fn open_default() -> Result<Self> {
        Ok(Engine::new(Runtime::open_default()?))
    }

    /// Train a classifier or plain AE with per-sample stochastic BP.
    /// `targets(i)` supplies the target row for sample `i`.
    pub fn train(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        let exe = self.rt.load(&net.train_artifact())?;
        let chunk = self.load_chunk(&format!(
            "{}_trainchunk_c{}", net.name, apps::TRAIN_CHUNK));
        let params = init_conductances(net.layers, seed);
        let (params, report) = self.train_loop(
            &exe, chunk.as_deref(), params, xs, &targets, epochs, lr, seed)?;
        Ok((params, report))
    }

    /// Load a chunked train artifact if it exists (older artifact trees
    /// may predate chunking; the per-sample path always works).
    fn load_chunk(&self, name: &str) -> Option<std::sync::Arc<Executable>> {
        self.rt.load(name).ok()
    }

    /// The generic training loop.
    ///
    /// Per-sample artifact signature: `params..., x, t, lr -> params...,
    /// loss`. The xla crate's PJRT wrapper returns the result *tuple* as
    /// a single buffer (no untupling), so parameters round-trip through
    /// host literals each step; when a scan-chunked artifact
    /// (`..._trainchunk_cK`, same per-sample semantics, K samples per
    /// execution) is available, full chunks go through it and only the
    /// epoch tail falls back to per-sample steps — the boundary crossing
    /// is amortised K-fold (EXPERIMENTS.md §Perf).
    fn train_loop(
        &self,
        exe: &Executable,
        chunk: Option<&Executable>,
        mut params: Vec<ArrayF32>,
        xs: &[Vec<f32>],
        targets: &impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        let n_params = params.len();
        let start = std::time::Instant::now();
        let lr_arr = ArrayF32::scalar(lr);
        let chunk_k = chunk.map(|c| c.meta.inputs[n_params][0]).unwrap_or(0);
        let dims = xs.first().map_or(0, Vec::len);
        let mut report = TrainReport::default();
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut rng = Rng::seeded(seed ^ 0x0BDE);
        let step_one = |params: &mut Vec<ArrayF32>, i: usize, x: &[f32],
                            epoch_loss: &mut f32| -> Result<()> {
            let mut ins = Vec::with_capacity(n_params + 3);
            ins.extend(params.iter().cloned());
            ins.push(ArrayF32::row(x.to_vec()));
            ins.push(ArrayF32::row(targets(i)));
            ins.push(lr_arr.clone());
            let mut outs = exe.run(&ins)?;
            let loss = outs.pop()
                .ok_or_else(|| anyhow!("train step returned nothing"))?;
            if outs.len() != n_params {
                return Err(anyhow!(
                    "train step returned {} params, expected {n_params}",
                    outs.len()
                ));
            }
            *params = outs;
            *epoch_loss += loss.data[0];
            Ok(())
        };
        for _epoch in 0..epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0f32;
            let mut pulled = 0usize;
            // chunk accumulation buffers (flushed at chunk_k samples)
            let mut buf_i: Vec<usize> = Vec::with_capacity(chunk_k);
            let mut buf_x: Vec<f32> = Vec::with_capacity(chunk_k * dims);
            stream::run(xs, &order, |i, x| {
                pulled += 1;
                if let Some(cexe) = chunk {
                    buf_i.push(i);
                    buf_x.extend_from_slice(x);
                    if buf_i.len() == chunk_k {
                        let t_dim = cexe.meta.inputs[n_params + 1][1];
                        let mut ts = Vec::with_capacity(chunk_k * t_dim);
                        for &j in &buf_i {
                            ts.extend(targets(j));
                        }
                        let mut ins = Vec::with_capacity(n_params + 3);
                        ins.extend(params.iter().cloned());
                        ins.push(
                            ArrayF32::matrix(chunk_k, dims,
                                             std::mem::take(&mut buf_x))
                                .map_err(anyhow::Error::msg)?,
                        );
                        ins.push(ArrayF32::matrix(chunk_k, t_dim, ts)
                            .map_err(anyhow::Error::msg)?);
                        ins.push(lr_arr.clone());
                        let mut outs = cexe.run(&ins)?;
                        let losses = outs.pop()
                            .ok_or_else(|| anyhow!("chunk returned nothing"))?;
                        params = outs;
                        epoch_loss += losses.data.iter().sum::<f32>();
                        buf_i.clear();
                    }
                    Ok(())
                } else {
                    step_one(&mut params, i, x, &mut epoch_loss)
                }
            })?;
            // epoch tail: fewer than chunk_k samples left over
            for &i in &buf_i {
                let x = xs[i].clone();
                step_one(&mut params, i, &x, &mut epoch_loss)?;
            }
            report.samples_seen += pulled;
            report.loss_curve.push(epoch_loss / pulled.max(1) as f32);
            report.epochs += 1;
        }
        report.wall_s = start.elapsed().as_secs_f64();
        Ok((params, report))
    }

    /// Layerwise DR pipeline (paper section II): train each AE stage on
    /// the current representation, then re-encode the dataset with the
    /// trained encoder and move on. Returns the encoder-stack params
    /// (matching the `{app}_fwd_b64` artifact layout) plus stage reports.
    pub fn train_dr(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        epochs_per_stage: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, Vec<TrainReport>)> {
        if net.kind != AppKind::DimReduction {
            return Err(anyhow!("{} is not a DR app", net.name));
        }
        let mut encoder_params: Vec<ArrayF32> = Vec::new();
        let mut reports = Vec::new();
        let mut current: Vec<Vec<f32>> = xs.to_vec();
        for (s, (n_in, n_hid)) in net.dr_stages().iter().enumerate() {
            let exe = self.rt.load(&net.stage_artifact(s))?;
            let chunk = self.load_chunk(&format!(
                "{}_stage{}_trainchunk_c{}", net.name, s, apps::TRAIN_CHUNK));
            let stage_params =
                init_conductances(&[*n_in, *n_hid, *n_in], seed + s as u64);
            let targets = {
                let cur = current.clone();
                move |i: usize| cur[i].clone()
            };
            let (trained, report) = self.train_loop(
                &exe, chunk.as_deref(), stage_params, &current, &targets,
                epochs_per_stage, lr, seed + s as u64,
            )?;
            // keep the encoder half; re-encode through it (bit-compatible
            // ideal-crossbar math) for the next stage
            let (gp, gn) = (&trained[0], &trained[1]);
            current = current
                .iter()
                .map(|x| params::encode_layer(x, gp, gn))
                .collect();
            encoder_params.extend_from_slice(&trained[..2]);
            reports.push(report);
        }
        Ok((encoder_params, reports))
    }

    /// Batched recognition through a `*_fwd_b64` artifact. Returns one
    /// output row per input sample (padding stripped).
    pub fn infer(&self, net: &Network, params: &[ArrayF32],
                 xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let exe = self.rt.load(&net.fwd_artifact())?;
        self.batched_forward(&exe, params, xs, 0)
    }

    /// Batched AE forward returning reconstruction rows (output 0).
    pub fn reconstruct(&self, net: &Network, params: &[ArrayF32],
                       xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(net, params, xs)
    }

    /// Batched encode to the bottleneck representation. Plain AEs return
    /// (reconstruction, code) — the code is output 1; DR apps' forward
    /// artifact *is* the encoder stack, so the code is output 0.
    pub fn encode(&self, net: &Network, params: &[ArrayF32],
                  xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let exe = self.rt.load(&net.fwd_artifact())?;
        let idx = usize::from(net.kind == AppKind::Autoencoder);
        self.batched_forward(&exe, params, xs, idx)
    }

    fn batched_forward(
        &self,
        exe: &Executable,
        params: &[ArrayF32],
        xs: &[Vec<f32>],
        output_idx: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let batch = apps::FWD_BATCH;
        let dims = xs.first().map_or(0, Vec::len);
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(batch) {
            let mut data = Vec::with_capacity(batch * dims);
            for x in chunk {
                data.extend_from_slice(x);
            }
            data.resize(batch * dims, 0.0); // pad the tail batch
            let mut inputs = params.to_vec();
            inputs.push(ArrayF32::matrix(batch, dims, data)
                .map_err(|e| anyhow!(e))?);
            let outs = exe.run(&inputs)?;
            let y = outs
                .get(output_idx)
                .ok_or_else(|| anyhow!("missing output {output_idx}"))?;
            for i in 0..chunk.len() {
                out.push(y.row_slice(i).to_vec());
            }
        }
        Ok(out)
    }

    /// Classifier predictions by argmax (sign for single-output nets).
    pub fn classify(&self, net: &Network, params: &[ArrayF32],
                    xs: &[Vec<f32>]) -> Result<Vec<usize>> {
        let outs = self.infer(net, params, xs)?;
        Ok(outs
            .iter()
            .map(|o| {
                if o.len() == 1 {
                    usize::from(o[0] > 0.0)
                } else {
                    o.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap()
                }
            })
            .collect())
    }

    /// k-means through the clustering-core artifact: batched assignment,
    /// centre accumulation on device, division at epoch end in the
    /// coordinator (as the core's registers do). Returns (centres,
    /// assignments).
    pub fn kmeans(
        &self,
        app: &apps::App,
        xs: &[Vec<f32>],
        epochs: usize,
        seed: u64,
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let exe = self.rt.load(&app.step_artifact())?;
        let (d, k) = (app.dims, app.clusters);
        let mut rng = Rng::seeded(seed ^ 0x63A5);
        // seed centres from k distinct samples
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        rng.shuffle(&mut idx);
        let mut centres: Vec<f32> = idx
            .iter()
            .take(k)
            .flat_map(|&i| xs[i].clone())
            .collect();
        let batch = apps::FWD_BATCH;
        let mut assign = vec![0usize; xs.len()];
        for _ in 0..epochs {
            let mut acc = vec![0.0f32; k * d];
            let mut counts = vec![0.0f32; k];
            let centres_arr =
                ArrayF32::matrix(k, d, centres.clone()).map_err(|e| anyhow!(e))?;
            for (ci, chunk) in xs.chunks(batch).enumerate() {
                let mut data = Vec::with_capacity(batch * d);
                for x in chunk {
                    data.extend_from_slice(x);
                }
                // pad with copies of the first row so padding joins that
                // row's cluster; its contribution is subtracted below.
                let pad_rows = batch - chunk.len();
                for _ in 0..pad_rows {
                    data.extend_from_slice(&chunk[0.min(chunk.len() - 1)].clone());
                }
                let x_arr = ArrayF32::matrix(batch, d, data)
                    .map_err(|e| anyhow!(e))?;
                let outs = exe.run(&[x_arr, centres_arr.clone()])?;
                let (a, ac, cn) = (&outs[0], &outs[1], &outs[2]);
                for i in 0..chunk.len() {
                    assign[ci * batch + i] = a.data[i] as usize;
                }
                for v in 0..k * d {
                    acc[v] += ac.data[v];
                }
                for c in 0..k {
                    counts[c] += cn.data[c];
                }
                if pad_rows > 0 {
                    // remove the padded duplicates' contribution
                    let c0 = a.data[batch - 1] as usize;
                    counts[c0] -= pad_rows as f32;
                    for dd in 0..d {
                        acc[c0 * d + dd] -=
                            pad_rows as f32 * chunk[chunk.len() - 1][dd];
                    }
                }
            }
            for c in 0..k {
                if counts[c] > 0.5 {
                    for dd in 0..d {
                        centres[c * d + dd] = acc[c * d + dd] / counts[c];
                    }
                }
            }
        }
        let centres_rows =
            centres.chunks(d).map(|c| c.to_vec()).collect();
        Ok((centres_rows, assign))
    }

    /// Anomaly scores: Manhattan distance between each input and its AE
    /// reconstruction (paper Figs 18–19).
    pub fn anomaly_scores(&self, net: &Network, params: &[ArrayF32],
                          xs: &[Vec<f32>]) -> Result<Vec<f64>> {
        let recon = self.reconstruct(net, params, xs)?;
        Ok(xs
            .iter()
            .zip(&recon)
            .map(|(x, r)| {
                x.iter()
                    .zip(r)
                    .map(|(a, b)| {
                        let ac = a.clamp(-0.5, 0.5);
                        (ac - b).abs() as f64
                    })
                    .sum()
            })
            .collect())
    }
}
