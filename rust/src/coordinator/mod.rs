//! Streaming training/inference coordinator — the chip's steady-state
//! control loop, in Rust, with Python nowhere on the path.
//!
//! The [`Engine`] owns a pluggable [`Backend`] and drives the
//! per-sample stochastic-BP loop (training), the batched recognition
//! loop, the layerwise DR pipeline, the clustering epochs and the
//! anomaly scorer. Samples arrive through the bounded double-buffered
//! stream of [`crate::coordinator::stream`] — the software twin of the
//! DMA + 4 kB input buffer front (backpressure included).
//!
//! The backend is chosen at construction: [`Engine::native`] composes
//! the reference kernels in-process (the default — no artifacts
//! needed), while the `pjrt` cargo feature adds the artifact-executing
//! PJRT backend ([`Engine::named`]`("pjrt")`). Both implement the same
//! per-sample semantics, so loss curves and trained conductances are
//! interchangeable.
//!
//! Hot-loop design: a PJRT execution round-trips every conductance
//! matrix through host literals, so the coordinator prefers the
//! chunked train operation (`Backend::train_chunk`, the
//! `..._trainchunk_cK` artifacts) which scans K samples of stochastic
//! BP per call, amortising that crossing K-fold; the native backend
//! keeps the same chunked loop to batch its per-step dispatch — the
//! software analogue of the paper's "processing happens at the physical
//! location of the data" (see EXPERIMENTS.md section Perf).

pub mod params;
pub mod stream;

pub use params::init_conductances;

use anyhow::{anyhow, Result};

use crate::config::{apps, AppKind, Network};
use crate::runtime::{ArrayF32, Backend, FwdMode, NativeBackend};
use crate::testing::Rng;

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Mean per-sample loss per epoch.
    pub loss_curve: Vec<f32>,
    pub epochs: usize,
    pub samples_seen: usize,
    /// Host wall-clock of the run (for the perf harness, not the chip
    /// timing model — that is `crate::sim`).
    pub wall_s: f64,
}

/// The streaming coordinator.
pub struct Engine {
    backend: Box<dyn Backend>,
}

impl Engine {
    /// Build over any compute backend.
    pub fn new(backend: Box<dyn Backend>) -> Self {
        Engine { backend }
    }

    /// The default engine: the in-process native backend.
    pub fn native() -> Self {
        Engine::new(Box::new(NativeBackend))
    }

    /// Build over a backend by name: `"native"`, or `"pjrt"` when the
    /// crate is compiled with the `pjrt` feature.
    pub fn named(name: &str) -> Result<Self> {
        match name {
            "native" => Ok(Engine::native()),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(Engine::new(Box::new(
                crate::runtime::PjrtBackend::open_default()?,
            ))),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => Err(anyhow!(
                "backend 'pjrt' needs the `pjrt` cargo feature \
                 (cargo build --features pjrt)"
            )),
            other => Err(anyhow!("unknown backend '{other}' (native|pjrt)")),
        }
    }

    /// Backend from `$RESTREAM_BACKEND` (default: `native`).
    pub fn open_default() -> Result<Self> {
        let name = std::env::var("RESTREAM_BACKEND")
            .unwrap_or_else(|_| "native".to_string());
        Self::named(&name)
    }

    /// The compute backend in use.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Train a classifier or plain AE with per-sample stochastic BP.
    /// `targets(i)` supplies the target row for sample `i`.
    pub fn train(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        targets: impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        let graph = net.train_artifact();
        let chunk_graph =
            format!("{}_trainchunk_c{}", net.name, apps::TRAIN_CHUNK);
        let params = init_conductances(net.layers, seed);
        self.train_loop(
            &graph, &chunk_graph, params, xs, &targets, epochs, lr, seed,
        )
    }

    /// The generic training loop.
    ///
    /// Per-sample semantics are `Backend::train_step` (`params…, x, t,
    /// lr -> params…, loss`); when the backend offers a chunked variant
    /// (`Backend::chunk_size > 1`), full chunks of K samples go through
    /// `Backend::train_chunk` (same per-sample math, one call) and only
    /// the epoch tail falls back to single steps — for the PJRT backend
    /// this amortises the host/device boundary K-fold (EXPERIMENTS.md
    /// §Perf), for the native backend it batches dispatch.
    #[allow(clippy::too_many_arguments)]
    fn train_loop(
        &self,
        graph: &str,
        chunk_graph: &str,
        mut params: Vec<ArrayF32>,
        xs: &[Vec<f32>],
        targets: &impl Fn(usize) -> Vec<f32>,
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, TrainReport)> {
        let start = std::time::Instant::now();
        let chunk_k = self.backend.chunk_size(chunk_graph);
        let dims = xs.first().map_or(0, Vec::len);
        let t_dim = if xs.is_empty() { 0 } else { targets(0).len() };
        let mut report = TrainReport::default();
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut rng = Rng::seeded(seed ^ 0x0BDE);
        for _epoch in 0..epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0f32;
            let mut pulled = 0usize;
            // chunk accumulation buffers (flushed at chunk_k samples)
            let mut buf_i: Vec<usize> = Vec::with_capacity(chunk_k);
            let mut buf_x: Vec<f32> = Vec::with_capacity(chunk_k * dims);
            stream::run(xs, &order, |i, x| {
                pulled += 1;
                if chunk_k > 1 {
                    buf_i.push(i);
                    buf_x.extend_from_slice(x);
                    if buf_i.len() == chunk_k {
                        let mut ts = Vec::with_capacity(chunk_k * t_dim);
                        for &j in &buf_i {
                            ts.extend(targets(j));
                        }
                        let xs_arr = ArrayF32::matrix(
                            chunk_k,
                            dims,
                            std::mem::take(&mut buf_x),
                        )
                        .map_err(anyhow::Error::msg)?;
                        let ts_arr = ArrayF32::matrix(chunk_k, t_dim, ts)
                            .map_err(anyhow::Error::msg)?;
                        let (next, losses) = self.backend.train_chunk(
                            chunk_graph,
                            std::mem::take(&mut params),
                            &xs_arr,
                            &ts_arr,
                            lr,
                        )?;
                        params = next;
                        epoch_loss += losses.iter().sum::<f32>();
                        buf_i.clear();
                    }
                    Ok(())
                } else {
                    let (next, loss) = self.backend.train_step(
                        graph,
                        std::mem::take(&mut params),
                        &ArrayF32::row(x.to_vec()),
                        &ArrayF32::row(targets(i)),
                        lr,
                    )?;
                    params = next;
                    epoch_loss += loss;
                    Ok(())
                }
            })?;
            // epoch tail: fewer than chunk_k samples left over
            for &i in &buf_i {
                let (next, loss) = self.backend.train_step(
                    graph,
                    std::mem::take(&mut params),
                    &ArrayF32::row(xs[i].clone()),
                    &ArrayF32::row(targets(i)),
                    lr,
                )?;
                params = next;
                epoch_loss += loss;
            }
            report.samples_seen += pulled;
            report.loss_curve.push(epoch_loss / pulled.max(1) as f32);
            report.epochs += 1;
        }
        report.wall_s = start.elapsed().as_secs_f64();
        Ok((params, report))
    }

    /// Layerwise DR pipeline (paper section II): train each AE stage on
    /// the current representation, then re-encode the dataset with the
    /// trained encoder and move on. Returns the encoder-stack params
    /// (matching the `{app}_fwd_b64` artifact layout) plus stage reports.
    pub fn train_dr(
        &self,
        net: &Network,
        xs: &[Vec<f32>],
        epochs_per_stage: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(Vec<ArrayF32>, Vec<TrainReport>)> {
        if net.kind != AppKind::DimReduction {
            return Err(anyhow!("{} is not a DR app", net.name));
        }
        let mut encoder_params: Vec<ArrayF32> = Vec::new();
        let mut reports = Vec::new();
        let mut current: Vec<Vec<f32>> = xs.to_vec();
        for (s, (n_in, n_hid)) in net.dr_stages().iter().enumerate() {
            let graph = net.stage_artifact(s);
            let chunk_graph = format!(
                "{}_stage{}_trainchunk_c{}",
                net.name,
                s,
                apps::TRAIN_CHUNK
            );
            let stage_params =
                init_conductances(&[*n_in, *n_hid, *n_in], seed + s as u64);
            let targets = {
                let cur = current.clone();
                move |i: usize| cur[i].clone()
            };
            let (trained, report) = self.train_loop(
                &graph,
                &chunk_graph,
                stage_params,
                &current,
                &targets,
                epochs_per_stage,
                lr,
                seed + s as u64,
            )?;
            // keep the encoder half; re-encode through it (bit-compatible
            // ideal-crossbar math) for the next stage
            let (gp, gn) = (&trained[0], &trained[1]);
            current = current
                .iter()
                .map(|x| params::encode_layer(x, gp, gn))
                .collect();
            encoder_params.extend_from_slice(&trained[..2]);
            reports.push(report);
        }
        Ok((encoder_params, reports))
    }

    /// Batched recognition through the net's forward graph. Returns one
    /// output row per input sample (padding stripped).
    pub fn infer(&self, net: &Network, params: &[ArrayF32],
                 xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mode = FwdMode::for_kind(net.kind);
        self.batched_forward(&net.fwd_artifact(), mode, params, xs, 0)
    }

    /// Batched AE forward returning reconstruction rows (output 0).
    pub fn reconstruct(&self, net: &Network, params: &[ArrayF32],
                       xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.infer(net, params, xs)
    }

    /// Batched encode to the bottleneck representation. Plain AEs return
    /// (reconstruction, code) — the code is output 1; DR apps' forward
    /// graph *is* the encoder stack, so the code is output 0.
    pub fn encode(&self, net: &Network, params: &[ArrayF32],
                  xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mode = FwdMode::for_kind(net.kind);
        // for AEs the code is output 1; a DR forward graph *is* the
        // encoder stack, so its code is output 0
        let idx = usize::from(mode == FwdMode::ReconAndCode);
        self.batched_forward(&net.fwd_artifact(), mode, params, xs, idx)
    }

    fn batched_forward(
        &self,
        graph: &str,
        mode: FwdMode,
        params: &[ArrayF32],
        xs: &[Vec<f32>],
        output_idx: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let batch = apps::FWD_BATCH;
        let dims = xs.first().map_or(0, Vec::len);
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(batch) {
            let mut data = Vec::with_capacity(batch * dims);
            for x in chunk {
                data.extend_from_slice(x);
            }
            data.resize(batch * dims, 0.0); // pad the tail batch
            let x_arr = ArrayF32::matrix(batch, dims, data)
                .map_err(|e| anyhow!(e))?;
            let outs =
                self.backend.forward_batch(graph, mode, params, &x_arr)?;
            let y = outs
                .get(output_idx)
                .ok_or_else(|| anyhow!("missing output {output_idx}"))?;
            for i in 0..chunk.len() {
                out.push(y.row_slice(i).to_vec());
            }
        }
        Ok(out)
    }

    /// Classifier predictions by argmax (sign for single-output nets).
    pub fn classify(&self, net: &Network, params: &[ArrayF32],
                    xs: &[Vec<f32>]) -> Result<Vec<usize>> {
        let outs = self.infer(net, params, xs)?;
        Ok(outs
            .iter()
            .map(|o| {
                if o.len() == 1 {
                    usize::from(o[0] > 0.0)
                } else {
                    o.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap()
                }
            })
            .collect())
    }

    /// k-means through the clustering-core graph: batched assignment,
    /// centre accumulation in the backend, division at epoch end in the
    /// coordinator (as the core's registers do). Returns (centres,
    /// assignments).
    pub fn kmeans(
        &self,
        app: &apps::App,
        xs: &[Vec<f32>],
        epochs: usize,
        seed: u64,
    ) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
        let graph = app.step_artifact();
        let (d, k) = (app.dims, app.clusters);
        let mut rng = Rng::seeded(seed ^ 0x63A5);
        // seed centres from k distinct samples
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        rng.shuffle(&mut idx);
        let mut centres: Vec<f32> = idx
            .iter()
            .take(k)
            .flat_map(|&i| xs[i].clone())
            .collect();
        let batch = apps::FWD_BATCH;
        let mut assign = vec![0usize; xs.len()];
        for _ in 0..epochs {
            let mut acc = vec![0.0f32; k * d];
            let mut counts = vec![0.0f32; k];
            let centres_arr = ArrayF32::matrix(k, d, centres.clone())
                .map_err(|e| anyhow!(e))?;
            for (ci, chunk) in xs.chunks(batch).enumerate() {
                let mut data = Vec::with_capacity(batch * d);
                for x in chunk {
                    data.extend_from_slice(x);
                }
                // pad with copies of the last real row so padding joins
                // that row's cluster; its contribution is subtracted
                // again below.
                let pad_rows = batch - chunk.len();
                let last = &chunk[chunk.len() - 1];
                for _ in 0..pad_rows {
                    data.extend_from_slice(last);
                }
                let x_arr = ArrayF32::matrix(batch, d, data)
                    .map_err(|e| anyhow!(e))?;
                let step =
                    self.backend.kmeans_batch(&graph, &x_arr, &centres_arr)?;
                for i in 0..chunk.len() {
                    assign[ci * batch + i] = step.assign[i];
                }
                for v in 0..k * d {
                    acc[v] += step.acc[v];
                }
                for c in 0..k {
                    counts[c] += step.counts[c];
                }
                if pad_rows > 0 {
                    // remove the padded duplicates' contribution
                    let c0 = step.assign[batch - 1];
                    counts[c0] -= pad_rows as f32;
                    for dd in 0..d {
                        acc[c0 * d + dd] -= pad_rows as f32 * last[dd];
                    }
                }
            }
            for c in 0..k {
                if counts[c] > 0.5 {
                    for dd in 0..d {
                        centres[c * d + dd] = acc[c * d + dd] / counts[c];
                    }
                }
            }
        }
        let centres_rows =
            centres.chunks(d).map(|c| c.to_vec()).collect();
        Ok((centres_rows, assign))
    }

    /// Anomaly scores: Manhattan distance between each input and its AE
    /// reconstruction (paper Figs 18–19).
    pub fn anomaly_scores(&self, net: &Network, params: &[ArrayF32],
                          xs: &[Vec<f32>]) -> Result<Vec<f64>> {
        let recon = self.reconstruct(net, params, xs)?;
        Ok(xs
            .iter()
            .zip(&recon)
            .map(|(x, r)| {
                x.iter()
                    .zip(r)
                    .map(|(a, b)| {
                        let ac = a.clamp(-0.5, 0.5);
                        (ac - b).abs() as f64
                    })
                    .sum()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_backends_resolve() {
        assert_eq!(Engine::native().backend().name(), "native");
        assert_eq!(Engine::named("native").unwrap().backend().name(),
                   "native");
        assert!(Engine::named("frobnicate").is_err());
        #[cfg(not(feature = "pjrt"))]
        {
            let err = Engine::named("pjrt").unwrap_err();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }

    #[test]
    fn default_backend_is_native() {
        // (the test runner does not set RESTREAM_BACKEND)
        if std::env::var("RESTREAM_BACKEND").is_err() {
            assert_eq!(Engine::open_default().unwrap().backend().name(),
                       "native");
        }
    }
}
