//! Deterministic PRNG and a minimal property-testing harness.
//!
//! The offline vendor registry has neither `rand` nor `proptest`, so both
//! are built here from scratch: [`Rng`] is xoshiro256++ (public-domain
//! reference algorithm), and [`forall`] runs a property over many derived
//! seeds, reporting the first failing seed so a failure is reproducible
//! with `Rng::seeded(seed)`.

mod rng;
pub use rng::Rng;

/// Run `prop` over `cases` deterministically derived RNGs; panic with the
/// failing seed + message on the first counterexample.
///
/// This is the crate's property-testing entry point. Properties take the
/// per-case RNG and return `Err(description)` to fail.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        // Split a fresh generator per case so failures replay standalone.
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1) ^ 0xD1B5;
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_every_case() {
        let mut n = 0;
        forall("count", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn forall_reports_failure() {
        forall("boom", 10, |rng| {
            if rng.uniform(0.0, 1.0) >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }
}
