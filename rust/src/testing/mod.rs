//! Deterministic PRNG and a minimal property-testing harness.
//!
//! The offline vendor registry has neither `rand` nor `proptest`, so both
//! are built here from scratch: [`Rng`] is xoshiro256++ (public-domain
//! reference algorithm), and [`forall`] runs a property over many derived
//! seeds, reporting the first failing seed so a failure is reproducible
//! with `Rng::seeded(seed)`.

mod rng;
pub use rng::Rng;

/// Run `prop` over `cases` deterministically derived RNGs; panic with the
/// failing seed + message on the first counterexample.
///
/// This is the crate's property-testing entry point. Properties take the
/// per-case RNG and return `Err(description)` to fail.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        // Split a fresh generator per case so failures replay standalone.
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1) ^ 0xD1B5;
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Run `f` with environment variables scoped-overridden (`None`
/// removes the variable), restoring the previous values afterwards —
/// on panic too, via a drop guard. Overrides are serialised through a
/// process-wide lock so concurrently running tests cannot interleave
/// their mutations of the (process-global) environment.
pub fn with_env<T>(
    vars: &[(&str, Option<&str>)],
    f: impl FnOnce() -> T,
) -> T {
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    // A panic inside an earlier `f` poisons the lock but leaves the
    // environment restored (the guard ran); keep going.
    let _serialise = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(Vec<(String, Option<String>)>);
    impl Drop for Restore {
        fn drop(&mut self) {
            for (key, prev) in &self.0 {
                match prev {
                    Some(v) => std::env::set_var(key, v),
                    None => std::env::remove_var(key),
                }
            }
        }
    }
    let _restore = Restore(
        vars.iter()
            .map(|(key, _)| ((*key).to_string(), std::env::var(key).ok()))
            .collect(),
    );
    for (key, value) in vars {
        match value {
            Some(v) => std::env::set_var(key, v),
            None => std::env::remove_var(key),
        }
    }
    f()
}

/// Drive `xs` through `svc`'s app `app` from `clients` concurrent
/// closed-loop threads and return the responses **in `xs` order**.
///
/// Thread `c` owns the contiguous slice `xs[c*chunk..]` (the last
/// thread takes the remainder), so the result only depends on the
/// inputs — never on thread scheduling. This is the shared harness of
/// the determinism tests: every [`Service`](crate::serve::Service)
/// implementation (dedicated server, multi-tenant chip, multi-chip
/// cluster) must produce bit-identical outputs through it.
///
/// Panics on any submit/serve error — determinism tests never expect
/// one.
pub fn drive_service(
    svc: &dyn crate::serve::Service,
    app: &str,
    xs: &[Vec<f32>],
    clients: usize,
) -> Vec<Vec<f32>> {
    let clients = clients.clamp(1, xs.len().max(1));
    let chunk = xs.len().div_ceil(clients);
    let mut out: Vec<Option<Vec<f32>>> = vec![None; xs.len()];
    std::thread::scope(|scope| {
        let mut slots = out.as_mut_slice();
        let mut inputs = xs;
        while !inputs.is_empty() {
            let take = chunk.min(inputs.len());
            let (my_in, rest_in) = inputs.split_at(take);
            let (my_out, rest_out) = slots.split_at_mut(take);
            inputs = rest_in;
            slots = rest_out;
            scope.spawn(move || {
                for (slot, x) in my_out.iter_mut().zip(my_in) {
                    let r = svc
                        .call(app, x.clone())
                        .expect("determinism drivers never expect errors");
                    *slot = Some(r.out);
                }
            });
        }
    });
    out.into_iter()
        .map(|slot| slot.expect("every request was answered"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_every_case() {
        let mut n = 0;
        forall("count", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    fn with_env_sets_and_restores() {
        // Probe key unique to this test, ambient-unset; every mutation
        // goes through with_env itself so no write happens outside its
        // lock (raw set_var here would race other threads' locked
        // overrides).
        let key = "RESTREAM_WITH_ENV_PROBE";
        assert!(std::env::var(key).is_err());
        let out = with_env(&[(key, Some("inside"))], || {
            assert_eq!(std::env::var(key).unwrap(), "inside");
            42
        });
        assert_eq!(out, 42);
        assert!(std::env::var(key).is_err(), "override not rolled back");
        // removing an absent variable is a no-op and still restores
        with_env(&[(key, None)], || {
            assert!(std::env::var(key).is_err());
        });
        assert!(std::env::var(key).is_err());
    }

    #[test]
    fn with_env_restores_on_panic() {
        let key = "RESTREAM_WITH_ENV_PANIC_PROBE";
        assert!(std::env::var(key).is_err());
        let result = std::panic::catch_unwind(|| {
            with_env(&[(key, Some("scoped"))], || panic!("inner"));
        });
        assert!(result.is_err());
        assert!(
            std::env::var(key).is_err(),
            "panicking scope must roll back"
        );
    }

    #[test]
    fn drive_service_is_input_order_deterministic() {
        use crate::config::apps;
        use crate::coordinator::{init_conductances, Engine};
        use crate::serve::{ServeConfig, Server};
        let net = apps::network("iris_ae").unwrap().clone();
        let params = init_conductances(net.layers, 11);
        let server = Server::start(
            Engine::native(),
            net,
            params,
            ServeConfig::default(),
        );
        let mut rng = Rng::seeded(9);
        let xs: Vec<Vec<f32>> =
            (0..10).map(|_| rng.vec_uniform(4, -0.5, 0.5)).collect();
        let one = drive_service(&server, "iris_ae", &xs, 1);
        let four = drive_service(&server, "iris_ae", &xs, 4);
        assert_eq!(one.len(), 10);
        assert_eq!(one, four, "outputs must depend on inputs alone");
        server.shutdown();
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn forall_reports_failure() {
        forall("boom", 10, |rng| {
            if rng.uniform(0.0, 1.0) >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }
}
