//! Deterministic PRNG and a minimal property-testing harness.
//!
//! The offline vendor registry has neither `rand` nor `proptest`, so both
//! are built here from scratch: [`Rng`] is xoshiro256++ (public-domain
//! reference algorithm), and [`forall`] runs a property over many derived
//! seeds, reporting the first failing seed so a failure is reproducible
//! with `Rng::seeded(seed)`.

mod rng;
pub use rng::Rng;

/// Run `prop` over `cases` deterministically derived RNGs; panic with the
/// failing seed + message on the first counterexample.
///
/// This is the crate's property-testing entry point. Properties take the
/// per-case RNG and return `Err(description)` to fail.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        // Split a fresh generator per case so failures replay standalone.
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1) ^ 0xD1B5;
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Run `f` with environment variables scoped-overridden (`None`
/// removes the variable), restoring the previous values afterwards —
/// on panic too, via a drop guard. Overrides are serialised through a
/// process-wide lock so concurrently running tests cannot interleave
/// their mutations of the (process-global) environment.
pub fn with_env<T>(
    vars: &[(&str, Option<&str>)],
    f: impl FnOnce() -> T,
) -> T {
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    // A panic inside an earlier `f` poisons the lock but leaves the
    // environment restored (the guard ran); keep going.
    let _serialise = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(Vec<(String, Option<String>)>);
    impl Drop for Restore {
        fn drop(&mut self) {
            for (key, prev) in &self.0 {
                match prev {
                    Some(v) => std::env::set_var(key, v),
                    None => std::env::remove_var(key),
                }
            }
        }
    }
    let _restore = Restore(
        vars.iter()
            .map(|(key, _)| ((*key).to_string(), std::env::var(key).ok()))
            .collect(),
    );
    for (key, value) in vars {
        match value {
            Some(v) => std::env::set_var(key, v),
            None => std::env::remove_var(key),
        }
    }
    f()
}

/// Drive `xs` through `svc`'s app `app` from `clients` concurrent
/// closed-loop threads and return the responses **in `xs` order**.
///
/// Thread `c` owns the contiguous slice `xs[c*chunk..]` (the last
/// thread takes the remainder), so the result only depends on the
/// inputs — never on thread scheduling. This is the shared harness of
/// the determinism tests: every [`Service`](crate::serve::Service)
/// implementation (dedicated server, multi-tenant chip, multi-chip
/// cluster) must produce bit-identical outputs through it.
///
/// Beyond the outputs, the drive asserts the front's accounting
/// invariants, so every determinism suite using this harness gets
/// them for free:
///
/// * **conservation** — the live [`ServeStats`](crate::serve::ServeStats)
///   request counter grew by exactly the answered responses plus the
///   errors (requests in = responses out + errors; a closed-loop drive
///   with every call answered admits no other balance);
/// * **latency ordering** — over the drive's own response timings,
///   p50 ≤ p99 ≤ max.
///
/// Panics on any submit/serve error — determinism tests never expect
/// one.
pub fn drive_service(
    svc: &dyn crate::serve::Service,
    app: &str,
    xs: &[Vec<f32>],
    clients: usize,
) -> Vec<Vec<f32>> {
    let clients = clients.clamp(1, xs.len().max(1));
    let chunk = xs.len().div_ceil(clients);
    let before = svc.stats();
    let mut out: Vec<Option<(Vec<f32>, f64)>> = vec![None; xs.len()];
    std::thread::scope(|scope| {
        let mut slots = out.as_mut_slice();
        let mut inputs = xs;
        while !inputs.is_empty() {
            let take = chunk.min(inputs.len());
            let (my_in, rest_in) = inputs.split_at(take);
            let (my_out, rest_out) = slots.split_at_mut(take);
            inputs = rest_in;
            slots = rest_out;
            scope.spawn(move || {
                for (slot, x) in my_out.iter_mut().zip(my_in) {
                    let r = svc
                        .call(app, x.clone())
                        .expect("determinism drivers never expect errors");
                    *slot = Some((r.out, r.timing.total_us()));
                }
            });
        }
    });
    let after = svc.stats();
    assert_eq!(
        after.requests - before.requests,
        xs.len() + (after.errors - before.errors),
        "requests in must balance responses out + errors"
    );
    let mut totals = Vec::with_capacity(xs.len());
    let outs: Vec<Vec<f32>> = out
        .into_iter()
        .map(|slot| {
            let (row, total_us) =
                slot.expect("every request was answered");
            totals.push(total_us);
            row
        })
        .collect();
    let lat = crate::serve::LatencyStats::from_us(&totals);
    assert!(
        lat.p50_us <= lat.p99_us && lat.p99_us <= lat.max_us,
        "latency order statistics inverted: p50 {} p99 {} max {}",
        lat.p50_us,
        lat.p99_us,
        lat.max_us
    );
    outs
}

/// Cross-mode equivalence harness: drives the same inputs through
/// every [`ExecMode`](crate::coordinator::ExecMode) × worker count ×
/// stage count and asserts every run is **bitwise identical** to the
/// sequential reference engine. `tests/pipeline_determinism.rs` runs
/// it over every registered app; new backends and exec modes get
/// equivalence coverage by constructing one of these.
pub struct ExecModeHarness {
    /// Worker-pool sizes to sweep (data-parallel shard counts;
    /// hybrid replica counts).
    pub workers: Vec<usize>,
    /// Stage counts to sweep for the pipelined modes (the engine
    /// clamps each to the app's layer count).
    pub stages: Vec<usize>,
}

impl Default for ExecModeHarness {
    /// The acceptance sweep: workers {1, 2, 4}, stage counts {2, 4}.
    fn default() -> ExecModeHarness {
        ExecModeHarness { workers: vec![1, 2, 4], stages: vec![2, 4] }
    }
}

impl ExecModeHarness {
    /// The default sweep (see [`ExecModeHarness::default`]).
    pub fn new() -> ExecModeHarness {
        ExecModeHarness::default()
    }

    /// One configured run; panics with the full configuration on any
    /// engine error, and checks the pipelined modes actually recorded
    /// their per-stage report.
    fn run(
        net: &crate::config::Network,
        params: &[crate::runtime::ArrayF32],
        xs: &[Vec<f32>],
        mode: crate::coordinator::ExecMode,
        workers: usize,
        stages: usize,
        encode: bool,
    ) -> Vec<Vec<f32>> {
        use crate::coordinator::{Engine, ExecMode};
        let engine = Engine::native()
            .with_workers(workers)
            .with_exec(mode)
            .with_pipeline_stages(stages);
        let ctx = format!(
            "{} {mode} workers={workers} stages={stages} encode={encode}",
            net.name
        );
        let out = if encode {
            engine.encode(net, params, xs)
        } else {
            engine.infer(net, params, xs)
        }
        .unwrap_or_else(|e| panic!("{ctx}: {e}"));
        if mode != ExecMode::DataParallel && !xs.is_empty() {
            let report = engine
                .last_pipeline_report()
                .unwrap_or_else(|| panic!("{ctx}: no pipeline report"));
            assert_eq!(report.samples, xs.len(), "{ctx}");
            assert!(!report.stages.is_empty(), "{ctx}");
        }
        out
    }

    /// Assert every exec mode × worker count × stage count reproduces
    /// the sequential reference bit for bit, over `net`'s forward
    /// output — and, for autoencoders, over the bottleneck code too
    /// (the code output rides the pipeline mid-stage).
    pub fn assert_bit_identical(
        &self,
        net: &crate::config::Network,
        params: &[crate::runtime::ArrayF32],
        xs: &[Vec<f32>],
    ) {
        use crate::config::AppKind;
        use crate::coordinator::ExecMode;
        let encodes: &[bool] = if net.kind == AppKind::Autoencoder {
            &[false, true]
        } else {
            &[false]
        };
        for &encode in encodes {
            let reference = Self::run(
                net, params, xs, ExecMode::DataParallel, 1, 0, encode,
            );
            for &w in &self.workers {
                let dp = Self::run(
                    net, params, xs, ExecMode::DataParallel, w, 0, encode,
                );
                assert_eq!(
                    dp, reference,
                    "{} data-parallel workers={w} encode={encode} \
                     diverged from sequential",
                    net.name
                );
                for &s in &self.stages {
                    for mode in [ExecMode::Pipelined, ExecMode::Hybrid] {
                        let got = Self::run(
                            net, params, xs, mode, w, s, encode,
                        );
                        assert_eq!(
                            got, reference,
                            "{} {mode} workers={w} stages={s} \
                             encode={encode} diverged from sequential",
                            net.name
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_every_case() {
        let mut n = 0;
        forall("count", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    fn with_env_sets_and_restores() {
        // Probe key unique to this test, ambient-unset; every mutation
        // goes through with_env itself so no write happens outside its
        // lock (raw set_var here would race other threads' locked
        // overrides).
        let key = "RESTREAM_WITH_ENV_PROBE";
        assert!(std::env::var(key).is_err());
        let out = with_env(&[(key, Some("inside"))], || {
            assert_eq!(std::env::var(key).unwrap(), "inside");
            42
        });
        assert_eq!(out, 42);
        assert!(std::env::var(key).is_err(), "override not rolled back");
        // removing an absent variable is a no-op and still restores
        with_env(&[(key, None)], || {
            assert!(std::env::var(key).is_err());
        });
        assert!(std::env::var(key).is_err());
    }

    #[test]
    fn with_env_restores_on_panic() {
        let key = "RESTREAM_WITH_ENV_PANIC_PROBE";
        assert!(std::env::var(key).is_err());
        let result = std::panic::catch_unwind(|| {
            with_env(&[(key, Some("scoped"))], || panic!("inner"));
        });
        assert!(result.is_err());
        assert!(
            std::env::var(key).is_err(),
            "panicking scope must roll back"
        );
    }

    #[test]
    fn drive_service_is_input_order_deterministic() {
        use crate::config::apps;
        use crate::coordinator::{init_conductances, Engine};
        use crate::serve::{ServeConfig, Server};
        let net = apps::network("iris_ae").unwrap().clone();
        let params = init_conductances(net.layers, 11);
        let server = Server::start(
            Engine::native(),
            net,
            params,
            ServeConfig::default(),
        );
        let mut rng = Rng::seeded(9);
        let xs: Vec<Vec<f32>> =
            (0..10).map(|_| rng.vec_uniform(4, -0.5, 0.5)).collect();
        let one = drive_service(&server, "iris_ae", &xs, 1);
        let four = drive_service(&server, "iris_ae", &xs, 4);
        assert_eq!(one.len(), 10);
        assert_eq!(one, four, "outputs must depend on inputs alone");
        server.shutdown();
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn forall_reports_failure() {
        forall("boom", 10, |rng| {
            if rng.uniform(0.0, 1.0) >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }
}
