//! xoshiro256++ PRNG (Blackman & Vigna public-domain reference) plus the
//! sampling helpers the simulator and tests need. Deterministic, seedable,
//! no external crates.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64, as the xoshiro authors recommend.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Raw generator state, for checkpointing the stream position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position previously read
    /// with [`Rng::state`]. The all-zero state is a xoshiro fixed point
    /// (it only ever emits zeros), so it is mapped to `seeded(0)` —
    /// no legitimate checkpoint can contain it, since seeding goes
    /// through splitmix64.
    pub fn from_state(s: [u64; 4]) -> Self {
        if s == [0; 4] {
            return Rng::seeded(0);
        }
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.unit()
    }

    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.uniform(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.unit().max(1e-300);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fill a vector with uniform f32 samples.
    pub fn vec_uniform(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32(lo, hi)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn unit_in_range_and_roughly_uniform() {
        let mut rng = Rng::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.unit();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seeded(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_shuffle_permutes() {
        let mut rng = Rng::seeded(3);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
