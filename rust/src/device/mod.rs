//! Memristor device physics — the Yakopcic generalized model (paper
//! ref [27]) with the Yu/Wong HfOx/AlOx device parameters of Fig 15.
//!
//! This is the SPICE stand-in: the same device model the paper simulates,
//! integrated with explicit Euler. It drives the Fig 15 reproduction and
//! grounds the weight-update nonlinearity assumptions of the L1
//! `weight_update` kernel (bounded conductance, threshold writes).

mod pair;
pub use pair::ConductancePair;

/// Yakopcic model parameters.
///
/// Defaults reproduce the device of paper ref [18] as parameterised in
/// Fig 15: Vp = Vn = 1.3 V, Ap = An = 5800, xp = xn = 0.9995,
/// alpha_p = alpha_n = 3, R_on ~ 10 kOhm, R_off/R_on ~ 1000, full-range
/// switch in ~20 us at 2.5 V.
#[derive(Clone, Copy, Debug)]
pub struct MemristorParams {
    /// Positive / negative write thresholds (V).
    pub vp: f64,
    pub vn: f64,
    /// State-change rate magnitudes (1/s after the exponential factor).
    pub ap: f64,
    pub an: f64,
    /// Window boundary points.
    pub xp: f64,
    pub xn: f64,
    /// Window decay exponents.
    pub alpha_p: f64,
    pub alpha_n: f64,
    /// I-V amplitude factors (A) for V >= 0 / V < 0.
    pub a1: f64,
    pub a2: f64,
    /// I-V sinh slope (1/V).
    pub b: f64,
    /// Minimum state (sets R_off = R_on / x_min).
    pub x_min: f64,
}

impl Default for MemristorParams {
    fn default() -> Self {
        // a1 chosen so R(x=1) at a 0.5 V read is ~10 kOhm:
        // I = a1 * sinh(b * 0.5), R = 0.5 / I.
        let b = 3.0;
        let a1 = 0.5 / (10.0e3 * (b * 0.5f64).sinh());
        MemristorParams {
            vp: 1.3,
            vn: 1.3,
            ap: 5800.0,
            an: 5800.0,
            xp: 0.9995,
            xn: 0.9995,
            alpha_p: 3.0,
            alpha_n: 3.0,
            a1,
            a2: a1,
            b,
            x_min: 1e-3,
        }
    }
}

/// One memristor with internal state `x` in `[x_min, 1]`.
#[derive(Clone, Copy, Debug)]
pub struct Memristor {
    pub params: MemristorParams,
    pub x: f64,
}

impl Memristor {
    /// A fresh device formed at high resistance (paper training step 1:
    /// "initialize the memristors with high random resistances").
    pub fn fresh(params: MemristorParams) -> Self {
        Memristor { params, x: params.x_min }
    }

    pub fn with_state(params: MemristorParams, x: f64) -> Self {
        Memristor { params, x: x.clamp(params.x_min, 1.0) }
    }

    /// Device current at voltage `v` (A).
    pub fn current(&self, v: f64) -> f64 {
        let p = &self.params;
        let amp = if v >= 0.0 { p.a1 } else { p.a2 };
        amp * self.x * (p.b * v).sinh()
    }

    /// Small-signal conductance at read voltage `v_read` (S).
    pub fn conductance(&self, v_read: f64) -> f64 {
        self.current(v_read) / v_read
    }

    /// Resistance at the standard 0.5 V read (Ohm).
    pub fn resistance(&self) -> f64 {
        1.0 / self.conductance(0.5)
    }

    /// Voltage-dependent state-change rate g(V): zero below threshold —
    /// this is what lets half-selected crossbar devices keep their state.
    fn g(&self, v: f64) -> f64 {
        let p = &self.params;
        if v > p.vp {
            p.ap * (v.exp() - p.vp.exp())
        } else if v < -p.vn {
            -p.an * ((-v).exp() - p.vn.exp())
        } else {
            0.0
        }
    }

    /// Motion window f(x): slows ion motion near the state boundaries.
    fn f(&self, x: f64, increasing: bool) -> f64 {
        let p = &self.params;
        if increasing {
            if x >= p.xp {
                let wp = (p.xp - x) / (1.0 - p.xp) + 1.0;
                (-p.alpha_p * (x - p.xp)).exp() * wp.max(0.0)
            } else {
                1.0
            }
        } else if x <= 1.0 - p.xn {
            let wn = x / (1.0 - p.xn);
            (p.alpha_n * (x + p.xn - 1.0)).exp() * wn.max(0.0)
        } else {
            1.0
        }
    }

    /// Advance the state by `dt` seconds under applied voltage `v`
    /// (explicit Euler; callers pick dt << switching time).
    pub fn step(&mut self, v: f64, dt: f64) {
        let g = self.g(v);
        if g == 0.0 {
            return;
        }
        let dx = g * self.f(self.x, g > 0.0) * dt;
        self.x = (self.x + dx).clamp(self.params.x_min, 1.0);
    }

    /// Apply a rectangular write pulse.
    pub fn pulse(&mut self, v: f64, duration_s: f64, dt: f64) {
        let mut t = 0.0;
        while t < duration_s {
            let step = dt.min(duration_s - t);
            self.step(v, step);
            t += step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Memristor {
        Memristor::fresh(MemristorParams::default())
    }

    #[test]
    fn resistance_range_matches_device() {
        let p = MemristorParams::default();
        let off = Memristor::with_state(p, p.x_min);
        let on = Memristor::with_state(p, 1.0);
        let r_on = on.resistance();
        let r_off = off.resistance();
        assert!((r_on - 10e3).abs() / 10e3 < 0.05, "R_on {r_on}");
        assert!((r_off / r_on - 1000.0).abs() / 1000.0 < 0.05,
                "ratio {}", r_off / r_on);
    }

    #[test]
    fn read_voltage_does_not_disturb() {
        let mut m = dev();
        let x0 = m.x;
        // 1 ms at the 0.5 V read rail — far below the 1.3 V threshold.
        m.pulse(0.5, 1e-3, 1e-7);
        m.pulse(-0.5, 1e-3, 1e-7);
        assert_eq!(m.x, x0);
    }

    #[test]
    fn full_switch_in_about_20us_at_2p5v() {
        let mut m = dev();
        m.pulse(2.5, 20e-6, 1e-9);
        assert!(m.x > 0.95, "x after 20us: {}", m.x);
        // and back down
        m.pulse(-2.5, 20e-6, 1e-9);
        assert!(m.x < 0.05, "x after erase: {}", m.x);
    }

    #[test]
    fn state_stays_bounded_under_overdrive() {
        let mut m = dev();
        m.pulse(3.5, 1e-3, 1e-8);
        assert!(m.x <= 1.0);
        m.pulse(-3.5, 1e-3, 1e-8);
        assert!(m.x >= m.params.x_min);
    }

    #[test]
    fn iv_curve_is_odd_and_monotone_in_x() {
        let p = MemristorParams::default();
        let lo = Memristor::with_state(p, 0.2);
        let hi = Memristor::with_state(p, 0.8);
        assert!(hi.current(0.5) > lo.current(0.5));
        assert!((lo.current(0.5) + lo.current(-0.5)).abs() < 1e-12);
    }

    #[test]
    fn partial_pulse_gives_partial_switch() {
        // Pulse-duration modulation — the training circuit's knob (Fig 11).
        let mut short = dev();
        let mut long = dev();
        short.pulse(2.0, 1e-6, 1e-9);
        long.pulse(2.0, 4e-6, 1e-9);
        assert!(short.x > short.params.x_min);
        assert!(long.x > short.x);
    }
}
