//! Differential conductance pair: two memristors encode one signed weight
//! (paper section III.B, two memristors per synapse).

use super::{Memristor, MemristorParams};

/// A (sigma+, sigma-) pair on two crossbar columns. Weight is the
/// normalised conductance difference, matching the L1 kernels'
/// `w = g+ - g-` convention with `g` normalised so `g(x=1) = 1`.
#[derive(Clone, Copy, Debug)]
pub struct ConductancePair {
    pub pos: Memristor,
    pub neg: Memristor,
}

impl ConductancePair {
    pub fn fresh(params: MemristorParams) -> Self {
        ConductancePair {
            pos: Memristor::fresh(params),
            neg: Memristor::fresh(params),
        }
    }

    /// Normalised conductances (x is proportional to conductance in the
    /// Yakopcic model, so the normalised g *is* the state).
    pub fn g_pos(&self) -> f64 {
        self.pos.x
    }

    pub fn g_neg(&self) -> f64 {
        self.neg.x
    }

    /// Effective synaptic weight.
    pub fn weight(&self) -> f64 {
        self.pos.x - self.neg.x
    }

    /// Apply a training update of `dw`: +dw/2 on sigma+, -dw/2 on sigma-
    /// (paper section III.F step 3), via threshold-crossing pulses whose
    /// duration encodes the magnitude. `dt` is the integration step.
    pub fn apply_dw(&mut self, dw: f64, dt: f64) {
        // Pulse amplitude fixed just above threshold; duration modulated.
        // At 2.0 V, dx/dt = ap*(e^2 - e^1.3) ~= 2.16e4 /s  => the duration
        // for a state change |dw|/2 is |dw| / (2 * rate).
        let rate = self.pos.params.ap
            * ((2.0f64).exp() - self.pos.params.vp.exp());
        let dur = (dw.abs() / 2.0) / rate;
        if dw >= 0.0 {
            self.pos.pulse(2.0, dur, dt);
            self.neg.pulse(-2.0, dur, dt);
        } else {
            self.pos.pulse(-2.0, dur, dt);
            self.neg.pulse(2.0, dur, dt);
        }
    }

    /// Program the pair to a target weight by iterated write-verify
    /// (how the configuration phase loads pre-trained weights).
    pub fn program_weight(&mut self, target: f64, tol: f64, dt: f64) -> usize {
        let mut iters = 0;
        while (self.weight() - target).abs() > tol && iters < 200 {
            self.apply_dw(target - self.weight(), dt);
            iters += 1;
        }
        iters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    fn pair() -> ConductancePair {
        ConductancePair::fresh(MemristorParams::default())
    }

    #[test]
    fn fresh_pair_is_zero_weight() {
        assert!(pair().weight().abs() < 1e-9);
    }

    #[test]
    fn apply_dw_moves_weight_in_the_right_direction() {
        let mut p = pair();
        p.apply_dw(0.2, 1e-9);
        assert!(p.weight() > 0.05, "w={}", p.weight());
        let w = p.weight();
        p.apply_dw(-0.1, 1e-9);
        assert!(p.weight() < w);
    }

    #[test]
    fn program_weight_converges_across_targets() {
        forall("program_weight", 20, |rng: &mut Rng| {
            let target = rng.uniform(-0.8, 0.8);
            let mut p = pair();
            p.program_weight(target, 0.01, 1e-9);
            let err = (p.weight() - target).abs();
            if err > 0.02 {
                return Err(format!("target {target} err {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn conductances_stay_physical() {
        let mut p = pair();
        for _ in 0..50 {
            p.apply_dw(0.5, 1e-8);
        }
        assert!(p.g_pos() <= 1.0 && p.g_neg() >= p.pos.params.x_min);
        // Weight saturates at the device limit.
        assert!(p.weight() <= 1.0);
    }
}
