//! Checkpoint manifest: the per-file integrity record and the atomic
//! commit protocol.
//!
//! A checkpoint directory is *invisible until complete*: every payload
//! file is written into a `.tmp-…` staging directory, the `MANIFEST`
//! (listing each file's byte length and FNV-1a 64 checksum) is written
//! last, and the staging directory is renamed into place in one
//! filesystem operation. A crash at any point leaves either the old
//! checkpoint or a `.tmp-…` directory that [`super::latest`] ignores —
//! never a half-written checkpoint that a restore could read.
//!
//! The manifest is a small LF-terminated text file:
//!
//! ```text
//! restream-checkpoint v1
//! app iris_ae
//! stage 0 epoch 2
//! file state.bin 167 9d2c5e8f01a3b47c
//! file params.bin 288 0f1e2d3c4b5a6978
//! ```
//!
//! Only the header and `file` lines are load-bearing; the `app`/`stage`
//! lines are for humans running `cat`.

use std::fs;
use std::path::{Path, PathBuf};

use super::codec::fnv64;
use super::CheckpointError;

/// Name of the manifest file inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "MANIFEST";
/// First line of every manifest; bump the `v` on a format break.
pub const MANIFEST_HEADER: &str = "restream-checkpoint v1";

/// One payload file recorded in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// File name relative to the checkpoint directory.
    pub name: String,
    /// Byte length (a short file is reported as Truncated, not as a
    /// checksum failure — the distinction matters when diagnosing a
    /// crashed copy vs flipped bits).
    pub len: u64,
    /// FNV-1a 64 checksum of the whole file.
    pub fnv: u64,
}

/// Render the manifest text for `entries` plus the human header lines.
pub fn render(
    app: &str,
    stage: usize,
    epoch: usize,
    entries: &[ManifestEntry],
) -> String {
    let mut s = String::new();
    s.push_str(MANIFEST_HEADER);
    s.push('\n');
    s.push_str(&format!("app {app}\n"));
    s.push_str(&format!("stage {stage} epoch {epoch}\n"));
    for e in entries {
        s.push_str(&format!("file {} {} {:016x}\n", e.name, e.len, e.fnv));
    }
    s
}

/// Parse a manifest back into its `file` entries.
pub fn parse(
    text: &str,
    path: &Path,
) -> Result<Vec<ManifestEntry>, CheckpointError> {
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h == MANIFEST_HEADER => {}
        other => {
            return Err(CheckpointError::BadFormat {
                file: path.to_path_buf(),
                detail: format!(
                    "manifest header {other:?}, want {MANIFEST_HEADER:?}"
                ),
            })
        }
    }
    let mut entries = Vec::new();
    for line in lines {
        let mut parts = line.split_whitespace();
        if parts.next() != Some("file") {
            continue; // informational line
        }
        let bad = || CheckpointError::BadFormat {
            file: path.to_path_buf(),
            detail: format!("unparseable manifest line: {line:?}"),
        };
        let name = parts.next().ok_or_else(bad)?.to_string();
        let len: u64 =
            parts.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let fnv = u64::from_str_radix(parts.next().ok_or_else(bad)?, 16)
            .map_err(|_| bad())?;
        entries.push(ManifestEntry { name, len, fnv });
    }
    if entries.is_empty() {
        return Err(CheckpointError::BadFormat {
            file: path.to_path_buf(),
            detail: "manifest lists no files".to_string(),
        });
    }
    Ok(entries)
}

/// Read and integrity-check every file the checkpoint directory's
/// manifest lists, returning `(name, bytes)` pairs in manifest order.
/// Length mismatches surface as [`CheckpointError::Truncated`], content
/// corruption as [`CheckpointError::ChecksumMismatch`].
pub fn read_verified(
    dir: &Path,
) -> Result<Vec<(String, Vec<u8>)>, CheckpointError> {
    let mpath = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&mpath).map_err(|err| {
        CheckpointError::io(mpath.clone(), err)
    })?;
    let entries = parse(&text, &mpath)?;
    let mut out = Vec::with_capacity(entries.len());
    for e in &entries {
        let fpath = dir.join(&e.name);
        let bytes = fs::read(&fpath)
            .map_err(|err| CheckpointError::io(fpath.clone(), err))?;
        if bytes.len() as u64 != e.len {
            return Err(CheckpointError::Truncated {
                file: fpath,
                needed: e.len,
                got: bytes.len() as u64,
            });
        }
        let got = fnv64(&bytes);
        if got != e.fnv {
            return Err(CheckpointError::ChecksumMismatch {
                file: fpath,
                expected: e.fnv,
                got,
            });
        }
        out.push((e.name.clone(), bytes));
    }
    Ok(out)
}

/// Atomically commit a checkpoint: stage every `(name, bytes)` file
/// plus the manifest under `dir/.tmp-<name>`, then rename the staging
/// directory to `dir/<name>` (replacing any previous checkpoint of the
/// same name). Returns the final checkpoint path.
pub fn commit(
    dir: &Path,
    name: &str,
    app: &str,
    stage: usize,
    epoch: usize,
    files: &[(&str, &[u8])],
) -> Result<PathBuf, CheckpointError> {
    fs::create_dir_all(dir)
        .map_err(|err| CheckpointError::io(dir.to_path_buf(), err))?;
    let staging = dir.join(format!(".tmp-{name}"));
    let final_dir = dir.join(name);
    if staging.exists() {
        fs::remove_dir_all(&staging)
            .map_err(|err| CheckpointError::io(staging.clone(), err))?;
    }
    fs::create_dir_all(&staging)
        .map_err(|err| CheckpointError::io(staging.clone(), err))?;
    let mut entries = Vec::with_capacity(files.len());
    for (fname, bytes) in files {
        let fpath = staging.join(fname);
        fs::write(&fpath, bytes)
            .map_err(|err| CheckpointError::io(fpath, err))?;
        entries.push(ManifestEntry {
            name: (*fname).to_string(),
            len: bytes.len() as u64,
            fnv: fnv64(bytes),
        });
    }
    let mpath = staging.join(MANIFEST_FILE);
    fs::write(&mpath, render(app, stage, epoch, &entries))
        .map_err(|err| CheckpointError::io(mpath, err))?;
    if final_dir.exists() {
        fs::remove_dir_all(&final_dir)
            .map_err(|err| CheckpointError::io(final_dir.clone(), err))?;
    }
    fs::rename(&staging, &final_dir)
        .map_err(|err| CheckpointError::io(final_dir.clone(), err))?;
    Ok(final_dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "restream-manifest-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn render_parse_roundtrip() {
        let entries = vec![
            ManifestEntry {
                name: "state.bin".into(),
                len: 167,
                fnv: 0x9d2c_5e8f_01a3_b47c,
            },
            ManifestEntry {
                name: "params.bin".into(),
                len: 288,
                fnv: 0x0f1e_2d3c_4b5a_6978,
            },
        ];
        let text = render("iris_ae", 0, 2, &entries);
        let back = parse(&text, Path::new("MANIFEST")).unwrap();
        assert_eq!(back, entries);
    }

    #[test]
    fn bad_header_and_garbled_lines_are_typed() {
        let p = Path::new("MANIFEST");
        assert!(matches!(
            parse("not-a-manifest\n", p),
            Err(CheckpointError::BadFormat { .. })
        ));
        let text = format!("{MANIFEST_HEADER}\nfile a.bin nope ffff\n");
        assert!(matches!(
            parse(&text, p),
            Err(CheckpointError::BadFormat { .. })
        ));
        let text = format!("{MANIFEST_HEADER}\napp only-info-lines\n");
        assert!(matches!(
            parse(&text, p),
            Err(CheckpointError::BadFormat { .. })
        ));
    }

    #[test]
    fn commit_then_verify_roundtrips_and_replaces() {
        let dir = scratch("commit");
        let path = commit(
            &dir,
            "ckpt-s000-e000001",
            "iris_ae",
            0,
            1,
            &[("state.bin", b"abc".as_slice()), ("params.bin", b"defg")],
        )
        .unwrap();
        assert!(path.ends_with("ckpt-s000-e000001"));
        let files = read_verified(&path).unwrap();
        assert_eq!(files[0].0, "state.bin");
        assert_eq!(files[0].1, b"abc");
        assert_eq!(files[1].1, b"defg");
        // committing the same name again replaces the old contents
        let path2 = commit(
            &dir,
            "ckpt-s000-e000001",
            "iris_ae",
            0,
            1,
            &[("state.bin", b"xyz".as_slice()), ("params.bin", b"defg")],
        )
        .unwrap();
        assert_eq!(path, path2);
        let files = read_verified(&path2).unwrap();
        assert_eq!(files[0].1, b"xyz");
        // no staging leftovers
        assert!(!dir.join(".tmp-ckpt-s000-e000001").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_classes_are_distinguished() {
        let dir = scratch("corrupt");
        let path = commit(
            &dir,
            "ckpt-s000-e000002",
            "iris_ae",
            0,
            2,
            &[("state.bin", b"hello-checkpoint".as_slice())],
        )
        .unwrap();
        // truncation → Truncated (length check fires before checksum)
        fs::write(path.join("state.bin"), b"hello").unwrap();
        match read_verified(&path) {
            Err(CheckpointError::Truncated { needed, got, .. }) => {
                assert_eq!(needed, 16);
                assert_eq!(got, 5);
            }
            other => panic!("want Truncated, got {other:?}"),
        }
        // same length, flipped bits → ChecksumMismatch
        fs::write(path.join("state.bin"), b"hello-checkpoinX").unwrap();
        assert!(matches!(
            read_verified(&path),
            Err(CheckpointError::ChecksumMismatch { .. })
        ));
        // a listed file missing entirely → Io (with the file's path)
        fs::remove_file(path.join("state.bin")).unwrap();
        assert!(matches!(
            read_verified(&path),
            Err(CheckpointError::Io { .. })
        ));
        let _ = fs::remove_dir_all(&dir);
    }
}
