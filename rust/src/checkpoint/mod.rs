//! Versioned training-state checkpoints: crash-safe snapshots of
//! everything a training run needs to resume **bit-identically**.
//!
//! The paper's architecture trains *and* serves; production training
//! additionally survives crashes. A checkpoint captures the full
//! resume state at an epoch boundary:
//!
//! * layer conductances (the live [`ArrayF32`] parameter pairs, plus
//!   the completed-stage encoder pairs of a DR pipeline),
//! * the optimizer cursor (completed epochs, samples seen, partial
//!   loss curve, mini-batch size, learning rate, seed),
//! * the RNG stream position (the raw xoshiro256++ state of the epoch
//!   shuffler) and the current sample-order permutation,
//! * app identity (name, kind, layer list) and the build's hardware
//!   fingerprint ([`hwspec_fingerprint`]).
//!
//! Because PRs 2–5 pinned the determinism contract — fixed shard
//! boundaries, left-to-right reduction, epoch order a function of the
//! seed stream alone — restoring this state and continuing produces
//! conductances **byte-identical** to the uninterrupted run
//! (`tests/checkpoint_determinism.rs` proves it per app).
//!
//! On disk a checkpoint is a directory committed atomically (staging
//! dir + rename, manifest with per-file FNV-1a checksums — see
//! [`manifest`]) holding two payloads encoded by the fixed-width LE
//! [`codec`]:
//!
//! | file | contents |
//! |------|----------|
//! | `state.bin`  | magic `RSCK`, version, app identity, fingerprint, optimizer cursor, RNG state, order, loss curve |
//! | `params.bin` | magic `RSPW`, version, encoder arrays, live parameter arrays |
//! | `MANIFEST`   | header, per-file byte length + FNV-1a 64 checksum |
//!
//! Failures are **typed** ([`CheckpointError`]) and total: a truncated
//! file, flipped bit, foreign app, or mismatched hardware build is
//! reported before any training state is touched — never a panic,
//! never a half-applied restore.

pub mod codec;
pub mod manifest;

pub use codec::fnv64;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::config::hwspec as hw;
use crate::config::{apps, AppKind, Network};
use crate::runtime::ArrayF32;

/// On-disk format version of `state.bin`/`params.bin`.
pub const FORMAT_VERSION: u32 = 1;

const STATE_MAGIC: &[u8; 4] = b"RSCK";
const PARAMS_MAGIC: &[u8; 4] = b"RSPW";
const STATE_FILE: &str = "state.bin";
const PARAMS_FILE: &str = "params.bin";

/// Everything that can go wrong saving or restoring a checkpoint.
/// Every variant names the offending file or quantity so an operator
/// can tell a crashed copy (truncation) from bit rot (checksum) from a
/// checkpoint that simply belongs to a different app or build.
#[derive(Debug)]
pub enum CheckpointError {
    /// Filesystem operation failed.
    Io { path: PathBuf, err: std::io::Error },
    /// No checkpoint found where one was required.
    Missing { path: PathBuf },
    /// A file is shorter than its manifest entry or a field's decoder
    /// needs bytes the payload does not have.
    Truncated { file: PathBuf, needed: u64, got: u64 },
    /// File length matches but the FNV-1a checksum does not.
    ChecksumMismatch { file: PathBuf, expected: u64, got: u64 },
    /// Structurally invalid payload (bad magic, version, field).
    BadFormat { file: PathBuf, detail: String },
    /// A stored `u64` length/index does not fit this target's `usize`.
    Overflow { file: PathBuf, field: &'static str, value: u64 },
    /// Checkpoint belongs to a different application.
    AppMismatch { expected: String, found: String },
    /// Checkpoint was written under different hardware constants.
    FingerprintMismatch { expected: u64, found: u64 },
    /// Checkpoint is internally inconsistent with the requested resume
    /// (dataset size, hyper-parameters, order length…).
    StateMismatch { detail: String },
}

impl CheckpointError {
    pub(crate) fn io(path: PathBuf, err: std::io::Error) -> CheckpointError {
        CheckpointError::Io { path, err }
    }
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io { path, err } => {
                write!(f, "checkpoint I/O on {}: {err}", path.display())
            }
            CheckpointError::Missing { path } => {
                write!(f, "no checkpoint found at {}", path.display())
            }
            CheckpointError::Truncated { file, needed, got } => write!(
                f,
                "checkpoint file {} truncated: need {needed} bytes, \
                 have {got}",
                file.display()
            ),
            CheckpointError::ChecksumMismatch { file, expected, got } => {
                write!(
                    f,
                    "checksum mismatch in {}: manifest says {expected:016x}, \
                     file hashes to {got:016x}",
                    file.display()
                )
            }
            CheckpointError::BadFormat { file, detail } => {
                write!(f, "malformed checkpoint {}: {detail}", file.display())
            }
            CheckpointError::Overflow { file, field, value } => write!(
                f,
                "checkpoint {}: {field} = {value} does not fit this \
                 target's usize",
                file.display()
            ),
            CheckpointError::AppMismatch { expected, found } => write!(
                f,
                "checkpoint belongs to app '{found}', not '{expected}'"
            ),
            CheckpointError::FingerprintMismatch { expected, found } => {
                write!(
                    f,
                    "hwspec fingerprint mismatch: this build is \
                     {expected:016x}, checkpoint was written under \
                     {found:016x}"
                )
            }
            CheckpointError::StateMismatch { detail } => {
                write!(f, "checkpoint does not match this run: {detail}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Fingerprint of the hardware constants the training math depends on.
///
/// FNV-1a 64 over the LE bytes of every `hwspec` constant plus the
/// coordinator tile sizes — if any of them changes, old checkpoints'
/// conductances were trained under different quantisers/shard shapes
/// and a resume would silently diverge, so [`TrainState::verify_matches`]
/// refuses them with [`CheckpointError::FingerprintMismatch`].
/// `python/tests/gen_ckpt_fixture.py` computes the same value from the
/// Python hwspec mirror; the golden-fixture test cross-checks the two.
pub fn hwspec_fingerprint() -> u64 {
    let mut bytes = Vec::with_capacity(26 * 8);
    for v in [
        hw::V_RAIL,
        hw::H_SLOPE,
        hw::H_CLIP_IN,
        hw::ERR_MAX,
        hw::G_MIN,
        hw::G_MAX,
    ] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for v in [
        hw::OUT_BITS as u64,
        hw::ERR_BITS as u64,
        hw::LUT_SIZE as u64,
        hw::CORE_INPUTS as u64,
        hw::CORE_NEURONS as u64,
        hw::KMEANS_MAX_CENTRES as u64,
        hw::KMEANS_MAX_DIM as u64,
        apps::GRAD_TILE as u64,
        apps::FWD_BATCH as u64,
        apps::TRAIN_CHUNK as u64,
    ] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fnv64(&bytes)
}

fn kind_tag(kind: AppKind) -> u8 {
    match kind {
        AppKind::Classifier => 0,
        AppKind::Autoencoder => 1,
        AppKind::DimReduction => 2,
        AppKind::Kmeans => 3,
    }
}

/// Full resume state of a training run at an epoch boundary.
///
/// Fields are public so tests (and tooling) can inspect or perturb
/// them; [`save`]/[`load`] are the only serialisation paths.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// Registered app name (checked against the resume target).
    pub app: String,
    /// [`kind_tag`] of the app's [`AppKind`].
    pub kind: u8,
    /// Layer sizes, input first (checked against the resume target).
    pub layers: Vec<usize>,
    /// [`hwspec_fingerprint`] of the build that wrote the checkpoint.
    pub fingerprint: u64,
    /// Training seed the run was started with.
    pub seed: u64,
    /// Learning rate (bit-compared on resume: a different lr cannot
    /// reproduce the uninterrupted run).
    pub lr: f32,
    /// Mini-batch size (1 = the sequential stochastic-BP path).
    pub batch: usize,
    /// DR pipeline stage the cursor sits in (0 for plain apps).
    pub stage: usize,
    /// Completed epochs within the current stage.
    pub epochs_done: usize,
    /// Samples consumed so far (current stage).
    pub samples_seen: usize,
    /// Dataset size the order permutation covers.
    pub n_samples: usize,
    /// Raw xoshiro256++ state of the epoch shuffler — the RNG stream
    /// position, so the next epoch's shuffle continues the exact
    /// sequence the uninterrupted run would have drawn.
    pub rng: [u64; 4],
    /// Current sample-order permutation (the cumulative result of
    /// `epochs_done` in-place shuffles).
    pub order: Vec<usize>,
    /// Per-epoch mean losses accumulated so far (current stage).
    pub loss_curve: Vec<f32>,
    /// Encoder conductance pairs of completed DR stages (empty for
    /// plain apps).
    pub encoder: Vec<ArrayF32>,
    /// Live training conductances `[gp0, gn0, gp1, gn1, …]`.
    pub params: Vec<ArrayF32>,
}

impl TrainState {
    /// Fresh state for `net` at epoch 0 of stage `stage` — the caller
    /// fills in the cursor fields as training progresses.
    pub fn fresh(net: &Network, seed: u64, lr: f32, batch: usize) -> Self {
        TrainState {
            app: net.name.to_string(),
            kind: kind_tag(net.kind),
            layers: net.layers.to_vec(),
            fingerprint: hwspec_fingerprint(),
            seed,
            lr,
            batch: batch.max(1),
            stage: 0,
            epochs_done: 0,
            samples_seen: 0,
            n_samples: 0,
            rng: [0; 4],
            order: Vec::new(),
            loss_curve: Vec::new(),
            encoder: Vec::new(),
            params: Vec::new(),
        }
    }

    /// Directory name this state saves under — lexicographic order of
    /// the names equals (stage, epoch) order, which is what makes
    /// [`latest`] a plain string max.
    pub fn dir_name(&self) -> String {
        format!("ckpt-s{:03}-e{:06}", self.stage, self.epochs_done)
    }

    /// Verify this checkpoint belongs to `net` as compiled into this
    /// binary: app name, kind, layer list and hardware fingerprint.
    /// Typed errors, no partial effects.
    pub fn verify_matches(
        &self,
        net: &Network,
    ) -> Result<(), CheckpointError> {
        if self.app != net.name {
            return Err(CheckpointError::AppMismatch {
                expected: net.name.to_string(),
                found: self.app.clone(),
            });
        }
        if self.layers != net.layers || self.kind != kind_tag(net.kind) {
            return Err(CheckpointError::StateMismatch {
                detail: format!(
                    "app '{}' is registered with layers {:?} (kind {}), \
                     checkpoint carries {:?} (kind {})",
                    net.name,
                    net.layers,
                    kind_tag(net.kind),
                    self.layers,
                    self.kind
                ),
            });
        }
        let expected = hwspec_fingerprint();
        if self.fingerprint != expected {
            return Err(CheckpointError::FingerprintMismatch {
                expected,
                found: self.fingerprint,
            });
        }
        Ok(())
    }

    /// Total payload bytes of the two binary files (for bandwidth
    /// accounting in `perf_ckpt`).
    pub fn payload_bytes(&self) -> u64 {
        (self.encode_state().len() + self.encode_params().len()) as u64
    }

    fn encode_state(&self) -> Vec<u8> {
        let mut w = codec::Writer::new();
        w.magic(STATE_MAGIC);
        w.u32(FORMAT_VERSION);
        w.bytes(self.app.as_bytes());
        w.u8(self.kind);
        w.index_vec(&self.layers);
        w.u64(self.fingerprint);
        w.u64(self.seed);
        w.f32(self.lr);
        w.u64(self.batch as u64);
        w.u64(self.stage as u64);
        w.u64(self.epochs_done as u64);
        w.u64(self.samples_seen as u64);
        w.u64(self.n_samples as u64);
        for s in self.rng {
            w.u64(s);
        }
        w.index_vec(&self.order);
        w.f32_vec(&self.loss_curve);
        w.finish()
    }

    fn encode_params(&self) -> Vec<u8> {
        let mut w = codec::Writer::new();
        w.magic(PARAMS_MAGIC);
        w.u32(FORMAT_VERSION);
        w.arrays(&self.encoder);
        w.arrays(&self.params);
        w.finish()
    }

    fn decode(
        state_bytes: &[u8],
        params_bytes: &[u8],
        dir: &Path,
    ) -> Result<TrainState, CheckpointError> {
        let sp = dir.join(STATE_FILE);
        let mut r = codec::Reader::new(state_bytes, &sp);
        r.magic(STATE_MAGIC)?;
        let version = r.u32()?;
        if version != FORMAT_VERSION {
            return Err(CheckpointError::BadFormat {
                file: sp,
                detail: format!(
                    "format version {version}, this build reads \
                     {FORMAT_VERSION}"
                ),
            });
        }
        let app = String::from_utf8(r.bytes()?.to_vec()).map_err(|e| {
            CheckpointError::BadFormat {
                file: sp.clone(),
                detail: format!("app name is not utf-8: {e}"),
            }
        })?;
        let kind = r.u8()?;
        let layers = r.index_vec("layers")?;
        let fingerprint = r.u64()?;
        let seed = r.u64()?;
        let lr = r.f32()?;
        let batch = r.to_index(r_u64(&mut r)?, "batch")?;
        let stage = r.to_index(r_u64(&mut r)?, "stage")?;
        let epochs_done = r.to_index(r_u64(&mut r)?, "epochs_done")?;
        let samples_seen = r.to_index(r_u64(&mut r)?, "samples_seen")?;
        let n_samples = r.to_index(r_u64(&mut r)?, "n_samples")?;
        let mut rng = [0u64; 4];
        for s in rng.iter_mut() {
            *s = r.u64()?;
        }
        let order = r.index_vec("order")?;
        let loss_curve = r.f32_vec("loss_curve")?;
        r.expect_end()?;
        if order.len() != n_samples {
            return Err(CheckpointError::BadFormat {
                file: sp,
                detail: format!(
                    "order permutation has {} entries for {} samples",
                    order.len(),
                    n_samples
                ),
            });
        }
        let pp = dir.join(PARAMS_FILE);
        let mut r = codec::Reader::new(params_bytes, &pp);
        r.magic(PARAMS_MAGIC)?;
        let pversion = r.u32()?;
        if pversion != FORMAT_VERSION {
            return Err(CheckpointError::BadFormat {
                file: pp,
                detail: format!(
                    "format version {pversion}, this build reads \
                     {FORMAT_VERSION}"
                ),
            });
        }
        let encoder = r.arrays()?;
        let params = r.arrays()?;
        r.expect_end()?;
        Ok(TrainState {
            app,
            kind,
            layers,
            fingerprint,
            seed,
            lr,
            batch,
            stage,
            epochs_done,
            samples_seen,
            n_samples,
            rng,
            order,
            loss_curve,
            encoder,
            params,
        })
    }
}

// Borrow helper: `r.to_index(r.u64()?, …)` double-borrows the reader;
// route the mutable read through a free function instead.
fn r_u64(r: &mut codec::Reader<'_>) -> Result<u64, CheckpointError> {
    r.u64()
}

/// Save `state` as an atomically committed checkpoint directory under
/// `dir` (named [`TrainState::dir_name`]); returns the final path.
pub fn save(
    dir: &Path,
    state: &TrainState,
) -> Result<PathBuf, CheckpointError> {
    let state_bytes = state.encode_state();
    let params_bytes = state.encode_params();
    manifest::commit(
        dir,
        &state.dir_name(),
        &state.app,
        state.stage,
        state.epochs_done,
        &[
            (STATE_FILE, state_bytes.as_slice()),
            (PARAMS_FILE, params_bytes.as_slice()),
        ],
    )
}

/// Load and integrity-check one checkpoint directory. Verifies the
/// manifest checksums before decoding; all failures are typed.
pub fn load(ckpt_dir: &Path) -> Result<TrainState, CheckpointError> {
    let files = manifest::read_verified(ckpt_dir)?;
    let find = |name: &str| {
        files
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, b)| b.as_slice())
            .ok_or_else(|| CheckpointError::Missing {
                path: ckpt_dir.join(name),
            })
    };
    let state_bytes = find(STATE_FILE)?;
    let params_bytes = find(PARAMS_FILE)?;
    TrainState::decode(state_bytes, params_bytes, ckpt_dir)
}

/// Most recent complete checkpoint under `dir` (highest stage, then
/// epoch — the [`TrainState::dir_name`] encoding makes that a string
/// max), or `None` when the directory holds none. Staging leftovers
/// (`.tmp-…`) and directories without a manifest are ignored — they
/// are crashes, not checkpoints.
pub fn latest(dir: &Path) -> Result<Option<PathBuf>, CheckpointError> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(err) if err.kind() == std::io::ErrorKind::NotFound => {
            return Ok(None)
        }
        Err(err) => return Err(CheckpointError::io(dir.to_path_buf(), err)),
    };
    let mut best: Option<(String, PathBuf)> = None;
    for entry in entries {
        let entry =
            entry.map_err(|err| CheckpointError::io(dir.to_path_buf(), err))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.starts_with("ckpt-") {
            continue;
        }
        let path = entry.path();
        if !path.join(manifest::MANIFEST_FILE).is_file() {
            continue; // incomplete (crashed mid-commit)
        }
        let newer = match &best {
            None => true,
            Some((b, _)) => name > *b,
        };
        if newer {
            best = Some((name, path));
        }
    }
    Ok(best.map(|(_, p)| p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::init_conductances;

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "restream-ckpt-mod-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample_state(epoch: usize) -> TrainState {
        let net = apps::network("iris_ae").unwrap();
        let mut s = TrainState::fresh(net, 7, 0.5, 1);
        s.stage = 0;
        s.epochs_done = epoch;
        s.samples_seen = 6 * epoch;
        s.n_samples = 6;
        s.rng = [1, 2, 3, 4];
        s.order = vec![3, 1, 0, 2, 5, 4];
        s.loss_curve = (0..epoch).map(|e| 0.5 / (e + 1) as f32).collect();
        s.params = init_conductances(net.layers, 7);
        s
    }

    #[test]
    fn save_load_roundtrips_bit_exact() {
        let dir = scratch("roundtrip");
        let state = sample_state(2);
        let path = save(&dir, &state).unwrap();
        assert!(path.ends_with("ckpt-s000-e000002"));
        let back = load(&path).unwrap();
        assert_eq!(back, state);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_picks_highest_stage_then_epoch() {
        let dir = scratch("latest");
        assert!(latest(&dir).unwrap().is_none());
        save(&dir, &sample_state(1)).unwrap();
        save(&dir, &sample_state(3)).unwrap();
        let mut staged = sample_state(2);
        staged.stage = 1;
        save(&dir, &staged).unwrap();
        // an incomplete dir (no manifest) must be ignored
        fs::create_dir_all(dir.join("ckpt-s009-e000009")).unwrap();
        let best = latest(&dir).unwrap().unwrap();
        assert!(best.ends_with("ckpt-s001-e000002"), "{best:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_matches_rejects_foreign_apps_and_builds() {
        let net = apps::network("iris_ae").unwrap();
        let other = apps::network("iris_class").unwrap();
        let state = sample_state(1);
        state.verify_matches(net).unwrap();
        assert!(matches!(
            state.verify_matches(other),
            Err(CheckpointError::AppMismatch { .. })
        ));
        let mut poisoned = sample_state(1);
        poisoned.fingerprint ^= 1;
        assert!(matches!(
            poisoned.verify_matches(net),
            Err(CheckpointError::FingerprintMismatch { .. })
        ));
        let mut wrong_layers = sample_state(1);
        wrong_layers.layers = vec![4, 3, 4];
        assert!(matches!(
            wrong_layers.verify_matches(net),
            Err(CheckpointError::StateMismatch { .. })
        ));
    }

    #[test]
    fn fingerprint_is_stable_within_a_build() {
        assert_eq!(hwspec_fingerprint(), hwspec_fingerprint());
        assert_ne!(hwspec_fingerprint(), 0);
    }

    #[test]
    fn errors_render_their_diagnosis() {
        let e = CheckpointError::ChecksumMismatch {
            file: PathBuf::from("params.bin"),
            expected: 0xAB,
            got: 0xCD,
        };
        let msg = e.to_string();
        assert!(msg.contains("checksum"), "{msg}");
        assert!(msg.contains("00000000000000ab"), "{msg}");
        let e = CheckpointError::FingerprintMismatch {
            expected: 1,
            found: 2,
        };
        assert!(e.to_string().contains("fingerprint"));
    }
}
