//! Evaluation metrics: classification accuracy, ROC sweeps for the
//! anomaly experiment (Figs 18–20), clustering purity (k-means quality),
//! and small statistics helpers used by the benches and the serving
//! layer's latency accounting ([`mean`], [`percentile`], and the
//! bounded-memory [`histogram_quantile`] behind
//! [`crate::telemetry`]'s registry histograms).
//!
//! This module is deliberately *outside* the determinism-tagged set
//! (see `rust/lint`): everything here is report-side arithmetic whose
//! output never feeds back into training or serving results, so it is
//! also where the one sanctioned wall-clock doorway, [`Stopwatch`],
//! lives.

use std::time::Instant;

/// Wall-clock stopwatch for report timing (shard wall times, queue
/// waits, per-stage occupancy). Determinism-tagged modules must not
/// call `Instant::now` directly (lint rule D2) — timing there flows
/// through this type so every wall-clock read is auditable as
/// report-only: a `Stopwatch` yields seconds for reports and nothing
/// else, and no result math may depend on it.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Classification accuracy from predictions and labels.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).filter(|(p, t)| p == t).count() as f64
        / pred.len() as f64
}

/// One point of a detection sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RocPoint {
    pub threshold: f64,
    /// True-positive rate (detection rate over attacks).
    pub tpr: f64,
    /// False-positive rate (false detection over normals).
    pub fpr: f64,
}

/// Sweep a decision threshold over anomaly scores. `is_attack[i]`
/// labels each score; a sample is flagged when `score >= threshold`
/// (inclusive, so at the top threshold — the maximum score — the
/// max-scoring sample is still flagged; an earlier strict `>` silently
/// understated TPR at that point). This regenerates the paper's Fig 20
/// ("detection rate for different decision parameters").
pub fn roc_sweep(scores: &[f64], is_attack: &[bool], n_points: usize)
    -> Vec<RocPoint> {
    assert_eq!(scores.len(), is_attack.len());
    let lo = scores.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let n_att = is_attack.iter().filter(|&&a| a).count().max(1);
    let n_norm = (is_attack.len() - n_att).max(1);
    (0..n_points)
        .map(|i| {
            let thr = lo + (hi - lo) * i as f64 / (n_points - 1).max(1) as f64;
            let mut tp = 0;
            let mut fp = 0;
            for (s, &a) in scores.iter().zip(is_attack) {
                if *s >= thr {
                    if a {
                        tp += 1;
                    } else {
                        fp += 1;
                    }
                }
            }
            RocPoint {
                threshold: thr,
                tpr: tp as f64 / n_att as f64,
                fpr: fp as f64 / n_norm as f64,
            }
        })
        .collect()
}

/// Area under the ROC curve by trapezoid over the sweep (sorted by
/// FPR). NaN-safe: points with a non-finite coordinate (a sweep over
/// all-NaN scores produces them) are dropped rather than poisoning the
/// sort, and the (0,0)/(1,1) anchor endpoints are only added when the
/// sweep doesn't already contain them.
pub fn auc(points: &[RocPoint]) -> f64 {
    let mut pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|p| p.fpr.is_finite() && p.tpr.is_finite())
        .map(|p| (p.fpr, p.tpr))
        .collect();
    for anchor in [(0.0, 0.0), (1.0, 1.0)] {
        if !pts.contains(&anchor) {
            pts.push(anchor);
        }
    }
    pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    pts.windows(2)
        .map(|w| (w[1].0 - w[0].0) * (w[0].1 + w[1].1) / 2.0)
        .sum()
}

/// Detection rate at (or just under) a target false-positive rate — the
/// paper's headline "96.6 % detection at 4 % false detection".
pub fn tpr_at_fpr(points: &[RocPoint], fpr_target: f64) -> f64 {
    points
        .iter()
        .filter(|p| p.fpr <= fpr_target + 1e-12)
        .map(|p| p.tpr)
        .fold(0.0, f64::max)
}

/// Cluster purity: fraction of samples in the majority class of their
/// assigned cluster.
pub fn purity(assign: &[usize], truth: &[usize], k: usize, classes: usize)
    -> f64 {
    assert_eq!(assign.len(), truth.len());
    if assign.is_empty() {
        return 0.0;
    }
    let mut table = vec![0usize; k * classes];
    for (&a, &t) in assign.iter().zip(truth) {
        table[a * classes + t] += 1;
    }
    let correct: usize = (0..k)
        .map(|c| *table[c * classes..(c + 1) * classes].iter().max().unwrap())
        .sum();
    correct as f64 / assign.len() as f64
}

/// Histogram of values into `bins` equal-width bins over [lo, hi] —
/// used to print Figs 18/19 (distance distributions).
pub fn histogram(values: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &v in values {
        if v < lo || !v.is_finite() {
            continue;
        }
        let b = (((v - lo) / w) as usize).min(bins - 1);
        h[b] += 1;
    }
    h
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Linearly-interpolated percentile of an (unsorted) sample, `q` in
/// `[0, 100]` — the definition NumPy calls `linear`. Returns 0 for an
/// empty sample. Used by the serving layer for p50/p99 latency
/// ([`crate::serve::LatencyStats`]).
///
/// ```
/// use restream::metrics::percentile;
/// let sample = [4.0, 1.0, 3.0, 2.0];
/// assert_eq!(percentile(&sample, 50.0), 2.5);
/// assert_eq!(percentile(&sample, 100.0), 4.0);
/// ```
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&sorted, q)
}

/// [`percentile`] over an **already ascending-sorted** sample — use
/// this to take several percentiles of one sample with a single sort
/// (as [`crate::serve::LatencyStats`] does for p50/p99).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
}

/// Percentile of a **fixed-bucket histogram** — the bounded-memory
/// sibling of [`percentile`], used by
/// [`crate::telemetry::HistogramSnapshot`] so long-running serves stop
/// accumulating unbounded per-request latency `Vec`s.
///
/// `bounds` are ascending bucket upper bounds; `buckets` has one count
/// per bound plus a final overflow slot. `min`/`max` are the exact
/// observed extremes (tracked alongside the buckets), `q` is in
/// percent. The rank is located in its bucket and linearly
/// interpolated across the bucket's width, then clamped to
/// `[min, max]` — so the result is monotone in `q`, exact at `q=100`,
/// and exact for single-sample series.
pub fn histogram_quantile(
    bounds: &[f64],
    buckets: &[u64],
    min: f64,
    max: f64,
    q: f64,
) -> f64 {
    let count: u64 = buckets.iter().sum();
    if count == 0 {
        return 0.0;
    }
    let rank = (q / 100.0).clamp(0.0, 1.0) * (count - 1) as f64;
    let mut below = 0u64;
    for (i, &n) in buckets.iter().enumerate() {
        if n == 0 {
            continue;
        }
        // rank falls in this bucket when below <= rank < below + n
        if rank < (below + n) as f64 || below + n == count {
            let lo = if i == 0 { 0.0 } else { bounds[i - 1] };
            let hi = bounds.get(i).copied().unwrap_or(max);
            let frac =
                (((rank - below as f64) + 1.0) / n as f64).clamp(0.0, 1.0);
            return (lo + (hi - lo) * frac).clamp(min, max);
        }
        below += n;
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_matches() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn roc_perfect_separation() {
        let scores = vec![0.1, 0.2, 0.8, 0.9];
        let labels = vec![false, false, true, true];
        let pts = roc_sweep(&scores, &labels, 50);
        let a = auc(&pts);
        assert!(a > 0.95, "auc {a}");
        assert!(tpr_at_fpr(&pts, 0.04) > 0.99);
    }

    #[test]
    fn roc_random_scores_give_half_auc() {
        // interleaved scores -> ~chance
        let scores: Vec<f64> = (0..100).map(|i| (i % 10) as f64).collect();
        let labels: Vec<bool> = (0..100).map(|i| i % 2 == 0).collect();
        let a = auc(&roc_sweep(&scores, &labels, 100));
        assert!((a - 0.5).abs() < 0.1, "auc {a}");
    }

    #[test]
    fn tpr_monotone_in_fpr_budget() {
        let scores = vec![0.1, 0.4, 0.5, 0.6, 0.9, 0.95];
        let labels = vec![false, false, true, false, true, true];
        let pts = roc_sweep(&scores, &labels, 64);
        assert!(tpr_at_fpr(&pts, 0.5) >= tpr_at_fpr(&pts, 0.1));
    }

    #[test]
    fn roc_top_threshold_flags_max_scoring_sample() {
        // The max-scoring attack must count at thr = hi (inclusive
        // compare); pre-fix the strict `>` reported tpr = 0 there.
        let scores = vec![0.1, 0.5, 0.9];
        let labels = vec![false, false, true];
        let pts = roc_sweep(&scores, &labels, 5);
        let top = pts.last().unwrap();
        assert_eq!(top.threshold, 0.9);
        assert_eq!(top.tpr, 1.0, "max sample missed at top threshold");
        assert_eq!(top.fpr, 0.0);
    }

    #[test]
    fn auc_survives_nan_points_and_dedupes_anchors() {
        // NaN sweep points (all-NaN scores) are dropped, not sorted on;
        // pre-fix this was a partial_cmp().unwrap() panic.
        let nanp = RocPoint { threshold: f64::NAN, tpr: f64::NAN,
                              fpr: f64::NAN };
        let good = RocPoint { threshold: 0.5, tpr: 0.8, fpr: 0.2 };
        let a = auc(&[nanp, good]);
        assert!(a.is_finite() && (0.0..=1.0).contains(&a), "auc {a}");
        // a sweep that already contains the (0,0)/(1,1) anchors gets
        // them once, not twice — the trapezoid count stays minimal
        let ends = [
            RocPoint { threshold: 1.0, tpr: 0.0, fpr: 0.0 },
            RocPoint { threshold: 0.5, tpr: 1.0, fpr: 0.5 },
            RocPoint { threshold: 0.0, tpr: 1.0, fpr: 1.0 },
        ];
        let with_ends = auc(&ends);
        assert!((with_ends - 0.75).abs() < 1e-12, "auc {with_ends}");
        // all points NaN: anchors alone give the chance diagonal
        let chance = auc(&[nanp]);
        assert!((chance - 0.5).abs() < 1e-12, "auc {chance}");
    }

    #[test]
    fn purity_perfect_and_mixed() {
        assert_eq!(purity(&[0, 0, 1, 1], &[2, 2, 5, 5], 2, 6), 1.0);
        assert_eq!(purity(&[0, 0, 0, 0], &[0, 0, 1, 1], 1, 2), 0.5);
    }

    #[test]
    fn percentile_interpolates_and_clamps() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 50.5);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 1e-9);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        // out-of-range q clamps; singleton and empty are total
        assert_eq!(percentile(&xs, 250.0), 100.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // unsorted input is handled
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 50.0), 5.0);
    }

    #[test]
    fn percentile_single_element_is_total() {
        // A one-request latency sample must answer every percentile
        // with that request's latency — the multi-tenant scheduler's
        // per-app splits start at a single request.
        for q in [0.0, 50.0, 99.0, 100.0, 250.0] {
            assert_eq!(percentile(&[3.25], q), 3.25, "q = {q}");
            assert_eq!(percentile_sorted(&[3.25], q), 3.25, "q = {q}");
        }
        assert_eq!(mean(&[3.25]), 3.25);
    }

    #[test]
    fn histogram_quantile_tracks_percentile_shape() {
        // bounds 10/100/1000 with an overflow slot
        let bounds = [10.0, 100.0, 1000.0];
        // empty histogram answers 0
        assert_eq!(histogram_quantile(&bounds, &[0, 0, 0, 0],
                                      0.0, 0.0, 50.0), 0.0);
        // single sample is exact at every q
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(histogram_quantile(&bounds, &[0, 1, 0, 0],
                                          42.0, 42.0, q), 42.0);
        }
        // q=100 is the exact max even past the last bound
        assert_eq!(histogram_quantile(&bounds, &[0, 0, 0, 3],
                                      2000.0, 9000.0, 100.0), 9000.0);
        // monotone in q, always inside [min, max]
        let buckets = [2u64, 5, 2, 1];
        let mut prev = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let v = histogram_quantile(&bounds, &buckets, 1.0, 5000.0, q);
            assert!(v >= prev, "q={q}: {v} < {prev}");
            assert!((1.0..=5000.0).contains(&v), "q={q}: {v}");
            prev = v;
        }
    }

    #[test]
    fn histogram_bins_and_edges() {
        let h = histogram(&[0.0, 0.49, 0.5, 0.99, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn stopwatch_is_monotonic_nonnegative() {
        let t = Stopwatch::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
