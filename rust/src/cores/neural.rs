//! Memristor neural core (paper section IV.A, Fig 12): a 400x200
//! crossbar (400 inputs x 100 differential neurons), input/output
//! buffers, training unit and control FSM.
//!
//! The core's *functional* behaviour is computed by the AOT artifacts
//! (or `crate::crossbar::ideal` on the pure-Rust path); this type owns
//! the architectural behaviour: capacity limits, per-step timing and
//! energy from the paper's Table II constants.

use crate::config::hwspec as hw;
use crate::power::neural_core as p;

/// Execution steps of a neural core (paper Table II rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Forward pass (recognition).
    Forward,
    /// Error back-propagation pass.
    Backward,
    /// Weight-update (training pulses).
    Update,
}

impl Step {
    /// Step latency (s) — Table II.
    pub fn time_s(self) -> f64 {
        match self {
            Step::Forward => p::FWD_TIME_S,
            Step::Backward => p::BWD_TIME_S,
            Step::Update => p::UPD_TIME_S,
        }
    }

    /// Step power (W) — Table II.
    pub fn power_w(self) -> f64 {
        match self {
            Step::Forward => p::FWD_POWER_W,
            Step::Backward => p::BWD_POWER_W,
            Step::Update => p::UPD_POWER_W,
        }
    }

    /// Step energy (J) for one core.
    pub fn energy_j(self) -> f64 {
        self.time_s() * self.power_w() + self.time_s() * p::CTRL_POWER_W
    }
}

/// One neural core's static assignment: a slice of a network layer.
#[derive(Clone, Debug)]
pub struct NeuralCore {
    pub id: usize,
    /// Crossbar rows in use (inputs incl. bias), <= CORE_INPUTS.
    pub inputs: usize,
    /// Differential neurons in use, <= CORE_NEURONS.
    pub neurons: usize,
}

impl NeuralCore {
    /// Create a core assignment; errors if it exceeds the crossbar.
    pub fn assign(id: usize, inputs: usize, neurons: usize) -> Result<Self, String> {
        Self::assign_with(id, inputs, neurons, hw::CORE_INPUTS, hw::CORE_NEURONS)
    }

    /// [`NeuralCore::assign`] against an explicit core geometry (used by
    /// the crossbar-size ablation; the real chip is 400x100).
    pub fn assign_with(
        id: usize,
        inputs: usize,
        neurons: usize,
        max_inputs: usize,
        max_neurons: usize,
    ) -> Result<Self, String> {
        if inputs == 0 || neurons == 0 {
            return Err("empty core assignment".into());
        }
        if inputs > max_inputs {
            return Err(format!(
                "{inputs} inputs exceed the {max_inputs}-row crossbar"
            ));
        }
        if neurons > max_neurons {
            return Err(format!(
                "{neurons} neurons exceed the {max_neurons}-neuron crossbar"
            ));
        }
        Ok(NeuralCore { id, inputs, neurons })
    }

    /// Synapse pairs physically used.
    pub fn synapses(&self) -> usize {
        self.inputs * self.neurons
    }

    /// Crossbar occupancy in [0, 1] (mapper packing quality metric).
    pub fn utilisation(&self) -> f64 {
        self.synapses() as f64 / (hw::CORE_INPUTS * hw::CORE_NEURONS) as f64
    }

    /// Output bits produced per evaluation (3-bit ADC per neuron).
    pub fn output_bits(&self) -> u64 {
        (self.neurons as u64) * hw::OUT_BITS as u64
    }

    /// Error bits consumed per backward pass (8 bits per neuron).
    pub fn error_bits(&self) -> u64 {
        (self.neurons as u64) * hw::ERR_BITS as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_energies() {
        // fwd: 0.27us * 0.794mW ~= 0.214 nJ
        let e = Step::Forward.energy_j();
        assert!((e - 0.27e-6 * 0.794e-3).abs() / e < 0.01, "{e}");
        // update dominates
        assert!(Step::Update.energy_j() > Step::Forward.energy_j());
        assert!(Step::Update.energy_j() > Step::Backward.energy_j());
    }

    #[test]
    fn assignment_respects_crossbar_limits() {
        assert!(NeuralCore::assign(0, 400, 100).is_ok());
        assert!(NeuralCore::assign(0, 401, 100).is_err());
        assert!(NeuralCore::assign(0, 400, 101).is_err());
        assert!(NeuralCore::assign(0, 0, 10).is_err());
    }

    #[test]
    fn utilisation_and_io_bits() {
        let c = NeuralCore::assign(1, 200, 50).unwrap();
        assert!((c.utilisation() - 0.25).abs() < 1e-12);
        assert_eq!(c.output_bits(), 150);
        assert_eq!(c.error_bits(), 400);
    }
}
