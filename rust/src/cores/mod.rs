//! The heterogeneous cores (paper section IV): memristor neural cores,
//! the digital k-means clustering core, and the RISC configuration core.

pub mod cluster;
pub mod neural;
pub mod risc;

pub use cluster::ClusterCore;
pub use neural::{NeuralCore, Step};
pub use risc::RiscCore;
