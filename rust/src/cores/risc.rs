//! RISC configuration core (paper sections II, VI.E).
//!
//! A single-issue pipelined core that configures the neural cores, the
//! routing switches and the DMA engine, then powers down: "the RISC core
//! is turned off afterwards during the actual training or evaluation
//! phases". Only the configuration phase therefore contributes time and
//! energy, and steady-state power excludes it entirely.

use crate::power::risc_core as p;

/// Configuration-phase cost model.
#[derive(Clone, Copy, Debug)]
pub struct RiscCore {
    pub clock_hz: f64,
}

impl Default for RiscCore {
    fn default() -> Self {
        RiscCore { clock_hz: 200e6 }
    }
}

/// What the RISC core must configure for a mapped application.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConfigWork {
    /// Neural cores to initialise (crossbar programming setup).
    pub neural_cores: usize,
    /// Routers whose SRAM slot images must be written.
    pub routers: usize,
    /// Total switch SRAM bits across those routers.
    pub switch_bits: usize,
    /// DMA descriptors to program.
    pub dma_descriptors: usize,
}

impl RiscCore {
    /// Configuration time: per-unit setup plus SRAM image writes (one
    /// 32-bit word per cycle over the config bus).
    pub fn config_time_s(&self, work: &ConfigWork) -> f64 {
        let unit_cycles = (work.neural_cores + work.routers + work.dma_descriptors)
            as u64
            * p::CONFIG_CYCLES_PER_UNIT;
        let sram_cycles = (work.switch_bits as u64).div_ceil(32);
        (unit_cycles + sram_cycles) as f64 / self.clock_hz
    }

    /// Configuration energy (core active for the whole phase).
    pub fn config_energy_j(&self, work: &ConfigWork) -> f64 {
        self.config_time_s(work) * p::POWER_W
    }

    /// Steady-state power contribution: zero — the core is gated off.
    pub fn steady_power_w(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_cost_scales_with_work() {
        let r = RiscCore::default();
        let small = ConfigWork { neural_cores: 1, routers: 1, switch_bits: 100, dma_descriptors: 1 };
        let big = ConfigWork { neural_cores: 144, routers: 146, switch_bits: 100_000, dma_descriptors: 4 };
        assert!(r.config_time_s(&big) > r.config_time_s(&small));
        assert!(r.config_energy_j(&big) > r.config_energy_j(&small));
    }

    #[test]
    fn config_phase_is_fast() {
        // Even a full-chip configuration finishes in well under a ms.
        let r = RiscCore::default();
        let work = ConfigWork { neural_cores: 144, routers: 146, switch_bits: 146 * 64 * 25, dma_descriptors: 8 };
        assert!(r.config_time_s(&work) < 1e-3);
    }

    #[test]
    fn steady_state_is_gated_off() {
        assert_eq!(RiscCore::default().steady_power_w(), 0.0);
    }
}
