//! Digital k-means clustering core (paper section IV.B, Fig 13).
//!
//! Datapath: per input element, Manhattan distances to all current
//! centres are updated in parallel subtract/accumulate lanes (one cycle
//! per element); after `d` elements the min-distance centre is found in
//! `k` compare cycles, with the centre-accumulator update overlapped with
//! the next sample's distance phase. At epoch end, new centres are
//! produced by dividing accumulators by counters.
//!
//! This type owns the cycle/power model and the core's configuration
//! limits; the functional math runs either through the `kmeans_step`
//! artifact (PJRT) or `crate::kmeans` (pure Rust reference).

use crate::config::hwspec as hw;
use crate::power::cluster_core as p;

/// Clustering-core configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClusterCore {
    /// Feature dimension (<= 32).
    pub dims: usize,
    /// Cluster count (<= 32).
    pub clusters: usize,
    /// Digital clock (Hz).
    pub clock_hz: f64,
    /// Divider latency per centre element at epoch end (shift-subtract
    /// serial divider, one bit per cycle on 16-bit accumulators).
    pub div_cycles: u64,
}

impl ClusterCore {
    pub fn configure(dims: usize, clusters: usize, clock_hz: f64)
        -> Result<Self, String> {
        if dims == 0 || dims > hw::KMEANS_MAX_DIM {
            return Err(format!(
                "dims {dims} outside the core's 1..={} range",
                hw::KMEANS_MAX_DIM
            ));
        }
        if clusters == 0 || clusters > hw::KMEANS_MAX_CENTRES {
            return Err(format!(
                "clusters {clusters} outside the core's 1..={} range",
                hw::KMEANS_MAX_CENTRES
            ));
        }
        Ok(ClusterCore { dims, clusters, clock_hz, div_cycles: 16 })
    }

    /// Cycles to assign one sample: d distance cycles + k min-search
    /// cycles (accumulator update overlaps the next sample, Fig 13).
    pub fn cycles_per_sample(&self) -> u64 {
        self.dims as u64 + self.clusters as u64
    }

    /// Cycles of the epoch-end centre recomputation.
    pub fn epoch_end_cycles(&self) -> u64 {
        (self.clusters * self.dims) as u64 * self.div_cycles
    }

    /// Time to process `n` samples (one epoch's assignment phase).
    pub fn assign_time_s(&self, n: usize) -> f64 {
        n as f64 * self.cycles_per_sample() as f64 / self.clock_hz
    }

    /// Full-epoch time over `n` samples including centre recomputation.
    pub fn epoch_time_s(&self, n: usize) -> f64 {
        self.assign_time_s(n)
            + self.epoch_end_cycles() as f64 / self.clock_hz
    }

    /// Energy over an interval at the core's (constant) power.
    pub fn energy_j(&self, time_s: f64) -> f64 {
        time_s * p::POWER_W
    }

    /// Per-sample recognition time/energy — the Table IV kmeans rows.
    pub fn recognition_cost(&self) -> (f64, f64) {
        let t = self.cycles_per_sample() as f64 / self.clock_hz;
        (t, self.energy_j(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core() -> ClusterCore {
        ClusterCore::configure(20, 26, 200e6).unwrap()
    }

    #[test]
    fn limits_enforced() {
        assert!(ClusterCore::configure(33, 10, 200e6).is_err());
        assert!(ClusterCore::configure(10, 33, 200e6).is_err());
        assert!(ClusterCore::configure(0, 10, 200e6).is_err());
        assert!(ClusterCore::configure(32, 32, 200e6).is_ok());
    }

    #[test]
    fn per_sample_time_matches_paper_table4_shape() {
        // Paper Table IV: kmeans recognition 0.32 us per input at d=20.
        let (t, _) = core().recognition_cost();
        assert!(t > 0.1e-6 && t < 0.5e-6, "t={t}");
    }

    #[test]
    fn epoch_cost_scales_with_samples() {
        let c = core();
        let t1 = c.epoch_time_s(1000);
        let t2 = c.epoch_time_s(2000);
        assert!(t2 > t1);
        // assignment dominates for large n
        assert!((t2 - t1) > 0.9 * c.assign_time_s(1000));
    }

    #[test]
    fn energy_uses_core_power() {
        let c = core();
        let e = c.energy_j(1.0);
        assert!((e - 1.36e-3).abs() < 1e-9);
    }
}
