//! Typed command-line parsing for the `restream` binary.
//!
//! The binary's subcommands used to share one ad-hoc `--key value`
//! HashMap; this module replaces that with a small typed layer: every
//! subcommand parses into its own option struct with defaults applied,
//! values validated, and **unknown flags rejected** (a typo like
//! `--epoch 9` is an error, not a silently ignored flag). `main.rs`
//! only pattern-matches the resulting [`Command`] — no string lookups
//! survive past [`parse`].
//!
//! Flag syntax is unchanged: `--key value` pairs after the subcommand,
//! where a flag followed by another flag (or by nothing) is a bare
//! boolean switch (`--resume` equals `--resume true`). When a flag
//! repeats, the last value wins.

use std::collections::HashMap;

use crate::config::apps;
use crate::coordinator::ExecMode;

/// One parsed `restream` invocation.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// No subcommand: print usage and exit cleanly.
    Usage,
    /// `restream chip` — chip inventory and area budget.
    Chip,
    /// `restream report --…` — regenerate a paper table or series.
    Report(ReportCmd),
    /// `restream train --…` — train an app on the simulated chip.
    Train(TrainCmd),
    /// `restream infer --…` — forward-only throughput probe.
    Infer(InferCmd),
    /// `restream cluster --…` — k-means clustering (the paper's
    /// clustering workload; unrelated to the serving [`crate::cluster`]
    /// fleet, which `serve --chips` drives).
    Kmeans(KmeansCmd),
    /// `restream anomaly --…` — KDD autoencoder anomaly detection.
    Anomaly(AnomalyCmd),
    /// `restream serve --…` — the serving stack (single app,
    /// multi-tenant chip, or multi-chip cluster).
    Serve(ServeCmd),
}

/// What `restream report` should print.
#[derive(Clone, Debug, PartialEq)]
pub enum ReportCmd {
    /// `--table 2|3|4`: a paper table.
    Table(u8),
    /// `--vs-gpu train|recog`: the Figs 22-25 series.
    VsGpu {
        /// True for the training series, false for recognition.
        train: bool,
    },
    /// `--occupancy all|A,B,…`: the multi-tenant occupancy table.
    Occupancy(String),
    /// `--metrics [--json]`: the process-wide telemetry registry
    /// snapshot (text table, or one canonical JSON document).
    Metrics {
        /// True for JSON output, false for the text table.
        json: bool,
    },
}

/// Observability knobs shared by the long-running subcommands
/// (`train` and every `serve` mode).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryOpts {
    /// `--trace-out FILE`, if given: record request-scoped spans and
    /// write chrome `trace_event` JSON at shutdown.
    pub trace_out: Option<String>,
    /// `--metrics-out FILE`, if given: append one metrics-snapshot
    /// JSON line per period while the run is live.
    pub metrics_out: Option<String>,
    /// `--metrics-every-ms N` (default 500): snapshot period for
    /// `--metrics-out`.
    pub metrics_every_ms: u64,
}

impl Default for TelemetryOpts {
    fn default() -> Self {
        TelemetryOpts {
            trace_out: None,
            metrics_out: None,
            metrics_every_ms: 500,
        }
    }
}

/// Backend/worker-pool selection shared by every functional-math
/// subcommand (`--backend native|pjrt`, `--workers N`, `--exec
/// parallel|pipeline|hybrid`, `--stages N`). `None` defers to the
/// environment (`$RESTREAM_BACKEND` / `$RESTREAM_WORKERS`) or the
/// engine default (data-parallel, one stage per layer).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EngineOpts {
    /// `--backend`, if given.
    pub backend: Option<String>,
    /// `--workers`, if given.
    pub workers: Option<usize>,
    /// `--exec`, if given: how batched forwards execute.
    pub exec: Option<ExecMode>,
    /// `--stages`, if given: pipeline stage count for `--exec
    /// pipeline|hybrid` (clamped to the app's layer count).
    pub stages: Option<usize>,
}

/// `restream train` options.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainCmd {
    /// `--app` (default `iris_class`).
    pub app: String,
    /// `--epochs` (default 5).
    pub epochs: usize,
    /// `--lr` (default 1.0).
    pub lr: f32,
    /// `--seed` (default 0).
    pub seed: u64,
    /// `--samples` (default 512): dataset size before the 80/20 split.
    pub samples: usize,
    /// `--batch` (default 1): mini-batch size; 1 is the paper's
    /// per-sample stochastic BP.
    pub batch: usize,
    /// `--checkpoint DIR [--every N] [--resume]`, if given.
    pub checkpoint: Option<CheckpointCmd>,
    /// Backend/worker selection.
    pub engine: EngineOpts,
    /// Trace/metrics export knobs.
    pub telemetry: TelemetryOpts,
}

/// The checkpoint policy of a `restream train --checkpoint` run.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointCmd {
    /// `--checkpoint DIR`: the snapshot directory.
    pub dir: String,
    /// `--every N` (default 1, floored to 1): epochs per snapshot.
    pub every: usize,
    /// `--resume`: restart from the latest complete snapshot.
    pub resume: bool,
}

/// `restream infer` options.
#[derive(Clone, Debug, PartialEq)]
pub struct InferCmd {
    /// `--app` (default `iris_class`).
    pub app: String,
    /// `--seed` (default 0).
    pub seed: u64,
    /// Backend/worker selection.
    pub engine: EngineOpts,
}

/// `restream cluster` (k-means) options.
#[derive(Clone, Debug, PartialEq)]
pub struct KmeansCmd {
    /// `--app` (default `mnist_kmeans`).
    pub app: String,
    /// `--epochs` (default 10).
    pub epochs: usize,
    /// `--seed` (default 0).
    pub seed: u64,
    /// Backend/worker selection.
    pub engine: EngineOpts,
}

/// `restream anomaly` options.
#[derive(Clone, Debug, PartialEq)]
pub struct AnomalyCmd {
    /// `--epochs` (default 3).
    pub epochs: usize,
    /// `--seed` (default 0).
    pub seed: u64,
    /// Backend/worker selection.
    pub engine: EngineOpts,
}

/// Load-generation knobs shared by every serving mode.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeLoad {
    /// `--max-batch` (default [`apps::FWD_BATCH`]).
    pub max_batch: usize,
    /// `--max-wait-us` (default 200).
    pub max_wait_us: u64,
    /// `--clients` (default 4): replay threads (per app when serving
    /// several).
    pub clients: usize,
    /// `--requests` (default 256): requests per replay thread.
    pub requests: usize,
    /// `--seed` (default 0): parameter init and replay streams.
    pub seed: u64,
}

/// `restream serve` — which serving stack to run.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeCmd {
    /// `--app NAME`: one dedicated [`Server`](crate::serve::Server).
    Single(ServeSingleCmd),
    /// `--apps A,B,…`: a multi-tenant chip
    /// ([`ChipScheduler`](crate::chip::ChipScheduler)), or with
    /// `--chips N > 1` a whole fleet ([`Cluster`](crate::cluster::Cluster)).
    Multi(ServeMultiCmd),
}

/// Single-app serving options.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSingleCmd {
    /// `--app` (default `iris_class`).
    pub app: String,
    /// `--source stdin` (default: `replay`).
    pub stdin: bool,
    /// Load-generation knobs.
    pub load: ServeLoad,
    /// Backend/worker selection.
    pub engine: EngineOpts,
    /// Trace/metrics export knobs.
    pub telemetry: TelemetryOpts,
}

/// Multi-app serving options (one chip, or a cluster of them).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeMultiCmd {
    /// `--apps A,B,…`: the hosted app names.
    pub apps: Vec<String>,
    /// `--chips` (default 1): fleet size; above 1 the apps serve from a
    /// [`Cluster`](crate::cluster::Cluster) instead of one chip.
    pub chips: usize,
    /// `--replicas` (default 1): serving replicas requested for every
    /// listed app (clamped to the fleet size at placement).
    pub replicas: usize,
    /// Load-generation knobs.
    pub load: ServeLoad,
    /// Backend/worker selection (each chip builds its own engine).
    pub engine: EngineOpts,
    /// Trace/metrics export knobs.
    pub telemetry: TelemetryOpts,
}

/// The `--key value` pairs of one subcommand, consumed flag by flag so
/// that leftovers can be rejected.
struct FlagSet {
    flags: HashMap<String, String>,
}

impl FlagSet {
    /// Parse `--key value` pairs. A flag followed by another flag (or
    /// by nothing) is a bare boolean switch and stores `"true"`.
    fn new(args: &[String]) -> Result<FlagSet, String> {
        let mut flags = HashMap::new();
        let mut it = args.iter().peekable();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {k}"))?;
            let v = match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    it.next().unwrap().clone()
                }
                _ => "true".to_string(),
            };
            flags.insert(key.to_string(), v);
        }
        Ok(FlagSet { flags })
    }

    /// Remove `--key` and return its raw value, if given.
    fn take(&mut self, key: &str) -> Option<String> {
        self.flags.remove(key)
    }

    /// Remove and parse `--key`, falling back to `default`.
    fn get<T: std::str::FromStr>(
        &mut self,
        key: &str,
        default: T,
    ) -> Result<T, String> {
        match self.flags.remove(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("bad value for --{key}: {v}")),
        }
    }

    /// Remove and parse `--key`, `None` when absent.
    fn opt<T: std::str::FromStr>(
        &mut self,
        key: &str,
    ) -> Result<Option<T>, String> {
        match self.flags.remove(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("bad value for --{key}: {v}")),
        }
    }

    /// Flags present that are not in `known`, sorted and
    /// `--`-prefixed — nothing is consumed.
    fn unknown_among(&self, known: &[&str]) -> Vec<String> {
        let mut left: Vec<String> = self
            .flags
            .keys()
            .filter(|k| !known.contains(&k.as_str()))
            .map(|k| format!("--{k}"))
            .collect();
        left.sort();
        left
    }

    /// Error on any flag the subcommand did not consume.
    fn finish(self) -> Result<(), String> {
        if self.flags.is_empty() {
            return Ok(());
        }
        let mut left: Vec<String> =
            self.flags.keys().map(|k| format!("--{k}")).collect();
        left.sort();
        Err(format!(
            "unknown flag(s) for this command: {}",
            left.join(" ")
        ))
    }
}

/// Parse one invocation (the argument list after the binary name).
/// Every subcommand rejects flags it does not define.
pub fn parse(args: &[String]) -> Result<Command, String> {
    let Some(cmd) = args.first() else {
        return Ok(Command::Usage);
    };
    let mut f = FlagSet::new(&args[1..])?;
    let parsed = match cmd.as_str() {
        "chip" => Command::Chip,
        "report" => Command::Report(parse_report(&mut f)?),
        "train" => Command::Train(parse_train(&mut f)?),
        "infer" => Command::Infer(InferCmd {
            app: f.get("app", "iris_class".to_string())?,
            seed: f.get("seed", 0)?,
            engine: engine_opts(&mut f)?,
        }),
        "cluster" => Command::Kmeans(KmeansCmd {
            app: f.get("app", "mnist_kmeans".to_string())?,
            epochs: f.get("epochs", 10)?,
            seed: f.get("seed", 0)?,
            engine: engine_opts(&mut f)?,
        }),
        "anomaly" => Command::Anomaly(AnomalyCmd {
            epochs: f.get("epochs", 3)?,
            seed: f.get("seed", 0)?,
            engine: engine_opts(&mut f)?,
        }),
        "serve" => Command::Serve(parse_serve(&mut f)?),
        other => return Err(format!("unknown command {other}")),
    };
    f.finish()?;
    Ok(parsed)
}

fn engine_opts(f: &mut FlagSet) -> Result<EngineOpts, String> {
    Ok(EngineOpts {
        backend: f.take("backend"),
        workers: f.opt("workers")?,
        exec: f.opt("exec")?,
        stages: f.opt("stages")?,
    })
}

fn telemetry_opts(f: &mut FlagSet) -> Result<TelemetryOpts, String> {
    let opts = TelemetryOpts {
        trace_out: f.take("trace-out"),
        metrics_out: f.take("metrics-out"),
        metrics_every_ms: f.get("metrics-every-ms", 500)?,
    };
    if opts.metrics_every_ms == 0 {
        return Err("--metrics-every-ms must be at least 1".to_string());
    }
    Ok(opts)
}

/// Flags `restream report` understands, sorted — rejected typos list
/// this menu verbatim.
const REPORT_FLAGS: &[&str] =
    &["json", "metrics", "occupancy", "table", "vs-gpu"];

fn parse_report(f: &mut FlagSet) -> Result<ReportCmd, String> {
    // Reject anything outside the report menu up front, so a typo gets
    // the full sorted flag list instead of the generic leftover error.
    let unknown = f.unknown_among(REPORT_FLAGS);
    if !unknown.is_empty() {
        let known: Vec<String> =
            REPORT_FLAGS.iter().map(|k| format!("--{k}")).collect();
        return Err(format!(
            "unknown report flag(s): {}; known report flags: {}",
            unknown.join(" "),
            known.join(" ")
        ));
    }
    let json: bool = f.get("json", false)?;
    if f.get("metrics", false)? {
        return Ok(ReportCmd::Metrics { json });
    }
    if json {
        return Err("--json needs --metrics".to_string());
    }
    // Precedence mirrors the old parser: --table, then --vs-gpu, then
    // --occupancy.
    if let Some(t) = f.take("table") {
        return match t.as_str() {
            "2" => Ok(ReportCmd::Table(2)),
            "3" => Ok(ReportCmd::Table(3)),
            "4" => Ok(ReportCmd::Table(4)),
            other => Err(format!("unknown table {other}")),
        };
    }
    if let Some(which) = f.take("vs-gpu") {
        return match which.as_str() {
            "train" => Ok(ReportCmd::VsGpu { train: true }),
            "recog" => Ok(ReportCmd::VsGpu { train: false }),
            other => {
                Err(format!("--vs-gpu must be train or recog, got {other}"))
            }
        };
    }
    if let Some(spec) = f.take("occupancy") {
        return Ok(ReportCmd::Occupancy(spec));
    }
    Err("report needs --table N, --vs-gpu train|recog, \
         --occupancy all|app,app,… or --metrics [--json]"
        .to_string())
}

fn parse_train(f: &mut FlagSet) -> Result<TrainCmd, String> {
    let every: usize = f.get("every", 1)?;
    let resume: bool = f.get("resume", false)?;
    let checkpoint = match f.take("checkpoint") {
        Some(dir) => {
            Some(CheckpointCmd { dir, every: every.max(1), resume })
        }
        None if resume => {
            return Err("--resume needs --checkpoint DIR".to_string())
        }
        None => None,
    };
    Ok(TrainCmd {
        app: f.get("app", "iris_class".to_string())?,
        epochs: f.get("epochs", 5)?,
        lr: f.get("lr", 1.0)?,
        seed: f.get("seed", 0)?,
        samples: f.get("samples", 512)?,
        batch: f.get("batch", 1)?,
        checkpoint,
        engine: engine_opts(f)?,
        telemetry: telemetry_opts(f)?,
    })
}

fn serve_load(f: &mut FlagSet) -> Result<ServeLoad, String> {
    Ok(ServeLoad {
        max_batch: f.get("max-batch", apps::FWD_BATCH)?,
        max_wait_us: f.get("max-wait-us", 200)?,
        clients: f.get("clients", 4)?,
        requests: f.get("requests", 256)?,
        seed: f.get("seed", 0)?,
    })
}

fn parse_serve(f: &mut FlagSet) -> Result<ServeCmd, String> {
    if let Some(list) = f.take("apps") {
        let apps_list: Vec<String> = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if apps_list.is_empty() {
            return Err("--apps needs a comma-separated app list".to_string());
        }
        if f.take("app").is_some() {
            return Err("pass --app NAME or --apps A,B,…, not both"
                .to_string());
        }
        if f.take("source").is_some() {
            return Err("--source only applies to single-app serving \
                        (--app NAME)"
                .to_string());
        }
        let chips: usize = f.get("chips", 1)?;
        if chips == 0 {
            return Err("--chips must be at least 1".to_string());
        }
        let replicas: usize = f.get("replicas", 1)?;
        if replicas == 0 {
            return Err("--replicas must be at least 1".to_string());
        }
        return Ok(ServeCmd::Multi(ServeMultiCmd {
            apps: apps_list,
            chips,
            replicas,
            load: serve_load(f)?,
            engine: engine_opts(f)?,
            telemetry: telemetry_opts(f)?,
        }));
    }
    for flag in ["chips", "replicas"] {
        if f.take(flag).is_some() {
            return Err(format!(
                "--{flag} needs --apps A,B,… (multi-app serving)"
            ));
        }
    }
    let stdin = match f.get("source", "replay".to_string())?.as_str() {
        "stdin" => true,
        "replay" => false,
        other => {
            return Err(format!(
                "--source must be stdin or replay, got {other}"
            ))
        }
    };
    Ok(ServeCmd::Single(ServeSingleCmd {
        app: f.get("app", "iris_class".to_string())?,
        stdin,
        load: serve_load(f)?,
        engine: engine_opts(f)?,
        telemetry: telemetry_opts(f)?,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(raw: &[&str]) -> Vec<String> {
        raw.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn no_arguments_ask_for_usage() {
        assert_eq!(parse(&[]).unwrap(), Command::Usage);
    }

    #[test]
    fn unknown_commands_and_flags_are_rejected() {
        let err = parse(&args(&["frobnicate"])).unwrap_err();
        assert!(err.contains("unknown command frobnicate"), "{err}");
        assert_eq!(parse(&args(&["chip"])).unwrap(), Command::Chip);
        let err = parse(&args(&["chip", "--nope", "1"])).unwrap_err();
        assert!(err.contains("unknown flag"), "{err}");
        assert!(err.contains("--nope"), "{err}");
        // a typo'd train flag no longer silently falls back to defaults
        let err = parse(&args(&["train", "--epoch", "9"])).unwrap_err();
        assert!(err.contains("--epoch"), "{err}");
        // and a value without its --flag is malformed
        let err = parse(&args(&["train", "epochs"])).unwrap_err();
        assert!(err.contains("expected --flag"), "{err}");
    }

    #[test]
    fn report_variants_parse_and_validate() {
        let t = parse(&args(&["report", "--table", "3"])).unwrap();
        assert_eq!(t, Command::Report(ReportCmd::Table(3)));
        let err = parse(&args(&["report", "--table", "9"])).unwrap_err();
        assert!(err.contains("unknown table 9"), "{err}");
        let v = parse(&args(&["report", "--vs-gpu", "train"])).unwrap();
        assert_eq!(v, Command::Report(ReportCmd::VsGpu { train: true }));
        let v = parse(&args(&["report", "--vs-gpu", "recog"])).unwrap();
        assert_eq!(v, Command::Report(ReportCmd::VsGpu { train: false }));
        let err = parse(&args(&["report", "--vs-gpu", "x"])).unwrap_err();
        assert!(err.contains("train or recog"), "{err}");
        let o = parse(&args(&["report", "--occupancy", "all"])).unwrap();
        assert_eq!(
            o,
            Command::Report(ReportCmd::Occupancy("all".to_string()))
        );
        let err = parse(&args(&["report"])).unwrap_err();
        assert!(err.contains("report needs"), "{err}");
    }

    #[test]
    fn report_metrics_parses_and_unknown_flags_list_the_menu() {
        let m = parse(&args(&["report", "--metrics"])).unwrap();
        assert_eq!(m, Command::Report(ReportCmd::Metrics { json: false }));
        let m =
            parse(&args(&["report", "--metrics", "--json"])).unwrap();
        assert_eq!(m, Command::Report(ReportCmd::Metrics { json: true }));
        let err = parse(&args(&["report", "--json"])).unwrap_err();
        assert!(err.contains("--json needs --metrics"), "{err}");
        // a typo gets the full sorted report-flag menu
        let err =
            parse(&args(&["report", "--metric", "--tabel", "2"]))
                .unwrap_err();
        assert!(
            err.contains("unknown report flag(s): --metric --tabel"),
            "{err}"
        );
        assert!(
            err.contains(
                "known report flags: --json --metrics --occupancy \
                 --table --vs-gpu"
            ),
            "{err}"
        );
    }

    #[test]
    fn telemetry_flags_parse_on_train_and_serve() {
        let Command::Train(t) = parse(&args(&[
            "train", "--trace-out", "/tmp/t.json", "--metrics-out",
            "/tmp/m.jsonl", "--metrics-every-ms", "100",
        ]))
        .unwrap() else {
            panic!("expected a train command")
        };
        assert_eq!(
            t.telemetry,
            TelemetryOpts {
                trace_out: Some("/tmp/t.json".to_string()),
                metrics_out: Some("/tmp/m.jsonl".to_string()),
                metrics_every_ms: 100,
            }
        );
        let Command::Serve(ServeCmd::Single(s)) =
            parse(&args(&["serve", "--trace-out", "trace.json"]))
                .unwrap()
        else {
            panic!("expected single-app serving")
        };
        assert_eq!(s.telemetry.trace_out, Some("trace.json".to_string()));
        assert_eq!(s.telemetry.metrics_every_ms, 500);
        let Command::Serve(ServeCmd::Multi(m)) = parse(&args(&[
            "serve", "--apps", "iris_ae", "--metrics-out", "m.jsonl",
        ]))
        .unwrap() else {
            panic!("expected multi-app serving")
        };
        assert_eq!(m.telemetry.metrics_out, Some("m.jsonl".to_string()));
        let err = parse(&args(&[
            "serve", "--metrics-out", "m", "--metrics-every-ms", "0",
        ]))
        .unwrap_err();
        assert!(err.contains("--metrics-every-ms"), "{err}");
    }

    #[test]
    fn train_applies_defaults_and_checkpoint_flags() {
        let Command::Train(t) = parse(&args(&["train"])).unwrap() else {
            panic!("expected a train command")
        };
        assert_eq!(t.app, "iris_class");
        assert_eq!((t.epochs, t.samples, t.batch), (5, 512, 1));
        assert_eq!(t.lr, 1.0);
        assert_eq!(t.checkpoint, None);
        assert_eq!(t.engine, EngineOpts::default());
        let Command::Train(t) = parse(&args(&[
            "train", "--app", "kdd_ae", "--batch", "16", "--checkpoint",
            "/tmp/ck", "--every", "0", "--resume", "--backend", "native",
            "--workers", "4",
        ]))
        .unwrap() else {
            panic!("expected a train command")
        };
        assert_eq!(t.app, "kdd_ae");
        assert_eq!(t.batch, 16);
        assert_eq!(
            t.checkpoint,
            Some(CheckpointCmd {
                dir: "/tmp/ck".to_string(),
                every: 1, // floored
                resume: true,
            })
        );
        assert_eq!(
            t.engine,
            EngineOpts {
                backend: Some("native".to_string()),
                workers: Some(4),
                ..EngineOpts::default()
            }
        );
    }

    #[test]
    fn exec_mode_flags_parse_everywhere() {
        let Command::Train(t) = parse(&args(&[
            "train", "--exec", "pipeline", "--stages", "3",
        ]))
        .unwrap() else {
            panic!("expected a train command")
        };
        assert_eq!(t.engine.exec, Some(ExecMode::Pipelined));
        assert_eq!(t.engine.stages, Some(3));
        let Command::Infer(i) =
            parse(&args(&["infer", "--exec", "hybrid"])).unwrap()
        else {
            panic!("expected an infer command")
        };
        assert_eq!(i.engine.exec, Some(ExecMode::Hybrid));
        assert_eq!(i.engine.stages, None);
        let Command::Serve(ServeCmd::Single(s)) =
            parse(&args(&["serve", "--exec", "parallel"])).unwrap()
        else {
            panic!("expected single-app serving")
        };
        assert_eq!(s.engine.exec, Some(ExecMode::DataParallel));
        let err =
            parse(&args(&["train", "--exec", "warp"])).unwrap_err();
        assert!(err.contains("bad value for --exec: warp"), "{err}");
    }

    #[test]
    fn resume_needs_a_checkpoint_dir() {
        let err = parse(&args(&["train", "--resume"])).unwrap_err();
        assert!(err.contains("--resume needs --checkpoint"), "{err}");
    }

    #[test]
    fn bad_values_name_the_flag() {
        let err = parse(&args(&["train", "--epochs", "x"])).unwrap_err();
        assert!(err.contains("bad value for --epochs: x"), "{err}");
        let err = parse(&args(&["infer", "--workers", "-1"])).unwrap_err();
        assert!(err.contains("bad value for --workers"), "{err}");
    }

    #[test]
    fn serve_single_defaults_to_replay() {
        let Command::Serve(ServeCmd::Single(s)) =
            parse(&args(&["serve"])).unwrap()
        else {
            panic!("expected single-app serving")
        };
        assert_eq!(s.app, "iris_class");
        assert!(!s.stdin);
        assert_eq!(s.load.max_batch, apps::FWD_BATCH);
        assert_eq!(
            (s.load.max_wait_us, s.load.clients, s.load.requests),
            (200, 4, 256)
        );
        let Command::Serve(ServeCmd::Single(s)) =
            parse(&args(&["serve", "--source", "stdin"])).unwrap()
        else {
            panic!("expected single-app serving")
        };
        assert!(s.stdin);
        let err =
            parse(&args(&["serve", "--source", "carrier-pigeon"]))
                .unwrap_err();
        assert!(err.contains("stdin or replay"), "{err}");
    }

    #[test]
    fn serve_multi_parses_the_fleet_shape() {
        let Command::Serve(ServeCmd::Multi(m)) = parse(&args(&[
            "serve", "--apps", "iris_ae, kdd_ae,", "--chips", "4",
            "--replicas", "2", "--clients", "8",
        ]))
        .unwrap() else {
            panic!("expected multi-app serving")
        };
        assert_eq!(m.apps, vec!["iris_ae", "kdd_ae"]);
        assert_eq!((m.chips, m.replicas), (4, 2));
        assert_eq!(m.load.clients, 8);
        // one chip and one replica by default
        let Command::Serve(ServeCmd::Multi(m)) =
            parse(&args(&["serve", "--apps", "iris_ae"])).unwrap()
        else {
            panic!("expected multi-app serving")
        };
        assert_eq!((m.chips, m.replicas), (1, 1));
    }

    #[test]
    fn fleet_flags_are_validated() {
        let err = parse(&args(&["serve", "--chips", "2"])).unwrap_err();
        assert!(err.contains("--chips needs --apps"), "{err}");
        let err =
            parse(&args(&["serve", "--app", "x", "--replicas", "2"]))
                .unwrap_err();
        assert!(err.contains("--replicas needs --apps"), "{err}");
        let err = parse(&args(&[
            "serve", "--apps", "a,b", "--chips", "0",
        ]))
        .unwrap_err();
        assert!(err.contains("--chips must be at least 1"), "{err}");
        let err = parse(&args(&[
            "serve", "--apps", "a", "--app", "b",
        ]))
        .unwrap_err();
        assert!(err.contains("not both"), "{err}");
        let err = parse(&args(&[
            "serve", "--apps", "a", "--source", "stdin",
        ]))
        .unwrap_err();
        assert!(err.contains("single-app"), "{err}");
        let err = parse(&args(&["serve", "--apps", ","])).unwrap_err();
        assert!(err.contains("comma-separated"), "{err}");
    }

    #[test]
    fn bare_flags_parse_as_boolean_switches() {
        // --resume directly followed by another flag means `true`
        let Command::Train(t) = parse(&args(&[
            "train", "--resume", "--checkpoint", "/tmp/ck",
        ]))
        .unwrap() else {
            panic!("expected a train command")
        };
        assert!(t.checkpoint.unwrap().resume);
        // and the last occurrence of a repeated flag wins
        let Command::Train(t) =
            parse(&args(&["train", "--epochs", "2", "--epochs", "7"]))
                .unwrap()
        else {
            panic!("expected a train command")
        };
        assert_eq!(t.epochs, 7);
    }
}
