//! Multi-chip cluster: one serving front end routing app requests
//! across a fleet of 144-core chips.
//!
//! The paper's efficiency claims are **per chip**; serving recognition
//! traffic from millions of users takes a fleet — the same jump the
//! TPU paper (Jouppi et al., arXiv:1704.04760) makes from accelerator
//! microarchitecture to in-datacenter serving, and the composition the
//! streaming-multicore follow-up (arXiv:1606.04609) frames these chips
//! for. This module is that front end:
//!
//! 1. **Placement** — apps land on chips by rendezvous hashing with
//!    capacity-aware spillover ([`plan_placement`]): stable (the same
//!    app set always places the same way), balanced (hash-spread), and
//!    budget-respecting (a full chip spills the app to its
//!    next-preferred chip). Each occupied chip runs its own
//!    [`ChipScheduler`] — per-chip health/occupancy/latency accounting
//!    is the chip layer's [`MultiServeReport`], surfaced per chip in
//!    the [`ClusterReport`].
//! 2. **Replication** — a hot app may ask for `n` replicas
//!    ([`ClusterApp::replicated`]); it lands on `n` distinct chips and
//!    the router picks the **least-loaded** replica per request
//!    (in-flight request count, chip index as the tie-break), so one
//!    app's throughput can exceed a single chip's.
//! 3. **Routing** — [`ClusterClient::submit`] is the only hot-path
//!    addition: pick a replica, bump its in-flight counter, delegate to
//!    the chip's bounded ingress. The counter drops when the request's
//!    [`Pending`] receipt settles, so backpressure and load tracking
//!    ride the existing reply path.
//! 4. **Accounting** — shutdown folds each chip's report plus its
//!    routed-request share priced at the Table IV per-sample
//!    recognition energy ([`crate::sim::serving_energy_j`]) into a
//!    [`ClusterReport`].
//!
//! # Determinism contract
//!
//! A request's result is **bit-identical regardless of which chip
//! served it**. Every replica serves the same `(network, params)`
//! through the same [`Engine::infer`] path, which is bit-identical at
//! any worker count, any batching, and any co-residency (PRs 2, 3, 5);
//! routing chooses *where* a sample runs, never *what* it computes.
//! `rust/tests/cluster_determinism.rs` pins results against a
//! dedicated single-app [`Server`](crate::serve::Server) across fleet
//! sizes {1, 2, 4} and client counts, plus placement stability and
//! chip-full spillover.
//!
//! # Example
//!
//! ```
//! use restream::cluster::{Cluster, ClusterApp, ClusterConfig};
//! use restream::config::apps;
//! use restream::coordinator::{init_conductances, Engine};
//!
//! let host = |name: &str| {
//!     let net = apps::network(name).unwrap().clone();
//!     let params = init_conductances(net.layers, 0);
//!     ClusterApp::new(net, params)
//! };
//! let cluster = Cluster::start(
//!     vec![host("iris_ae"), host("kdd_ae")],
//!     ClusterConfig { chips: 2, ..ClusterConfig::default() },
//!     |_chip| Ok(Engine::native()),
//! )
//! .unwrap();
//! let out = cluster
//!     .client("iris_ae")
//!     .unwrap()
//!     .call(vec![0.1, -0.2, 0.3, 0.0])
//!     .unwrap();
//! assert_eq!(out.out.len(), 4); // iris_ae reconstruction
//! let report = cluster.shutdown();
//! assert_eq!(report.total_requests(), 1);
//! ```

// Rule P1's compiler-side shadow: the request path answers with typed
// errors, never panics. Tests keep their unwraps (the cfg_attr gate).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::dbg_macro))]

mod placement;
mod report;

pub use placement::{
    plan_placement, preference, AppDemand, AppPlacement, Placement,
};
pub use report::{ClusterChipReport, ClusterReport};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::chip::{
    footprint, ChipApp, ChipConfig, ChipScheduler, MultiServeReport,
};
use crate::coordinator::Engine;
use crate::serve::{Client, Pending, Response, ServeStats, Service};
use crate::sim;
use crate::telemetry::TraceSink;

/// One application hosted by a [`Cluster`]: the chip-level
/// [`ChipApp`] plus how many chips should hold a serving replica.
#[derive(Clone)]
pub struct ClusterApp {
    /// The served network and its parameters.
    pub app: ChipApp,
    /// Requested replica count (clamped to `1..=chips` at placement).
    pub replicas: usize,
}

impl ClusterApp {
    /// Host `net`/`params` with a single replica.
    pub fn new(
        net: crate::config::Network,
        params: Vec<crate::runtime::ArrayF32>,
    ) -> ClusterApp {
        ClusterApp { app: ChipApp { net, params }, replicas: 1 }
    }

    /// Ask for `n` replicas (a hot app that should exceed one chip's
    /// throughput).
    pub fn replicated(mut self, n: usize) -> ClusterApp {
        self.replicas = n;
        self
    }
}

/// Tuning knobs of a [`Cluster`].
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Fleet size (default 1 — a cluster of one chip behaves exactly
    /// like a standalone [`ChipScheduler`]).
    pub chips: usize,
    /// Per-chip configuration, applied to every chip in the fleet.
    pub chip: ChipConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { chips: 1, chip: ChipConfig::default() }
    }
}

/// Shared per-chip load counters the router and every
/// [`ClusterClient`] clone read and update.
struct ClusterLoad {
    /// Requests submitted to the chip and not yet settled (their
    /// [`Pending`] receipt still outstanding).
    in_flight: Vec<AtomicUsize>,
    /// Requests ever routed to the chip.
    routed: Vec<AtomicU64>,
}

impl ClusterLoad {
    fn new(chips: usize) -> ClusterLoad {
        ClusterLoad {
            in_flight: (0..chips).map(|_| AtomicUsize::new(0)).collect(),
            routed: (0..chips).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// Drop-guard parked inside a routed request's [`Pending`] receipt:
/// the chip's in-flight count drops exactly when the request settles
/// (answered, failed, or abandoned).
struct InFlightToken {
    load: Arc<ClusterLoad>,
    chip: usize,
}

impl Drop for InFlightToken {
    fn drop(&mut self) {
        self.load.in_flight[self.chip].fetch_sub(1, Ordering::AcqRel);
    }
}

/// Routing handle for one app: picks the least-loaded replica per
/// request and delegates to that chip's bounded ingress. Cheap to
/// clone; clones share the load counters and the per-chip queues.
#[derive(Clone)]
pub struct ClusterClient {
    app: String,
    replicas: Vec<(usize, Client)>,
    load: Arc<ClusterLoad>,
    sink: TraceSink,
}

impl ClusterClient {
    /// Route one sample to the least-loaded replica (in-flight count,
    /// chip index as tie-break) and return its [`Pending`] receipt;
    /// blocks while that chip's bounded ingress queue is full.
    pub fn submit(&self, x: Vec<f32>) -> Result<Pending> {
        let (chip, client) = self
            .replicas
            .iter()
            .min_by_key(|(chip, _)| {
                (self.load.in_flight[*chip].load(Ordering::Acquire), *chip)
            })
            // lint: allow(P1) — plan_placement rejects apps it cannot
            // place, and start() built one client per placed replica,
            // so `replicas` is structurally non-empty here.
            .expect("a placed app has at least one replica");
        self.load.in_flight[*chip].fetch_add(1, Ordering::AcqRel);
        match client.submit(x) {
            Ok(pending) => {
                self.load.routed[*chip].fetch_add(1, Ordering::Relaxed);
                self.sink.route(pending.trace_id(), *chip);
                Ok(pending.with_guard(Box::new(InFlightToken {
                    load: Arc::clone(&self.load),
                    chip: *chip,
                })))
            }
            Err(e) => {
                self.load.in_flight[*chip].fetch_sub(1, Ordering::AcqRel);
                Err(e)
            }
        }
    }

    /// Submit and block for the response — one closed-loop request.
    pub fn call(&self, x: Vec<f32>) -> Result<Response> {
        self.submit(x)?.wait()
    }

    /// The app this handle routes for.
    pub fn app(&self) -> &str {
        &self.app
    }

    /// Chips holding a replica of this app, in preference order.
    pub fn chips(&self) -> Vec<usize> {
        self.replicas.iter().map(|(chip, _)| *chip).collect()
    }

    /// Requests accepted so far across every replica (feeds the live
    /// [`Service::stats`]).
    fn submitted(&self) -> usize {
        self.replicas.iter().map(|(_, c)| c.submitted()).sum()
    }
}

/// A running cluster: one [`ChipScheduler`] per occupied chip behind a
/// placement-driven router. See the module docs for placement,
/// replication and the determinism contract, and DESIGN.md "Cluster
/// layer" for the diagram.
pub struct Cluster {
    schedulers: Vec<Option<ChipScheduler>>,
    clients: Vec<ClusterClient>,
    placement: Placement,
    load: Arc<ClusterLoad>,
    /// Per hosted app: modeled energy of one served request (J), used
    /// to price each chip's routed share at shutdown.
    energy_per_req: Vec<(String, f64)>,
    n_chips: usize,
}

impl Cluster {
    /// Plan the placement and start one [`ChipScheduler`] per occupied
    /// chip. `engine` builds each occupied chip's engine (chips cannot
    /// share one — every chip owns its worker pool), called once per
    /// occupied chip in ascending chip order.
    ///
    /// Fails when the fleet or app list is empty, an app name repeats,
    /// the chip configuration is invalid, any app cannot map onto the
    /// chip at all, or an engine fails to build. With
    /// [`ChipConfig::require_resident`] set, a placement that forced an
    /// overflow (an app no chip had room for) fails at that chip's
    /// start, exactly as a standalone scheduler would.
    pub fn start<F>(
        hosted: Vec<ClusterApp>,
        cfg: ClusterConfig,
        mut engine: F,
    ) -> Result<Cluster>
    where
        F: FnMut(usize) -> Result<Engine>,
    {
        if hosted.is_empty() {
            return Err(anyhow!("the cluster needs at least one app"));
        }
        for (i, a) in hosted.iter().enumerate() {
            if hosted[..i].iter().any(|b| b.app.net.name == a.app.net.name) {
                return Err(anyhow!(
                    "app {} is hosted twice — each app needs a unique name",
                    a.app.net.name
                ));
            }
        }
        cfg.chip.sys.validate().map_err(anyhow::Error::msg)?;
        let mut demands = Vec::with_capacity(hosted.len());
        let mut energy_per_req = Vec::with_capacity(hosted.len());
        for a in &hosted {
            let fp = footprint(&a.app.net, &cfg.chip.sys)
                .map_err(anyhow::Error::msg)?;
            energy_per_req.push((
                a.app.net.name.to_string(),
                sim::serving_energy_j(&a.app.net, &cfg.chip.sys, 1)
                    .map_err(anyhow::Error::msg)?,
            ));
            demands.push(AppDemand {
                app: a.app.net.name.to_string(),
                cores: fp.cores,
                replicas: a.replicas,
            });
        }
        let placement =
            plan_placement(&demands, cfg.chips, cfg.chip.sys.neural_cores)
                .map_err(anyhow::Error::msg)?;
        // Group hosted apps per chip (registration order within a chip).
        let mut per_chip: Vec<Vec<ChipApp>> = vec![Vec::new(); cfg.chips];
        for (i, a) in hosted.iter().enumerate() {
            for &c in &placement.apps[i].chips {
                per_chip[c].push(a.app.clone());
            }
        }
        let mut schedulers: Vec<Option<ChipScheduler>> =
            (0..cfg.chips).map(|_| None).collect();
        for (c, apps) in per_chip.into_iter().enumerate() {
            if apps.is_empty() {
                continue;
            }
            schedulers[c] = Some(ChipScheduler::start(
                engine(c)?,
                apps,
                cfg.chip.clone(),
            )?);
        }
        let load = Arc::new(ClusterLoad::new(cfg.chips));
        let mut clients = Vec::with_capacity(hosted.len());
        for (i, a) in hosted.iter().enumerate() {
            let name = a.app.net.name;
            let mut replicas = Vec::new();
            for &c in &placement.apps[i].chips {
                let sched = schedulers[c]
                    .as_ref()
                    // lint: allow(P1) — the loop above constructed a
                    // scheduler for exactly the occupied chips, and
                    // `placement.apps` only names occupied chips.
                    .expect("a placed chip has a scheduler");
                replicas.push((c, sched.client(name)?));
            }
            clients.push(ClusterClient {
                app: name.to_string(),
                replicas,
                load: Arc::clone(&load),
                sink: TraceSink::for_app(cfg.chip.trace.clone(), name),
            });
        }
        Ok(Cluster {
            schedulers,
            clients,
            placement,
            load,
            energy_per_req,
            n_chips: cfg.chips,
        })
    }

    /// Names of the hosted apps, in registration order.
    pub fn apps(&self) -> Vec<String> {
        self.clients.iter().map(|c| c.app.clone()).collect()
    }

    /// Fleet size the cluster was started with.
    pub fn chips(&self) -> usize {
        self.n_chips
    }

    /// The placement the router runs under.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// A routing handle for `app` (any number may exist; all share the
    /// load counters and the per-chip bounded queues).
    pub fn client(&self, app: &str) -> Result<ClusterClient> {
        self.clients
            .iter()
            .find(|c| c.app == app)
            .cloned()
            .ok_or_else(|| anyhow!("app {app} is not hosted by this cluster"))
    }

    /// Requests currently in flight per chip (routed, not yet
    /// settled) — the router's live health/load view.
    pub fn in_flight(&self) -> Vec<usize> {
        self.load
            .in_flight
            .iter()
            .map(|n| n.load(Ordering::Acquire))
            .collect()
    }

    /// Stop accepting requests and return the fleet-level
    /// [`ClusterReport`]. Blocks until every outstanding
    /// [`ClusterClient`] clone has been dropped and each chip's final
    /// batches have been answered — the same contract as
    /// [`ChipScheduler::shutdown`].
    pub fn shutdown(self) -> ClusterReport {
        let Cluster {
            schedulers,
            clients,
            placement,
            load,
            energy_per_req,
            n_chips,
        } = self;
        drop(clients);
        let price = |report: &MultiServeReport| -> f64 {
            report
                .apps
                .iter()
                .map(|a| {
                    energy_per_req
                        .iter()
                        .find(|(name, _)| *name == a.app)
                        .map_or(0.0, |(_, j)| j * a.serve.requests as f64)
                })
                .sum()
        };
        let mut chips = Vec::new();
        let mut wall_s = 0.0f64;
        for (c, slot) in schedulers.into_iter().enumerate() {
            let Some(sched) = slot else { continue };
            let serve = sched.shutdown();
            wall_s = wall_s.max(serve.wall_s);
            chips.push(ClusterChipReport {
                chip: c,
                routed: load.routed[c].load(Ordering::Relaxed),
                modeled_energy_j: price(&serve),
                serve,
            });
        }
        ClusterReport { n_chips, chips, placement: placement.apps, wall_s }
    }
}

/// The unified serving surface (see [`crate::serve::Service`]): submit
/// routes through the app's [`ClusterClient`], live stats sum replica
/// acceptance, shutdown collapses the [`ClusterReport`] into the
/// interface-level counters.
impl Service for Cluster {
    fn apps(&self) -> Vec<String> {
        Cluster::apps(self)
    }

    fn submit(&self, app: &str, x: Vec<f32>) -> Result<Pending> {
        self.clients
            .iter()
            .find(|c| c.app == app)
            .ok_or_else(|| {
                anyhow!("app {app} is not hosted by this cluster")
            })?
            .submit(x)
    }

    fn stats(&self) -> ServeStats {
        ServeStats {
            apps: self.clients.len(),
            requests: self.clients.iter().map(ClusterClient::submitted).sum(),
            ..ServeStats::default()
        }
    }

    fn shutdown(self: Box<Self>) -> ServeStats {
        Cluster::shutdown(*self).stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::apps;
    use crate::coordinator::init_conductances;

    fn host(name: &str, seed: u64) -> ClusterApp {
        let net = apps::network(name).unwrap().clone();
        let params = init_conductances(net.layers, seed);
        ClusterApp::new(net, params)
    }

    fn native(_chip: usize) -> Result<Engine> {
        Ok(Engine::native())
    }

    #[test]
    fn routes_round_trips_across_a_two_chip_fleet() {
        let cluster = Cluster::start(
            vec![host("iris_ae", 3), host("kdd_ae", 3)],
            ClusterConfig { chips: 2, ..ClusterConfig::default() },
            native,
        )
        .unwrap();
        assert_eq!(cluster.apps(), vec!["iris_ae", "kdd_ae"]);
        assert_eq!(cluster.chips(), 2);
        assert!(cluster.client("nope").is_err());
        let iris = cluster.client("iris_ae").unwrap();
        let kdd = cluster.client("kdd_ae").unwrap();
        assert_eq!(iris.chips().len(), 1);
        for _ in 0..3 {
            assert_eq!(iris.call(vec![0.1, -0.2, 0.3, 0.0]).unwrap().out.len(), 4);
            assert_eq!(kdd.call(vec![0.05; 41]).unwrap().out.len(), 41);
        }
        assert_eq!(cluster.in_flight().iter().sum::<usize>(), 0);
        drop(iris);
        drop(kdd);
        let report = cluster.shutdown();
        assert_eq!(report.n_chips, 2);
        assert_eq!(report.total_requests(), 6);
        assert_eq!(report.total_errors(), 0);
        // every answered request was routed, and routed shares agree
        let routed: u64 = report.chips.iter().map(|c| c.routed).sum();
        assert_eq!(routed, 6);
        assert!(report.total_energy_j() > 0.0);
        assert!(report.summary().contains("aggregate: 6 requests"));
    }

    #[test]
    fn a_replicated_app_spreads_over_the_fleet() {
        let cluster = Cluster::start(
            vec![host("iris_ae", 3).replicated(2)],
            ClusterConfig { chips: 2, ..ClusterConfig::default() },
            native,
        )
        .unwrap();
        let client = cluster.client("iris_ae").unwrap();
        assert_eq!(client.chips().len(), 2);
        // Open-loop submits: nothing settles until we wait, so the
        // in-flight counts force strict alternation between replicas.
        let pendings: Vec<Pending> = (0..8)
            .map(|i| client.submit(vec![i as f32 * 0.1, 0.0, 0.1, -0.1]))
            .collect::<Result<_>>()
            .unwrap();
        assert_eq!(cluster.in_flight().iter().sum::<usize>(), 8);
        for p in pendings {
            p.wait().unwrap();
        }
        assert_eq!(cluster.in_flight(), vec![0, 0]);
        drop(client);
        let report = cluster.shutdown();
        assert_eq!(report.total_requests(), 8);
        let routed: Vec<u64> = report.chips.iter().map(|c| c.routed).collect();
        assert_eq!(routed, vec![4, 4], "least-loaded routing must alternate");
    }

    #[test]
    fn bad_fleets_and_app_sets_are_rejected() {
        let err = Cluster::start(
            Vec::new(),
            ClusterConfig::default(),
            native,
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one app"), "{err}");
        let err = Cluster::start(
            vec![host("iris_ae", 0), host("iris_ae", 1)],
            ClusterConfig::default(),
            native,
        )
        .unwrap_err();
        assert!(err.to_string().contains("hosted twice"), "{err}");
        let err = Cluster::start(
            vec![host("iris_ae", 0)],
            ClusterConfig { chips: 0, ..ClusterConfig::default() },
            native,
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one chip"), "{err}");
    }

    #[test]
    fn engine_factory_failures_surface_at_start() {
        let err = Cluster::start(
            vec![host("iris_ae", 0)],
            ClusterConfig { chips: 1, ..ClusterConfig::default() },
            |_| Err(anyhow!("no engine for you")),
        )
        .unwrap_err();
        assert!(err.to_string().contains("no engine"), "{err}");
    }

    #[test]
    fn serves_through_the_service_trait() {
        let svc: Box<dyn Service> = Box::new(
            Cluster::start(
                vec![host("iris_ae", 3), host("kdd_ae", 3)],
                ClusterConfig { chips: 2, ..ClusterConfig::default() },
                native,
            )
            .unwrap(),
        );
        assert_eq!(svc.apps(), vec!["iris_ae", "kdd_ae"]);
        assert!(svc.submit("nope", vec![0.0; 4]).is_err());
        let r = svc.call("iris_ae", vec![0.1, -0.2, 0.3, 0.0]).unwrap();
        assert_eq!(r.out.len(), 4);
        let live = svc.stats();
        assert_eq!((live.apps, live.requests), (2, 1));
        let done = svc.shutdown();
        assert_eq!((done.apps, done.requests, done.errors), (2, 1, 0));
    }
}
