//! Fleet-level serving metrics: per-chip shares of a cluster lifetime
//! plus the placement they ran under, returned by
//! [`Cluster::shutdown`](super::Cluster::shutdown) and printed by
//! `restream serve --chips` / the `perf_cluster` bench.

use crate::chip::MultiServeReport;
use crate::serve::ServeStats;

use super::placement::AppPlacement;

/// One chip's share of a cluster lifetime.
#[derive(Clone, Debug)]
pub struct ClusterChipReport {
    /// Chip index in the fleet.
    pub chip: usize,
    /// Requests the router sent to this chip.
    pub routed: u64,
    /// Modeled serving energy of the chip's answered traffic (J):
    /// per-app request counts priced at the Table IV per-sample
    /// recognition energy ([`crate::sim::serving_energy_j`]).
    pub modeled_energy_j: f64,
    /// The chip's own multi-tenant report (per-app latency splits,
    /// occupancy, swaps) — exactly what a standalone
    /// [`ChipScheduler`](crate::chip::ChipScheduler) returns.
    pub serve: MultiServeReport,
}

/// Aggregate statistics of one [`Cluster`](super::Cluster) lifetime.
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    /// Fleet size the cluster was started with (occupied or not).
    pub n_chips: usize,
    /// Per-chip breakdown, ascending chip index; chips that hosted no
    /// app are omitted.
    pub chips: Vec<ClusterChipReport>,
    /// The placement the router ran under, in app registration order.
    pub placement: Vec<AppPlacement>,
    /// Slowest chip's dispatch span (s) — the fleet-level wall the
    /// aggregate throughput divides by.
    pub wall_s: f64,
}

impl ClusterReport {
    /// Requests answered across the fleet (successes plus errors).
    pub fn total_requests(&self) -> usize {
        self.chips.iter().map(|c| c.serve.total_requests()).sum()
    }

    /// Batches dispatched across the fleet.
    pub fn total_batches(&self) -> usize {
        self.chips.iter().map(|c| c.serve.total_batches()).sum()
    }

    /// Requests answered with an error across the fleet.
    pub fn total_errors(&self) -> usize {
        self.chips.iter().map(|c| c.serve.total_errors()).sum()
    }

    /// Modeled serving energy across the fleet (J).
    pub fn total_energy_j(&self) -> f64 {
        self.chips.iter().map(|c| c.modeled_energy_j).sum()
    }

    /// Aggregate throughput in requests per second over [`Self::wall_s`]
    /// (0 before any request).
    pub fn aggregate_rps(&self) -> f64 {
        let requests = self.total_requests();
        if requests == 0 {
            0.0
        } else {
            requests as f64 / self.wall_s.max(1e-12)
        }
    }

    /// Collapse into the interface-level [`ServeStats`] counters.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            apps: self.placement.len(),
            requests: self.total_requests(),
            batches: self.total_batches(),
            errors: self.total_errors(),
            wall_s: self.wall_s,
        }
    }

    /// Serialise under the shared report schema
    /// ([`crate::telemetry::REPORT_SCHEMA`], kind `"cluster"`); every
    /// per-chip entry embeds its full
    /// [`MultiServeReport`](crate::chip::MultiServeReport) object.
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        let chips: Vec<Json> = self
            .chips
            .iter()
            .map(|c| {
                Json::obj()
                    .with("chip", Json::Int(c.chip as i64))
                    .with("routed", Json::Int(c.routed as i64))
                    .with("modeled_energy_j", Json::Num(c.modeled_energy_j))
                    .with("serve", c.serve.to_json())
            })
            .collect();
        let placement: Vec<Json> = self
            .placement
            .iter()
            .map(|p| {
                Json::obj()
                    .with("app", Json::Str(p.app.clone()))
                    .with("cores", Json::Int(p.cores as i64))
                    .with(
                        "chips",
                        Json::Arr(
                            p.chips
                                .iter()
                                .map(|&c| Json::Int(c as i64))
                                .collect(),
                        ),
                    )
                    .with("overflow", Json::Bool(p.overflow))
            })
            .collect();
        Json::obj()
            .with(
                "schema",
                Json::Str(crate::telemetry::REPORT_SCHEMA.to_string()),
            )
            .with("kind", Json::Str("cluster".to_string()))
            .with("n_chips", Json::Int(self.n_chips as i64))
            .with("wall_s", Json::Num(self.wall_s))
            .with("aggregate_rps", Json::Num(self.aggregate_rps()))
            .with("total_energy_j", Json::Num(self.total_energy_j()))
            .with("placement", Json::Arr(placement))
            .with("chips", Json::Arr(chips))
    }

    /// Human-readable multi-line summary (what `restream serve --chips`
    /// prints after the request streams end).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "cluster: {} app(s) over {} chip(s) ({} occupied)\n",
            self.placement.len(),
            self.n_chips,
            self.chips.len(),
        );
        for p in &self.placement {
            s.push_str(&format!(
                "  {:<14} {:>3} cores x{} replica(s) on chip(s) {:?}{}\n",
                p.app,
                p.cores,
                p.chips.len(),
                p.chips,
                if p.overflow { "  [overflow: served via swapping]" } else { "" },
            ));
        }
        for c in &self.chips {
            s.push_str(&format!(
                "  chip {:>2}: {:>6} routed, {:>5} batches ({} err), \
                 occupancy {:.1}%, {} swaps, modeled {:.3} uJ\n",
                c.chip,
                c.routed,
                c.serve.total_batches(),
                c.serve.total_errors(),
                c.serve.occupancy_pct,
                c.serve.swaps,
                c.modeled_energy_j * 1e6,
            ));
        }
        s.push_str(&format!(
            "aggregate: {} requests in {} batches over {:.3}s -> \
             {:.0} req/s, modeled {:.3} uJ\n",
            self.total_requests(),
            self.total_batches(),
            self.wall_s,
            self.aggregate_rps(),
            self.total_energy_j() * 1e6,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::AppServeReport;
    use crate::serve::ServeReport;

    fn chip_report(chip: usize, requests: usize) -> ClusterChipReport {
        ClusterChipReport {
            chip,
            routed: requests as u64,
            modeled_energy_j: requests as f64 * 1e-7,
            serve: MultiServeReport {
                apps: vec![AppServeReport {
                    app: format!("app{chip}"),
                    cores: 2,
                    resident: true,
                    offset: Some(0),
                    swaps_in: 0,
                    reconfig_s: 0.0,
                    serve: ServeReport {
                        requests,
                        batches: requests / 2,
                        errors: 0,
                        wall_s: 1.0,
                        ..Default::default()
                    },
                }],
                wall_s: 1.0,
                chip_cores: 144,
                occupancy_pct: 1.4,
                swaps: 0,
                evictions: 0,
                reconfig_total_s: 0.0,
            },
        }
    }

    #[test]
    fn aggregates_sum_over_chips() {
        let r = ClusterReport {
            n_chips: 4,
            chips: vec![chip_report(0, 10), chip_report(2, 30)],
            placement: vec![
                AppPlacement {
                    app: "app0".to_string(),
                    cores: 2,
                    chips: vec![0],
                    overflow: false,
                },
                AppPlacement {
                    app: "app2".to_string(),
                    cores: 2,
                    chips: vec![2],
                    overflow: true,
                },
            ],
            wall_s: 2.0,
        };
        assert_eq!(r.total_requests(), 40);
        assert_eq!(r.total_batches(), 20);
        assert_eq!(r.total_errors(), 0);
        assert_eq!(r.aggregate_rps(), 20.0);
        assert!((r.total_energy_j() - 40.0e-7).abs() < 1e-18);
        let flat = r.stats();
        assert_eq!((flat.apps, flat.requests), (2, 40));
        assert_eq!(flat.wall_s, 2.0);
        let s = r.summary();
        assert!(s.contains("2 app(s) over 4 chip(s)"), "{s}");
        assert!(s.contains("overflow"), "{s}");
        // the empty report guards its ratios
        assert_eq!(ClusterReport::default().aggregate_rps(), 0.0);

        // and the report round-trips through the shared schema
        use crate::telemetry::json;
        let text = r.to_json().to_string();
        let doc = json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("kind").and_then(json::Json::as_str),
            Some("cluster")
        );
        let placement = doc.get("placement").expect("placement").items();
        assert_eq!(
            placement[1].get("overflow"),
            Some(&json::Json::Bool(true))
        );
        let chips = doc.get("chips").expect("chips").items();
        assert_eq!(
            chips[1]
                .get("serve")
                .and_then(|s| s.get("kind"))
                .and_then(json::Json::as_str),
            Some("multi_serve")
        );
    }
}
