//! App-to-chip placement: rendezvous (highest-random-weight) hashing
//! with capacity-aware fallback.
//!
//! Placement must be **stable** — the same app set over the same fleet
//! size always lands the same way, so a restarted router reproduces its
//! routing and the determinism tests can pin it. Rendezvous hashing
//! gives that for free: every `(app, chip)` pair gets a deterministic
//! weight ([`crate::checkpoint::fnv64`] over the app name and the chip
//! index — FNV-1a is stable across platforms and toolchains, unlike
//! `DefaultHasher`), and an app prefers chips by descending weight. On
//! top of the hash order, [`plan_placement`] is capacity-aware: a chip
//! whose planned resident demand would exceed its core budget is
//! skipped, so a full chip spills the app over to its next-preferred
//! chip instead of overcommitting.
//!
//! Replication: an app asking for `replicas > 1` takes the first `n`
//! chips of its preference order that have room — one
//! [`ChipScheduler`](crate::chip::ChipScheduler) replica per chip —
//! and the router load-balances between them at submit time.

use crate::checkpoint::fnv64;

/// One app's placement request: how many cores one replica needs
/// (its serving [`footprint`](crate::chip::footprint)) and how many
/// replicas it wants.
#[derive(Clone, Debug)]
pub struct AppDemand {
    /// Application name (the hash key — placement depends on nothing
    /// else about the app).
    pub app: String,
    /// Peak core demand of one serving replica.
    pub cores: usize,
    /// Requested replica count (clamped to `1..=chips`).
    pub replicas: usize,
}

/// Where one app landed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AppPlacement {
    /// Application name.
    pub app: String,
    /// Peak core demand of one replica.
    pub cores: usize,
    /// Chips hosting a replica, in the app's preference order (the
    /// router's tie-break order). Never empty; may be shorter than the
    /// requested replica count when the fleet lacks room.
    pub chips: Vec<usize>,
    /// True when no chip had room and the first replica was *forced*
    /// onto the app's most-preferred chip anyway — the chip layer then
    /// serves it by LRU swapping (or rejects it under
    /// [`require_resident`](crate::chip::ChipConfig::require_resident)).
    pub overflow: bool,
}

/// A full fleet placement, as [`plan_placement`] returns it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    /// Per-app placements, in registration order.
    pub apps: Vec<AppPlacement>,
    /// Planned resident core demand per chip (may exceed the budget
    /// only on chips that took a forced overflow replica).
    pub chip_cores_used: Vec<usize>,
}

impl Placement {
    /// The placement of `app`, if it was planned.
    pub fn of(&self, app: &str) -> Option<&AppPlacement> {
        self.apps.iter().find(|p| p.app == app)
    }
}

/// Rendezvous weight of placing `app` on `chip`.
fn weight(app: &str, chip: usize) -> u64 {
    let mut key = Vec::with_capacity(app.len() + 8);
    key.extend_from_slice(app.as_bytes());
    key.extend_from_slice(&(chip as u64).to_le_bytes());
    fnv64(&key)
}

/// `app`'s chip preference order over a fleet of `chips`: descending
/// rendezvous weight, chip index as the (vanishingly unlikely) final
/// tie-break. Pure in its inputs — the stability anchor of the whole
/// placement.
pub fn preference(app: &str, chips: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..chips).collect();
    order.sort_by_key(|&c| (std::cmp::Reverse(weight(app, c)), c));
    order
}

/// Plan the fleet placement for `demands` over `chips` chips of
/// `chip_budget` neural cores each. Deterministic in its inputs (see
/// the module docs); errors only on an empty fleet.
///
/// ```
/// use restream::cluster::{plan_placement, AppDemand};
///
/// let demand = |app: &str, replicas| AppDemand {
///     app: app.to_string(),
///     cores: 2,
///     replicas,
/// };
/// let p =
///     plan_placement(&[demand("iris_ae", 1), demand("kdd_ae", 2)], 4, 144)
///         .unwrap();
/// assert_eq!(p.apps[0].chips.len(), 1);
/// assert_eq!(p.apps[1].chips.len(), 2);
/// // stable: planning again places identically
/// let again =
///     plan_placement(&[demand("iris_ae", 1), demand("kdd_ae", 2)], 4, 144)
///         .unwrap();
/// assert_eq!(p, again);
/// ```
pub fn plan_placement(
    demands: &[AppDemand],
    chips: usize,
    chip_budget: usize,
) -> Result<Placement, String> {
    if chips == 0 {
        return Err("the cluster needs at least one chip".to_string());
    }
    let mut used = vec![0usize; chips];
    let mut apps = Vec::with_capacity(demands.len());
    for d in demands {
        let replicas = d.replicas.clamp(1, chips);
        let pref = preference(&d.app, chips);
        let mut placed = Vec::with_capacity(replicas);
        for &c in &pref {
            if placed.len() == replicas {
                break;
            }
            if used[c] + d.cores <= chip_budget {
                used[c] += d.cores;
                placed.push(c);
            }
        }
        let overflow = placed.is_empty();
        if overflow {
            // No chip has room: force the first replica onto the most
            // preferred chip — the chip layer serves it via swapping.
            used[pref[0]] += d.cores;
            placed.push(pref[0]);
        }
        apps.push(AppPlacement {
            app: d.app.clone(),
            cores: d.cores,
            chips: placed,
            overflow,
        });
    }
    Ok(Placement { apps, chip_cores_used: used })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand(app: &str, cores: usize, replicas: usize) -> AppDemand {
        AppDemand { app: app.to_string(), cores, replicas }
    }

    #[test]
    fn preference_is_stable_and_a_permutation() {
        for chips in [1usize, 2, 4, 7] {
            for app in ["iris_ae", "kdd_ae", "mnist_class"] {
                let p = preference(app, chips);
                assert_eq!(p, preference(app, chips), "{app}/{chips}");
                let mut sorted = p.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..chips).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn apps_spread_rather_than_pile_up() {
        // Rendezvous hashing should not send every app to chip 0: over
        // the registered app names and a 4-chip fleet, at least two
        // distinct chips are someone's first choice.
        let firsts: std::collections::BTreeSet<usize> =
            ["iris_ae", "kdd_ae", "mnist_class", "iris_class", "isolet_class"]
                .iter()
                .map(|a| preference(a, 4)[0])
                .collect();
        assert!(firsts.len() >= 2, "all apps prefer chip {firsts:?}");
    }

    #[test]
    fn replicas_land_on_distinct_chips() {
        let p = plan_placement(&[demand("kdd_ae", 2, 3)], 4, 144).unwrap();
        let placed = &p.apps[0];
        assert_eq!(placed.chips.len(), 3);
        assert!(!placed.overflow);
        let distinct: std::collections::BTreeSet<_> =
            placed.chips.iter().collect();
        assert_eq!(distinct.len(), 3);
        // replica order follows the preference order
        let pref = preference("kdd_ae", 4);
        assert_eq!(placed.chips, pref[..3].to_vec());
    }

    #[test]
    fn replica_count_clamps_to_the_fleet() {
        let p = plan_placement(&[demand("iris_ae", 2, 99)], 2, 144).unwrap();
        assert_eq!(p.apps[0].chips.len(), 2);
        let p = plan_placement(&[demand("iris_ae", 2, 0)], 2, 144).unwrap();
        assert_eq!(p.apps[0].chips.len(), 1);
    }

    #[test]
    fn full_chips_spill_to_the_next_preferred() {
        // Two 2-core chips, three 2-core apps: the first two apps each
        // fill a chip, the third fits nowhere and is forced (overflow)
        // onto its preferred chip.
        let demands =
            [demand("a", 2, 1), demand("b", 2, 1), demand("c", 2, 1)];
        let p = plan_placement(&demands, 2, 2).unwrap();
        assert_eq!(p.apps[0].chips.len(), 1);
        assert_eq!(p.apps[1].chips.len(), 1);
        assert_ne!(
            p.apps[0].chips[0], p.apps[1].chips[0],
            "the second app must spill to the other chip"
        );
        assert!(!p.apps[0].overflow && !p.apps[1].overflow);
        let c = &p.apps[2];
        assert!(c.overflow);
        assert_eq!(c.chips, vec![preference("c", 2)[0]]);
        assert_eq!(p.chip_cores_used.iter().sum::<usize>(), 6);
    }

    #[test]
    fn an_empty_fleet_is_rejected() {
        let err = plan_placement(&[demand("a", 2, 1)], 0, 144).unwrap_err();
        assert!(err.contains("at least one chip"), "{err}");
        // an empty demand list over a real fleet is fine: nothing to
        // place, every chip idle
        let p = plan_placement(&[], 3, 144).unwrap();
        assert!(p.apps.is_empty());
        assert_eq!(p.chip_cores_used, vec![0, 0, 0]);
    }

    #[test]
    fn an_app_larger_than_every_chip_is_forced_with_overflow() {
        // 200 cores will not fit a 144-core chip even empty: the app
        // is forced onto its most-preferred chip (marked overflow, the
        // chip layer swap-serves it) and the planned use records the
        // overcommit instead of hiding it.
        let p = plan_placement(&[demand("huge", 200, 2)], 3, 144).unwrap();
        let placed = &p.apps[0];
        assert!(placed.overflow);
        assert_eq!(placed.chips, vec![preference("huge", 3)[0]]);
        assert_eq!(p.chip_cores_used[placed.chips[0]], 200);
        // the other chips stay untouched
        let others: usize = (0..3)
            .filter(|c| *c != placed.chips[0])
            .map(|c| p.chip_cores_used[c])
            .sum();
        assert_eq!(others, 0);
    }

    #[test]
    fn a_completely_full_fleet_forces_overflow() {
        // Two chips exactly filled by the first two apps: the third
        // finds no room anywhere and must be a forced single-replica
        // overflow on its preferred chip, even though it asked for
        // replicas on both.
        let demands = [
            demand("fill_a", 144, 1),
            demand("fill_b", 144, 1),
            demand("late", 2, 2),
        ];
        let p = plan_placement(&demands, 2, 144).unwrap();
        assert!(!p.apps[0].overflow && !p.apps[1].overflow);
        let late = &p.apps[2];
        assert!(late.overflow);
        assert_eq!(late.chips, vec![preference("late", 2)[0]]);
        // the forced replica overcommits exactly one chip
        assert_eq!(p.chip_cores_used[late.chips[0]], 146);
    }

    #[test]
    fn preference_matches_the_pinned_fnv64_goldens() {
        // Byte-stability contract: the rendezvous weight is
        // fnv64(app-name bytes ‖ chip index as u64 little-endian),
        // FNV-1a 64. These orders were computed by an independent
        // Python implementation of that exact key layout; any change
        // to the hash, the key bytes or the tie-break reorders a live
        // fleet's placement on upgrade and must show up here.
        assert_eq!(preference("iris_ae", 4), vec![2, 3, 0, 1]);
        assert_eq!(preference("kdd_ae", 4), vec![0, 1, 2, 3]);
        assert_eq!(preference("mnist_class", 4), vec![2, 3, 0, 1]);
        assert_eq!(preference("iris_ae", 8), vec![6, 7, 4, 5, 2, 3, 0, 1]);
        assert_eq!(preference("kdd_ae", 8), vec![0, 1, 6, 7, 4, 5, 2, 3]);
        assert_eq!(
            preference("mnist_class", 8),
            vec![7, 4, 5, 2, 3, 0, 1, 6]
        );
        // and the raw weight keys themselves, pinned at the fnv64 level
        let key = |app: &str, chip: u64| {
            let mut k = app.as_bytes().to_vec();
            k.extend_from_slice(&chip.to_le_bytes());
            fnv64(&k)
        };
        assert_eq!(key("iris_ae", 0), 0x25ea_965b_7322_bdf1);
        assert_eq!(key("iris_ae", 3), 0x44e5_5d64_7e12_0812);
        assert_eq!(key("kdd_ae", 1), 0xcebb_9c34_846e_6a9c);
        assert_eq!(key("mnist_class", 2), 0x9202_5445_ae10_c5df);
    }

    #[test]
    fn lookup_finds_planned_apps() {
        let p = plan_placement(&[demand("iris_ae", 2, 1)], 2, 144).unwrap();
        assert!(p.of("iris_ae").is_some());
        assert!(p.of("nope").is_none());
    }
}
